"""Build the device cost table: micro-calibration + block autotuning +
online refinement, written as the versioned JSON artifact every other
consumer reads (optimizer, ``estimate_caps``, the kernel wrappers, the
lifecycle checkpoint codec, CI).

    PYTHONPATH=src python -m benchmarks.calibrate \
        [--smoke] [--out BENCH_costtable.json] [--json rows.json] \
        [--refine-from BENCH_*.json ...]

Stages (each emits bench rows, so the calibration itself lands in the
``BENCH_*.json`` trajectory):

1. **rungs** — the capacity rungs the engine's caps-ladder actually
   starts the gated probe templates at (``costmodel.ladder_rungs``);
2. **calibrate** — per-operator affine stage constants fitted from the
   synthetic micro-benchmarks at those rungs;
3. **autotune** — Pallas ``block_q``/``block_t`` sweeps per rung
   (``kernels.autotune``), winners cached in the table;
4. **refine** — end-to-end probe queries on a real engine correct the
   synthetic scale (``costmodel.refine_with_engine``), and any
   ``--refine-from`` bench JSONs from previous runs feed
   ``refine_from_trajectory`` — the loop that makes every CI run
   training data for the next one.

The table never gates correctness here — ``bench_query --cost-table``
owns the answer/plan gates; this tool only fails on calibration
breakage (no samples, unwritable output).
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewest rungs/repeats that still fit")
    ap.add_argument("--out", default="BENCH_costtable.json", metavar="PATH",
                    help="where to write the DeviceCostTable JSON")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted bench rows as JSON")
    ap.add_argument("--refine-from", nargs="*", default=[], metavar="BENCH",
                    help="previous BENCH_*.json payloads whose "
                         "predicted_ns-tagged rows refine the scale")
    args, _ = ap.parse_known_args()

    from repro.core import costmodel
    from repro.core import index as cindex
    from repro.core.engine import Engine
    from repro.core.query import instantiate_template
    from repro.kernels.autotune import autotune

    from benchmarks.bench_query import OPT_EXTRA, OPT_GATED, OPT_RUNG_GATED
    from benchmarks.common import DATASETS, emit, write_json

    repeats = 2 if args.smoke else 5

    g = DATASETS["skewed-hub"]()
    idx = cindex.build(g, 2)
    engine = Engine(idx)
    probes = [instantiate_template(name, labels)
              for name, labels in OPT_GATED + OPT_RUNG_GATED + OPT_EXTRA]

    rungs = costmodel.ladder_rungs(engine, probes,
                                   max_rungs=2 if args.smoke else 4)
    emit("calibrate/rungs", 0.0,
         "rungs=" + "/".join(str(r) for r in rungs))

    table = costmodel.calibrate(rungs=rungs, repeats=repeats,
                                n_vertices=g.n_vertices)
    for op in costmodel.OPERATORS:
        c = table.ops.get(op)
        if c is None:
            continue
        emit(f"calibrate/op/{op}", c.fixed_ns / 1e3,
             f"fixed_ns={c.fixed_ns:.0f};per_row_ns={c.per_row_ns:.3f};"
             f"n_samples={len(table.samples.get(op, []))}")

    block_q, block_t, raw = autotune(rungs, repeats=repeats)
    table.block_q.update(block_q)
    table.block_t.update(block_t)
    for (kind, rung, blk), ns in sorted(raw.items()):
        win = (block_q if kind == "block_q" else block_t)[rung]
        emit(f"calibrate/{kind}/r{rung}/b{blk}", ns / 1e3,
             f"winner={win};chosen={blk == win}")

    scale = costmodel.refine_with_engine(table, engine, probes,
                                         repeats=repeats)
    emit("calibrate/refine/engine", 0.0,
         f"scale={scale:.4f};dispatch_floor_ns={table.dispatch_floor_ns:.0f}")

    used = 0
    payloads = []
    for path in args.refine_from:
        try:
            with open(path) as fh:
                payloads.append(json.load(fh))
        except (OSError, ValueError) as exc:
            emit("calibrate/refine/trajectory", 0.0,
                 f"SKIP;{path}={exc.__class__.__name__}")
    if payloads:
        used = table.refine_from_trajectory(payloads)
    emit("calibrate/refine/trajectory", 0.0,
         f"rows_used={used};scale={table.scale:.4f}")

    if not table.samples:
        print("FAIL: calibration produced no samples", file=sys.stderr)
        sys.exit(1)
    table.save(args.out)
    emit("calibrate/artifact", 0.0,
         f"out={args.out};device={table.device_kind};"
         f"vmem_words={table.vmem_words};"
         f"rungs_tuned={len(table.block_q)}")

    if args.json:
        write_json(args.json, bench="calibrate", smoke=args.smoke,
                   refined_from=len(payloads))


if __name__ == "__main__":
    main()
