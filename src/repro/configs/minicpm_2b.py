"""minicpm-2b [arXiv:2404.06395; hf]: 40L d_model=2304 36H (GQA kv=36 ==
MHA) d_ff=5760 vocab=122753 — llama-like with muP-style scaling:
scale_emb=12, residual scale 1.4/sqrt(40), logit scale d_model/256.
Trained with the WSD schedule (implemented in train/schedules.py)."""

import dataclasses

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="minicpm-2b",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    attn_pattern=("global",),
    rope_theta=10_000.0,
    activation="silu",
    embed_scale=12.0,
    residual_scale=1.4 / (40 ** 0.5),
    logit_scale=2304.0 / 256.0,
    tie_embeddings=True,
    max_seq_len=32768 * 16 + 64,
    remat=True,
    q_chunk=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, residual_scale=1.4 / (2 ** 0.5),
    logit_scale=64.0 / 256.0, max_seq_len=128, param_dtype="float32",
)

SPEC = ArchSpec(
    arch_id="minicpm-2b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=lm_shapes(long_ok=False, arch="minicpm-2b"),
    notes="muP-style scaling knobs; WSD schedule wired in the train loop.",
)
