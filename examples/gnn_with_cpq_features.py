"""The paper's technique feeding the GNN substrate: CPQ-equivalence
class ids as *language-aware structural features* for node-level GNNs.

For each vertex v we derive a feature vector from the CPQx partition:
which equivalence classes v participates in as a source (bucketed
histogram over class ids).  Vertices that are CPQ_k-indistinguishable
get identical features — a structural positional encoding strictly
stronger than degree features for any downstream task expressible in
CPQ_k (Thm. 4.1).

    PYTHONPATH=src python examples/gnn_with_cpq_features.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import index as cindex
from repro.data.graphs import gmark_citation
from repro.models import gnn as G
from repro.train.optim import adamw_init, adamw_update


def cpq_class_features(g, idx, n_buckets: int = 16) -> np.ndarray:
    """(|V|, n_buckets) histogram of the CPQx classes each vertex sources."""
    v = np.asarray(idx.arrays.pair_v)[: idx.n_pairs]
    cls = np.asarray(idx.arrays.pair_cls)[: idx.n_pairs]
    feats = np.zeros((g.n_vertices, n_buckets), np.float32)
    np.add.at(feats, (v, cls % n_buckets), 1.0)
    return np.log1p(feats)


def main() -> None:
    graph = gmark_citation(300, avg_degree=5, seed=0)
    idx = cindex.build(graph, 2)
    feats = cpq_class_features(graph, idx)
    print(f"graph {graph}; CPQx classes: {idx.n_classes}; "
          f"feature matrix {feats.shape}")

    # node-level task: predict out-degree (sanity target) from structure
    deg = graph.out_degree().astype(np.float32)[:, None]
    cfg = get_arch("gatedgcn").smoke
    import dataclasses

    cfg = dataclasses.replace(cfg, d_in=feats.shape[1], d_out=1)
    gb = G.GraphBatch(
        node_feat=jnp.asarray(feats),
        edge_feat=jnp.zeros((graph.n_edges, 4), jnp.float32),
        senders=jnp.asarray(graph.src), receivers=jnp.asarray(graph.dst),
        node_mask=jnp.ones(graph.n_vertices, bool),
        edge_mask=jnp.ones(graph.n_edges, bool),
        positions=None, graph_ids=jnp.zeros(graph.n_vertices, jnp.int32),
        n_graphs=1,
    )
    params = G.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    targets = jnp.asarray(deg)

    @jax.jit
    def step(p, o):
        (loss, _), grads = jax.value_and_grad(
            lambda p: G.train_loss(cfg, p, gb, targets), has_aux=True)(p)
        p, o, _ = adamw_update(grads, o, p, lr=3e-3)
        return p, o, loss

    for i in range(60):
        params, opt, loss = step(params, opt)
        if i % 20 == 0:
            print(f"  step {i:3d}  mse {float(loss):.4f}")
    print(f"final mse {float(loss):.4f} — language-aware features train ✓")


if __name__ == "__main__":
    main()
