"""Paper Fig. 6: average query time per template x method.

Methods: CPQx (device engine), iaCPQx, Path [14], iaPath, BFS (index-free
host evaluation).  Datasets are CPU-scaled members of the paper's
generator families; the claim under reproduction is the *ordering* and
the orders-of-magnitude conjunction gap, not absolute wall times."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import baselines, interest, oracle
from repro.core import index as cindex
from repro.core.baselines import PathEngine
from repro.core.engine import Engine
from repro.data.graphs import random_queries_for_graph

from .common import DATASETS, TEMPLATE_NAMES, emit, timeit

QUERY_DATASETS = ["robots-like", "gmark-small"]
N_PER_TEMPLATE = 3


def interests_for(g, k=2, n=6, seed=0):
    """Interest set = the 2-sequences realized by the benchmark queries
    (the paper uses the query workload's sequences as interests)."""
    rng = np.random.default_rng(seed)
    present = np.unique(g.lbl)
    return [tuple(rng.choice(present, 2)) for _ in range(n)]


def main() -> None:
    for ds in QUERY_DATASETS:
        g = DATASETS[ds]()
        ints = interests_for(g)
        methods = {
            "CPQx": Engine(cindex.build(g, 2)),
            "iaCPQx": Engine(interest.build_interest(g, 2, ints)),
            "Path": PathEngine(baselines.build_path(g, 2)),
            "iaPath": PathEngine(baselines.build_path(g, 2, interests=ints)),
        }
        queries = random_queries_for_graph(g, TEMPLATE_NAMES,
                                           N_PER_TEMPLATE, seed=7)
        for template in TEMPLATE_NAMES:
            qs = [q for name, q in queries if name == template]
            for mname, engine in methods.items():
                us = timeit(lambda: [engine.execute(q) for q in qs]) / len(qs)
                emit(f"fig6/{ds}/{template}/{mname}", us,
                     f"n_queries={len(qs)}")
            # index-free BFS baseline (host semantics walk)
            us = timeit(lambda: [oracle.bfs_eval(g, q) for q in qs],
                        warmup=0, iters=1) / len(qs)
            emit(f"fig6/{ds}/{template}/BFS", us, f"n_queries={len(qs)}")
        # answers agree across all methods (correctness gate of the bench)
        for name, q in queries[:6]:
            gt = oracle.cpq_eval(g, q)
            for mname, engine in methods.items():
                got = {tuple(r) for r in engine.execute(q).tolist()}
                assert got == gt, (ds, name, mname)
        jax.clear_caches()


if __name__ == "__main__":
    main()
