"""Shared benchmark plumbing: datasets scaled for CPU CI, timing
helpers, CSV emission.  Every bench prints ``name,us_per_call,derived``
rows so ``python -m benchmarks.run`` produces one machine-readable
stream (deliverable (d): one bench per paper table/figure)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.graph import LabeledGraph, example_graph
from repro.data.graphs import gmark_citation, powerlaw_graph

# CPU-scaled stand-ins for the paper's dataset suite (Table II): same
# generator *families* (social-like powerlaw with exponential labels;
# gMark citation schema), sized for CI.
DATASETS = {
    "robots-like": lambda: powerlaw_graph(300, 1200, n_labels=4, seed=1),
    "advogato-like": lambda: powerlaw_graph(600, 4000, n_labels=4, seed=2),
    "gmark-small": lambda: gmark_citation(500, avg_degree=6, seed=3),
    "gmark-medium": lambda: gmark_citation(1500, avg_degree=6, seed=4),
    "example": example_graph,
}

TEMPLATE_NAMES = ["C2", "C4", "C2i", "T", "Ti", "S", "Si", "TT", "St",
                  "TC", "SC", "ST"]


def timeit(fn, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) over iters after warmup."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
