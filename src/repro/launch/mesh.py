"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests must keep seeing the single real device.
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CI shard_map tests (8 forced host devices)."""
    return compat.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def flat_axes(mesh) -> tuple:
    """All axes flattened — GNN node/edge and engine pair sharding."""
    return tuple(mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]
