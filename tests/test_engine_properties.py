"""Property tests on the engine's operational invariants: overflow-retry
convergence, capacity independence of results, identity handling, and
k=4 coverage (the paper's full k range)."""

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from conftest import random_graph
from repro.core import index as cindex
from repro.core import oracle
from repro.core.engine import Engine, QueryCaps
from repro.core.query import Conj, Edge, Identity, Join, parse


class TestCapacityIndependence:
    @given(cap=st.sampled_from([2, 8, 64, 512]))
    @settings(max_examples=4, deadline=None)
    def test_results_independent_of_starting_caps(self, cap, ex_graph):
        """Any starting capacity converges to the same exact answer via
        overflow-retry (the dynamic->static contract)."""
        eng = Engine(cindex.build(ex_graph, 2))
        q = parse("(f . f) & f-", {"f": 0, "v": 1}, 2)
        got = {tuple(r) for r in eng.execute(
            q, caps=QueryCaps(cap, cap, cap)).tolist()}
        assert got == {(0, 2), (1, 0), (2, 1)}

    def test_identity_only_query(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        got = {tuple(r) for r in eng.execute(Identity()).tolist()}
        assert got == {(v, v) for v in range(ex_graph.n_vertices)}

    def test_conj_with_identity_both_sides(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        q1 = Conj(Join(Edge(0), Edge(2)), Identity())
        q2 = Conj(Identity(), Join(Edge(0), Edge(2)))
        a = {tuple(r) for r in eng.execute(q1).tolist()}
        b = {tuple(r) for r in eng.execute(q2).tolist()}
        assert a == b == oracle.cpq_eval(ex_graph, q1)


class TestK4:
    """The paper evaluates k up to 4 (Sec. VI-D)."""

    def test_k4_partition_and_queries(self):
        g = random_graph(21, n_max=10, m_max=20)
        part = oracle.path_partition(g, 4)
        assert oracle.verify_partition(g, 4, part)
        idx = cindex.build(g, 4)
        opart = oracle.path_partition(g, 4)
        assert idx.n_classes == len(opart.classes)
        eng = Engine(idx)
        rng = np.random.default_rng(0)
        for _ in range(5):
            q = oracle.random_cpq(rng, g, 3)
            got = {tuple(r) for r in eng.execute(q).tolist()}
            assert got == oracle.cpq_eval(g, q)
        jax.clear_caches()

    def test_diameter_k_query_uses_single_lookup(self):
        """A diameter-k chain on a k-index is ONE lookup (Sec. VI-D: the
        query with diameter i is fastest when k = i)."""
        g = random_graph(22, n_max=10, m_max=25)
        idx = cindex.build(g, 3)
        eng = Engine(idx)
        q = Join(Edge(0), Join(Edge(1), Edge(0)))
        plan = eng.plan(q)
        assert plan[0] == "lookup" and len(plan[1]) == 1
        assert len(plan[1][0]) == 3
