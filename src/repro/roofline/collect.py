"""Extract roofline inputs from a compiled XLA executable.

* ``cost_analysis()``  -> HLO FLOPs and HBM bytes accessed
* ``memory_analysis()``-> per-device argument/output/temp allocation
* collective bytes     -> NOT in cost_analysis: parsed from the
  post-SPMD-partitioning optimized HLO (``compiled.as_text()``), summing
  the operand sizes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute instruction.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per assignment).
"""

from __future__ import annotations

import re

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  f32[128,256]{1,0}  or  bf16[64,4096,6144]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"((?:\([^)]*\)|[\w\[\],{}\s]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string like 'bf16[64,128]' or a tuple
    '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind from optimized HLO.

    Counted once per instruction (start/done pairs deduped by counting
    only ``-start`` or the fused form, never ``-done``)."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": out, "counts_by_kind": counts,
            "total_bytes": sum(out.values())}


def analyze_compiled(compiled, mesh) -> dict:
    """Everything §Roofline needs, JSON-serializable."""
    rec: dict = {}
    n_dev = int(np.prod(mesh.devices.shape))
    rec["n_devices"] = n_dev
    rec["mesh_shape"] = {k: int(v) for k, v in
                         zip(mesh.axis_names, mesh.devices.shape)}

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["total_flops"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        rec["cost_analysis_keys"] = sorted(ca.keys())[:40]
    except Exception as e:  # noqa: BLE001
        rec["cost_analysis_error"] = str(e)

    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                rec[k] = int(v)
        live = (rec.get("argument_size_in_bytes", 0)
                + rec.get("output_size_in_bytes", 0)
                + rec.get("temp_size_in_bytes", 0)
                - rec.get("alias_size_in_bytes", 0))
        rec["per_device_hbm_bytes"] = int(live)
        rec["per_device_hbm_gb"] = round(live / 2**30, 3)
    except Exception as e:  # noqa: BLE001
        rec["memory_analysis_error"] = str(e)

    try:
        hlo = compiled.as_text()
        rec["hlo_lines"] = hlo.count("\n")
        # naive (loop-unaware) pass — kept for comparison
        rec["collectives_unscaled"] = collective_bytes(hlo)
        # scan-aware static cost model (see hlo_cost.py): loop bodies are
        # multiplied by their trip counts — the real roofline numerators
        from .hlo_cost import analyze_hlo

        scan_aware = analyze_hlo(hlo)
        rec["scan_flops"] = scan_aware["flops"]
        rec["scan_traffic_bytes"] = scan_aware["traffic_bytes"]
        rec["collectives"] = scan_aware["collectives"]
        rec["loops"] = scan_aware["loops"][:24]
    except Exception as e:  # noqa: BLE001
        rec["collective_error"] = str(e)
    return rec


def roofline_terms(rec: dict, model_flops: float | None = None) -> dict:
    """The three-term roofline (seconds) from a dry-run record.

    SPMD convention: all numerators are per-partition (the compiled
    module is the per-device program).  Uses the scan-aware static cost
    model (hlo_cost.py); ``total_flops``/``hlo_bytes`` from XLA's own
    cost_analysis are loop-unaware and kept only for cross-checks."""
    n = rec["n_devices"]
    flops = rec.get("scan_flops") or rec.get("total_flops", 0.0)
    bytes_hbm = rec.get("scan_traffic_bytes") or rec.get("hlo_bytes", 0.0)
    coll = rec.get("collectives", {}).get("total_bytes", 0)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
    }
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flop_frac"] = (model_flops / n) / max(flops, 1.0)
    return out
