"""Shared LM building blocks: norms, RoPE, GQA attention (full / sliding /
softcap), gated MLPs, and sort-based top-k MoE dispatch.

Conventions
-----------
* Params are plain nested dicts of jnp arrays; layer-stacked weights carry
  a leading ``n_layers`` axis and the forward pass scans over it (compact
  HLO — essential for the 512-device dry-run compile).
* Every function is shape-polymorphic over batch; dtype policy: params in
  ``cfg.param_dtype`` (bf16 default), accumulation in f32 where it
  matters (softmax, norms, router).
* Sharding is *not* baked in here: launch/shardings.py assigns
  PartitionSpecs to the same tree structure by logical name.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------- #
# norms
# ---------------------------------------------------------------------- #


def rms_norm(x: jax.Array, weight: jax.Array, eps: float,
             gemma_style: bool = False) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    out = xf * (1.0 + w) if gemma_style else xf * w
    return out.astype(dt)


# ---------------------------------------------------------------------- #
# rotary position embedding
# ---------------------------------------------------------------------- #


def rope_table(head_dim: int, max_len: int, theta: float) -> tuple:
    """(cos, sin) tables of shape (max_len, head_dim // 2), f32."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    t = np.arange(max_len)
    freqs = np.outer(t, inv)
    return jnp.asarray(np.cos(freqs), jnp.float32), jnp.asarray(
        np.sin(freqs), jnp.float32
    )


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               positions: jax.Array) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    dt = x.dtype
    c = cos[positions][..., None, :]  # (..., S, 1, D/2)
    s = sin[positions][..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------- #
# attention
# ---------------------------------------------------------------------- #


def _softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


def causal_mask(s_q: int, s_kv: int, window: Optional[int] = None,
                q_offset: int = 0) -> jax.Array:
    """(s_q, s_kv) bool mask.  ``window``: sliding-window width (local
    attention); ``q_offset``: absolute position of query row 0."""
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_kv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def gqa_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, KV, D)
    v: jax.Array,  # (B, T, KV, D)
    mask: jax.Array,  # broadcastable to (B, H, S, T) — bool
    scale: float,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Grouped-query attention; f32 softmax accumulation."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    groups = h // kv
    qg = q.reshape(b, s, kv, groups, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    logits = _softcap(logits, softcap)
    neg = jnp.finfo(jnp.float32).min
    if mask.ndim == 2:  # (S, T) shared across batch/heads
        m = mask[None, None, None]
    elif mask.ndim == 3:  # (B, S, T) per-example (e.g. decode lengths)
        m = mask[:, None, None]
    else:
        raise ValueError(f"mask ndim {mask.ndim} unsupported")
    logits = jnp.where(m, logits, neg)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(b, s, h, d)


def chunked_gqa_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, KV, D)
    v: jax.Array,  # (B, T, KV, D)
    qpos: jax.Array,  # (S,) absolute query positions
    kpos: jax.Array,  # (T,) absolute key positions
    window: jax.Array,  # traced scalar: window size (> T means global)
    scale: float,
    softcap: Optional[float],
    q_chunk: int,
) -> jax.Array:
    """Query-chunked attention for long sequences (32k+ prefill): scans
    over S/q_chunk query blocks so the logits working set is
    (B, H, q_chunk, T) instead of (B, H, S, T) — the O(S·T) mask is never
    materialized either (membership computed from positions per block).
    Numerics identical to gqa_attention (masked f32 softmax)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    assert s % q_chunk == 0, (s, q_chunk)
    qg = q.reshape(b, s, kv, g, d)
    neg = jnp.finfo(jnp.float32).min

    def body(_, i):
        qs = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(qpos, i * q_chunk, q_chunk, axis=0)
        logits = jnp.einsum("bskgd,btkd->bkgst", qs, k).astype(jnp.float32)
        logits = _softcap(logits * scale, softcap)
        mask = (kpos[None, :] <= qp[:, None]) & (kpos[None, :] > qp[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, neg)
        p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", p, v)
        return None, out.reshape(b, q_chunk, h, d)

    _, chunks = jax.lax.scan(body, None, jnp.arange(s // q_chunk))
    # (nq, B, qc, H, D) -> (B, S, H, D)
    return jnp.moveaxis(chunks, 0, 1).reshape(b, s, h, d)


# ---------------------------------------------------------------------- #
# gated MLP
# ---------------------------------------------------------------------- #


def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, activation: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": lambda t: jax.nn.gelu(t, approximate=True)}[
        activation
    ]
    h = act(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------- #
# sort-based top-k MoE (dropping, GShard-equivalent capacity semantics)
# ---------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    capacity: int  # per expert per group (static)


def moe_capacity(tokens_per_group: int, top_k: int, n_experts: int,
                 capacity_factor: float) -> int:
    c = int(np.ceil(tokens_per_group * top_k * capacity_factor / n_experts))
    return max(1, c)


def _topk_gates(probs: jax.Array, k: int):
    """Iterative top-k (argmax + mask, k small) — differentiable through
    the one-hot·probs product.  Replaces ``lax.top_k``, whose JVP (like
    batched sort's) builds gathers with operand_batching_dims that the
    SPMD partitioner cannot handle."""
    e = probs.shape[-1]
    p = probs
    gis, gvs = [], []
    for _ in range(k):
        gi = jnp.argmax(p, axis=-1)
        onehot = jax.nn.one_hot(gi, e, dtype=probs.dtype)
        gvs.append(jnp.sum(probs * onehot, axis=-1))
        gis.append(gi.astype(jnp.int32))
        p = p * (1 - onehot) - onehot  # never re-picked
    return jnp.stack(gvs, -1), jnp.stack(gis, -1)


def moe_ffn(
    x: jax.Array,  # (G, S, D) tokens, G = data-sharded groups
    router_w: jax.Array,  # (D, E)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    dims: MoEDims,
    activation: str = "silu",
    c_axes: tuple = (),  # token-TP: shard the capacity dim over these axes
    batch_axes: tuple = (),
):
    """Token-dropping top-k MoE with group-local dispatch.

    TPU-native dispatch (DESIGN.md §2): instead of GShard's (S, E, C)
    one-hot dispatch einsum (S·E·C·D FLOPs of pure bookkeeping), the
    (group, expert) assignments are ordered by ONE flat integer-only
    stable sort on the composite key ``group*E + expert`` (group-major =>
    each group's segment stays contiguous, so the G axis still shards
    over "data"), and the (G, E, C, D) expert buffers are built with
    *gathers only* — position-in-expert falls out of the sorted order; no
    scatter, no batched-gather dims (GSPMD-hostile), and gradients flow
    through gathers and the one-hot gate product, never through a sort.

    Returns (y (G, S, D), aux) with the load-balancing loss.
    """
    g, s, d = x.shape
    e, k, c = dims.n_experts, dims.top_k, dims.capacity
    n = g * s * k  # total routed assignments
    dt = x.dtype

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = _topk_gates(probs, k)  # (G,S,k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- flat assignment lists ------------------------------------------ #
    flat_e = expert_idx.reshape(n)  # (N,)
    g_of = jnp.arange(n, dtype=jnp.int32) // (s * k)
    tok_global = g_of * s + (jnp.arange(n, dtype=jnp.int32) % (s * k)) // k
    flat_gate = gate_vals.reshape(n)
    kcomp = g_of * e + flat_e  # group-major composite key, in [0, G*E)

    # ONE flat integer sort (ints only => no sort-JVP under grad)
    skey, sidx = jax.lax.sort(
        (kcomp, jnp.arange(n, dtype=jnp.int32)), num_keys=1, is_stable=True
    )
    # per-(group, expert) segment sizes and exclusive offsets
    sizes = jax.ops.segment_sum(jnp.ones(n, jnp.int32), kcomp, g * e)  # (G*E,)
    offs = jnp.cumsum(sizes) - sizes

    # ---- expert buffers via pure gather ---------------------------------- #
    # buffer row r = (group gg, expert ee, slot p)
    r = jnp.arange(g * e * c, dtype=jnp.int32)
    ge = r // c
    p = r % c
    src = jnp.take(offs, ge, 0) + p
    valid = p < jnp.take(sizes, ge, 0)
    srcc = jnp.clip(src, 0, n - 1)
    assign = jnp.take(sidx, srcc, 0)  # original assignment index
    tok = jnp.take(tok_global, assign, 0)  # global token id (G*S)
    xb = jnp.take(x.reshape(g * s, d), tok, axis=0)  # (G*E*C, D)
    xb = jnp.where(valid[:, None], xb, 0).reshape(g, e, c, d)
    if c_axes:
        # token-TP for tiny-expert MoE (granite): shard the capacity dim
        # over "model" with expert weights F-replicated — expert matmuls
        # run full-width per shard, no contraction psums (the baseline
        # F-sharded layout all-reduces (G,E,C,D) per layer)
        from jax.sharding import PartitionSpec as _P

        xb = jax.lax.with_sharding_constraint(
            xb, _P(batch_axes or None, None, c_axes, None))

    # ---- expert computation (the only real FLOPs) ------------------------ #
    act = {"silu": jax.nn.silu, "gelu": lambda t: jax.nn.gelu(t, approximate=True)}[
        activation
    ]
    hg = jnp.einsum("gecd,edf->gecf", xb, w_gate.astype(dt))
    hu = jnp.einsum("gecd,edf->gecf", xb, w_up.astype(dt))
    yb = jnp.einsum("gecf,efd->gecd", act(hg) * hu, w_down.astype(dt))
    yb = yb.reshape(g * e * c, d)

    # ---- combine: flat segment-sum back to tokens ------------------------- #
    gates_b = jnp.take(flat_gate, assign, 0)  # (G*E*C,)
    contrib = yb * (gates_b * valid.astype(jnp.float32)).astype(dt)[:, None]
    seg = jnp.where(valid, tok, g * s)  # dropped -> trash segment
    y = jax.ops.segment_sum(contrib, seg, g * s + 1)[: g * s].reshape(g, s, d)

    # ---- aux: load-balancing loss (Switch-style) ------------------------ #
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    aux_loss = e * jnp.sum(me * ce)
    kept = jnp.sum(valid.astype(jnp.float32))  # routed assignments kept
    dropped = 1.0 - kept / (g * s * k)
    return y.astype(dt), {"moe_aux_loss": aux_loss,
                          "moe_dropped_frac": dropped}


# ---------------------------------------------------------------------- #
# init helpers
# ---------------------------------------------------------------------- #


def normal_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
