"""Training substrate: AdamW, WSD/cosine schedules, gradient clipping,
grad accumulation, int8 error-feedback gradient compression, and the
fault-tolerant train loop."""
