"""Paper Fig. 6 (query time per template x method) + the PR 4 optimizer
gate (cost-based vs syntactic plans on a label-skewed graph).

Fig. 6 methods: CPQx (device engine), iaCPQx, Path [14], iaPath, BFS
(index-free host evaluation).  Datasets are CPU-scaled members of the
paper's generator families; the claim under reproduction is the
*ordering* and the orders-of-magnitude conjunction gap, not absolute
wall times.

The optimizer section runs every probe query through two engines bound
to the same index — ``Engine(idx, optimize=False)`` (the syntactic
``plan_query`` + stats-free capacity estimate) and the default
cost-based engine — and *gates on answers*: optimized == syntactic ==
numpy oracle, else FAIL and a non-zero exit.  In ``--smoke`` (CI) mode
it also requires >= 2 of the gated Fig. 5 templates to speed up >= 2x
at BOTH n_shards=1 and n_shards=8 (8 fake XLA devices, set before the
first jax import; run standalone, not under pytest).

    PYTHONPATH=src python -m benchmarks.bench_query [--smoke] [--json out.json]
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

QUERY_DATASETS = ["robots-like", "gmark-small"]
N_PER_TEMPLATE = 3

# Optimizer probes on the skewed-hub graph (label 0 = dense hub, 1..5
# rare).  The gated four are conjunction-heavy Fig. 5 templates whose
# answers track their *smallest* conjunct — where stats-blind planning
# hurts most.  C4 is the ROADMAP's skewed-fanout chain: its answer far
# exceeds the uniform join estimate, so before the PR 5 endpoint
# statistics its caps laddered every call — it is PASS-gated on
# *estimator health* (answers == oracle AND zero retry rungs), not on
# the >= 2x bar (at CI scale its wall-clock is dispatch-bound).  C2i
# documents identity-closure behavior ungated.
OPT_GATED = [
    ("T", [0, 0, 1]),  # (hub.hub) & rare
    ("S", [0, 0, 2, 3]),  # (hub.hub) & (rare.rare)
    ("St", [0, 4, 5]),  # hub & rare & rare  (parallel edges)
    ("TT", [0, 0, 0, 0, 1]),  # two hub triangles glued on a rare edge
]
OPT_RUNG_GATED = [
    ("C4", [1, 0, 2, 3]),  # skewed-fanout chain: join_cap estimate gate
]
OPT_EXTRA = [
    ("C2i", [0, 1]),  # (hub.rare) & id
]


def interests_for(g, k=2, n=6, seed=0):
    """Interest set = the 2-sequences realized by the benchmark queries
    (the paper uses the query workload's sequences as interests)."""
    rng = np.random.default_rng(seed)
    present = np.unique(g.lbl)
    return [tuple(rng.choice(present, 2)) for _ in range(n)]


def fig6_section() -> None:
    import jax

    from repro.core import baselines, interest, oracle
    from repro.core import index as cindex
    from repro.core.baselines import PathEngine
    from repro.core.engine import Engine
    from repro.data.graphs import random_queries_for_graph

    from benchmarks.common import DATASETS, TEMPLATE_NAMES, emit, timeit

    for ds in QUERY_DATASETS:
        g = DATASETS[ds]()
        ints = interests_for(g)
        methods = {
            "CPQx": Engine(cindex.build(g, 2)),
            "iaCPQx": Engine(interest.build_interest(g, 2, ints)),
            "Path": PathEngine(baselines.build_path(g, 2)),
            "iaPath": PathEngine(baselines.build_path(g, 2, interests=ints)),
        }
        queries = random_queries_for_graph(g, TEMPLATE_NAMES,
                                           N_PER_TEMPLATE, seed=7)
        for template in TEMPLATE_NAMES:
            qs = [q for name, q in queries if name == template]
            for mname, engine in methods.items():
                us = timeit(lambda: [engine.execute(q) for q in qs]) / len(qs)
                emit(f"fig6/{ds}/{template}/{mname}", us,
                     f"n_queries={len(qs)}")
            # index-free BFS baseline (host semantics walk)
            us = timeit(lambda: [oracle.bfs_eval(g, q) for q in qs],
                        warmup=0, iters=1) / len(qs)
            emit(f"fig6/{ds}/{template}/BFS", us, f"n_queries={len(qs)}")
        # answers agree across all methods (correctness gate of the bench)
        for name, q in queries[:6]:
            gt = oracle.cpq_eval(g, q)
            for mname, engine in methods.items():
                got = {tuple(r) for r in engine.execute(q).tolist()}
                assert got == gt, (ds, name, mname)
        jax.clear_caches()


def optimizer_section(shard_counts, iters: int, gate_speedup: bool = True) -> bool:
    """Optimized vs syntactic plans, same index, answers oracle-gated.
    Returns True when anything failed: wrong answers always fail; the
    >= 2x bar (two gated templates, every requested shard count) only
    fails when ``gate_speedup`` — the CI --smoke acceptance; full local
    runs report speedups without hard-failing on machine noise."""
    import jax

    from repro import compat
    from repro.core import index as cindex, oracle
    from repro.core.engine import Engine
    from repro.core.query import instantiate_template

    from benchmarks.common import DATASETS, emit, timeit

    g = DATASETS["skewed-hub"]()
    idx = cindex.build(g, 2)
    probes = [(name, instantiate_template(name, labels))
              for name, labels in OPT_GATED + OPT_RUNG_GATED + OPT_EXTRA]
    truth = {name: oracle.cpq_eval(g, q) for name, q in probes}

    failed = False
    for n_shards in shard_counts:
        if n_shards > 1 and jax.device_count() < n_shards:
            # a skipped leg counts as a failure when the speedup gate is
            # on: CI must never report the 8-shard acceptance green
            # without having run it
            emit(f"optimizer/skewed-hub/shards{n_shards}/acceptance", 0.0,
                 f"SKIP;only {jax.device_count()} devices"
                 + (";FAIL" if gate_speedup else ""))
            failed |= gate_speedup
            continue
        if n_shards == 1:
            e_syn = Engine(idx, optimize=False)
            e_opt = Engine(idx)
        else:
            mesh = compat.make_mesh((n_shards,), ("engine",))
            e_syn = Engine(idx, mesh=mesh, optimize=False)
            e_opt = Engine(idx, mesh=mesh)
        wins = 0
        for i, (name, q) in enumerate(probes):
            rungs0 = e_opt.telemetry.retry_rungs
            syn_rows = e_syn.execute(q)
            opt_rows = e_opt.execute(q)
            rungs_opt = e_opt.telemetry.retry_rungs - rungs0
            ok = (syn_rows.shape == opt_rows.shape
                  and bool(np.all(syn_rows == opt_rows))
                  and {tuple(r) for r in opt_rows.tolist()} == truth[name])
            us_syn = timeit(lambda: e_syn.execute(q), iters=iters)
            us_opt = timeit(lambda: e_opt.execute(q), iters=iters)
            speedup = us_syn / max(us_opt, 1e-9)
            gated = i < len(OPT_GATED)
            rung_gated = len(OPT_GATED) <= i < len(OPT_GATED) + len(
                OPT_RUNG_GATED)
            if gated and ok and speedup >= 2.0:
                wins += 1
            if rung_gated:
                # estimator-health gate: the endpoint/fanout statistics
                # must size join_cap so this skewed-fanout chain never
                # ladders (it did, every call, under the uniform estimate)
                est_ok = ok and rungs_opt == 0
                failed |= gate_speedup and not est_ok
                tag = f";estimator={'PASS' if est_ok else 'FAIL'}"
            else:
                tag = "" if gated else ";ungated"
            emit(f"optimizer/skewed-hub/shards{n_shards}/{name}", us_opt,
                 f"syntactic_us={us_syn:.1f};speedup={speedup:.2f}x;"
                 f"rungs={rungs_opt};n_rows={len(truth[name])};"
                 f"answers={'PASS' if ok else 'FAIL'}" + tag)
            failed |= not ok
        verdict = "PASS" if (wins >= 2 and not failed) else "FAIL"
        emit(f"optimizer/skewed-hub/shards{n_shards}/acceptance", 0.0,
             f"ge2x_wins={wins}/{len(OPT_GATED)};"
             f"answers==syntactic==oracle;{verdict}")
        failed |= gate_speedup and wins < 2
        del e_syn, e_opt
        jax.clear_caches()
    return failed


def calibrated_section(table_path: str, iters: int) -> bool:
    """The PR 8 cost-model gate: plans priced through a calibrated
    :class:`DeviceCostTable` vs the row-count planner vs syntactic.

    Gates (any failure returns True):

    * every calibrated plan is answer-identical to the syntactic planner
      and the numpy oracle (a mispriced table may only change plan
      choice/capacities, never answers);
    * the C4 chain — whose 3-leaf row-optimal split loses 0.3–0.6x to
      per-stage dispatch overhead at CI scale — is >= 1x vs the 2-leaf
      syntactic plan.  When the calibrated planner picks the *same* plan
      as syntactic (the expected outcome: the stage constants price the
      third dispatch out), the speedup is definitionally 1x (same jit
      executable) and the gate passes without a wall-clock coin flip.

    Every row carries ``predicted_ns`` in its derived tag —
    ``DeviceCostTable.refine_from_trajectory`` parses exactly this, so
    the emitted JSON is next run's training data.
    """
    import jax

    from repro.core import costmodel
    from repro.core import index as cindex, oracle
    from repro.core.engine import Engine
    from repro.core.optimizer import estimate_plan
    from repro.core.query import freeze_plan, instantiate_template

    from benchmarks.common import DATASETS, emit, timeit

    table = costmodel.DeviceCostTable.load(table_path)
    costmodel.activate(table)  # tuned blocks + VMEM ceiling for kernels
    g = DATASETS["skewed-hub"]()
    idx = cindex.build(g, 2)
    probes = [(name, instantiate_template(name, labels))
              for name, labels in OPT_GATED + OPT_RUNG_GATED + OPT_EXTRA]
    truth = {name: oracle.cpq_eval(g, q) for name, q in probes}

    e_syn = Engine(idx, optimize=False)
    e_cal = Engine(idx, cost_table=table)
    failed = False
    for name, q in probes:
        syn_rows = e_syn.execute(q)
        cal_rows = e_cal.execute(q)
        ok = (syn_rows.shape == cal_rows.shape
              and bool(np.all(syn_rows == cal_rows))
              and {tuple(r) for r in cal_rows.tolist()} == truth[name])
        failed |= not ok
        plan_cal = e_cal.plan(q)
        plans_equal = freeze_plan(plan_cal) == freeze_plan(e_syn.plan(q))
        predicted = estimate_plan(plan_cal, e_cal.stats,
                                  cost_table=table).cost_ns
        us_syn = timeit(lambda: e_syn.execute(q), iters=iters)
        us_cal = timeit(lambda: e_cal.execute(q), iters=iters)
        speedup = 1.0 if plans_equal else us_syn / max(us_cal, 1e-9)
        if name == "C4":
            c4_ok = ok and (plans_equal or speedup >= 1.0)
            failed |= not c4_ok
            tag = f";c4_gate={'PASS' if c4_ok else 'FAIL'}"
        else:
            tag = ""
        emit(f"calibrated/skewed-hub/{name}", us_cal,
             f"syntactic_us={us_syn:.1f};speedup={speedup:.2f}x;"
             f"plans_equal={plans_equal};predicted_ns={predicted:.0f};"
             f"scale={table.scale:.3f};"
             f"answers={'PASS' if ok else 'FAIL'}" + tag)
    emit("calibrated/skewed-hub/acceptance", 0.0,
         f"answers==syntactic==oracle;"
         f"{'FAIL' if failed else 'PASS'}")
    costmodel.activate(None)
    del e_syn, e_cal
    jax.clear_caches()
    return failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: optimizer gate only, n_shards in {1, 8}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON")
    ap.add_argument("--cost-table", default=None, metavar="PATH",
                    help="run the calibrated-planner gate against this "
                         "DeviceCostTable JSON (benchmarks.calibrate "
                         "writes one)")
    args, _ = ap.parse_known_args()

    if args.smoke and "XLA_FLAGS" not in os.environ:
        # must precede the first jax import (the 8-shard leg)
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

    if not args.smoke:
        fig6_section()
    failed = optimizer_section([1, 8] if args.smoke else [1],
                               iters=2 if args.smoke else 3,
                               gate_speedup=args.smoke)
    if args.cost_table:
        failed |= calibrated_section(args.cost_table,
                                     iters=2 if args.smoke else 3)
    if args.json:
        from benchmarks.common import write_json

        write_json(args.json, bench="bench_query", smoke=args.smoke,
                   cost_table=bool(args.cost_table))
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
