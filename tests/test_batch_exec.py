"""Differential tests for the batched execution path (PR 1 tentpole):
``Engine.execute_batch`` must be bit-identical to the semantics oracle
(and to sequential ``execute``) across templates, mixed batches,
duplicates, singleton batches, and the per-lane overflow-retry path."""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import index as cindex
from repro.core import oracle
from repro.core.engine import Engine, QueryCaps
from repro.core.graph import LabeledGraph
from repro.core.query import TEMPLATES, TEMPLATE_ARITY, instantiate_template


def _rows(arr) -> set:
    return {tuple(r) for r in arr.tolist()}


@pytest.fixture(scope="module")
def built(ex_graph):
    return ex_graph, Engine(cindex.build(ex_graph, 2))


def _template_queries(g, rng, names, n_per=1):
    out = []
    for name in names:
        for _ in range(n_per):
            labels = rng.integers(0, g.alphabet_size,
                                  TEMPLATE_ARITY[name]).tolist()
            out.append(instantiate_template(name, labels))
    return out


class TestBatchedDifferential:
    def test_all_templates_in_one_mixed_batch(self, built):
        """One mixed batch covering all 12 Fig. 5 templates, two label
        draws each — results must equal the oracle query by query."""
        g, eng = built
        rng = np.random.default_rng(7)
        qs = _template_queries(g, rng, sorted(TEMPLATES), n_per=2)
        res = eng.execute_batch(qs)
        assert len(res) == len(qs)
        for q, r in zip(qs, res):
            assert _rows(r) == oracle.cpq_eval(g, q), q

    def test_batch_matches_sequential_execute(self, built):
        g, eng = built
        rng = np.random.default_rng(3)
        qs = _template_queries(g, rng, ["C2", "T", "S", "St", "C2i"], n_per=2)
        batched = eng.execute_batch(qs)
        for q, r in zip(qs, batched):
            assert _rows(r) == _rows(eng.execute(q)), q

    @pytest.mark.parametrize("seed", [1, 5])
    def test_random_graphs(self, seed):
        g = random_graph(seed, n_max=16, m_max=40)
        eng = Engine(cindex.build(g, 2))
        rng = np.random.default_rng(seed)
        qs = [oracle.random_cpq(rng, g, 2) for _ in range(6)]
        for q, r in zip(qs, eng.execute_batch(qs)):
            assert _rows(r) == oracle.cpq_eval(g, q), q

    def test_duplicate_queries_in_batch(self, built):
        g, eng = built
        q = instantiate_template("T", [0, 0, 1])
        qs = [q, q, q, instantiate_template("C2", [0, 1]), q]
        res = eng.execute_batch(qs)
        gt = oracle.cpq_eval(g, q)
        for i in (0, 1, 2, 4):
            assert _rows(res[i]) == gt
        assert _rows(res[3]) == oracle.cpq_eval(
            g, instantiate_template("C2", [0, 1]))

    def test_batch_of_one(self, built):
        g, eng = built
        q = instantiate_template("S", [0, 1, 1, 0])
        (r,) = eng.execute_batch([q])
        assert _rows(r) == oracle.cpq_eval(g, q)

    def test_empty_batch(self, built):
        _, eng = built
        assert eng.execute_batch([]) == []


class TestBatchOverflowRetry:
    def test_tiny_caps_per_lane_retry(self, built):
        """Every lane starts overflowing at caps (2,2,2); the sticky
        per-lane flags must drive retries until all answers are exact."""
        g, eng = built
        rng = np.random.default_rng(11)
        qs = _template_queries(g, rng, ["C2", "C4", "T", "TT"], n_per=2)
        res = eng.execute_batch(qs, caps=QueryCaps(2, 2, 2))
        for q, r in zip(qs, res):
            assert _rows(r) == oracle.cpq_eval(g, q), q

    def test_mixed_sizes_only_overflowing_lanes_grow(self, built):
        """A batch mixing an empty-answer query with heavy ones: caps
        sized so some lanes succeed on the first dispatch while others
        must retry — both kinds end exact."""
        g, eng = built
        heavy = instantiate_template("C4", [0, 2, 0, 2])
        light = instantiate_template("C2", [1, 1])
        qs = [heavy, light, heavy, light]
        res = eng.execute_batch(qs, caps=QueryCaps(4, 4, 4))
        for q, r in zip(qs, res):
            assert _rows(r) == oracle.cpq_eval(g, q), q

    def test_min_bucket_variants_agree(self, built):
        """Bucket merging is a perf knob, never a semantics knob."""
        g, eng = built
        rng = np.random.default_rng(13)
        qs = _template_queries(g, rng, ["T", "S", "C2"], n_per=3)
        base = [_rows(r) for r in eng.execute_batch(qs, min_bucket=1)]
        merged = [_rows(r) for r in eng.execute_batch(qs, min_bucket=16)]
        assert base == merged
        assert base == [oracle.cpq_eval(g, q) for q in qs]


def _heavy_graph() -> LabeledGraph:
    """Complete bipartite label-0 waves in both directions: (0.0) has
    72 answer pairs, so tiny caps overflow through every doubling rung
    and land on the default-caps jump (attempt >= 3)."""
    A, B = range(0, 6), range(6, 12)
    edges = [(a, b, 0) for a in A for b in B]
    edges += [(b, a, 0) for a in A for b in B]
    return LabeledGraph.from_edges(12, 2, edges)


class TestTelemetryParity:
    def test_one_lane_batch_matches_execute(self):
        """Bug 3 regression, half one: a 1-lane ``execute_batch`` must
        report the SAME ladder telemetry as ``execute`` — queries,
        rungs, and default jumps."""
        g = _heavy_graph()
        idx = cindex.build(g, 2)
        e1, e2 = Engine(idx), Engine(idx)
        q = instantiate_template("C2", [0, 0])
        r1 = e1.execute(q, caps=QueryCaps(2, 2, 2))
        (r2,) = e2.execute_batch([q], caps=QueryCaps(2, 2, 2))
        assert _rows(r1) == _rows(r2) == oracle.cpq_eval(g, q)
        t1, t2 = e1.telemetry, e2.telemetry
        assert t1.default_jumps > 0  # the ladder actually jumped
        assert (t1.queries, t1.retry_rungs, t1.default_jumps) == \
            (t2.queries, t2.retry_rungs, t2.default_jumps)

    def test_default_jumps_count_per_lane(self):
        """Bug 3 regression, half two: N lanes that each exhaust the
        doubling rungs are N default-caps jumps, not one per dispatch —
        the pre-fix per-dispatch counter under-reported by the batch
        width, hiding estimator misses exactly when batching amortized
        them."""
        g = _heavy_graph()
        idx = cindex.build(g, 2)
        q = instantiate_template("C2", [0, 0])
        single = Engine(idx)
        single.execute(q, caps=QueryCaps(2, 2, 2))
        per_lane = single.telemetry.default_jumps
        assert per_lane > 0
        batch = Engine(idx)
        batch.execute_batch([q] * 4, caps=QueryCaps(2, 2, 2))
        assert batch.telemetry.default_jumps == 4 * per_lane
        assert batch.telemetry.retry_rungs == \
            4 * single.telemetry.retry_rungs


class TestUnionExecutable:
    def test_union_matches_shaped_and_oracle(self, built):
        """Straggler fusion is a perf knob, never a semantics knob: a
        mixed-template batch forced through ONE union dispatch is
        bit-identical to the per-shape path and the oracle."""
        g, _ = built
        idx = cindex.build(g, 2)
        shaped, fused = Engine(idx), Engine(idx)
        rng = np.random.default_rng(19)
        qs = _template_queries(g, rng, ["C2", "T", "S", "C2i", "St", "C4"])
        base = shaped.execute_batch(qs, min_bucket=1)
        got = fused.execute_batch(qs, union=True, min_bucket=64)
        for q, r, u in zip(qs, base, got):
            assert _rows(u) == _rows(r) == oracle.cpq_eval(g, q), q
        assert fused.telemetry.union_lanes == len(qs)
        assert fused.telemetry.dispatches <= shaped.telemetry.dispatches

    def test_union_drives_the_retry_ladder(self, built):
        """Per-lane sticky overflow keeps working through the union VM:
        tiny caps force the ladder and every answer ends exact."""
        g, _ = built
        eng = Engine(cindex.build(g, 2))
        rng = np.random.default_rng(23)
        qs = _template_queries(g, rng, ["C2", "C4", "T", "TT"])
        res = eng.execute_batch(qs, caps=QueryCaps(2, 2, 2), union=True,
                                min_bucket=64)
        for q, r in zip(qs, res):
            assert _rows(r) == oracle.cpq_eval(g, q), q
        assert eng.telemetry.union_lanes == len(qs)
        assert eng.telemetry.retry_rungs > 0

    def test_full_buckets_are_not_fused(self, built):
        """Only sub-``min_bucket`` stragglers fuse; a bucket already
        wide enough keeps its specialized executable."""
        g, _ = built
        eng = Engine(cindex.build(g, 2))
        rng = np.random.default_rng(29)
        qs = _template_queries(g, rng, ["T"], n_per=5)  # one shape, 5 wide
        res = eng.execute_batch(qs, union=True, min_bucket=4)
        for q, r in zip(qs, res):
            assert _rows(r) == oracle.cpq_eval(g, q), q
        assert eng.telemetry.union_lanes == 0


class TestAdaptiveCaps:
    def test_estimates_are_safe_or_retried(self, built):
        """estimate_caps may undersize (that's the design) but execute
        must still deliver exact answers via the retry ladder."""
        g, eng = built
        rng = np.random.default_rng(17)
        for q in _template_queries(g, rng, sorted(TEMPLATES)):
            assert _rows(eng.execute(q)) == oracle.cpq_eval(g, q), q

    def test_identity_floor(self, built):
        """A bare `id` query needs pair_cap >= n_vertices up front."""
        g, eng = built
        from repro.core.query import Identity, plan_query, plan_shape

        plan = plan_query(Identity(), eng.index.k)
        caps = eng.estimate_caps(eng.lookup_ranges(plan), plan_shape(plan))
        assert caps.pair_cap >= g.n_vertices
        assert _rows(eng.execute(Identity())) == {
            (v, v) for v in range(g.n_vertices)}
