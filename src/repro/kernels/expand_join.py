"""Pallas TPU kernel: fused CSR expansion-join gather — the JOIN /
materialization hot spot (Algorithm 4 JOIN; I_c2p expansion).

Given per-probe match ranges (``lo``, inclusive-cumsum ``ends``) against a
CSR-sorted build side, output row t belongs to probe
``i = searchsorted(ends, t, 'right')`` at offset ``t - starts[i]``, i.e.
build row ``lo[i] + t - starts[i]``.  XLA materializes the intermediate
``i``/``j`` index vectors in HBM; this kernel fuses the binary search, the
offset arithmetic and the payload gathers into one VMEM pass over the
output tile: one HBM read per input element, one write per output row.

Tiling: output rows are blocked along the grid; the probe-side ranges and
the build-side payload columns are VMEM-resident blocks (the engine sizes
relations to fit; beyond-VMEM sizes fall back to the jnp path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 1024


def _expand_kernel(ends_ref, lo_ref, av_ref, bv_ref, bu_ref, total_ref,
                   outv_ref, outu_ref, outa_ref, *, steps: int, block_t: int,
                   sentinel: int):
    ends = ends_ref[...]
    lo_b = lo_ref[...]
    av = av_ref[...]
    bv = bv_ref[...]
    bu = bu_ref[...]
    total = total_ref[0]
    n_a = ends.shape[0]
    n_b = bv.shape[0]

    t = pl.program_id(0) * block_t + jax.lax.iota(jnp.int32, block_t)

    # binary search: first i with ends[i] > t  (searchsorted right)
    loi = jnp.zeros(t.shape, jnp.int32)
    hii = jnp.full(t.shape, n_a, jnp.int32)

    def body(_, lohi):
        l, h = lohi
        mid = (l + h) >> 1
        v = ends[jnp.clip(mid, 0, n_a - 1)]
        go_right = v <= t
        active = l < h
        l = jnp.where(active & go_right, mid + 1, l)
        h = jnp.where(active & (~go_right), mid, h)
        return l, h

    ai, _ = jax.lax.fori_loop(0, steps, body, (loi, hii))
    aic = jnp.clip(ai, 0, n_a - 1)
    # starts[i] = ends[i] - cnt[i] = ends[i-1] (exclusive cumsum)
    starts = jnp.where(aic > 0, ends[jnp.clip(aic - 1, 0, n_a - 1)], 0)
    bj = jnp.clip(lo_b[aic] + (t - starts), 0, n_b - 1)
    ok = t < total
    outv_ref[...] = jnp.where(ok, bv[bj], sentinel)
    outu_ref[...] = jnp.where(ok, bu[bj], sentinel)
    outa_ref[...] = jnp.where(ok, av[aic], sentinel)


@functools.partial(jax.jit, static_argnames=("out_capacity", "block_t", "sentinel"))
def expand_join_gather(
    ends: jax.Array,  # (n_a,) inclusive cumsum of per-probe match counts
    lo: jax.Array,  # (n_a,) first matching build row per probe
    a_payload: jax.Array,  # (n_a,) probe payload column (e.g. v)
    b_v: jax.Array,  # (n_b,) build payload columns
    b_u: jax.Array,
    total: jax.Array,  # scalar: true output row count
    out_capacity: int,
    block_t: int = DEFAULT_BLOCK_T,
    sentinel: int = 2**31 - 1,
):
    """Returns (out_bv, out_bu, out_a): the expanded join projection, with
    rows >= total set to ``sentinel``."""
    assert out_capacity % block_t == 0, (out_capacity, block_t)
    steps = max(1, int(ends.shape[0]).bit_length())
    kernel = functools.partial(_expand_kernel, steps=steps, block_t=block_t,
                               sentinel=sentinel)
    full = lambda arr: pl.BlockSpec(arr.shape, lambda i: (0,), memory_space=pltpu.VMEM)
    out = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((out_capacity,), jnp.int32)] * 3,
        grid=(out_capacity // block_t,),
        in_specs=[
            full(ends), full(lo), full(a_payload), full(b_v), full(b_u),
            pl.BlockSpec((1,), lambda i: (0,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,), memory_space=pltpu.VMEM)
        ] * 3,
        interpret=jax.default_backend() == "cpu",
    )(ends, lo, a_payload, b_v, b_u, jnp.asarray(total, jnp.int32).reshape(1))
    return out
