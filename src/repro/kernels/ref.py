"""Pure-jnp oracles for every Pallas kernel — the correctness references
the per-kernel tests sweep shapes/dtypes against."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.int32(2**31 - 1)


def sorted_member_mask(hay: jax.Array, hay_count, queries: jax.Array) -> jax.Array:
    """0/1 membership of queries in hay[:hay_count] (hay sorted)."""
    pos = jnp.searchsorted(hay, queries, side="left").astype(jnp.int32)
    posc = jnp.clip(pos, 0, hay.shape[0] - 1)
    found = (pos < hay_count) & (hay[posc] == queries)
    return found.astype(jnp.int32)


def expand_join_gather(ends, lo, a_payload, b_v, b_u, total, out_capacity,
                       sentinel: int = int(SENTINEL)):
    n_a = ends.shape[0]
    n_b = b_v.shape[0]
    t = jnp.arange(out_capacity, dtype=jnp.int32)
    ai = jnp.searchsorted(ends, t, side="right").astype(jnp.int32)
    aic = jnp.clip(ai, 0, n_a - 1)
    starts = jnp.where(aic > 0, ends[jnp.clip(aic - 1, 0, n_a - 1)], 0)
    bj = jnp.clip(lo[aic] + (t - starts), 0, n_b - 1)
    ok = t < total
    return (
        jnp.where(ok, b_v[bj], sentinel),
        jnp.where(ok, b_u[bj], sentinel),
        jnp.where(ok, a_payload[aic], sentinel),
    )


def fingerprint_rows(cols: tuple, salt: int = 0):
    """Must stay bit-identical to relational.fingerprint_rows."""
    from repro.core.relational import fingerprint_rows as _fp

    return _fp(cols, salt)


def segment_softmax(scores, segment_ids, num_segments, eps: float = 1e-9):
    seg = segment_ids.astype(jnp.int32)
    mx = jax.ops.segment_max(scores, seg, num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(scores - mx[jnp.clip(seg, 0, num_segments - 1)])
    den = jax.ops.segment_sum(ex, seg, num_segments)
    return ex / (den[jnp.clip(seg, 0, num_segments - 1)] + eps)
