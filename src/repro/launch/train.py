"""End-to-end training driver (deliverable (b)).

Local mode (default) trains a reduced config on the host device — the
quickstart path: ``python -m repro.launch.train --arch minicpm-2b
--steps 50``.  Mesh modes jit the same step function under the
production mesh with the launch/shardings.py layout (the dry-run proves
those lower; real execution needs real chips).

Fault tolerance wired in: atomic async checkpoints, resume-from-LATEST,
deterministic data skip, straggler logging, non-finite-loss breaker.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_sharded, wait_for_writes
from repro.configs import get_arch
from repro.data.tokens import TokenStream
from repro.models import transformer as T
from repro.train.loop import TrainConfig, train
from repro.train.optim import adamw_init


def build_lm(arch_id: str, full: bool, batch: int, seq: int, scale: int):
    spec = get_arch(arch_id)
    if spec.family != "lm":
        raise SystemExit(f"{arch_id} is not an LM; use its example script")
    cfg = spec.config if full else spec.smoke
    if not full and scale > 1:
        # "~100M" example scale: widen the smoke config
        cfg = dataclasses.replace(
            cfg, d_model=cfg.d_model * scale, d_ff=cfg.d_ff * scale,
            n_layers=min(cfg.n_layers * scale, 12),
            head_dim=cfg.head_dim * max(1, scale // 2),
        )
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (paper-exact) config, not smoke")
    ap.add_argument("--scale", type=int, default=1,
                    help="widen the smoke config (4 => ~100M params)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--schedule", default=None,
                    help="cosine|wsd (default: wsd for minicpm else cosine)")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    cfg = build_lm(args.arch, args.full, args.batch, args.seq, args.scale)
    sched = args.schedule or ("wsd" if args.arch == "minicpm-2b" else "cosine")
    print(f"[train] {args.arch}: {cfg.param_count():,} params, "
          f"schedule={sched}, batch={args.batch}x{args.seq}")

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    stream = TokenStream(cfg.vocab_size, args.batch * args.accum, args.seq)

    def loss_fn(p, batch):
        toks, labels = batch
        return T.train_loss(cfg, p, toks, labels)

    def data_at(step):
        toks, labels = stream.batch_at(step)
        if args.accum > 1:
            toks = toks.reshape(args.accum, args.batch, -1)
            labels = labels.reshape(args.accum, args.batch, -1)
        return jnp.asarray(toks), jnp.asarray(labels)

    tcfg = TrainConfig(steps=args.steps, peak_lr=args.lr,
                       warmup=max(args.steps // 10, 5), schedule=sched,
                       accum=args.accum, ckpt_dir=args.ckpt_dir)

    start, opt_state = 0, None
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            like = {"params": params, "opt": adamw_init(params)}
            restored = restore_sharded(args.ckpt_dir, last, like)
            params, opt_state = restored["params"], restored["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    def on_metrics(rec):
        print(f"  step {rec['step']:5d}  loss {rec['loss']:.4f}  "
              f"lr {rec['lr']:.2e}  gnorm {rec['grad_norm']:.2f}  "
              f"{rec['dt']*1000:.0f}ms" + ("  [STRAGGLER]" if rec["straggler"] else ""))

    params, opt_state, history = train(
        loss_fn, params, data_at, tcfg, on_metrics=on_metrics,
        start_step=start, opt_state=opt_state)
    wait_for_writes()
    print(f"[train] done: loss {history[0]['loss']:.4f} -> "
          f"{history[-1]['loss']:.4f} over {len(history)} steps; "
          f"stragglers={sum(h['straggler'] for h in history)}")
    if args.history_out:
        os.makedirs(os.path.dirname(args.history_out) or ".", exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
