import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run — deliverable (e).

For every (architecture x input shape) cell, on the single-pod 16x16 and
the multi-pod 2x16x16 production meshes:

    lowered  = jax.jit(step, in_shardings=...).lower(*input_specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / collective-bytes (HLO parse)

A failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system.  Results are written as JSON records
under experiments/dryrun/ for the roofline analysis (§Roofline).

Usage:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --all --mesh single --skip-existing
"""

import argparse
import json
import time
import traceback

import jax


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun") -> dict:
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.roofline.collect import analyze_compiled

    spec = get_arch(arch_id)
    shape = spec.shape(shape_name)
    mesh_name = "multi" if multi_pod else "single"
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "dims": {k: (list(v) if isinstance(v, tuple) else v)
                                     for k, v in shape.dims.items()},
        "status": "pending",
    }
    if shape.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = shape.skip
        return rec

    from repro import compat

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(spec, shape, mesh)
    with compat.set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        rec.update(analyze_compiled(compiled, mesh))
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["description"] = cell.description
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, get_arch

    cells = []
    if args.all:
        for aid in ARCH_IDS:
            spec = get_arch(aid)
            for sh in spec.shapes:
                cells.append((aid, sh.name))
    else:
        if not args.arch:
            ap.error("--arch required without --all")
        spec = get_arch(args.arch)
        shapes = [args.shape] if args.shape else [s.name for s in spec.shapes]
        cells = [(args.arch, s) for s in shapes]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_fail = n_skip = 0
    for aid, sname in cells:
        for multi in meshes:
            mesh_name = "multi" if multi else "single"
            path = os.path.join(
                args.out, f"{aid}__{sname}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip existing] {aid} {sname} {mesh_name}")
                continue
            try:
                rec = run_cell(aid, sname, multi, args.out)
                if rec["status"] == "skipped":
                    n_skip += 1
                    print(f"[SKIP] {aid} {sname} {mesh_name}: "
                          f"{rec['skip_reason'][:60]}...")
                else:
                    n_ok += 1
                    print(f"[ok] {aid} {sname} {mesh_name}: "
                          f"compile {rec['compile_s']}s, "
                          f"{rec.get('per_device_hbm_gb', '?')} GB/dev, "
                          f"{rec.get('total_flops', 0):.3e} flops")
            except Exception as e:  # noqa: BLE001 — record and continue
                n_fail += 1
                rec = {"arch": aid, "shape": sname, "mesh": mesh_name,
                       "status": "fail", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[FAIL] {aid} {sname} {mesh_name}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            # keep the JIT arena bounded across many huge compilations
            jax.clear_caches()
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
