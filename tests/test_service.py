"""QueryService (serving layer) tests: differential correctness through
the queue/flush path, plan-shape bucketing, in-flight dedup, the LRU
result cache, and epoch-keyed invalidation under graph maintenance."""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import index as cindex
from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import TEMPLATES, TEMPLATE_ARITY, instantiate_template
from repro.core.service import QueryService
from repro.core.workload import AdaptationConfig, AdaptationController


def _rows(arr) -> set:
    return {tuple(r) for r in arr.tolist()}


def _workload(g, rng, names, n_per=1):
    out = []
    for name in names:
        for _ in range(n_per):
            labels = rng.integers(0, g.alphabet_size,
                                  TEMPLATE_ARITY[name]).tolist()
            out.append(instantiate_template(name, labels))
    return out


@pytest.fixture()
def svc(ex_graph):
    return QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=64)


class TestServiceDifferential:
    def test_all_templates_through_the_queue(self, ex_graph, svc):
        g = ex_graph
        rng = np.random.default_rng(2)
        qs = _workload(g, rng, sorted(TEMPLATES), n_per=2)
        reqs = [svc.submit(q) for q in qs]
        assert svc.pending == len(qs)
        done = svc.flush()
        assert len(done) == len(qs) and svc.pending == 0
        for q, r in zip(qs, reqs):
            assert r.done
            assert _rows(r.result) == oracle.cpq_eval(g, q), q
        # 12 templates collapse to fewer than 12 plan-shape buckets
        assert 0 < svc.stats.shape_buckets <= len(qs)

    def test_random_graph(self):
        g = random_graph(9, n_max=14, m_max=35)
        svc = QueryService(Engine(cindex.build(g, 2)), max_batch=16)
        rng = np.random.default_rng(9)
        qs = [oracle.random_cpq(rng, g, 2) for _ in range(5)]
        for q in qs:
            assert _rows(svc.query(q)) == oracle.cpq_eval(g, q), q


class TestQueueAndCache:
    def test_auto_flush_at_max_batch(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=3)
        qs = _workload(ex_graph, np.random.default_rng(4),
                       ["C2", "T", "S", "C4"])
        reqs = [svc.submit(q) for q in qs]
        assert all(r.done for r in reqs[:3])  # flushed on admission limit
        assert not reqs[3].done and svc.pending == 1
        svc.flush()
        assert reqs[3].done

    def test_duplicates_fold_into_one_execution(self, ex_graph, svc):
        q = instantiate_template("T", [0, 0, 1])
        reqs = [svc.submit(q) for _ in range(4)]
        svc.flush()
        gt = oracle.cpq_eval(ex_graph, q)
        for r in reqs:
            assert _rows(r.result) == gt
        assert svc.stats.executed == 1
        assert svc.stats.deduped == 3

    def test_repeat_query_served_from_cache(self, ex_graph, svc):
        q = instantiate_template("C2", [0, 1])
        first = svc.submit(q)
        svc.flush()
        again = svc.submit(q)
        assert again.done and again.from_cache
        assert _rows(again.result) == _rows(first.result)
        assert svc.stats.cache_hits == 1
        # cached answers bypass the device entirely
        assert svc.stats.executed == 1

    def test_failed_flush_requeues_requests(self, ex_graph):
        """If the engine raises mid-flush (retry exhaustion), queued
        requests must survive for the next flush, not vanish."""
        svc = QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=64,
                           max_retries=0)
        q = instantiate_template("C2", [0, 0])
        req = svc.submit(q)
        with pytest.raises(RuntimeError):
            svc.flush()
        assert svc.pending == 1 and not req.done
        svc.max_retries = 8
        svc.flush()
        assert req.done
        assert _rows(req.result) == oracle.cpq_eval(ex_graph, q)

    def test_lru_result_cache_is_bounded(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=64,
                           result_cache_size=2)
        qs = _workload(ex_graph, np.random.default_rng(6),
                       ["C2", "T", "S"])
        for q in qs:
            svc.query(q)
        assert len(svc._results) <= 2
        # oldest entry evicted -> re-submitting executes again
        r = svc.submit(qs[0])
        assert not (r.done and r.from_cache)


class TestEpochInvalidation:
    def test_maintenance_mutation_invalidates_result_cache(self, ex_graph):
        """Mutate the graph via core.maintenance, rebuild, rebind: the
        service must stop serving pre-mutation answers (epoch key) and
        agree with the oracle on the new graph."""
        g = ex_graph
        svc = QueryService(Engine(cindex.build(g, 2)), max_batch=8)
        q = instantiate_template("C2", [0, 0])

        before = _rows(svc.query(q))
        assert before == oracle.cpq_eval(g, q)
        hit = svc.submit(q)
        assert hit.from_cache  # warmed

        m = MaintainableIndex.build(g, 2)
        m.insert_edge(2, 3, 0)  # zoe -> tim: adds the f.f path zoe->tim->sue
        assert oracle.cpq_eval(m.g, q) != before  # the mutation matters

        old_epoch = svc.graph_epoch
        svc.rebind(cindex.build(m.g, 2))
        assert svc.graph_epoch == old_epoch + 1

        fresh = svc.submit(q)
        assert not fresh.from_cache  # epoch key killed the cached answer
        svc.flush()
        assert _rows(fresh.result) == oracle.cpq_eval(m.g, q)
        # and the post-mutation answer is itself cacheable
        warm = svc.submit(q)
        assert warm.from_cache
        assert _rows(warm.result) == oracle.cpq_eval(m.g, q)

    def test_bump_epoch_alone_invalidates(self, ex_graph, svc):
        q = instantiate_template("T", [0, 1, 0])
        svc.query(q)
        assert svc.submit(q).from_cache
        svc.bump_epoch()
        assert not svc.submit(q).from_cache
        svc.flush()

    def test_plan_cache_keyed_on_epoch(self, ex_graph, svc):
        """Plans are optimized against the index *statistics* (PR 4), so
        an epoch bump makes cached plans unreachable without a scan —
        same O(1) invalidation contract as the result cache."""
        q = instantiate_template("T", [0, 1, 0])
        svc._plan(q)
        svc._plan(q)
        assert svc.stats.plan_hits == 1
        svc.bump_epoch()
        svc._plan(q)  # re-planned: the old epoch's entry is stale
        assert svc.stats.plan_hits == 1
        svc._plan(q)
        assert svc.stats.plan_hits == 2

    def test_rebind_drains_pending_against_old_index(self, ex_graph):
        """Requests submitted before a rebind were planned against the
        old graph; rebind flushes them first so they complete (and
        against the index they targeted)."""
        g = ex_graph
        svc = QueryService(Engine(cindex.build(g, 2)), max_batch=64)
        q = instantiate_template("C2", [0, 0])
        req = svc.submit(q)
        gt_old = oracle.cpq_eval(g, q)

        m = MaintainableIndex.build(g, 2)
        m.insert_edge(1, 3, 0)
        svc.rebind(cindex.build(m.g, 2))
        assert req.done and _rows(req.result) == gt_old


def _adaptive_svc(g, **kw):
    """Interest-aware service with a controlled adapter for the PR 7
    serializability/vote-accounting regressions."""
    mi = MaintainableIndex.build(g, 2, interests=[])
    adapter = AdaptationController(
        2, config=AdaptationConfig(budget=2, min_count=2.0, dwell=1,
                                   decay=0.5))
    kw.setdefault("adapt_interval", 10_000)
    kw.setdefault("max_batch", 8)
    svc = QueryService(Engine(mi.flush()), maintainer=mi, adapter=adapter,
                       **kw)
    return svc, mi


class TestServingBugRegressions:
    def test_adapt_drains_queued_reads_before_queueing_interests(
            self, ex_graph):
        """Bug 1 (serializability crack): an adaptation round fired while
        reads sit in the queue — reachable through a cache-hit submit,
        which never flushes — must drain those reads BEFORE extending the
        pending-update queue.  Pre-fix, the next flush applied the
        interest batch first, so a read executed against state from a
        write accepted AFTER it was submitted."""
        svc, mi = _adaptive_svc(ex_graph)
        qc = instantiate_template("C2", [0, 1])
        q1 = instantiate_template("T", [0, 0, 1])
        svc.query(qc)  # warm the result cache
        queued = svc.submit(q1)  # parks: max_batch > 1, auto flush off
        assert not queued.done
        # arm the next _maybe_adapt with a proposal we control
        svc._planned_since_adapt = svc.adapt_interval
        svc.adapter.propose = lambda stats, cur: [
            ("insert_interest", (0, 0))]
        seen = []  # interest set live at each device dispatch
        orig = svc.engine.dispatch_batch

        def spy(*args, **kwargs):
            seen.append(frozenset(mi.index.interests))
            return orig(*args, **kwargs)

        svc.engine.dispatch_batch = spy
        hit = svc.submit(qc)  # cache hit -> _maybe_adapt -> adapt()
        assert hit.from_cache
        # the queued read drained inside adapt(), on the PRE-round index
        assert queued.done
        assert _rows(queued.result) == oracle.cpq_eval(mi.g, q1)
        assert seen and all((0, 0) not in s for s in seen)
        svc.flush()  # now the interest batch drains
        assert (0, 0) in mi.index.interests

    def test_failed_flush_does_not_double_vote(self, ex_graph):
        """Bug 2 (vote accounting): a flush that dies in the engine
        requeues its requests; the retry re-plans but must NOT credit
        the workload sketch again — votes are idempotent per request.
        Pre-fix, every requeue inflated the hot sequence's count, so
        flaky traffic steered adaptation."""
        svc, mi = _adaptive_svc(ex_graph)
        q = instantiate_template("T", [0, 0, 1])  # votes (0, 0)
        req = svc.submit(q)
        svc.max_retries = 0
        with pytest.raises(RuntimeError):
            svc.flush()
        assert not req.done  # requeued, not lost
        assert svc.adapter.sketch.count((0, 0)) == 1  # voted exactly once
        svc.max_retries = 8
        svc.flush()
        assert req.done
        assert _rows(req.result) == oracle.cpq_eval(mi.g, q)
        assert svc.adapter.sketch.count((0, 0)) == 1  # still exactly once


class TestMultiTenantServing:
    def test_shed_is_explicit_and_accepted_never_lost(self, ex_graph):
        """Admission control's two-sided contract: overflow is rejected
        AT SUBMIT (shed=True, done=True, result=None) and everything
        accepted completes with oracle-exact rows."""
        svc = QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=4,
                           max_queue=4, auto_flush=False)
        qs = _workload(ex_graph, np.random.default_rng(21),
                       ["C2", "T", "S", "C4", "C2i", "St", "TT"])
        reqs = [svc.submit(q, tenant=f"t{i % 2}")
                for i, q in enumerate(qs)]
        shed = [r for r in reqs if r.shed]
        accepted = [r for r in reqs if not r.shed]
        assert len(shed) == 3 and svc.stats.shed == 3
        for r in shed:
            assert r.done and r.result is None
        svc.flush()
        for r in accepted:
            assert r.done
            assert _rows(r.result) == oracle.cpq_eval(ex_graph, r.query)

    def test_one_shot_query_raises_on_shed(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)),
                           max_queue=1, auto_flush=False)
        svc.submit(instantiate_template("C2", [0, 1]))
        with pytest.raises(RuntimeError, match="shed"):
            svc.query(instantiate_template("C2", [1, 0]))

    def test_per_tenant_queue_bound(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=8,
                           max_queue_per_tenant=2, auto_flush=False)
        qs = _workload(ex_graph, np.random.default_rng(25),
                       ["C2", "T", "S", "C4"])
        a = [svc.submit(q, tenant="a") for q in qs[:3]]
        b = svc.submit(qs[3], tenant="b")
        assert [r.shed for r in a] == [False, False, True]
        assert not b.shed  # a's flood never blocks b
        assert svc.stats.tenant("a").shed == 1
        svc.flush()
        for r in (a[0], a[1], b):
            assert _rows(r.result) == oracle.cpq_eval(ex_graph, r.query)

    def test_fair_drain_round_robins_across_tenants(self, ex_graph):
        """A tenant flooding the queue only delays itself: with rounds
        of 4, tenant b's two requests ride the FIRST round even though
        tenant a submitted four requests ahead of them."""
        svc = QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=4,
                           auto_flush=False)
        qa = _workload(ex_graph, np.random.default_rng(31),
                       ["C2", "T", "S", "C4"])
        qb = _workload(ex_graph, np.random.default_rng(33), ["C2i", "St"])
        for q in qa:
            svc.submit(q, tenant="a")
        for q in qb:
            svc.submit(q, tenant="b")
        rounds = []
        orig = svc.engine.dispatch_batch

        def spy(queries, *args, **kwargs):
            rounds.append(list(queries))
            return orig(queries, *args, **kwargs)

        svc.engine.dispatch_batch = spy
        done = svc.flush()
        assert len(done) == 6 and svc.stats.drain_rounds == 2
        assert all(q in rounds[0] for q in qb)  # b served in round one
        assert set(rounds[1]) <= set(qa)  # only a's tail waits

    def test_per_tenant_stats(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)), max_batch=8)
        q = instantiate_template("C2", [0, 1])
        svc.query(q, tenant="a")
        svc.query(q, tenant="b")  # served from a's cached answer
        a, b = svc.stats.tenant("a"), svc.stats.tenant("b")
        assert (a.submitted, a.served, a.cache_hits) == (1, 1, 0)
        assert (b.submitted, b.served, b.cache_hits) == (1, 1, 1)

    def test_union_service_differential(self, ex_graph):
        """A union-dispatch service fusing straggler shape buckets still
        answers every template oracle-exactly."""
        svc = QueryService(Engine(cindex.build(ex_graph, 2)),
                           max_batch=32, union=True)
        rng = np.random.default_rng(37)
        qs = _workload(ex_graph, rng, sorted(TEMPLATES))
        reqs = [svc.submit(q) for q in qs]
        svc.flush()
        for q, r in zip(qs, reqs):
            assert _rows(r.result) == oracle.cpq_eval(ex_graph, q), q
        assert svc.engine.telemetry.union_lanes > 0


class TestCrossRoundDedup:
    """A request identical to one already dispatched in a *previous*
    (unharvested) round joins that round's result instead of
    re-executing — the satellite fix for the pipelined drain's old
    execute-twice trade."""

    def test_duplicate_joins_previous_rounds_dispatch(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)),
                           max_batch=1, auto_flush=False)
        qa = instantiate_template("T", [0, 0, 1])
        qb = instantiate_template("C2", [0, 1])
        reqs = [svc.submit(qa), svc.submit(qa), svc.submit(qb)]
        done = svc.flush()
        assert len(done) == 3 and all(r.done for r in reqs)
        gt = oracle.cpq_eval(ex_graph, qa)
        assert _rows(reqs[0].result) == gt
        assert _rows(reqs[1].result) == gt
        assert _rows(reqs[2].result) == oracle.cpq_eval(ex_graph, qb)
        assert svc.stats.cross_round_joins == 1
        assert svc.stats.executed == 2  # qa once, qb once — no re-execute
        assert svc.stats.deduped == 1  # the joiner folded at finalize

    def test_third_duplicate_lands_on_the_result_cache(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)),
                           max_batch=1, auto_flush=False)
        q = instantiate_template("T", [0, 0, 1])
        reqs = [svc.submit(q) for _ in range(3)]
        svc.flush()
        gt = oracle.cpq_eval(ex_graph, q)
        assert all(_rows(r.result) == gt for r in reqs)
        assert svc.stats.executed == 1  # one device execution for all 3
        assert svc.stats.cross_round_joins == 1  # req 2 joined round 1
        assert svc.stats.cache_hits == 1  # req 3 hit the published answer

    def test_joiners_votes_and_tenancy_still_count(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)),
                           max_batch=1, auto_flush=False)
        q = instantiate_template("C2", [0, 1])
        svc.submit(q, tenant="a")
        svc.submit(q, tenant="b")
        svc.flush()
        a, b = svc.stats.tenant("a"), svc.stats.tenant("b")
        assert (a.submitted, a.served) == (1, 1)
        assert (b.submitted, b.served) == (1, 1)


class TestSLOShedding:
    """Satellite: with a DeviceCostTable present, admission sheds by
    *predicted dispatch cost* against a per-tenant latency budget."""

    def _engine(self, g):
        from test_costmodel import _toy_table

        return Engine(cindex.build(g, 2), cost_table=_toy_table())

    def test_shed_by_predicted_cost_with_reason(self, ex_graph):
        eng = self._engine(ex_graph)
        q = instantiate_template("TT", [0, 1, 0, 1, 2])  # join: expensive
        cost = eng.predict_cost_ns(eng.plan(q))
        assert cost > 0
        svc = QueryService(eng, slo_ns=cost * 0.5, auto_flush=False)
        r = svc.submit(q)
        assert r.shed and r.done and r.result is None
        assert r.shed_reason == "slo"
        ts = svc.stats.tenant(r.tenant)
        assert ts.shed == 1 and ts.shed_reasons == {"slo": 1}

    def test_backlog_accumulates_until_the_budget_sheds(self, ex_graph):
        eng = self._engine(ex_graph)
        q = instantiate_template("TT", [0, 1, 0, 1, 2])
        cost = eng.predict_cost_ns(eng.plan(q))
        svc = QueryService(eng, slo_ns=cost * 2.5, auto_flush=False)
        r1, r2, r3 = (svc.submit(q) for _ in range(3))
        assert not r1.shed and not r2.shed  # backlog 1c, then 2c <= 2.5c
        assert r3.shed and r3.shed_reason == "slo"  # 3c > 2.5c
        done = svc.flush()
        assert {id(x) for x in done} == {id(r1), id(r2)}
        gt = oracle.cpq_eval(ex_graph, q)
        assert _rows(r1.result) == gt and _rows(r2.result) == gt

    def test_per_tenant_budgets(self, ex_graph):
        eng = self._engine(ex_graph)
        q = instantiate_template("TT", [0, 1, 0, 1, 2])
        cost = eng.predict_cost_ns(eng.plan(q))
        svc = QueryService(eng, slo_ns={"free": cost * 0.5},
                           auto_flush=False)
        assert svc.submit(q, tenant="free").shed_reason == "slo"
        assert not svc.submit(q, tenant="paid").shed  # unbudgeted admits
        svc.flush()

    def test_inert_without_a_cost_table(self, ex_graph):
        # no table -> every prediction is 0.0 -> the SLO gate never fires
        svc = QueryService(Engine(cindex.build(ex_graph, 2)),
                           slo_ns=1.0, auto_flush=False)
        q = instantiate_template("TT", [0, 1, 0, 1, 2])
        assert not svc.submit(q).shed
        svc.flush()

    def test_queue_depth_gates_still_report_reasons(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)),
                           max_queue=1, auto_flush=False)
        q1 = instantiate_template("C2", [0, 1])
        q2 = instantiate_template("C2", [1, 0])
        assert not svc.submit(q1).shed
        r = svc.submit(q2)
        assert r.shed and r.shed_reason == "queue"
        assert svc.stats.tenant(r.tenant).shed_reasons == {"queue": 1}
        svc.flush()
