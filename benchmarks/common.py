"""Shared benchmark plumbing: datasets scaled for CPU CI, timing
helpers, CSV emission.  Every bench prints ``name,us_per_call,derived``
rows so ``python -m benchmarks.run`` produces one machine-readable
stream (deliverable (d): one bench per paper table/figure); the same
rows accumulate in :data:`RESULTS` so a driver can serialize the run
(``python -m benchmarks.run --json out.json`` — the CI perf-trajectory
artifact)."""

from __future__ import annotations

import json
import platform
import time

import numpy as np

from repro.core.graph import LabeledGraph, example_graph
from repro.data.graphs import gmark_citation, powerlaw_graph, skewed_labeled_graph

# CPU-scaled stand-ins for the paper's dataset suite (Table II): same
# generator *families* (social-like powerlaw with exponential labels;
# gMark citation schema), sized for CI.  "skewed-hub" is the optimizer's
# adversarial workload: one hub label carries most edges (bench_query's
# optimized-vs-syntactic gate, bench_pruning's skew section).
DATASETS = {
    "robots-like": lambda: powerlaw_graph(300, 1200, n_labels=4, seed=1),
    "advogato-like": lambda: powerlaw_graph(600, 4000, n_labels=4, seed=2),
    "gmark-small": lambda: gmark_citation(500, avg_degree=6, seed=3),
    "gmark-medium": lambda: gmark_citation(1500, avg_degree=6, seed=4),
    "skewed-hub": lambda: skewed_labeled_graph(seed=5),
    # CI-scaled twin of skewed-hub for benches that pay host-side path
    # enumeration per step (bench_adaptive's interest insertions)
    "skewed-hub-small": lambda: skewed_labeled_graph(
        n_vertices=96, wave=30, rare_edges=24, seed=5),
    "example": example_graph,
}

#: The drifting-workload phases of ``bench_adaptive`` on the skewed-hub
#: graphs: phase 0 hammers forward hub/bridge templates (hot sequences
#: (0,0) and (2,3)), phase 1 drifts to their *inverse-label* twins (hot
#: sequences (6,6) and (9,8) — same shapes, disjoint sequence space), so
#: convergence requires both mining AND eviction under a tight budget.
ADAPTIVE_PHASES = [
    [("T", (0, 0, 1)), ("S", (0, 0, 2, 3))],
    [("T", (6, 6, 7)), ("S", (6, 6, 9, 8))],
]

#: Every ``emit`` row of the process, in order — the machine-readable
#: twin of the CSV stream on stdout.
RESULTS: list[dict] = []


def write_json(path: str, **meta) -> None:
    """Serialize everything emitted so far (plus ``meta``) to ``path``."""
    payload = {
        "meta": {"platform": platform.platform(),
                 "python": platform.python_version(), **meta},
        "rows": RESULTS,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
    print(f"# wrote {len(RESULTS)} rows to {path}", flush=True)

TEMPLATE_NAMES = ["C2", "C4", "C2i", "T", "Ti", "S", "Si", "TT", "St",
                  "TC", "SC", "ST"]


def timeit(fn, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) over iters after warmup."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str = "") -> None:
    RESULTS.append({"name": name, "us_per_call": round(float(us), 1),
                    "derived": derived})
    print(f"{name},{us:.1f},{derived}")
