"""Per-architecture smoke tests (deliverable (f)): every assigned arch
instantiates a REDUCED config and runs one forward/train step on CPU,
asserting output shapes and no NaNs.  Plus model-specific invariants:
MoE == dense-expert reference, E(3) in/equivariance, EmbeddingBag
correctness, gemma2 softcap bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import gnn as G
from repro.models import layers as L
from repro.models import recsys as RS
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
LM_ARCHS = ["grok-1-314b", "granite-moe-3b-a800m", "gemma2-2b",
            "minicpm-2b", "mistral-nemo-12b"]
GNN_ARCHS = ["mace", "egnn", "gatedgcn", "graphcast"]


def _toy_graph(d_in, d_e, n=20, e=60, seed=0):
    rng = np.random.default_rng(seed)
    return G.GraphBatch(
        node_feat=jnp.array(rng.normal(0, 1, (n, d_in)), jnp.float32),
        edge_feat=(jnp.array(rng.normal(0, 1, (e, max(d_e, 1))), jnp.float32)
                   if d_e else None),
        senders=jnp.array(rng.integers(0, n, e), jnp.int32),
        receivers=jnp.array(rng.integers(0, n, e), jnp.int32),
        node_mask=jnp.ones(n, bool), edge_mask=jnp.ones(e, bool),
        positions=jnp.array(rng.normal(0, 1, (n, 3)), jnp.float32),
        graph_ids=jnp.zeros(n, jnp.int32), n_graphs=1,
    )


class TestLMSmoke:
    @pytest.mark.parametrize("arch", LM_ARCHS)
    def test_train_step(self, arch):
        cfg = get_arch(arch).smoke
        params = T.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        loss, aux = T.train_loss(cfg, params, toks, toks)
        assert np.isfinite(float(loss))
        logits, _ = T.forward(cfg, params, toks)
        assert logits.shape == (2, 16, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits)).all()

    @pytest.mark.parametrize("arch", LM_ARCHS)
    def test_prefill_decode(self, arch):
        cfg = get_arch(arch).smoke
        params = T.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        cache = T.make_cache(cfg, 2, 32)
        logits, cache = T.prefill(cfg, params, toks, cache)
        assert logits.shape == (2, cfg.padded_vocab)
        logits2, cache = T.decode_step(cfg, params, cache, toks[:, :1],
                                       jnp.int32(8))
        assert logits2.shape == (2, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits2)).all()

    def test_decode_matches_forward(self):
        """Greedy decode logits == full forward logits at each position
        (KV-cache correctness)."""
        cfg = get_arch("mistral-nemo-12b").smoke
        params = T.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (1, 6), 0, cfg.vocab_size)
        full_logits, _ = T.forward(cfg, params, toks)
        cache = T.make_cache(cfg, 1, 8)
        _, cache = T.prefill(cfg, params, toks[:, :5], cache)
        dec_logits, _ = T.decode_step(cfg, params, cache, toks[:, 5:6],
                                      jnp.int32(5))
        np.testing.assert_allclose(np.asarray(dec_logits),
                                   np.asarray(full_logits[:, 5]),
                                   rtol=2e-3, atol=2e-3)

    def test_gemma2_softcap_bounds(self):
        cfg = get_arch("gemma2-2b").smoke
        params = T.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
        logits, _ = T.forward(cfg, params, toks)
        assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3

    def test_param_counts_match_billing(self):
        """Full configs land near their advertised sizes."""
        expected = {"grok-1-314b": 314e9, "mistral-nemo-12b": 12e9,
                    "gemma2-2b": 2.6e9, "minicpm-2b": 2.7e9,
                    "granite-moe-3b-a800m": 3.3e9}
        for arch, want in expected.items():
            got = get_arch(arch).config.param_count()
            assert abs(got - want) / want < 0.15, (arch, got)


class TestMoE:
    def test_matches_dense_reference(self):
        rng = np.random.default_rng(0)
        g, s, d, e, k, f = 2, 8, 8, 4, 2, 12
        x = jnp.array(rng.normal(0, 1, (g, s, d)), jnp.float32)
        rw = jnp.array(rng.normal(0, 1, (d, e)), jnp.float32)
        wg = jnp.array(rng.normal(0, 0.3, (e, d, f)), jnp.float32)
        wu = jnp.array(rng.normal(0, 0.3, (e, d, f)), jnp.float32)
        wd = jnp.array(rng.normal(0, 0.3, (e, f, d)), jnp.float32)
        dims = L.MoEDims(e, k, L.moe_capacity(s, k, e, 8.0))
        y, aux = L.moe_ffn(x, rw, wg, wu, wd, dims)
        assert float(aux["moe_dropped_frac"]) == 0.0
        probs = jax.nn.softmax(x @ rw, -1)
        gv, gi = jax.lax.top_k(probs, k)
        gv = gv / gv.sum(-1, keepdims=True)
        ref = np.zeros((g, s, d), np.float32)
        xn = np.asarray(x)
        for gg in range(g):
            for ss in range(s):
                for j in range(k):
                    ee = int(gi[gg, ss, j])
                    h = xn[gg, ss] @ np.asarray(wg)[ee]
                    h = h / (1 + np.exp(-h)) * (xn[gg, ss] @ np.asarray(wu)[ee])
                    ref[gg, ss] += float(gv[gg, ss, j]) * (h @ np.asarray(wd)[ee])
        np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4)

    def test_capacity_drops_tokens(self):
        rng = np.random.default_rng(1)
        g, s, d, e, k, f = 1, 32, 8, 4, 2, 8
        x = jnp.array(rng.normal(0, 1, (g, s, d)), jnp.float32)
        rw = jnp.zeros((d, e), jnp.float32)  # uniform router -> argmax=0
        wg = jnp.array(rng.normal(0, 0.3, (e, d, f)), jnp.float32)
        dims = L.MoEDims(e, k, 2)  # tiny capacity
        y, aux = L.moe_ffn(x, rw, wg, wg, jnp.swapaxes(wg, 1, 2), dims)
        assert float(aux["moe_dropped_frac"]) > 0.5

    def test_topk_gates_match_lax(self):
        rng = np.random.default_rng(2)
        probs = jax.nn.softmax(jnp.array(rng.normal(0, 1, (3, 5, 8)),
                                         jnp.float32), -1)
        gv, gi = L._topk_gates(probs, 3)
        rv, ri = jax.lax.top_k(probs, 3)
        np.testing.assert_allclose(np.asarray(gv), np.asarray(rv), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))


class TestGNNSmoke:
    @pytest.mark.parametrize("arch", GNN_ARCHS)
    def test_forward_and_train(self, arch):
        cfg = get_arch(arch).smoke
        g = _toy_graph(cfg.d_in, cfg.d_edge_in)
        params = G.init_params(cfg, KEY)
        out = G.apply(cfg, params, g)
        assert out.shape == (20, cfg.d_out)
        assert np.isfinite(np.asarray(out)).all()
        loss, _ = G.train_loss(cfg, params, g, jnp.zeros((20, cfg.d_out)))
        grads = jax.grad(lambda p: G.train_loss(cfg, p, g,
                                                jnp.zeros((20, cfg.d_out)))[0])(params)
        for leaf in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(leaf)).all()

    @pytest.mark.parametrize("arch", ["egnn", "mace"])
    def test_e3_invariance(self, arch):
        cfg = get_arch(arch).smoke
        g = _toy_graph(cfg.d_in, cfg.d_edge_in)
        params = G.init_params(cfg, KEY)
        th = 0.7
        Q = jnp.array([[np.cos(th), -np.sin(th), 0],
                       [np.sin(th), np.cos(th), 0], [0, 0, 1]], jnp.float32)
        out1 = G.apply(cfg, params, g)
        out2 = G.apply(cfg, params,
                       g._replace(positions=g.positions @ Q.T + 3.0))
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   atol=1e-4)

    def test_egnn_coordinate_equivariance(self):
        cfg = get_arch("egnn").smoke
        g = _toy_graph(cfg.d_in, cfg.d_edge_in)
        params = G.init_params(cfg, KEY)
        th = 1.1
        Q = jnp.array([[np.cos(th), -np.sin(th), 0],
                       [np.sin(th), np.cos(th), 0], [0, 0, 1]], jnp.float32)
        _, x1 = G.egnn_apply(cfg, params, g)
        _, x2 = G.egnn_apply(cfg, params,
                             g._replace(positions=g.positions @ Q.T + 3.0))
        np.testing.assert_allclose(np.asarray(x1 @ Q.T + 3.0), np.asarray(x2),
                                   atol=1e-4)

    def test_masked_edges_do_not_contribute(self):
        cfg = get_arch("gatedgcn").smoke
        g = _toy_graph(cfg.d_in, cfg.d_edge_in)
        params = G.init_params(cfg, KEY)
        out1 = G.apply(cfg, params, g)
        # adding masked-out garbage edges must not change anything
        g2 = g._replace(
            senders=jnp.concatenate([g.senders, jnp.zeros(8, jnp.int32)]),
            receivers=jnp.concatenate([g.receivers, jnp.ones(8, jnp.int32)]),
            edge_feat=jnp.concatenate(
                [g.edge_feat, 99 * jnp.ones((8, g.edge_feat.shape[1]),
                                            jnp.float32)]),
            edge_mask=jnp.concatenate([g.edge_mask, jnp.zeros(8, bool)]),
        )
        out2 = G.apply(cfg, params, g2)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   atol=1e-5)


class TestBSTSmoke:
    def _batch(self, cfg, b=8, seed=0):
        rng = np.random.default_rng(seed)
        f = 4
        return RS.BSTBatch(
            item_ids=jnp.array(rng.integers(0, cfg.n_items, (b, cfg.seq_len)),
                               jnp.int32),
            cat_ids=jnp.array(rng.integers(0, cfg.n_cats, (b, cfg.seq_len)),
                              jnp.int32),
            ctx_ids=jnp.array(rng.integers(0, cfg.n_context, b * f), jnp.int32),
            ctx_segs=jnp.array(np.repeat(np.arange(b), f), jnp.int32),
            labels=jnp.array(rng.integers(0, 2, b), jnp.int32),
        )

    def test_train_and_serve(self):
        cfg = get_arch("bst").smoke
        params = RS.init_params(cfg, KEY)
        batch = self._batch(cfg)
        loss, _ = RS.train_loss(cfg, params, batch)
        assert np.isfinite(float(loss))
        logits = RS.forward(cfg, params, batch)
        assert logits.shape == (8,)

    def test_embedding_bag_matches_loop(self):
        cfg = get_arch("bst").smoke
        rng = np.random.default_rng(0)
        table = jnp.array(rng.normal(0, 1, (50, 8)), jnp.float32)
        ids = jnp.array(rng.integers(0, 50, 12), jnp.int32)
        segs = jnp.array(np.sort(rng.integers(0, 4, 12)), jnp.int32)
        out = RS.embedding_bag(table, ids, segs, 4)
        ref = np.zeros((4, 8), np.float32)
        for i, s in zip(np.asarray(ids), np.asarray(segs)):
            ref[s] += np.asarray(table)[i]
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)

    def test_retrieval_topk_correct(self):
        cfg = get_arch("bst").smoke
        params = RS.init_params(cfg, KEY)
        b = self._batch(cfg, b=1)
        cand = jnp.arange(200, dtype=jnp.int32)
        scores = RS.retrieval_scores(cfg, params, b.item_ids, b.cat_ids,
                                     b.ctx_ids[:4], jnp.zeros(4, jnp.int32),
                                     cand)
        vals, ids = RS.retrieval_topk(cfg, params, b.item_ids, b.cat_ids,
                                      b.ctx_ids[:4], jnp.zeros(4, jnp.int32),
                                      cand, k=5)
        order = np.argsort(-np.asarray(scores))[:5]
        np.testing.assert_array_equal(np.asarray(ids), order)
