"""Quickstart: the paper's running example, end to end.

Builds CPQx over the Fig.-1 social graph, runs the triad query
ff ∩ f⁻ (people and their followers in a 3-cycle), and shows the
class-space pruning that makes it fast — all on the public API.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import index as cindex
from repro.core import interest, oracle
from repro.core.engine import Engine
from repro.core.graph import example_graph
from repro.core.query import parse

NAMES = ["sue", "joe", "zoe", "tim", "ada", "tom", "bob", "kim",
         "amy", "ben", "eva", "max", "blog123", "blog987"]


def main() -> None:
    g = example_graph()
    print(f"graph: {g}")

    # 1. build the CPQ-aware index (k = 2, the paper's default)
    idx = cindex.build(g, k=2)
    l2c, c2p = idx.size_entries()
    print(f"CPQx built: {idx.n_classes} equivalence classes over "
          f"{idx.n_pairs} s-t pairs (|I_l2c|={l2c}, |I_c2p|={c2p})")

    # 2. the paper's query: conjunction of ff and f⁻ (Sec. I)
    q = parse("(f . f) & f-", {"f": 0, "v": 1}, g.n_labels)
    engine = Engine(idx)
    answers = engine.execute(q)
    print(f"\n⟦ff ∩ f⁻⟧ = {[(NAMES[v], NAMES[u]) for v, u in answers]}")

    # 3. why it was fast: the conjunction ran on class ids (Prop. 4.1)
    ff = set(np.asarray(idx.arrays.l2c_cls)[slice(*idx.lookup_range((0, 0)))].tolist())
    fi = set(np.asarray(idx.arrays.l2c_cls)[slice(*idx.lookup_range((2,)))].tolist())
    print(f"lookup(ff) -> classes {sorted(ff)}; lookup(f⁻) -> {sorted(fi)}; "
          f"intersection {sorted(ff & fi)} — one class holds every answer")

    # 4. ground truth check against the denotational semantics
    assert {tuple(r) for r in answers.tolist()} == oracle.cpq_eval(g, q)
    print("matches the CPQ semantics oracle ✓")

    # 5. the interest-aware variant: tiny index, same answers
    ia = interest.build_interest(g, 2, interests=[(0, 0)])
    got = {tuple(r) for r in Engine(ia).execute(q).tolist()}
    print(f"\niaCPQx (interest = {{ff}}): {ia.n_classes} classes "
          f"(vs {idx.n_classes}); same answers: "
          f"{got == oracle.cpq_eval(g, q)}")


if __name__ == "__main__":
    main()
