"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracle in ref.py, swept across shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SENTINEL = np.int32(2**31 - 1)


class TestSortedIntersect:
    @pytest.mark.parametrize("n_hay", [1, 7, 128, 1000])
    @pytest.mark.parametrize("n_q", [1, 64, 1024, 1500])
    def test_shape_sweep(self, n_hay, n_q):
        rng = np.random.default_rng(n_hay * 10_007 + n_q)
        hay = np.sort(rng.choice(5 * n_hay, n_hay, replace=False)).astype(np.int32)
        count = rng.integers(0, n_hay + 1)
        queries = rng.integers(0, 5 * n_hay, n_q).astype(np.int32)
        got = np.asarray(ops.sorted_member_mask(jnp.array(hay), count, jnp.array(queries)))
        exp = np.asarray(ref.sorted_member_mask(jnp.array(hay), count, jnp.array(queries)))
        np.testing.assert_array_equal(got, exp)
        # and vs python ground truth
        gt = np.isin(queries, hay[:count]).astype(np.int32)
        np.testing.assert_array_equal(got, gt)

    def test_sentinel_queries_never_match(self):
        hay = jnp.array([1, 5, 9, SENTINEL], jnp.int32)
        q = jnp.array([5, SENTINEL, 9, SENTINEL], jnp.int32)
        got = np.asarray(ops.sorted_member_mask(hay, 3, q))
        np.testing.assert_array_equal(got, [1, 0, 1, 0])


class TestExpandJoin:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_matches_ref_and_python(self, seed):
        rng = np.random.default_rng(seed)
        n_a, n_b = int(rng.integers(1, 40)), int(rng.integers(1, 40))
        a = rng.integers(0, 6, (n_a, 2)).astype(np.int32)
        b = rng.integers(0, 6, (n_b, 2)).astype(np.int32)
        b = b[np.lexsort((b[:, 1], b[:, 0]))]
        lo = np.searchsorted(b[:, 0], a[:, 1], "left").astype(np.int32)
        hi = np.searchsorted(b[:, 0], a[:, 1], "right").astype(np.int32)
        cnt = hi - lo
        ends = np.cumsum(cnt).astype(np.int32)
        total = int(ends[-1]) if n_a else 0
        cap = max(8, 1 << max(0, (total - 1)).bit_length())
        args = (jnp.array(ends), jnp.array(lo), jnp.array(a[:, 0]),
                jnp.array(b[:, 0]), jnp.array(b[:, 1]), total, cap)
        got = [np.asarray(x) for x in ops.expand_join_gather(*args)]
        exp = [np.asarray(x) for x in ref.expand_join_gather(*args)]
        for g, e in zip(got, exp):
            np.testing.assert_array_equal(g, e)
        # python ground truth of the join projection
        rows = sorted(
            (int(bv), int(bu), int(av))
            for (av, ak) in a
            for (bv, bu) in b
            if bv == ak
        )
        got_rows = sorted(zip(*(g[:total].tolist() for g in got)))
        assert got_rows == rows


class TestFingerprint:
    @pytest.mark.parametrize("n_cols", [1, 2, 4])
    @pytest.mark.parametrize("n", [16, 100, 2048, 4096])
    def test_bit_identical_to_relational(self, n_cols, n):
        from repro.core.relational import fingerprint_rows as core_fp

        rng = np.random.default_rng(n * 31 + n_cols)
        cols = tuple(
            jnp.array(rng.integers(-5, 1000, n), jnp.int32) for _ in range(n_cols)
        )
        g1, g2 = ops.fingerprint_rows(cols, salt=3)
        e1, e2 = core_fp(cols, salt=3)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(e1))
        np.testing.assert_array_equal(np.asarray(g2), np.asarray(e2))


class TestSegmentSoftmax:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("e,d,n", [(512, 1, 16), (1024, 8, 64), (2048, 4, 100)])
    def test_matches_ref(self, dtype, e, d, n):
        rng = np.random.default_rng(e + d + n)
        scores = jnp.array(rng.normal(0, 3, (e, d)), dtype)
        seg = jnp.array(np.sort(rng.integers(0, n, e)), jnp.int32)
        got = np.asarray(ops.segment_softmax(scores, seg, n), np.float32)
        exp = np.asarray(ref.segment_softmax(scores, seg, n), np.float32)
        tol = 1e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(got, exp, rtol=tol, atol=tol)

    def test_normalization_sums_to_one(self):
        rng = np.random.default_rng(0)
        scores = jnp.array(rng.normal(0, 1, (512, 1)), jnp.float32)
        seg = jnp.array(np.sort(rng.integers(0, 10, 512)), jnp.int32)
        out = np.asarray(ops.segment_softmax(scores, seg, 10))
        sums = np.zeros(10)
        np.add.at(sums, np.asarray(seg), out[:, 0])
        present = np.unique(np.asarray(seg))
        np.testing.assert_allclose(sums[present], 1.0, rtol=1e-5)


class TestEngineUsesKernels:
    def test_engine_results_invariant_to_pallas_flag(self, monkeypatch, ex_graph):
        """The engine must produce identical answers with kernels on/off."""
        from repro.core import index as cindex
        from repro.core.engine import Engine
        from repro.core.query import parse

        q = parse("(f . f) & f-", {"f": 0, "v": 1}, 2)
        eng = Engine(cindex.build(ex_graph, 2))
        a = {tuple(r) for r in eng.execute(q).tolist()}
        monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
        b = {tuple(r) for r in eng.execute(q).tolist()}
        assert a == b == {(0, 2), (1, 0), (2, 1)}
