"""Pure numpy/python reference implementation of the whole paper.

This module is the *ground truth* for every device-side component:

* ``enumerate_pairs``          — P^{<=k} with label-sequence sets L^{<=k}(v,u)
* ``cpq_eval``                 — the denotational semantics ⟦q⟧_G (Sec. III-B)
* ``path_partition``           — Algorithm 1 (bottom-up block refinement)
* ``build_index``              — Algorithm 2 (CPQx = I_l2c + I_c2p)
* ``build_interest_index``     — Def. 5.1 (iaCPQx)
* ``query_with_index``         — Algorithms 3-4 (class-granular evaluation)
* ``path_index``/``bfs_eval``  — baselines: language-unaware path index [14], BFS
* ``verify_partition``         — checks the CPQ-correctness invariant of any
                                 candidate partition (used by property tests)

Everything here is deliberately simple (dict/set based) — it is the oracle
the JAX implementation is validated against, and the capacity estimator the
host driver uses to size device buffers.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Iterable, Mapping

import numpy as np

from .graph import LabeledGraph
from .query import CPQ, Conj, Edge, Identity, Join  # AST (host-side, no jax import)

# ---------------------------------------------------------------------- #
# P^{<=k} enumeration
# ---------------------------------------------------------------------- #


def enumerate_pairs(g: LabeledGraph, k: int) -> dict[tuple[int, int], set[tuple[int, ...]]]:
    """Return {(v, u): set of label sequences (length 1..k) realized v->u}.

    Pairs with no path of length in [1, k] do not appear.  Identity pairs
    (v, v) appear only if they lie on a cycle of length <= k (matching the
    index: identity itself is synthesized by the evaluator)."""
    # seqs[j] : {(v,u): set of length-j sequences}
    by_pair: dict[tuple[int, int], set[tuple[int, ...]]] = defaultdict(set)
    # frontier: list of (v, u, seq) of length j
    cur: dict[tuple[int, int], set[tuple[int, ...]]] = defaultdict(set)
    for s, d, l in zip(g.src, g.dst, g.lbl):
        cur[(int(s), int(d))].add((int(l),))
    out_edges: dict[int, list[tuple[int, int]]] = defaultdict(list)
    for s, d, l in zip(g.src, g.dst, g.lbl):
        out_edges[int(s)].append((int(d), int(l)))
    for j in range(1, k + 1):
        for p, seqs in cur.items():
            by_pair[p] |= seqs
        if j == k:
            break
        nxt: dict[tuple[int, int], set[tuple[int, ...]]] = defaultdict(set)
        for (v, u), seqs in cur.items():
            for (w, l) in out_edges[u]:
                for sq in seqs:
                    nxt[(v, w)].add(sq + (l,))
        cur = nxt
    return dict(by_pair)


# ---------------------------------------------------------------------- #
# CPQ semantics — the ground truth evaluator (paper Sec. III-B)
# ---------------------------------------------------------------------- #


def cpq_eval(g: LabeledGraph, q: CPQ) -> set[tuple[int, int]]:
    if isinstance(q, Identity):
        return {(v, v) for v in range(g.n_vertices)}
    if isinstance(q, Edge):
        return {(int(s), int(d)) for s, d, l in zip(g.src, g.dst, g.lbl) if int(l) == q.label}
    if isinstance(q, Join):
        left = cpq_eval(g, q.lhs)
        right = cpq_eval(g, q.rhs)
        by_src: dict[int, list[int]] = defaultdict(list)
        for x, y in right:
            by_src[x].append(y)
        return {(v, y) for (v, u) in left for y in by_src.get(u, ())}
    if isinstance(q, Conj):
        return cpq_eval(g, q.lhs) & cpq_eval(g, q.rhs)
    raise TypeError(f"not a CPQ node: {q!r}")


# ---------------------------------------------------------------------- #
# Algorithm 1 — bottom-up path partition (k-path-bisimulation, index form)
# ---------------------------------------------------------------------- #


@dataclasses.dataclass
class Partition:
    """Result of CPQPATHPARTITION: per-pair block-id signature + class ids.

    pairs      : list[(v, u)] sorted
    signatures : {pair: tuple of k block ids (None where no length-i path)}
    cyclic     : {pair: bool}
    class_of   : {pair: class id}  (dense ints, 0..n_classes-1)
    classes    : {class id: sorted list of pairs}
    """

    k: int
    pairs: list
    signatures: dict
    cyclic: dict
    class_of: dict
    classes: dict


def path_partition(g: LabeledGraph, k: int) -> Partition:
    """Bottom-up block refinement per Algorithm 1.

    b_1 partitions pairs with >=1 edge by their *set* of edge labels (and
    cycle flag).  b_i partitions pairs with >=1 length-i path by the *set*
    of (b_{i-1}(v,m), b_1(m,u)) over intermediates m (and cycle flag).
    Class id = dense id of (cyclic, <b_1..b_k>) signature.
    """
    # S^1: pair -> frozenset of labels
    s1: dict[tuple[int, int], set[int]] = defaultdict(set)
    for s, d, l in zip(g.src, g.dst, g.lbl):
        s1[(int(s), int(d))].add(int(l))
    b: list[dict[tuple[int, int], int]] = []  # b[i-1] : pair -> block id at level i
    b1 = _dense_ids({p: (p[0] == p[1], frozenset(v)) for p, v in s1.items()})
    b.append(b1)

    # group S^1 by source for the join;  edges from m:  (m, u) in s1
    prev = b1
    for i in range(2, k + 1):
        si: dict[tuple[int, int], set[tuple[int, int]]] = defaultdict(set)
        # join pairs (v, m) at level i-1 with edges (m, u) at level 1
        edges_by_src: dict[int, list[tuple[int, int]]] = defaultdict(list)
        for (m, u), blk in b1.items():
            edges_by_src[m].append((u, blk))
        for (v, m), blk_prev in prev.items():
            for (u, blk_edge) in edges_by_src[m]:
                si[(v, u)].add((blk_prev, blk_edge))
        bi = _dense_ids({p: (p[0] == p[1], frozenset(v)) for p, v in si.items()})
        b.append(bi)
        prev = bi

    all_pairs = sorted(set().union(*[set(bi) for bi in b]) if b else set())
    signatures = {
        p: tuple(bi.get(p) for bi in b) for p in all_pairs
    }
    cyclic = {p: p[0] == p[1] for p in all_pairs}
    class_of = _dense_ids({p: (cyclic[p], signatures[p]) for p in all_pairs})
    classes: dict[int, list] = defaultdict(list)
    for p in all_pairs:
        classes[class_of[p]].append(p)
    for c in classes:
        classes[c].sort()
    return Partition(k, all_pairs, signatures, cyclic, class_of, dict(classes))


def _dense_ids(keyed: Mapping) -> dict:
    """Assign dense ids (by sorted key order, deterministic) to equal values."""
    uniq = sorted(set(keyed.values()), key=repr)
    rank = {v: i for i, v in enumerate(uniq)}
    return {p: rank[v] for p, v in keyed.items()}


# ---------------------------------------------------------------------- #
# Interest-aware partition (Def. 5.1)
# ---------------------------------------------------------------------- #


def interest_partition(
    g: LabeledGraph, k: int, interests: Iterable[tuple[int, ...]]
) -> Partition:
    """Partition pairs by (cycle flag, L^{<=k}(v,u) ∩ L_q).

    L_q always includes every length-1 sequence (all closure labels), per
    Sec. V-A, so arbitrary CPQs remain evaluable.  Pairs realizing no
    sequence of L_q are dropped from the index (they can still be reached
    by query-time splitting)."""
    lq: set[tuple[int, ...]] = {(l,) for l in range(g.alphabet_size)}
    lq |= {tuple(s) for s in interests}
    if any(len(s) > k or len(s) == 0 for s in lq):
        raise ValueError("interest sequences must have length in [1, k]")
    seqs = enumerate_pairs(g, k)
    keyed = {}
    for p, ss in seqs.items():
        hit = frozenset(s for s in ss if s in lq)
        if hit:
            keyed[p] = (p[0] == p[1], hit)
    class_of = _dense_ids(keyed)
    pairs = sorted(keyed)
    classes: dict[int, list] = defaultdict(list)
    for p in pairs:
        classes[class_of[p]].append(p)
    for c in classes:
        classes[c].sort()
    signatures = {p: keyed[p][1] for p in pairs}
    return Partition(k, pairs, signatures, {p: p[0] == p[1] for p in pairs},
                     class_of, dict(classes))


# ---------------------------------------------------------------------- #
# Algorithm 2 — index construction
# ---------------------------------------------------------------------- #


@dataclasses.dataclass
class Index:
    """CPQx / iaCPQx (host form).

    l2c : {label sequence tuple: sorted list of class ids}
    c2p : {class id: sorted list of (v, u)}
    cyclic : {class id: bool}   (classes are cycle-pure by construction)
    k, interests (None for full CPQx)
    """

    k: int
    l2c: dict
    c2p: dict
    cyclic: dict
    interests: frozenset | None = None

    @property
    def n_classes(self) -> int:
        return len(self.c2p)

    def size_entries(self) -> tuple[int, int]:
        """(|I_l2c| entries, |I_c2p| entries) — the paper's size measure."""
        return (sum(len(v) for v in self.l2c.values()),
                sum(len(v) for v in self.c2p.values()))


def build_index(g: LabeledGraph, k: int) -> Index:
    part = path_partition(g, k)
    seqs = enumerate_pairs(g, k)
    return _index_from_partition(part, seqs, k, None)


def build_interest_index(
    g: LabeledGraph, k: int, interests: Iterable[tuple[int, ...]]
) -> Index:
    lq: set[tuple[int, ...]] = {(l,) for l in range(g.alphabet_size)}
    lq |= {tuple(s) for s in interests}
    part = interest_partition(g, k, interests)
    seqs = enumerate_pairs(g, k)
    # keep only interest sequences in l2c
    seqs = {p: {s for s in ss if s in lq} for p, ss in seqs.items()}
    return _index_from_partition(part, seqs, k, frozenset(lq))


def _index_from_partition(part: Partition, seqs, k: int, interests) -> Index:
    l2c: dict[tuple[int, ...], set[int]] = defaultdict(set)
    for p, c in part.class_of.items():
        for s in seqs.get(p, ()):
            l2c[s].add(c)
    return Index(
        k=k,
        l2c={s: sorted(cs) for s, cs in l2c.items()},
        c2p={c: list(ps) for c, ps in part.classes.items()},
        cyclic={c: part.cyclic[ps[0]] for c, ps in part.classes.items()},
        interests=interests,
    )


# ---------------------------------------------------------------------- #
# Algorithms 3-4 — query processing with the index
# ---------------------------------------------------------------------- #


def _lookup(index: Index, seq: tuple[int, ...]) -> set[int]:
    return set(index.l2c.get(tuple(seq), ()))


def _materialize(index: Index, classes: Iterable[int]) -> set[tuple[int, int]]:
    out: set[tuple[int, int]] = set()
    for c in classes:
        out.update(index.c2p[c])
    return out


def split_sequence(seq: tuple[int, ...], k: int,
                   available: set[tuple[int, ...]] | None = None) -> list[tuple[int, ...]]:
    """Split a label sequence into sub-sequences of length <= k that are
    present in the index (greedy longest-prefix; Sec. IV-D / Sec. V-B)."""
    out, i = [], 0
    n = len(seq)
    while i < n:
        step = min(k, n - i)
        while step > 1:
            cand = seq[i: i + step]
            if available is None or cand in available:
                break
            step -= 1
        out.append(seq[i: i + step])
        i += step
    return out


def query_with_index(
    g: LabeledGraph, index: Index, q: CPQ
) -> set[tuple[int, int]]:
    """Two-stage evaluation: class-granular where possible (Prop. 4.1),
    pair-granular after any JOIN.  Returns the exact ⟦q⟧_G."""
    from .query import plan_query  # local import to avoid cycle at module load

    plan = plan_query(q, index.k, available=set(index.l2c) if index.interests else None)
    pairs, classes = _eval_plan(g, index, plan)
    if classes is not None:
        pairs = _materialize(index, classes)
    return pairs


def _eval_plan(g, index, node):
    """Returns (pairs | None, classes | None) — exactly one is non-None."""
    kind = node[0]
    if kind == "lookup":
        segs = node[1]  # list of label sequences, each length <= k
        # single segment: stay in class space
        cls = _lookup(index, segs[0])
        if len(segs) == 1:
            return None, cls
        pairs = _materialize(index, cls)
        for seg in segs[1:]:
            nxt = _materialize(index, _lookup(index, seg))
            pairs = _join_pairs(pairs, nxt)
        return pairs, None
    if kind == "identity":
        # bare `id` query
        return {(v, v) for v in range(g.n_vertices)}, None
    if kind == "conj_id":  # q ∩ id — cycle-pure classes make this a flag check
        inner = _eval_plan(g, index, node[1])
        if inner[1] is not None:
            return None, {c for c in inner[1] if index.cyclic[c]}
        return {p for p in inner[0] if p[0] == p[1]}, None
    left = _eval_plan(g, index, node[1])
    right = _eval_plan(g, index, node[2])
    if kind == "join":
        lp = left[0] if left[0] is not None else _materialize(index, left[1])
        rp = right[0] if right[0] is not None else _materialize(index, right[1])
        return _join_pairs(lp, rp), None
    if kind == "conj":
        if left[1] is not None and right[1] is not None:
            return None, left[1] & right[1]  # Prop. 4.1 — class intersection
        lp = left[0] if left[0] is not None else _materialize(index, left[1])
        rp = right[0] if right[0] is not None else _materialize(index, right[1])
        return lp & rp, None
    raise ValueError(f"bad plan node {kind}")


def _join_pairs(lp, rp):
    by_src = defaultdict(list)
    for x, y in rp:
        by_src[x].append(y)
    return {(v, y) for (v, u) in lp for y in by_src.get(u, ())}


# ---------------------------------------------------------------------- #
# Baseline 1 — language-unaware path index [14] (inverted index
# label sequence -> s-t pairs), with the same two-stage evaluator but no
# class space: every operator works on pairs.
# ---------------------------------------------------------------------- #


@dataclasses.dataclass
class PathIndex:
    k: int
    l2p: dict  # {seq: sorted list of pairs}
    interests: frozenset | None = None

    def size_entries(self) -> int:
        return sum(len(v) for v in self.l2p.values())


def build_path_index(g: LabeledGraph, k: int,
                     interests: Iterable[tuple[int, ...]] | None = None) -> PathIndex:
    seqs = enumerate_pairs(g, k)
    lq = None
    if interests is not None:
        lq = {(l,) for l in range(g.alphabet_size)} | {tuple(s) for s in interests}
    l2p: dict[tuple[int, ...], list] = defaultdict(list)
    for p, ss in seqs.items():
        for s in ss:
            if lq is None or s in lq:
                l2p[s].append(p)
    for s in l2p:
        l2p[s].sort()
    return PathIndex(k=k, l2p=dict(l2p),
                     interests=frozenset(lq) if lq is not None else None)


def query_with_path_index(g: LabeledGraph, pindex: PathIndex, q: CPQ) -> set:
    from .query import plan_query

    plan = plan_query(q, pindex.k,
                      available=set(pindex.l2p) if pindex.interests else None)

    def ev(node):
        kind = node[0]
        if kind == "lookup":
            pairs = set(pindex.l2p.get(tuple(node[1][0]), ()))
            for seg in node[1][1:]:
                pairs = _join_pairs(pairs, set(pindex.l2p.get(tuple(seg), ())))
            return pairs
        if kind == "identity":
            return {(v, v) for v in range(g.n_vertices)}
        if kind == "conj_id":
            return {p for p in ev(node[1]) if p[0] == p[1]}
        l, r = ev(node[1]), ev(node[2])
        if kind == "join":
            return _join_pairs(l, r)
        if kind == "conj":
            return l & r
        raise ValueError(kind)

    return ev(plan)


# ---------------------------------------------------------------------- #
# Baseline 2 — index-free BFS evaluation (semantics-directed, no index)
# ---------------------------------------------------------------------- #


def bfs_eval(g: LabeledGraph, q: CPQ) -> set[tuple[int, int]]:
    """Same as cpq_eval — named separately as the paper's BFS baseline;
    walks the graph with no precomputation."""
    return cpq_eval(g, q)


# ---------------------------------------------------------------------- #
# Invariant checking — used by hypothesis property tests
# ---------------------------------------------------------------------- #


def verify_partition(g: LabeledGraph, k: int, part: Partition) -> bool:
    """A partition is CPQ-correct iff every class is (a) cycle-pure and
    (b) label-sequence-set pure: all pairs realize the same L^{<=k} set.
    (Refinement of this partition is what all query-time pruning needs.)"""
    seqs = enumerate_pairs(g, k)
    for c, ps in part.classes.items():
        sig0 = frozenset(seqs.get(ps[0], frozenset()))
        cyc0 = ps[0][0] == ps[0][1]
        for p in ps[1:]:
            if frozenset(seqs.get(p, frozenset())) != sig0:
                return False
            if (p[0] == p[1]) != cyc0:
                return False
    return True


def random_cpq(rng: np.random.Generator, g: LabeledGraph, max_depth: int = 3) -> CPQ:
    """Random CPQ generator for property tests."""
    if max_depth == 0 or rng.random() < 0.35:
        if rng.random() < 0.08:
            return Identity()
        return Edge(int(rng.integers(0, g.alphabet_size)))
    l = random_cpq(rng, g, max_depth - 1)
    r = random_cpq(rng, g, max_depth - 1)
    if rng.random() < 0.5:
        return Join(l, r)
    return Conj(l, r)


# ---------------------------------------------------------------------- #
# RPQ reference evaluator — Thompson NFA product (ground truth for
# core.rpq's Glushkov/fixpoint path, exactly like cpq_eval gates CPQ).
#
# Deliberately a DIFFERENT construction and evaluation strategy from the
# engine: ε-transitions (Thompson) instead of a position automaton, and
# single-edge product-graph BFS per source instead of a semi-naive
# fixpoint of k-truncated per-sequence lookups — a shared bug would have
# to live in two unrelated algorithms to survive the differential gate.
# ---------------------------------------------------------------------- #


@dataclasses.dataclass
class _ThompsonNFA:
    """ε-NFA: ``eps[s]`` = ε-successors, ``trans[s]`` = {label: set of
    successors}; one start, one accept state."""

    eps: list
    trans: list
    start: int
    accept: int


def _thompson_nfa(q, n_labels: int) -> _ThompsonNFA:
    from .rpq import RAlt, RConcat, RInv, ROpt, RPlus, RStar, RSym

    eps: list[set] = []
    trans: list[dict] = []

    def new_state() -> int:
        eps.append(set())
        trans.append({})
        return len(eps) - 1

    def inv_push(node, flip: bool):
        """Independent inverse push-down: a flipped subtree reverses
        concatenation order and maps each label through the closure
        involution l <-> l + n_labels."""
        if isinstance(node, RSym):
            lbl = (node.label + n_labels) % (2 * n_labels) if flip \
                else node.label
            return RSym(int(lbl))
        if isinstance(node, RInv):
            return inv_push(node.inner, not flip)
        if isinstance(node, RConcat):
            l, r = inv_push(node.lhs, flip), inv_push(node.rhs, flip)
            return RConcat(r, l) if flip else RConcat(l, r)
        if isinstance(node, RAlt):
            return RAlt(inv_push(node.lhs, flip), inv_push(node.rhs, flip))
        if isinstance(node, (RStar, RPlus, ROpt)):
            return type(node)(inv_push(node.inner, flip))
        raise TypeError(f"not an RPQ node: {node!r}")

    def frag(node) -> tuple[int, int]:
        if isinstance(node, RSym):
            s, a = new_state(), new_state()
            trans[s].setdefault(int(node.label), set()).add(a)
            return s, a
        if isinstance(node, RConcat):
            s1, a1 = frag(node.lhs)
            s2, a2 = frag(node.rhs)
            eps[a1].add(s2)
            return s1, a2
        if isinstance(node, RAlt):
            s, a = new_state(), new_state()
            for side in (node.lhs, node.rhs):
                si, ai = frag(side)
                eps[s].add(si)
                eps[ai].add(a)
            return s, a
        if isinstance(node, RStar):
            s, a = new_state(), new_state()
            si, ai = frag(node.inner)
            eps[s] |= {si, a}
            eps[ai] |= {si, a}
            return s, a
        if isinstance(node, RPlus):
            si, ai = frag(node.inner)
            eps[ai].add(si)
            return si, ai
        if isinstance(node, ROpt):
            s, a = new_state(), new_state()
            si, ai = frag(node.inner)
            eps[s] |= {si, a}
            eps[ai].add(a)
            return s, a
        raise TypeError(f"not a normalized RPQ node: {node!r}")

    start, accept = frag(inv_push(q, False))
    return _ThompsonNFA(eps=eps, trans=trans, start=start, accept=accept)


def rpq_eval(g: LabeledGraph, q, srcs=None, dsts=None) -> set[tuple[int, int]]:
    """⟦q⟧_G for an RPQ ``q`` (:mod:`repro.core.rpq` AST): all (v, u)
    with a path v→u whose label sequence the expression accepts (ε
    accepted ⇒ the identity pairs, matching ``cpq_eval(Identity)``).
    ``srcs``/``dsts`` restrict the endpoints (the Cypher pins)."""
    nfa = _thompson_nfa(q, g.n_labels)
    out_edges: dict[int, list] = defaultdict(list)
    for s, d, l in zip(g.src, g.dst, g.lbl):
        out_edges[int(s)].append((int(d), int(l)))
    seeds = range(g.n_vertices) if srcs is None else sorted(set(srcs))
    results: set[tuple[int, int]] = set()
    for v in seeds:
        seen = set()
        stack = [(v, nfa.start)]
        while stack:
            u, s = stack.pop()
            if (u, s) in seen:
                continue
            seen.add((u, s))
            for t in nfa.eps[s]:
                stack.append((u, t))
            for (w, l) in out_edges[u]:
                for t in nfa.trans[s].get(l, ()):
                    stack.append((w, t))
        for (u, s) in seen:
            if s == nfa.accept:
                results.add((v, u))
    if dsts is not None:
        pins = set(dsts)
        results = {(v, u) for v, u in results if u in pins}
    return results


def random_rpq(rng: np.random.Generator, g: LabeledGraph,
               max_depth: int = 3):
    """Random RPQ generator for property tests (star/plus/optional kept
    shallow — macro-edge fan-out is exponential in nesting)."""
    from .rpq import RAlt, RConcat, RInv, ROpt, RPlus, RStar, RSym

    if max_depth == 0 or rng.random() < 0.3:
        return RSym(int(rng.integers(0, g.alphabet_size)))
    r = rng.random()
    if r < 0.30:
        return RConcat(random_rpq(rng, g, max_depth - 1),
                       random_rpq(rng, g, max_depth - 1))
    if r < 0.50:
        return RAlt(random_rpq(rng, g, max_depth - 1),
                    random_rpq(rng, g, max_depth - 1))
    if r < 0.65:
        return RStar(random_rpq(rng, g, max_depth - 1))
    if r < 0.75:
        return RPlus(random_rpq(rng, g, max_depth - 1))
    if r < 0.87:
        return ROpt(random_rpq(rng, g, max_depth - 1))
    return RInv(random_rpq(rng, g, max_depth - 1))
