"""Sharded, atomic, async checkpointing with elastic restore.

Layout::

    <dir>/step_000123/
        manifest.json        tree structure + leaf metadata + status
        leaf_00000.npy ...   one file per pytree leaf (host-gathered here;
                             on a real multi-host pod each host writes its
                             own shard files — the manifest records which)
    <dir>/LATEST             committed step pointer (atomic rename)

Guarantees:
  * atomic commit: data written to ``step_X.tmp`` then renamed, LATEST
    updated last — a crash mid-write can never corrupt a committed step;
  * async: writes happen on a daemon thread; ``wait_for_writes`` joins
    (the train loop calls it before exit);
  * elastic restore: leaves are loaded on host and ``jax.device_put`` to
    ANY target sharding — restarting on a different mesh shape (scale up
    or down) just works; no resharding pass needed.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_PENDING: list = []
_LOCK = threading.Lock()


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


# numpy can't round-trip ml_dtypes (bfloat16, fp8) through npy files —
# store them as raw uint views with the true dtype in the manifest.
_RAW_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _to_native(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _RAW_VIEW:
        return arr.view(_RAW_VIEW[name]), name
    return arr, name


def _from_native(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _RAW_VIEW:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr.astype(dtype_name)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    async_write: bool = False) -> str:
    """Write one checkpoint; returns the committed directory path."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(l) for l in leaves]

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (p, arr) in enumerate(zip(paths, host_leaves)):
            fname = f"leaf_{i:05d}.npy"
            raw, dtype_name = _to_native(arr)
            np.save(os.path.join(tmp, fname), raw)
            manifest["leaves"].append(
                {"path": p, "file": fname, "shape": list(arr.shape),
                 "dtype": dtype_name})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
        return final

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        with _LOCK:
            _PENDING.append(t)
        t.start()
        return os.path.join(ckpt_dir, f"step_{step:09d}")
    return _write()


def wait_for_writes():
    with _LOCK:
        pending = list(_PENDING)
        _PENDING.clear()
    for t in pending:
        t.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def load_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Load into the structure of ``like`` (host numpy leaves)."""
    wait_for_writes()
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for p, leaf in zip(paths, leaves):
        e = by_path[p]
        arr = _from_native(np.load(os.path.join(d, e["file"])), e["dtype"])
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {p}: checkpoint shape {arr.shape} != model {want}")
        out.append(arr.astype(leaf.dtype) if str(arr.dtype) != str(leaf.dtype)
                   else arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_sharded(ckpt_dir: str, step: int, like: Any,
                    shardings: Any = None) -> Any:
    """Elastic restore: host leaves -> device_put with target shardings
    (any mesh shape — scale-up/down restart)."""
    host = load_checkpoint(ckpt_dir, step, like)
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, host)
    return jax.tree.map(
        lambda arr, s: jax.device_put(arr, s), host, shardings)
