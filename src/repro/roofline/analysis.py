"""§Roofline aggregation: read the dry-run JSON records and emit the
per-(arch x shape x mesh) three-term roofline table, dominant-bottleneck
calls, and MODEL_FLOPS/HLO_FLOPS usefulness ratios.

    PYTHONPATH=src python -m repro.roofline.analysis [--dir experiments/dryrun]
        [--markdown experiments/roofline.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from .collect import HBM_BW, ICI_BW, PEAK_FLOPS, roofline_terms


def model_flops(rec: dict) -> float | None:
    """Analytic MODEL_FLOPS per step: 6·N·D (dense) / 6·N_active·D (MoE)
    for LM training; 2·N·D for prefill; 2·N_active·B for decode; GNN/BST:
    2 x parameter-matmul flops x items."""
    from repro.configs import get_arch

    try:
        spec = get_arch(rec["arch"])
    except Exception:  # noqa: BLE001
        return None
    dims = rec.get("dims", {})
    if spec.family == "lm":
        cfg = spec.config
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
        attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
            + cfg.n_heads * hd * d
        if cfg.is_moe:
            ffn = cfg.top_k * 3 * d * f
        else:
            ffn = 3 * d * f
        n_active = cfg.n_layers * (attn + ffn) + cfg.vocab_size * d
        if rec["kind"] == "train":
            tokens = dims["global_batch"] * dims["seq_len"]
            return 6.0 * n_active * tokens
        if rec["kind"] == "prefill":
            tokens = dims["global_batch"] * dims["seq_len"]
            return 2.0 * n_active * tokens
        if rec["kind"] == "decode":
            # one token per sequence + attention over the cache
            b, t = dims["global_batch"], dims["seq_len"]
            attn_cache = (cfg.n_layers * 2 * 2 * t
                          * cfg.n_kv_heads * hd)
            return b * (2.0 * n_active + attn_cache)
    if spec.family == "gnn":
        cfg = spec.config
        dd = cfg.d_hidden
        if "pad_nodes" in dims:  # sampled shape: the subgraph, not the graph
            n, e = dims["pad_nodes"], dims["pad_edges"]
        else:
            n, e = dims.get("n_nodes", 0), dims.get("n_edges", 0)
        if rec["shape"] == "molecule":
            n *= dims.get("batch", 1)
            e *= dims.get("batch", 1)
        # per-arch dominant matmul costs (fwd), x3 for train (fwd+bwd)
        per_edge = {"gatedgcn": 6 * dd * dd, "egnn": 8 * dd * dd,
                    "graphcast": 8 * dd * dd,
                    "mace": 2 * (3 * cfg.n_rbf * dd + 15 * dd)}[cfg.arch]
        per_node = {"gatedgcn": 4 * dd * dd, "egnn": 6 * dd * dd,
                    "graphcast": 6 * dd * dd, "mace": 20 * dd * dd}[cfg.arch]
        return 3.0 * 2.0 * cfg.n_layers * (e * per_edge + n * per_node) / 2
    if spec.family == "recsys":
        cfg = spec.config
        b = dims.get("batch", 1)
        dm = 2 * cfg.embed_dim
        blk = cfg.seq_len * (4 * dm * dm + 2 * dm * cfg.d_ff) \
            + 2 * cfg.seq_len * cfg.seq_len * dm
        mlp_in = cfg.seq_len * dm + cfg.embed_dim
        dims_mlp = (mlp_in,) + cfg.mlp_dims + (1,)
        mlp = sum(dims_mlp[i] * dims_mlp[i + 1] for i in range(len(dims_mlp) - 1))
        fwd = 2.0 * b * (blk + mlp)
        return 3.0 * fwd if rec["kind"] == "train" else fwd
    return None


def load_records(d: str) -> list:
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list) -> list:
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "SKIP",
                         "note": r.get("skip_reason", "")[:60]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "FAIL",
                         "note": r.get("error", "")[:60]})
            continue
        mf = model_flops(r)
        t = roofline_terms(r, model_flops=mf)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "hbm_gb": r.get("per_device_hbm_gb"),
            "t_compute": t["t_compute_s"], "t_memory": t["t_memory_s"],
            "t_coll": t["t_collective_s"], "dominant": t["dominant"],
            "bound_s": t["bound_s"],
            "useful_frac": t.get("useful_flop_frac"),
            "coll_gb": r.get("collectives", {}).get("total_bytes", 0) / 2**30,
        })
    return rows


def to_markdown(rows: list) -> str:
    out = ["| arch | shape | mesh | GB/dev | t_comp (s) | t_mem (s) | "
           "t_coll (s) | dominant | useful FLOP frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']}: {r['note']} | | | | | |")
            continue
        uf = f"{r['useful_frac']:.2f}" if r.get("useful_frac") else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['hbm_gb']} | "
            f"{r['t_compute']:.4f} | {r['t_memory']:.4f} | "
            f"{r['t_coll']:.4f} | **{r['dominant']}** | {uf} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", default=None)
    args = ap.parse_args()
    rows = table(load_records(args.dir))
    md = to_markdown(rows)
    print(md)
    ok = [r for r in rows if r["status"] == "ok"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\n{len(ok)} ok cells; dominant terms: {doms}")
    print(f"constants: {PEAK_FLOPS/1e12:.0f} TF/s bf16, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {ICI_BW/1e9:.0f} GB/s ICI")
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
