"""Decoder-only LM supporting all five assigned architectures:

  grok-1-314b        MoE 8e top-2, GQA kv=8
  granite-moe-3b     MoE 40e top-8, tiny per-expert FFN
  gemma2-2b          dense, alternating local/global attention, softcaps,
                     GeGLU, sandwich norms, gemma-style RMSNorm
  minicpm-2b         dense llama-like with muP-style embed/residual scaling
  mistral-nemo-12b   dense, head_dim 128 != d_model/n_heads, 128k rope

One config dataclass drives everything; layers are stacked and scanned so
the 512-device dry-run compiles in seconds, not hours.

Entry points:
  init_params(cfg, key)                     parameter pytree
  train_loss(cfg, params, tokens, labels)   next-token CE loss (f32)
  prefill(cfg, params, tokens)              logits + KV cache
  decode_step(cfg, params, cache, tok, pos) one-token serve step
  abstract_params(cfg)                      ShapeDtypeStruct tree (dry-run)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention flavor
    attn_pattern: tuple = ("global",)  # cycled over layers
    window: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    # norm / activation / scaling
    activation: str = "silu"
    gemma_norms: bool = False  # (1+w) RMSNorm + sandwich (post) norms
    embed_scale: Optional[float] = None  # e.g. sqrt(d_model) (gemma), 12 (minicpm)
    residual_scale: Optional[float] = None  # minicpm depth scaling
    logit_scale: Optional[float] = None  # minicpm: d_model/dim_model_base
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    max_seq_len: int = 131_072
    # vocab padded for TP divisibility (logical vocab_size preserved)
    vocab_pad_to: int = 256
    # memory policy: remat the layer scan (training); q-chunked attention
    # for sequences >= 2*q_chunk (long prefill) — 0 disables
    remat: bool = False
    q_chunk: int = 0
    # context-parallel attention hints (set by the launcher when n_heads
    # does not divide the TP axis — otherwise attention math replicates
    # over "model", measured 16x redundant traffic on minicpm/gemma2):
    # full path shards the QUERY seq dim; chunked path shards the KV time
    # dim.  Empty tuples disable.
    attn_batch_axes: tuple = ()
    attn_seq_axes: tuple = ()
    moe_c_axes: tuple = ()  # MoE expert-buffer capacity-dim sharding (TP)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kind(self, i: int) -> str:
        return self.attn_pattern[i % len(self.attn_pattern)]

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Exact parameter count (excluding vocab padding)."""
        d, h, kv, hd, f, v = (self.d_model, self.n_heads, self.n_kv_heads,
                              self.head_dim, self.d_ff, self.vocab_size)
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            ffn = 3 * d * f
        norms = 2 * d + (2 * d if self.gemma_norms else 0)
        per_layer = attn + ffn + norms
        head = 0 if self.tie_embeddings else d * v
        return self.n_layers * per_layer + v * d + d + head


# ---------------------------------------------------------------------- #
# parameters
# ---------------------------------------------------------------------- #


def init_params(cfg: LMConfig, key: jax.Array) -> dict:
    dt = cfg.dtype
    d, h, kv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                       cfg.d_ff)
    nl, v = cfg.n_layers, cfg.padded_vocab
    ks = jax.random.split(key, 16)
    depth_scale = 1.0 / np.sqrt(2 * nl)

    def stack(k, shape, scale=1.0):
        return L.normal_init(k, (nl,) + shape, dt, scale)

    layer = {
        "attn_norm": jnp.ones((nl, d), dt) * (0.0 if cfg.gemma_norms else 1.0),
        "wq": stack(ks[0], (d, h * hd)),
        "wk": stack(ks[1], (d, kv * hd)),
        "wv": stack(ks[2], (d, kv * hd)),
        "wo": stack(ks[3], (h * hd, d), depth_scale),
        "mlp_norm": jnp.ones((nl, d), dt) * (0.0 if cfg.gemma_norms else 1.0),
    }
    if cfg.gemma_norms:
        layer["post_attn_norm"] = jnp.zeros((nl, d), dt)
        layer["post_mlp_norm"] = jnp.zeros((nl, d), dt)
    if cfg.is_moe:
        e = cfg.n_experts
        layer["router"] = stack(ks[4], (d, e))
        layer["w_gate"] = stack(ks[5], (e, d, f))
        layer["w_up"] = stack(ks[6], (e, d, f))
        layer["w_down"] = stack(ks[7], (e, f, d), depth_scale)
    else:
        layer["w_gate"] = stack(ks[5], (d, f))
        layer["w_up"] = stack(ks[6], (d, f))
        layer["w_down"] = stack(ks[7], (f, d), depth_scale)

    params = {
        "embed": L.normal_init(ks[8], (v, d), dt),
        "final_norm": jnp.ones((d,), dt) * (0.0 if cfg.gemma_norms else 1.0),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.normal_init(ks[9], (d, v), dt)
    return params


def abstract_params(cfg: LMConfig) -> dict:
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------- #
# forward
# ---------------------------------------------------------------------- #


def _norm(x, w, cfg):
    return L.rms_norm(x, w, cfg.norm_eps, gemma_style=cfg.gemma_norms)


def _layer_masks(cfg: LMConfig, s_q: int, s_kv: int, q_offset: int = 0):
    """One mask per attention kind used by the pattern."""
    kinds = sorted(set(cfg.attn_pattern))
    masks = {}
    for kd in kinds:
        win = cfg.window if kd == "local" else None
        masks[kd] = L.causal_mask(s_q, s_kv, window=win, q_offset=q_offset)
    return masks


def _block(cfg: LMConfig, x, lp, kind_code, masks, cos, sin, positions,
           cache_kv=None, cache_pos=None):
    """One transformer block.  ``kind_code``: 0 global / 1 local (traced
    scalar from the scanned layer index).  Returns (x, new_cache_kv)."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype

    a_in = _norm(x, lp["attn_norm"], cfg)
    q = (a_in @ lp["wq"]).reshape(b, s, h, hd)
    kk = (a_in @ lp["wk"]).reshape(b, s, kv, hd)
    vv = (a_in @ lp["wv"]).reshape(b, s, kv, hd)
    q = L.apply_rope(q, cos, sin, positions)
    kk = L.apply_rope(kk, cos, sin, positions)

    if cache_kv is not None:
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, kk.astype(ck.dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, vv.astype(cv.dtype),
                                          (0, cache_pos, 0, 0))
        k_att, v_att = ck, cv
        new_cache = (ck, cv)
    else:
        k_att, v_att = kk, vv
        new_cache = None

    t_kv = k_att.shape[1]
    from jax.sharding import PartitionSpec as _P

    if cfg.q_chunk and s >= 2 * cfg.q_chunk:
        # long-sequence path: never materialize the (S, T) mask or logits
        if cfg.attn_seq_axes:
            # context parallelism: shard the KV time dim over TP so the
            # per-chunk logits shard instead of replicating
            kv_spec = _P(cfg.attn_batch_axes or None, cfg.attn_seq_axes,
                         None, None)
            k_att = jax.lax.with_sharding_constraint(k_att, kv_spec)
            v_att = jax.lax.with_sharding_constraint(v_att, kv_spec)
        qpos = positions.reshape(-1)[:s] if positions.shape[-1] == s else (
            jnp.arange(s, dtype=jnp.int32))
        kpos = jnp.arange(t_kv, dtype=jnp.int32)
        window = jnp.where(kind_code == 0, jnp.int32(t_kv + 1),
                           jnp.int32(cfg.window))
        att = L.chunked_gqa_attention(q, k_att, v_att, qpos, kpos, window,
                                      scale=hd ** -0.5,
                                      softcap=cfg.attn_softcap,
                                      q_chunk=cfg.q_chunk)
    else:
        if cfg.attn_seq_axes and s > 1:
            # context parallelism: shard the QUERY seq dim over TP
            q = jax.lax.with_sharding_constraint(
                q, _P(cfg.attn_batch_axes or None, cfg.attn_seq_axes,
                      None, None))
        mask = jnp.where(kind_code == 0, masks["global"],
                         masks.get("local", masks["global"]))
        att = L.gqa_attention(q, k_att, v_att, mask, scale=hd ** -0.5,
                              softcap=cfg.attn_softcap)
    att = att.reshape(b, s, h * hd) @ lp["wo"]
    if cfg.gemma_norms:
        att = _norm(att, lp["post_attn_norm"], cfg)
    if cfg.residual_scale is not None:
        att = att * cfg.residual_scale
    x = x + att

    m_in = _norm(x, lp["mlp_norm"], cfg)
    if cfg.is_moe:
        dims = L.MoEDims(cfg.n_experts, cfg.top_k,
                         L.moe_capacity(s, cfg.top_k, cfg.n_experts,
                                        cfg.capacity_factor))
        mlp, aux = L.moe_ffn(m_in, lp["router"], lp["w_gate"], lp["w_up"],
                             lp["w_down"], dims, cfg.activation,
                             c_axes=cfg.moe_c_axes,
                             batch_axes=cfg.attn_batch_axes)
    else:
        mlp = L.gated_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"],
                          cfg.activation)
        aux = {"moe_aux_loss": jnp.float32(0.0),
               "moe_dropped_frac": jnp.float32(0.0)}
    if cfg.gemma_norms:
        mlp = _norm(mlp, lp["post_mlp_norm"], cfg)
    if cfg.residual_scale is not None:
        mlp = mlp * cfg.residual_scale
    return x + mlp, new_cache, aux


def _embed(cfg: LMConfig, params, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale is not None:
        x = x * jnp.asarray(cfg.embed_scale, x.dtype)
    return x


def _unembed(cfg: LMConfig, params, x):
    x = _norm(x, params["final_norm"], cfg)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.logit_scale is not None:
        logits = logits * cfg.logit_scale
    logits = L._softcap(logits, cfg.final_softcap)
    return logits


def _kind_codes(cfg: LMConfig) -> jax.Array:
    return jnp.asarray(
        [0 if cfg.layer_kind(i) == "global" else 1 for i in range(cfg.n_layers)],
        jnp.int32,
    )


def forward(cfg: LMConfig, params: dict, tokens: jax.Array,
            positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence forward (training / prefill compute).  Returns f32
    logits (B, S, padded_vocab)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s)[None]
    cos, sin = L.rope_table(cfg.head_dim, cfg.max_seq_len, cfg.rope_theta)
    chunked = cfg.q_chunk and s >= 2 * cfg.q_chunk
    masks = ({"global": jnp.ones((1, 1), bool)} if chunked
             else _layer_masks(cfg, s, s))
    x = _embed(cfg, params, tokens)
    kinds = _kind_codes(cfg)

    def body(carry, inp):
        x, aux_sum = carry
        lp, kind = inp
        x, _, aux = _block(cfg, x, lp, kind, masks, cos, sin, positions)
        return (x, aux_sum + aux["moe_aux_loss"]), None

    if cfg.remat:
        body = jax.checkpoint(body)  # per-layer rematerialization
    (x, aux_sum), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   (params["layers"], kinds))
    logits = _unembed(cfg, params, x)
    return logits, aux_sum / cfg.n_layers


def train_loss(cfg: LMConfig, params: dict, tokens: jax.Array,
               labels: jax.Array, aux_weight: float = 0.01):
    logits, aux = forward(cfg, params, tokens)
    # cross entropy WITHOUT take_along_axis over the vocab axis: a gather
    # over the model-sharded V dimension forces GSPMD to replicate the
    # (B, S, V) logp tensor (measured: 82 GB/device on gemma2 train_4k).
    # The iota/where form is elementwise over V — every term stays
    # vocab-sharded and reduces with one tiny psum.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    correct = jnp.sum(
        jnp.where(iota == labels[..., None], logits, 0.0), axis=-1
    )
    ll = correct - lse
    mask = labels >= 0
    loss = -jnp.sum(jnp.where(mask, ll, 0.0)) / jnp.maximum(
        jnp.sum(mask), 1
    )
    return loss + aux_weight * aux, {"ce_loss": loss, "aux": aux}


# ---------------------------------------------------------------------- #
# serving
# ---------------------------------------------------------------------- #


def make_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def abstract_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dt),
            "v": jax.ShapeDtypeStruct(shape, dt)}


def prefill(cfg: LMConfig, params: dict, tokens: jax.Array, cache: dict):
    """Run the prompt, filling the cache.  Returns (last-token logits,
    cache)."""
    b, s = tokens.shape
    max_len = cache["k"].shape[2]
    positions = jnp.arange(s)[None]
    cos, sin = L.rope_table(cfg.head_dim, cfg.max_seq_len, cfg.rope_theta)
    chunked = cfg.q_chunk and s >= 2 * cfg.q_chunk
    masks = ({"global": jnp.ones((1, 1), bool)} if chunked
             else _layer_masks(cfg, s, max_len))
    x = _embed(cfg, params, tokens)
    kinds = _kind_codes(cfg)

    def body(x, inp):
        lp, kind, ck, cv = inp
        x, new_cache, _ = _block(cfg, x, lp, kind, masks, cos, sin, positions,
                                 cache_kv=(ck, cv), cache_pos=0)
        return x, new_cache

    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], kinds, cache["k"], cache["v"]))
    logits = _unembed(cfg, params, x[:, -1:, :])
    return logits[:, 0], {"k": nk, "v": nv}


def decode_step(cfg: LMConfig, params: dict, cache: dict, tokens: jax.Array,
                pos: jax.Array):
    """One-token decode: tokens (B, 1), pos scalar (current position).
    Returns (logits (B, padded_vocab), new cache)."""
    b = tokens.shape[0]
    max_len = cache["k"].shape[2]
    positions = jnp.full((b, 1), pos, jnp.int32)
    cos, sin = L.rope_table(cfg.head_dim, cfg.max_seq_len, cfg.rope_theta)
    # masks over the cache: global = all positions <= pos; local = window
    t = jnp.arange(max_len)[None, :]
    gmask = (t <= pos)
    lmask = gmask & (t > pos - cfg.window)
    masks = {"global": jnp.broadcast_to(gmask, (1, max_len)),
             "local": jnp.broadcast_to(lmask, (1, max_len))}
    x = _embed(cfg, params, tokens)
    kinds = _kind_codes(cfg)

    def body(x, inp):
        lp, kind, ck, cv = inp
        x, new_cache, _ = _block(cfg, x, lp, kind, masks, cos, sin, positions,
                                 cache_kv=(ck, cv), cache_pos=pos)
        return x, new_cache

    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], kinds, cache["k"], cache["v"]))
    logits = _unembed(cfg, params, x)
    return logits[:, 0], {"k": nk, "v": nv}
