"""Device query processing with CPQx — Algorithms 3 & 4 on TPU.

The host plans (``core.query.plan_query``) and the device executes.  A
plan is compiled once per (plan shape, capacity profile) — plans are
nested tuples, hence hashable jit keys; the per-query *data* (the
(start, len) ranges of each LOOKUP) streams in as traced scalars, so ten
queries of the same template hit one executable.

Evaluation is two-stage exactly as in the paper:
  * class space: LOOKUP returns sorted class-id lists; CONJUNCTION is a
    sorted intersection of class ids (Prop. 4.1); IDENTITY is a gather of
    the cycle-purity flag (classes are cycle-pure by construction).
  * pair space: after any JOIN the evaluator materializes s-t pairs
    (expansion join through I_c2p) and proceeds with sorted set algebra.

Every relation is capacity-padded; ``execute`` retries with doubled
capacities on overflow (the honest dynamic->static bridge).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import relational as R
from .index import CPQxIndex, DeviceIndexArrays
from .query import CPQ, plan_query, plan_lookup_seqs, plan_shape
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class QueryCaps:
    """Static capacities of the compiled plan (jit key)."""

    class_cap: int  # class-id sets
    pair_cap: int  # materialized pair sets
    join_cap: int  # expansion-join outputs (pre-dedup)

    def doubled(self) -> "QueryCaps":
        return QueryCaps(self.class_cap * 2, self.pair_cap * 2, self.join_cap * 2)


def default_caps(index: CPQxIndex) -> QueryCaps:
    n_pairs = max(16, int(index.arrays.pair_count))
    n_cls = max(16, int(index.arrays.n_classes))
    p2 = 1 << (n_pairs - 1).bit_length()
    c2 = 1 << (n_cls - 1).bit_length()
    return QueryCaps(class_cap=c2, pair_cap=p2, join_cap=2 * p2)


# ---------------------------------------------------------------------- #
# device operators
# ---------------------------------------------------------------------- #


def _lookup_classes(a: DeviceIndexArrays, start, length, cap: int) -> R.Relation:
    idx = jnp.arange(cap, dtype=R.I32)
    valid = idx < length
    src = jnp.clip(start + idx, 0, a.l2c_cls.shape[0] - 1)
    ids = jnp.where(valid, a.l2c_cls[src], R.SENTINEL)
    ovf = length > cap
    return R.Relation((ids,), jnp.minimum(length, cap).astype(R.I32), ovf)


def _materialize(a: DeviceIndexArrays, classes: R.Relation, pair_cap: int) -> R.Relation:
    """classes -> sorted distinct (v, u).  Classes are disjoint, so the
    expansion introduces no duplicate pairs.  The gather pass is the
    ``expand_join`` Pallas kernel (fused binary search + payload gather)."""
    cid = jnp.clip(classes.cols[0], 0, a.class_starts.shape[0] - 2)
    lo = a.class_starts[cid]
    cnt = a.class_starts[cid + 1] - lo
    cnt = jnp.where(R.valid_mask(classes), cnt, 0).astype(R.I32)
    ends = jnp.cumsum(cnt, dtype=R.I32)
    total = ends[-1]
    v, u, _ = kops.expand_join_gather(
        ends, lo, classes.cols[0], a.c2p_v, a.c2p_u, total, pair_cap
    )
    rel = R.Relation((v, u), jnp.minimum(total, pair_cap).astype(R.I32),
                     classes.overflow | (total > pair_cap))
    return R.rel_sort(rel, num_keys=2)


def _join_pairs(a: R.Relation, b: R.Relation, join_cap: int, pair_cap: int) -> R.Relation:
    """(v,u) ⋈ (x,y) on u == x -> distinct (v, y).  b sorted by (x, y)."""
    out = R.expansion_join(a, b, a_on=[1], out_cols=[("a", 0), ("b", 1)],
                           out_capacity=join_cap)
    out = R.rel_unique(R.rel_sort(out, num_keys=2), 2)
    # re-embed at pair_cap
    idx = jnp.arange(pair_cap, dtype=R.I32)
    m = idx < out.count
    src = jnp.clip(idx, 0, out.capacity - 1)
    cols = tuple(jnp.where(m, c[src], R.SENTINEL) for c in out.cols)
    return R.Relation(cols, jnp.minimum(out.count, pair_cap).astype(R.I32),
                      out.overflow | (out.count > pair_cap))


def _conj_id_classes(a: DeviceIndexArrays, classes: R.Relation) -> R.Relation:
    cyc = a.class_cyclic[jnp.clip(classes.cols[0], 0, a.class_cyclic.shape[0] - 1)]
    keep = (cyc == 1) & R.valid_mask(classes)
    return R.rel_compact(classes, keep)


# ---------------------------------------------------------------------- #
# plan executor (one jit per plan shape x caps)
# ---------------------------------------------------------------------- #


def _run_plan(a: DeviceIndexArrays, plan, caps: QueryCaps, n_vertices: int,
              lookup_ranges: jax.Array):
    """Execute a physical plan.  ``lookup_ranges``: (n_lookups, 2) int32 of
    (start, len) per LOOKUP segment, in plan order.  Returns a pair
    Relation (sorted distinct (v, u)) and the sticky overflow flag.

    ``plan`` may be a frozen plan or its :func:`plan_shape` — the device
    computation only depends on the shape (LOOKUP nodes carry their
    segment count; the label values stream in via ``lookup_ranges``)."""
    counter = [0]

    def next_range():
        i = counter[0]
        counter[0] += 1
        return lookup_ranges[i, 0], lookup_ranges[i, 1]

    def as_pairs(res):
        kind, rel = res
        if kind == "classes":
            return _materialize(a, rel, caps.pair_cap)
        return rel

    def ev(node):
        kind = node[0]
        if kind == "lookup":
            nseg = node[1] if isinstance(node[1], int) else len(node[1])
            start, length = next_range()
            cur = ("classes", _lookup_classes(a, start, length, caps.class_cap))
            for _ in range(nseg - 1):
                start, length = next_range()
                nxt = _lookup_classes(a, start, length, caps.class_cap)
                cur = ("pairs", _join_pairs(as_pairs(cur),
                                            _materialize(a, nxt, caps.pair_cap),
                                            caps.join_cap, caps.pair_cap))
            return cur
        if kind == "identity":
            v = jnp.arange(caps.pair_cap, dtype=R.I32)
            m = v < n_vertices
            col = jnp.where(m, v, R.SENTINEL)
            return ("pairs", R.Relation((col, col),
                                        jnp.asarray(min(n_vertices, caps.pair_cap), R.I32),
                                        jnp.asarray(n_vertices > caps.pair_cap)))
        if kind == "conj_id":
            res = ev(node[1])
            if res[0] == "classes":
                return ("classes", _conj_id_classes(a, res[1]))
            rel = res[1]
            return ("pairs", R.rel_compact(rel, rel.cols[0] == rel.cols[1]))
        left = ev(node[1])
        right = ev(node[2])
        if kind == "conj":
            if left[0] == "classes" and right[0] == "classes":
                # Prop. 4.1 on device: sorted-intersect Pallas kernel
                lrel, rrel = left[1], right[1]
                mask = kops.sorted_member_mask(rrel.cols[0], rrel.count,
                                               lrel.cols[0])
                out = R.rel_compact(lrel, mask > 0)
                # an undersized RIGHT list means missing matches: sticky
                out = R.Relation(out.cols, out.count,
                                 out.overflow | rrel.overflow)
                return ("classes", out)
            return ("pairs", R.rel_intersect(as_pairs(left), as_pairs(right), 2))
        if kind == "join":
            return ("pairs", _join_pairs(as_pairs(left), as_pairs(right),
                                         caps.join_cap, caps.pair_cap))
        raise ValueError(kind)

    res = ev(plan)
    pairs = as_pairs(res)
    return pairs, pairs.overflow


run_plan = functools.partial(
    jax.jit, static_argnames=("plan", "caps", "n_vertices"))(_run_plan)


@functools.partial(jax.jit, static_argnames=("plan", "caps", "n_vertices"))
def run_plan_batch(a: DeviceIndexArrays, plan, caps: QueryCaps,
                   n_vertices: int, lookup_ranges: jax.Array):
    """Batched :func:`run_plan`: ``lookup_ranges`` is (batch, n_lookups, 2)
    and the whole batch evaluates through one vmapped dispatch of the same
    executable a single query would use.  Returns a batched Relation
    (cols (batch, cap)) and a per-query (batch,) overflow vector — each
    lane's overflow is its own sticky flag, so the host retries only the
    lanes that overflowed."""
    return jax.vmap(lambda r: _run_plan(a, plan, caps, n_vertices, r))(
        lookup_ranges)


# ---------------------------------------------------------------------- #
# host driver
# ---------------------------------------------------------------------- #


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()




def _has_identity(shape) -> bool:
    if shape[0] == "identity":
        return True
    return any(_has_identity(s) for s in shape[1:]
               if isinstance(s, tuple))


class Engine:
    """Query engine bound to a built index."""

    def __init__(self, index: CPQxIndex):
        self.rebind(index)

    def rebind(self, index: CPQxIndex) -> None:
        """Swap in a new index (a maintenance flush or a rebuild) in
        place: re-pulls the host-side estimator mirrors and the default
        caps.  Compiled executables are keyed on (plan shape, caps,
        n_vertices) — not on the index identity — so traffic after a
        rebind keeps hitting the same jit cache as long as the flushed
        arrays keep their capacities."""
        self.index = index
        self._available = index.available_seqs() if index.interests is not None else None
        # host mirrors for the adaptive capacity estimator: per-class pair
        # counts and the l2c class table (a few KB — pulled once)
        starts = np.asarray(index.arrays.class_starts, np.int64)
        self._class_sizes = starts[1:] - starts[:-1]
        self._l2c_host = np.asarray(index.arrays.l2c_cls, np.int64)
        self._default_caps = default_caps(index)  # one device sync, here

    def plan(self, q: CPQ):
        return plan_query(q, self.index.k, available=self._available)

    def estimate_caps(self, ranges: np.ndarray, shape) -> QueryCaps:
        """Optimistic per-query capacities from the host index stats: the
        class cap covers the largest LOOKUP's class list, the pair cap a
        2x headroom over the largest single-lookup materialization.  Far
        tighter than :func:`default_caps` for typical template queries —
        the sticky-overflow retry (which doubles along the same power-of-
        two ladder, so executables are shared) keeps this exact."""
        max_classes, max_pairs = 1, 1
        for start, length in np.asarray(ranges, np.int64).reshape(-1, 2):
            max_classes = max(max_classes, int(length))
            cls = self._l2c_host[start: start + length]
            max_pairs = max(max_pairs, int(self._class_sizes[cls].sum()))
        floor = self.index.n_vertices if _has_identity(shape) else 0
        # never *start* above the worst-case default (the retry ladder can
        # still climb past it if a join genuinely needs more)
        ceiling = max(self._default_caps.pair_cap, _pow2(floor))
        pair_cap = min(_pow2(max(64, 2 * max_pairs, floor)), ceiling)
        return QueryCaps(class_cap=_pow2(max(16, max_classes)),
                         pair_cap=pair_cap, join_cap=2 * pair_cap)

    def lookup_ranges(self, plan) -> np.ndarray:
        """(n_lookups, 2) int32 (start, len) rows, in plan order — the
        per-query data streamed into the compiled plan executable."""
        seqs = plan_lookup_seqs(plan)
        ranges = np.array(
            [self.index.lookup_range(s) for s in seqs], np.int32
        ).reshape(-1, 2)
        ranges[:, 1] = ranges[:, 1] - ranges[:, 0]  # (start, len)
        return ranges

    def execute(self, q: CPQ, caps: QueryCaps | None = None,
                max_retries: int = 8) -> np.ndarray:
        """Evaluate ⟦q⟧_G; returns (n, 2) numpy array of s-t pairs."""
        plan = self.plan(q)
        ranges = self.lookup_ranges(plan)
        shape = plan_shape(plan)
        caps = caps or self.estimate_caps(ranges, shape)
        for attempt in range(max_retries):
            pairs, overflow = run_plan(
                self.index.arrays, shape, caps, self.index.n_vertices,
                jnp.asarray(ranges),
            )
            if not bool(overflow):
                return R.to_numpy(pairs)
            caps = self._escalate(caps, attempt)
        raise RuntimeError("query overflow not resolved after retries")

    def _escalate(self, caps: QueryCaps, attempt: int) -> QueryCaps:
        """Overflow-retry schedule: double, but after two failed attempts
        from a (possibly far-too-tight) estimate jump to at least the
        worst-case default so the ladder can't exhaust below the caps the
        pre-estimator engine would have started from."""
        caps = caps.doubled()
        if attempt >= 1:
            d = self._default_caps
            caps = QueryCaps(max(caps.class_cap, d.class_cap),
                             max(caps.pair_cap, d.pair_cap),
                             max(caps.join_cap, d.join_cap))
        return caps

    def execute_batch(self, queries, caps: QueryCaps | None = None,
                      max_retries: int = 8, plans: list | None = None,
                      min_bucket: int = 4) -> list:
        """Evaluate many queries; returns one (n, 2) array per query, in
        input order.

        Queries are grouped by (plan *shape*, estimated caps) — labels
        don't change the executable, and the power-of-two capacity
        estimates quantize size-similar queries into shared buckets, so
        a lane never pays for a much larger neighbor.  Buckets smaller
        than ``min_bucket`` merge upward into the next-larger caps rung
        (one dispatch beats a little lane padding).  Each group's lookup
        ranges stack into a (batch, n_lookups, 2) array evaluated by a
        single vmapped dispatch.  Overflow is tracked per lane: only the
        queries whose own sticky flag tripped are retried, at doubled
        capacities.

        ``plans`` lets a caller with a plan cache (the service layer)
        skip re-planning; must align with ``queries``."""
        if not queries:
            return []
        if plans is None:
            plans = [self.plan(q) for q in queries]
        all_ranges = [self.lookup_ranges(p) for p in plans]

        shape_groups: dict = {}
        for i, p in enumerate(plans):
            shape = plan_shape(p)
            e = caps or self.estimate_caps(all_ranges[i], shape)
            shape_groups.setdefault(shape, {}).setdefault(e, []).append(i)

        work: list = []  # (shape, caps, member indices)
        for shape, by_caps in shape_groups.items():
            if caps is not None:
                work.extend((shape, c, m) for c, m in by_caps.items())
                continue
            buckets = sorted(
                by_caps.items(),
                key=lambda kv: (kv[0].pair_cap, kv[0].join_cap,
                                kv[0].class_cap))
            cur_caps, cur_members = None, []
            for cb, mem in buckets:
                if cur_caps is None:
                    cur_caps, cur_members = cb, list(mem)
                else:
                    cur_caps = QueryCaps(
                        max(cur_caps.class_cap, cb.class_cap),
                        max(cur_caps.pair_cap, cb.pair_cap),
                        max(cur_caps.join_cap, cb.join_cap))
                    cur_members += mem
                if len(cur_members) >= min_bucket:
                    work.append((shape, cur_caps, cur_members))
                    cur_caps, cur_members = None, []
            if cur_caps is not None:
                # undersized largest-caps tail: keep it separate rather
                # than inflating an already-flushed smaller bucket
                work.append((shape, cur_caps, cur_members))

        results: list = [None] * len(queries)
        for shape, grp_caps, members in work:
            pending = np.asarray(members, np.int64)
            ranges = np.stack([all_ranges[i] for i in members])
            for attempt in range(max_retries):
                rel, overflow = run_plan_batch(
                    self.index.arrays, shape, grp_caps,
                    self.index.n_vertices, jnp.asarray(ranges),
                )
                overflow = np.asarray(overflow)
                ok = np.nonzero(~overflow)[0]
                if ok.size:
                    for lane, rows in zip(ok, R.batch_to_numpy(rel, lanes=ok)):
                        results[pending[lane]] = rows
                if not overflow.any():
                    break
                pending = pending[overflow]
                ranges = ranges[overflow]
                grp_caps = self._escalate(grp_caps, attempt)
            else:
                raise RuntimeError("query overflow not resolved after retries")
        return results
