"""Pallas block-shape autotuner — the silicon half of the cost model.

The kernel wrappers in ``ops.py`` historically picked block shapes by a
power-of-two heuristic capped at a hard default (1024).  The right block
is a device property — it balances grid parallelism against per-block
launch overhead and VMEM residency — so this module measures it: for
each capacity rung the engine's caps-ladder actually dispatches (see
``core.costmodel.ladder_rungs``), every candidate block shape is timed
against the raw kernels (``sorted_intersect.sorted_member_mask`` for
``block_q``, ``expand_join.expand_join_gather`` for ``block_t``) on
rung-sized synthetic int32 inputs, and the winners are cached in the
:class:`~repro.core.costmodel.DeviceCostTable` keyed by rung.

Answers never depend on the block shape (each candidate is asserted
equal to the 1024 baseline during the sweep), so a stale table is a
performance bug at worst — the same contract as the cost model's pricing
half.

Candidates stay multiples of 128 (the TPU int32 lane tile — see the
Pallas guide) and never exceed the rung, mirroring the wrapper's
``min(block, pow2(n))`` clamp.
"""

from __future__ import annotations

import time

import numpy as np

#: Block-shape candidates swept per rung.  128-multiple keeps TPU lane
#: tiling exact; 2048 doubles the historical ceiling to let big rungs
#: trade grid steps for per-block work.
CANDIDATES = (256, 512, 1024, 2048)


def _time_ns(fn, repeats: int = 3, warmup: int = 1) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e9)
    return float(np.median(ts))


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


def sweep_block_q(rung: int, repeats: int = 3, candidates=CANDIDATES):
    """Time ``sorted_member_mask`` at ``rung`` queries for each candidate
    block; returns (winner, {block: ns}).  Results are asserted identical
    across candidates — the sweep can only change speed."""
    import jax.numpy as jnp

    from . import sorted_intersect as _si

    rung = _pow2(rung)
    rng = np.random.default_rng(rung)
    hay = jnp.asarray(np.sort(rng.choice(4 * rung, rung, replace=False))
                      .astype(np.int32))
    queries = jnp.asarray(rng.integers(0, 4 * rung, rung).astype(np.int32))
    count = jnp.asarray(rung, jnp.int32)
    timings: dict[int, float] = {}
    baseline = None
    for blk in candidates:
        blk = min(blk, rung)
        if rung % blk or blk in timings:
            continue
        out = _si.sorted_member_mask(hay, count, queries, block_q=blk)
        if baseline is None:
            baseline = np.asarray(out)
        else:
            assert np.array_equal(baseline, np.asarray(out)), blk
        timings[blk] = _time_ns(
            lambda b=blk: _si.sorted_member_mask(hay, count, queries,
                                                 block_q=b), repeats)
    winner = min(timings, key=timings.get)
    return winner, timings


def sweep_block_t(rung: int, repeats: int = 3, candidates=CANDIDATES):
    """Time ``expand_join_gather`` producing ``rung`` output rows for
    each candidate block; returns (winner, {block: ns})."""
    import jax.numpy as jnp

    from . import expand_join as _ej

    rung = _pow2(rung)
    # one match per probe: ends = 1..rung, lo = 0..rung-1 — a clean
    # rung-sized gather whose cost is all in the kernel's tiling
    ends = jnp.arange(1, rung + 1, dtype=jnp.int32)
    lo = jnp.arange(rung, dtype=jnp.int32)
    payload = jnp.arange(rung, dtype=jnp.int32)
    total = jnp.asarray(rung, jnp.int32)
    timings: dict[int, float] = {}
    baseline = None
    for blk in candidates:
        blk = min(blk, rung)
        if rung % blk or blk in timings:
            continue
        out = _ej.expand_join_gather(ends, lo, payload, payload, payload,
                                     total, rung, block_t=blk)
        got = np.stack([np.asarray(c) for c in out])
        if baseline is None:
            baseline = got
        else:
            assert np.array_equal(baseline, got), blk
        timings[blk] = _time_ns(
            lambda b=blk: _ej.expand_join_gather(
                ends, lo, payload, payload, payload, total, rung,
                block_t=b)[0], repeats)
    winner = min(timings, key=timings.get)
    return winner, timings


def autotune(rungs, repeats: int = 3, candidates=CANDIDATES):
    """Sweep both kernels over ``rungs``; returns ``(block_q, block_t,
    raw)`` — two {rung: winner} dicts ready for the cost table, plus the
    raw {(kind, rung, block): ns} timings for bench emission."""
    block_q: dict[int, int] = {}
    block_t: dict[int, int] = {}
    raw: dict[tuple, float] = {}
    for rung in sorted({_pow2(r) for r in rungs}):
        wq, tq = sweep_block_q(rung, repeats, candidates)
        wt, tt = sweep_block_t(rung, repeats, candidates)
        block_q[rung] = wq
        block_t[rung] = wt
        for blk, ns in tq.items():
            raw[("block_q", rung, blk)] = ns
        for blk, ns in tt.items():
            raw[("block_t", rung, blk)] = ns
    return block_q, block_t, raw
