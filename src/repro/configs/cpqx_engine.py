"""The paper's own engine as a distributed workload (bonus dry-run cell):
CPQx index build + conjunction-heavy query processing over a sharded pair
table.  Shapes model the paper's largest interest-aware settings."""

import dataclasses

from repro.configs import ArchSpec, ShapeSpec


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    name: str = "cpqx-engine"
    k: int = 2
    n_labels: int = 8


CONFIG = EngineConfig()
SMOKE = EngineConfig(n_labels=3)

SPEC = ArchSpec(
    arch_id="cpqx-engine", family="engine", config=CONFIG, smoke=SMOKE,
    shapes=(
        ShapeSpec("build_64m", "engine",
                  {"n_pairs": 64 * 2**20, "n_edges": 16 * 2**20,
                   "n_classes": 2**20, "n_seqs": 2**14}),
        ShapeSpec("query_s", "engine",
                  {"n_pairs": 64 * 2**20, "n_classes": 2**20,
                   "lookup_classes": 2**16, "join_cap": 2**22}),
    ),
    notes="pair tables sharded over (data, model) flattened; distributed "
          "join via all_to_all hash partitioning (core/distributed.py).",
)
