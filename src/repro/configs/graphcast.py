"""graphcast [arXiv:2212.12794; unverified]: 16L d_hidden=512,
mesh_refinement=6, sum aggregator, n_vars=227 — encoder-processor-decoder
interaction-network GNN.  The assigned graph shapes stand in for the
icosahedral mesh; n_vars drives d_out."""

import dataclasses

from repro.configs import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

N_VARS = 227

CONFIG = GNNConfig(
    name="graphcast", arch="graphcast", n_layers=16, d_hidden=512,
    d_in=N_VARS, d_out=N_VARS, d_edge_in=4,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=32, d_in=8, d_out=8)

SPEC = ArchSpec(
    arch_id="graphcast", family="gnn", config=CONFIG, smoke=SMOKE,
    shapes=gnn_shapes(),
    notes="encode-process-decode; d_in/d_out fixed at n_vars=227 except "
          "where a shape pins d_feat (the encoder adapts).",
)
