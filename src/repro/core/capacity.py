"""Host-side capacity estimator — the dynamic->static bridge.

XLA relations are fixed-capacity; someone has to pick the capacities.
This module mirrors the device pipeline with vectorized numpy (sorted
expansion joins + ``np.unique``) and returns *exact* row counts per
level, rounded up to powers of two for jit-cache friendliness.  It is
also used by tests as an independent size oracle and by the benchmark
harness to report |P^{<=k}|, gamma, and |C| (paper Tables III/IV).

On overflow (a device op reports dropped rows — only possible when the
caller overrides the estimate downward) the driver doubles the failed
capacity and re-runs; see ``core.engine.run_plan``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .graph import LabeledGraph


def _round_pow2(n: int, floor: int = 16) -> int:
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class BuildCaps:
    """Capacities for index construction.

    level_rows[i-1] : rows of the level-i path relation (v,u,seq) and of
                      the level-i bisimulation S-set incidence relation
    pair_cap        : |P^{<=k}| capacity (pair tables, class tables)
    seq_rows        : total (seq, v, u) incidence rows across levels
    l2c_rows        : distinct (seq, class) rows
    n_seqs          : distinct label sequences
    """

    level_rows: tuple
    pair_cap: int
    union_pair_cap: int  # >= sum of per-level distinct pairs (pre-dedup union)
    seq_rows: int
    l2c_rows: int
    n_seqs: int

    def key(self) -> tuple:
        return (self.level_rows, self.pair_cap, self.union_pair_cap,
                self.seq_rows, self.l2c_rows, self.n_seqs)


def path_level_counts(
    g: LabeledGraph, k: int, return_raw: bool = False
):
    """Exact per-level distinct (v, u, seq) rows, vectorized numpy.
    Returns the actual row arrays (n_i, 2+i) so callers can derive any
    statistic.  With ``return_raw`` also returns the *pre-dedup* join
    output size per level — the capacity the device expansion join needs
    (its output is materialized before sort+dedup)."""
    edges = np.stack([g.src, g.dst, g.lbl], axis=1).astype(np.int64)
    edges = edges[np.lexsort((edges[:, 2], edges[:, 1], edges[:, 0]))]
    levels = [edges]
    raw = [edges.shape[0]]
    # CSR over src for the expansion
    indptr = np.zeros(g.n_vertices + 1, np.int64)
    np.add.at(indptr, edges[:, 0] + 1, 1)
    np.cumsum(indptr, out=indptr)
    for i in range(2, k + 1):
        prev = levels[-1]  # (v, m, s...) rows
        m = prev[:, 1]
        cnt = indptr[m + 1] - indptr[m]
        rep = np.repeat(np.arange(prev.shape[0]), cnt)
        raw.append(rep.shape[0])
        # edge row index per expanded output
        offs = np.concatenate([[0], np.cumsum(cnt)])[:-1]
        within = np.arange(rep.shape[0]) - offs[rep]
        erow = indptr[m[rep]] + within
        out = np.concatenate(
            [prev[rep, :1], edges[erow, 1:2], prev[rep, 2:], edges[erow, 2:3]],
            axis=1,
        )
        out = np.unique(out, axis=0)
        levels.append(out)
    if return_raw:
        return levels, raw
    return levels


def estimate_build_caps(g: LabeledGraph, k: int, slack: float = 1.0) -> BuildCaps:
    levels, raw = path_level_counts(g, k, return_raw=True)
    level_rows = []
    pair_sets = []
    seq_rows_total = 0
    for i, (rows, raw_n) in enumerate(zip(levels, raw), start=1):
        # the device join materializes the *raw* (pre-dedup) expansion; the
        # bisim S-set join is bounded by the same raw size (pair tables are
        # subsets of path tables)
        level_rows.append(_round_pow2(int(max(rows.shape[0], raw_n) * slack)))
        pair_sets.append(np.unique(rows[:, :2], axis=0))
        seq_rows_total += rows.shape[0]
    all_pairs = np.unique(np.concatenate(pair_sets, axis=0), axis=0)
    union_rows = sum(p.shape[0] for p in pair_sets)
    # distinct sequences across levels
    n_seqs = 0
    for rows in levels:
        seqs = np.unique(rows[:, 2:], axis=0)
        n_seqs += seqs.shape[0]
    # l2c rows upper bound: one row per (seq, class) <= (seq, pair) rows
    l2c_upper = seq_rows_total
    return BuildCaps(
        level_rows=tuple(level_rows),
        pair_cap=_round_pow2(int(all_pairs.shape[0] * slack)),
        union_pair_cap=_round_pow2(int(union_rows * slack)),
        seq_rows=_round_pow2(int(seq_rows_total * slack)),
        l2c_rows=_round_pow2(int(l2c_upper * slack)),
        n_seqs=_round_pow2(int(n_seqs * slack)),
    )


@dataclasses.dataclass(frozen=True)
class FlushCaps:
    """Capacities for re-serializing a lazily-updated host mirror into
    device arrays (``core.maintenance.MaintainableIndex.flush``).

    Unlike :class:`BuildCaps` (sized for the whole device build pipeline,
    including intermediate join relations), a flush only materializes the
    final two inverted maps, so three capacities suffice:

    pair_cap : |P^{<=k}| rows (pair table, c2p table, class CSR)
    l2c_cap  : distinct (seq, class) entries
    seq_cap  : distinct label sequences
    """

    pair_cap: int
    l2c_cap: int
    seq_cap: int

    @staticmethod
    def for_sizes(n_pairs: int, n_l2c: int, n_seqs: int) -> "FlushCaps":
        return FlushCaps(_round_pow2(n_pairs), _round_pow2(n_l2c),
                         _round_pow2(n_seqs))

    def grown_for(self, n_pairs: int, n_l2c: int, n_seqs: int) -> "FlushCaps":
        """Geometric growth: double each capacity until the mirror fits
        (capacities never shrink, so repeated flushes of a growing mirror
        reuse the same array shapes — and the same jit executables —
        until a doubling is genuinely needed)."""

        def grow(cap: int, need: int) -> int:
            while cap < need:
                cap *= 2
            return cap

        out = FlushCaps(grow(self.pair_cap, n_pairs),
                        grow(self.l2c_cap, n_l2c),
                        grow(self.seq_cap, n_seqs))
        return self if out == self else out


# ---------------------------------------------------------------------- #
# checkpoint codec — caps travel inside index snapshots as one small int
# vector (strings/dataclasses can't be npy leaves).  Tag word selects the
# kind; capacities only ever hold small non-negative ints, so -1 is free
# to mean "no caps recorded".
# ---------------------------------------------------------------------- #
def encode_caps(caps) -> np.ndarray:
    """``FlushCaps``/``BuildCaps``/``None`` -> int64 vector."""
    if caps is None:
        return np.array([-1], dtype=np.int64)
    if isinstance(caps, FlushCaps):
        return np.array([0, caps.pair_cap, caps.l2c_cap, caps.seq_cap],
                        dtype=np.int64)
    if isinstance(caps, BuildCaps):
        return np.array(
            [1, caps.pair_cap, caps.union_pair_cap, caps.seq_rows,
             caps.l2c_rows, caps.n_seqs, *caps.level_rows], dtype=np.int64)
    raise TypeError(f"cannot encode caps of type {type(caps).__name__}")


def decode_caps(arr):
    """Inverse of :func:`encode_caps`."""
    a = np.asarray(arr, dtype=np.int64).ravel()
    tag = int(a[0])
    if tag == -1:
        return None
    if tag == 0:
        return FlushCaps(int(a[1]), int(a[2]), int(a[3]))
    if tag == 1:
        return BuildCaps(
            level_rows=tuple(int(x) for x in a[6:]),
            pair_cap=int(a[1]), union_pair_cap=int(a[2]),
            seq_rows=int(a[3]), l2c_rows=int(a[4]), n_seqs=int(a[5]))
    raise ValueError(f"unknown caps tag {tag}")


def graph_stats(g: LabeledGraph, k: int) -> dict:
    """|P^{<=k}|, gamma (avg distinct seqs per pair), degree stats —
    the quantities of paper Sec. III-A / Table IV."""
    levels = path_level_counts(g, k)
    seq_rows = sum(r.shape[0] for r in levels)
    pairs = np.unique(
        np.concatenate([r[:, :2] for r in levels], axis=0), axis=0
    )
    return {
        "n_pairs": int(pairs.shape[0]),
        "seq_incidences": int(seq_rows),
        "gamma": float(seq_rows / max(1, pairs.shape[0])),
        "max_out_degree": g.max_degree(),
        "level_rows": [int(r.shape[0]) for r in levels],
    }
