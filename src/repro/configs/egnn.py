"""egnn [arXiv:2102.09844; paper]: 4L d_hidden=64, E(n)-equivariant."""

import dataclasses

from repro.configs import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="egnn", arch="egnn", n_layers=4, d_hidden=64, d_in=64, d_out=1,
)

SMOKE = dataclasses.replace(CONFIG, d_hidden=16, d_in=8)

SPEC = ArchSpec(
    arch_id="egnn", family="gnn", config=CONFIG, smoke=SMOKE,
    shapes=gnn_shapes(),
    notes="scalar-distance messages + coordinate updates (no irreps).",
)
