"""Workload subsystem (PR 5): sequence harvesting, the Space-Saving
sketch's bounds and eviction, benefit-model pricing, the adaptation
controller's hysteresis/budget/dwell rules, and the end-to-end property
that NO interleaving of queries, graph updates and adaptation rounds
can ever change answers — adaptive serving == a never-adapted full
index == the numpy oracle, locally and sharded."""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import index as cindex
from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import instantiate_template, parse
from repro.core.service import QueryService
from repro.core.stats import IndexStats
from repro.core.workload import (
    AdaptationConfig,
    AdaptationController,
    BenefitModel,
    WorkloadSketch,
    harvest_sequences,
)


def _rows(arr) -> set:
    return {tuple(r) for r in arr.tolist()}


# ---------------------------------------------------------------------- #
# harvesting
# ---------------------------------------------------------------------- #


class TestHarvest:
    def test_chain_windows(self):
        q = parse("l0 . l1 . l2", None, 6)
        assert sorted(harvest_sequences(q, 2)) == [(0, 1), (1, 2)]
        assert sorted(harvest_sequences(q, 3)) == [
            (0, 1), (0, 1, 2), (1, 2)]

    def test_conj_operands_recurse_and_singletons_are_silent(self):
        q = instantiate_template("T", [0, 0, 1])  # (l0.l0) & l1
        assert harvest_sequences(q, 2) == [(0, 0)]
        q = instantiate_template("St", [0, 4, 5])  # three singletons
        assert harvest_sequences(q, 2) == []

    def test_identity_breaks_runs(self):
        q = parse("l0 . id . l1", None, 6)
        # q ∘ id == q, but the harvest is syntactic: id splits the run
        # conservatively (the planner strips it; both windows of the
        # stripped chain still get their votes from other traffic)
        assert (0, 1) not in harvest_sequences(q, 2)

    def test_nested_join_subplans(self):
        q = instantiate_template("TC", [0, 0, 1, 2, 3])  # ((l0.l0)&l1).l2.l3
        assert sorted(harvest_sequences(q, 2)) == [(0, 0), (2, 3)]


# ---------------------------------------------------------------------- #
# the sketch
# ---------------------------------------------------------------------- #


class TestWorkloadSketch:
    def test_exact_below_capacity(self):
        sk = WorkloadSketch(8)
        for _ in range(5):
            sk.observe("a")
        sk.observe("b")
        assert sk.count("a") == 5 and sk.guaranteed("a") == 5
        assert sk.count("b") == 1 and sk.count("c") == 0

    def test_eviction_inherits_min_and_records_error(self):
        sk = WorkloadSketch(2)
        sk.observe("a", 5)
        sk.observe("b", 2)
        sk.observe("c")  # evicts b (the min), inherits its count
        assert set(sk.counts) == {"a", "c"}
        assert sk.count("c") == 3  # 2 (inherited) + 1
        assert sk.guaranteed("c") == 1  # error records the inheritance
        assert sk.guaranteed("a") == 5

    def test_heavy_hitter_guarantee(self):
        """Space-Saving: any item with true count > N/capacity is
        monitored, whatever the adversarial order."""
        rng = np.random.default_rng(0)
        stream = ["hot"] * 40 + [f"cold{i}" for i in range(60)]
        rng.shuffle(stream)
        sk = WorkloadSketch(16)
        for x in stream:
            sk.observe(x)
        assert sk.count("hot") >= 40  # count is an upper bound
        assert "hot" in dict((i, c) for i, c, _ in sk.heavy_hitters())

    def test_capacity_is_bounded(self):
        sk = WorkloadSketch(4)
        for i in range(100):
            sk.observe(i)
        assert len(sk) == 4

    def test_decay_fades_and_drops(self):
        sk = WorkloadSketch(8)
        sk.observe("a", 8)
        sk.observe("b", 1)
        sk.decay(0.4)
        assert sk.count("a") == pytest.approx(3.2)
        assert sk.count("b") == 0  # faded below the drop floor
        assert len(sk) == 1

    def test_deterministic_order(self):
        sk = WorkloadSketch(8)
        for x in ["b", "a", "c"]:
            sk.observe(x, 2)
        assert [i for i, _, _ in sk.heavy_hitters()] == ["a", "b", "c"]


# ---------------------------------------------------------------------- #
# benefit model
# ---------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def skewed_stats():
    from repro.data.graphs import skewed_labeled_graph

    g = skewed_labeled_graph(n_vertices=40, wave=12, rare_edges=10, seed=7)
    oidx = oracle.build_index(g, 2)
    return g, IndexStats.from_oracle(oidx, g.n_vertices)


class TestBenefitModel:
    def test_hub_sequence_saves_most(self, skewed_stats):
        """Indexing the hub 2-sequence avoids the hub x hub expansion
        join — its saving must dwarf a rare x rare sequence's."""
        _, stats = skewed_stats
        m = BenefitModel(stats)
        assert m.saved((0, 0)) > 10 * m.saved((2, 3))
        assert m.saved((0, 0)) > 0

    def test_benefit_scales_with_frequency(self, skewed_stats):
        _, stats = skewed_stats
        m = BenefitModel(stats)
        assert m.benefit((0, 0), 10) == 10 * m.saved((0, 0))
        assert m.benefit((0, 0), 0) == 0

    def test_absent_label_sequence_prices_to_zero(self, skewed_stats):
        """A sequence over a label with no pairs can never materialize
        anything — nothing to save, nothing to spend."""
        g, stats = skewed_stats
        dead = g.alphabet_size  # out-of-alphabet id: seq_pairs == 0
        m = BenefitModel(stats)
        assert m.saved((dead, dead)) == 0.0
        assert m.est_pairs((dead, dead)) == 0.0

    def test_indexed_pairs_are_exact(self, skewed_stats):
        _, stats = skewed_stats
        m = BenefitModel(stats)
        assert m.est_pairs((0, 0)) == stats.seq_pairs((0, 0))


# ---------------------------------------------------------------------- #
# controller: hysteresis, dwell, budget
# ---------------------------------------------------------------------- #


class TestAdaptationController:
    def _controller(self, **kw):
        defaults = dict(budget=1, min_count=2.0, min_benefit=1.0,
                        swap_margin=2.0, dwell=1, decay=1.0)
        defaults.update(kw)
        return AdaptationController(2, config=AdaptationConfig(**defaults))

    def test_mines_the_hot_sequence(self, skewed_stats):
        _, stats = skewed_stats
        c = self._controller()
        q = instantiate_template("T", [0, 0, 1])
        for _ in range(5):
            c.observe(q)
        ops = c.propose(stats, frozenset())
        assert ops == [("insert_interest", (0, 0))]

    def test_below_min_count_is_ignored(self, skewed_stats):
        _, stats = skewed_stats
        c = self._controller(min_count=10.0)
        for _ in range(5):
            c.observe(instantiate_template("T", [0, 0, 1]))
        assert c.propose(stats, frozenset()) == []

    def test_hysteresis_resident_defends_slot(self, skewed_stats):
        """A challenger with merely-equal benefit must NOT evict the
        resident — only a swap_margin-factor winner may."""
        _, stats = skewed_stats
        c = self._controller(dwell=0)
        q_res = instantiate_template("S", [0, 0, 2, 3])  # votes (0,0),(2,3)
        for _ in range(8):
            c.observe(q_res)
        ops = c.propose(stats, frozenset())
        assert ("insert_interest", (0, 0)) in ops
        # same traffic again: (0,0) resident, (2,3) equally hot but far
        # lower benefit — no churn
        for _ in range(8):
            c.observe(q_res)
        assert c.propose(stats, frozenset({(0, 0)})) == []

    def test_eviction_after_drift(self, skewed_stats):
        """When traffic drifts, decay + margin eventually hand the slot
        to the new hot sequence — and the swap arrives as one coalesced
        delete+insert batch."""
        _, stats = skewed_stats
        c = self._controller(decay=0.25, dwell=0)
        hot1 = instantiate_template("T", [0, 0, 1])
        for _ in range(6):
            c.observe(hot1)
        assert c.propose(stats, frozenset()) == [
            ("insert_interest", (0, 0))]
        hot2 = instantiate_template("S", [2, 3, 1, 1])  # votes (2,3),(1,1)
        for rnd in range(6):
            for _ in range(8):
                c.observe(hot2)
            ops = c.propose(stats, frozenset({(0, 0)}))
            if ops:
                assert ("delete_interest", (0, 0)) in ops
                assert any(op[0] == "insert_interest" for op in ops)
                return
        pytest.fail("drifted workload never captured the slot")

    def test_dwell_protects_fresh_admissions(self, skewed_stats):
        """Right after admission a resident cannot be evicted, even by a
        margin-clearing challenger."""
        _, stats = skewed_stats
        c = self._controller(dwell=5, decay=1.0)
        for _ in range(4):
            c.observe(instantiate_template("S", [2, 3, 1, 1]))
        ops = c.propose(stats, frozenset())
        inserts = [op for op in ops if op[0] == "insert_interest"]
        assert inserts
        admitted = inserts[0][1]
        # now a far hotter, far more beneficial challenger shows up
        for _ in range(50):
            c.observe(instantiate_template("T", [0, 0, 1]))
        ops = c.propose(stats, frozenset({admitted}))
        assert ("delete_interest", admitted) not in ops

    def test_budget_is_respected(self, skewed_stats):
        _, stats = skewed_stats
        c = self._controller(budget=2, dwell=0)
        for labels in ([0, 0, 1], [6, 6, 7]):
            for _ in range(6):
                c.observe(instantiate_template("T", labels))
        for _ in range(6):
            c.observe(instantiate_template("S", [2, 3, 1, 1]))
        ops = c.propose(stats, frozenset())
        inserts = [op for op in ops if op[0] == "insert_interest"]
        assert len(inserts) == 2  # three candidates, two slots

    def test_pair_budget_skips_oversized(self, skewed_stats):
        _, stats = skewed_stats
        c = self._controller(budget=4, dwell=0, pair_budget=10.0)
        for _ in range(6):
            c.observe(instantiate_template("T", [0, 0, 1]))  # huge seq
        ops = c.propose(stats, frozenset())
        assert ops == []  # (0,0)'s footprint alone blows the budget


# ---------------------------------------------------------------------- #
# end-to-end: adaptation can never change answers
# ---------------------------------------------------------------------- #


def _adaptive_service(g, mesh=None, **cfg):
    mi = MaintainableIndex.build(g, 2, interests=[])
    defaults = dict(budget=3, min_count=2.0, dwell=1, decay=0.5)
    defaults.update(cfg)
    adapter = AdaptationController(2, config=AdaptationConfig(**defaults))
    engine = (Engine(mi.flush()) if mesh is None
              else Engine(mi.flush(), mesh=mesh))
    return QueryService(engine, maintainer=mi, adapter=adapter,
                        adapt_interval=5, max_batch=8), mi


def _query_pool(g, rng, n=8):
    names = ["C2", "T", "S", "C4", "C2i", "St"]
    from repro.core.query import TEMPLATE_ARITY

    present = np.unique(g.lbl)
    out = []
    for i in range(n):
        name = names[i % len(names)]
        labels = rng.choice(present, TEMPLATE_ARITY[name]).tolist()
        out.append(instantiate_template(name, labels))
    return out


def _random_graph_ops(g, rng, n=2):
    base = g._base_edges()
    ops = []
    for _ in range(n):
        if rng.random() < 0.5 or base.shape[0] == 0:
            ops.append(("insert_edge", int(rng.integers(0, g.n_vertices)),
                        int(rng.integers(0, g.n_vertices)),
                        int(rng.integers(0, g.n_labels))))
        else:
            e = base[int(rng.integers(0, base.shape[0]))]
            ops.append(("delete_edge", int(e[0]), int(e[1]), int(e[2])))
    return ops


class TestAdaptiveEndToEnd:
    def test_interleaved_queries_updates_adaptation(self):
        """Queries, graph updates and forced adaptation rounds in one
        stream: every answer equals the oracle on the current graph (==
        a never-adapted full index by the oracle's own equivalence)."""
        g = random_graph(41, n_max=12, m_max=26)
        svc, mi = _adaptive_service(g)
        rng = np.random.default_rng(41)
        for step in range(4):
            pool = _query_pool(mi.g, rng)
            for q in pool:
                assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q), q
            if step % 2 == 0:
                svc.apply_updates(_random_graph_ops(mi.g, rng))
            svc.adapt()
        svc.flush()
        # the loop actually adapted (non-vacuous test)
        assert svc.stats.adapt_rounds >= 4
        for q in _query_pool(mi.g, rng):
            assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q), q

    def test_adaptation_matches_never_adapted_full_index(self):
        """The tentpole invariant, verbatim: an adapted service and a
        full-CPQx engine rebuilt on the same graph agree on every
        probe at every step."""
        g = random_graph(43, n_max=11, m_max=24)
        svc, mi = _adaptive_service(g)
        rng = np.random.default_rng(43)
        for step in range(3):
            svc.apply_updates(_random_graph_ops(mi.g, rng, n=2))
            pool = _query_pool(mi.g, rng, n=6)
            for q in pool:
                svc.query(q)  # traffic the adaptation round prices
            svc.adapt()
            svc.flush()
            full = Engine(cindex.build(mi.g, 2))
            for q in pool:
                assert (_rows(svc.query(q)) == _rows(full.execute(q))
                        == oracle.cpq_eval(mi.g, q)), q

    def test_sharded_adaptive_service(self):
        """The same loop off a sharded backend: adaptation flushes
        reshard at rebind and answers stay oracle-identical."""
        import jax

        from repro import compat

        mesh = compat.make_mesh((max(1, jax.device_count()),), ("engine",))
        g = random_graph(47, n_max=10, m_max=22)
        svc, mi = _adaptive_service(g, mesh=mesh)
        rng = np.random.default_rng(47)
        for step in range(3):
            for q in _query_pool(mi.g, rng, n=5):
                assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q), q
            svc.apply_updates(_random_graph_ops(mi.g, rng, n=1))
            svc.adapt()
        svc.flush()
        assert svc.stats.adapt_rounds >= 3
        for q in _query_pool(mi.g, rng, n=5):
            assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q), q

    def test_property_interleavings(self):
        """Hypothesis: arbitrary interleavings of queries, graph
        updates, interest writes and adaptation rounds leave every
        answer equal to the oracle (and hence to a never-adapted full
        index) on the live graph."""
        hypothesis = pytest.importorskip("hypothesis")
        given, settings, st = (hypothesis.given, hypothesis.settings,
                               hypothesis.strategies)

        @settings(max_examples=10, deadline=None)
        @given(seed=st.integers(0, 10_000),
               script=st.lists(st.sampled_from(["q", "u", "a", "i"]),
                               min_size=4, max_size=10))
        def run(seed, script):
            g = random_graph(seed % 89, n_max=10, m_max=20)
            svc, mi = _adaptive_service(g)
            rng = np.random.default_rng(seed)
            for action in script:
                if action == "q":
                    for q in _query_pool(mi.g, rng, n=3):
                        assert _rows(svc.query(q)) == \
                            oracle.cpq_eval(mi.g, q), (action, q)
                elif action == "u":
                    svc.apply_updates(_random_graph_ops(mi.g, rng, n=1))
                elif action == "a":
                    svc.adapt()
                else:  # a manual interest write, coalesced like any other
                    l1 = int(rng.integers(0, mi.g.alphabet_size))
                    l2 = int(rng.integers(0, mi.g.alphabet_size))
                    if rng.random() < 0.5:
                        svc.insert_interest((l1, l2))
                    else:
                        svc.delete_interest((l1, l2))
            svc.flush()
            for q in _query_pool(mi.g, rng, n=3):
                assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q), q

        run()


class TestMultiTenantAdaptation:
    def test_sketches_are_isolated_per_tenant(self):
        c = AdaptationController(2)
        q = instantiate_template("T", [0, 0, 1])
        c.observe(q, tenant="a")
        assert c.sketch_for("a").count((0, 0)) == 1
        assert c.sketch_for("b").count((0, 0)) == 0
        assert c.sketch.count((0, 0)) == 0  # default tenant untouched

    def test_round_robin_budget_arbitration(self, skewed_stats):
        """`budget` is PER TENANT, admitted round-robin under the global
        pair budget: a tenant flooding its sketch cannot consume another
        tenant's adaptation capacity."""
        _, stats = skewed_stats
        c = AdaptationController(2, config=AdaptationConfig(
            budget=1, min_count=2.0, min_benefit=1.0, swap_margin=2.0,
            dwell=1, decay=1.0))
        hot_a = instantiate_template("T", [0, 0, 1])  # votes (0, 0)
        hot_b = instantiate_template("S", [2, 3, 1, 1])  # (2,3), (1,1)
        for _ in range(50):  # a floods
            c.observe(hot_a, tenant="a")
        for _ in range(6):  # b is merely warm
            c.observe(hot_b, tenant="b")
        ops = c.propose(stats, frozenset())
        inserts = {op[1] for op in ops if op[0] == "insert_interest"}
        assert (0, 0) in inserts  # tenant a's slot
        assert inserts & {(2, 3), (1, 1)}  # b still got its own slot
        assert len(inserts) == 2  # one per tenant under budget=1

    def test_single_tenant_path_is_the_legacy_controller(self, skewed_stats):
        """With one tenant the arbitration degenerates to the PR 5
        controller: the `sketch` property aliases the default tenant."""
        _, stats = skewed_stats
        c = AdaptationController(2, config=AdaptationConfig(
            budget=1, min_count=2.0, min_benefit=1.0, swap_margin=2.0,
            dwell=1, decay=1.0))
        for _ in range(5):
            c.observe(instantiate_template("T", [0, 0, 1]))
        assert c.sketch.count((0, 0)) == 5
        assert c.propose(stats, frozenset()) == [
            ("insert_interest", (0, 0))]

    def test_multi_tenant_codec_round_trip(self):
        c = AdaptationController(2)
        c.observe(instantiate_template("T", [0, 0, 1]), tenant="a")
        c.observe(instantiate_template("S", [2, 3, 1, 1]), weight=2.0,
                  tenant="beta-2")
        c2 = AdaptationController.from_state(c.export_state())
        assert sorted(c2.sketches) == sorted(c.sketches)
        assert c2.sketch_for("a").count((0, 0)) == 1
        assert c2.sketch_for("beta-2").count((2, 3)) == 2.0


def _serializable_prefix_script(seed, script):
    """Drive one interleaving of burst-submitted reads ('s'), graph
    writes ('u'), adaptation rounds ('a') and manual flushes ('f')
    through an auto_flush=False adaptive service, asserting the
    serializable-prefix contract: every answer equals the oracle on the
    graph AS OF THE REQUEST'S SUBMISSION — a write accepted after a
    submit is never visible to it.  Bug 1's schedule class (adapt()
    firing with reads still queued) is reachable via 'a'."""
    from repro.core.graph import LabeledGraph

    g = random_graph(seed % 83, n_max=9, m_max=18)
    mi = MaintainableIndex.build(g, 2, interests=[])
    adapter = AdaptationController(2, config=AdaptationConfig(
        budget=2, min_count=2.0, dwell=1, decay=0.5))
    svc = QueryService(Engine(mi.flush()), maintainer=mi,
                       adapter=adapter, adapt_interval=4,
                       max_batch=4, auto_flush=False)
    rng = np.random.default_rng(seed)
    # the prefix of writes ACCEPTED so far, mirrored host-side (the
    # service's own mirror only advances at drain time)
    shadow = {tuple(map(int, e)) for e in g._base_edges()}
    expected = []  # (request, oracle truth at submit time)

    def shadow_graph():
        return LabeledGraph.from_edges(g.n_vertices, g.n_labels,
                                       sorted(shadow))

    for action in script:
        if action == "s":
            sg = shadow_graph()
            for q in _query_pool(sg, rng, n=2):
                expected.append((svc.submit(q), oracle.cpq_eval(sg, q)))
        elif action == "u":
            if len(shadow) > 1 and rng.random() < 0.5:
                e = sorted(shadow)[int(rng.integers(0, len(shadow)))]
                shadow.discard(e)
                svc.apply_updates([("delete_edge", *e)])
            else:
                e = (int(rng.integers(0, g.n_vertices)),
                     int(rng.integers(0, g.n_vertices)),
                     int(rng.integers(0, g.n_labels)))
                shadow.add(e)
                svc.apply_updates([("insert_edge", *e)])
        elif action == "a":
            svc.adapt()
        else:
            svc.flush()
    svc.flush()
    for req, truth in expected:
        assert req.done and not req.shed
        assert _rows(req.result) == truth, req.query


class TestSerializablePrefixProperty:
    def test_fixed_interleavings(self):
        """Deterministic schedules covering the Bug 1 class and its
        neighbors: reads queued across adaptation rounds, writes
        between bursts, back-to-back writes, adapt-then-write."""
        for seed, script in [
            (7, ["s", "a", "s", "u", "f"]),  # Bug 1: adapt on a queue
            (19, ["s", "u", "s", "a", "f"]),
            (23, ["s", "s", "u", "u", "a", "s", "f"]),
            (41, ["u", "s", "a", "u", "s", "f", "a"]),
        ]:
            _serializable_prefix_script(seed, script)

    def test_property_queued_reads_see_only_prior_writes(self):
        hypothesis = pytest.importorskip("hypothesis")
        given, settings, st = (hypothesis.given, hypothesis.settings,
                               hypothesis.strategies)

        @settings(max_examples=8, deadline=None)
        @given(seed=st.integers(0, 10_000),
               script=st.lists(st.sampled_from(["s", "u", "a", "f"]),
                               min_size=4, max_size=12))
        def run(seed, script):
            _serializable_prefix_script(seed, script)

        run()


class TestVoteAccounting:
    def test_folded_duplicates_and_cache_hits_still_vote(self):
        """N submissions of one hot template must credit ~N votes, not
        1: in-flight duplicates fold into one execution and repeats are
        served from the result cache, but both ARE workload — the
        sketch must see the true frequency or it starves exactly when a
        sequence is hottest."""
        g = random_graph(53, n_max=10, m_max=22)
        svc, mi = _adaptive_service(g)
        svc.adapt_interval = 10_000  # isolate vote accounting
        q = instantiate_template("T", [0, 0, 1])  # votes (0, 0)
        for _ in range(6):  # fold into ONE execution at flush
            svc.submit(q)
        svc.flush()
        assert svc.adapter.sketch.count((0, 0)) == 6
        for _ in range(4):  # served from the result cache
            svc.submit(q)
        assert svc.adapter.sketch.count((0, 0)) == 10


class TestServiceInterestCoalescing:
    def test_interest_and_graph_updates_share_one_flush(self, ex_graph):
        """The satellite fix, verbatim: interest writes issued through
        the service coalesce with queued graph updates into ONE
        maintenance round (one update_batch, one rebind) instead of
        forcing their own."""
        mi = MaintainableIndex.build(ex_graph, 2, interests=[])
        svc = QueryService(Engine(mi.flush()), maintainer=mi, max_batch=16)
        q = instantiate_template("C2", [0, 0])
        before = _rows(svc.query(q))

        svc.apply_updates([("insert_edge", 2, 3, 0)])
        svc.insert_interest((0, 0))
        svc.apply_updates([("delete_edge", 0, 1, 0)])
        assert svc.pending_updates == 3  # still queued, nothing flushed
        assert svc.stats.update_batches == 0

        got = _rows(svc.query(q))
        assert svc.stats.update_batches == 1  # ONE coalesced round
        assert svc.stats.updates_applied == 3
        assert svc.stats.interests_inserted == 1
        assert (0, 0) in mi.index.interests
        assert got == oracle.cpq_eval(mi.g, q) != before

    def test_interest_delete_coalesces_too(self, ex_graph):
        mi = MaintainableIndex.build(ex_graph, 2, interests=[(0, 0)])
        svc = QueryService(Engine(mi.flush()), maintainer=mi)
        q = instantiate_template("C2", [0, 0])
        svc.delete_interest((0, 0))
        assert svc.pending_updates == 1
        assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q)
        assert (0, 0) not in mi.index.interests
        assert svc.stats.interests_deleted == 1

    def test_interest_ops_rejected_without_interest_aware_maintainer(
            self, ex_graph):
        mi = MaintainableIndex.build(ex_graph, 2)  # full CPQx
        svc = QueryService(Engine(mi.flush()), maintainer=mi)
        with pytest.raises(ValueError, match="interest-aware"):
            svc.insert_interest((0, 0))
        assert svc.pending_updates == 0

    def test_invalid_interest_rejected_at_enqueue(self, ex_graph):
        mi = MaintainableIndex.build(ex_graph, 2, interests=[])
        svc = QueryService(Engine(mi.flush()), maintainer=mi)
        with pytest.raises(ValueError, match="length"):
            svc.insert_interest((0, 0, 0))  # k == 2
        with pytest.raises(ValueError, match="alphabet"):
            svc.insert_interest((0, 99))
        assert svc.pending_updates == 0

    def test_adapter_requires_interest_aware_maintainer(self, ex_graph):
        mi = MaintainableIndex.build(ex_graph, 2)
        with pytest.raises(ValueError, match="interest-aware"):
            QueryService(Engine(mi.flush()), maintainer=mi,
                         adapter=AdaptationController(2))

    def test_adapter_k_must_fit_the_index(self, ex_graph):
        """An adapter harvesting windows longer than the index's k would
        propose uninsertable interests — rejected at construction."""
        mi = MaintainableIndex.build(ex_graph, 2, interests=[])
        with pytest.raises(ValueError, match="k=3"):
            QueryService(Engine(mi.flush()), maintainer=mi,
                         adapter=AdaptationController(3))

    def test_adapt_drops_invalid_proposals(self, ex_graph):
        """A proposal the mirror would reject is dropped at adapt time,
        never queued — one bad op must not poison every later coalesced
        round (the queue invariant, applied to the controller too)."""
        mi = MaintainableIndex.build(ex_graph, 2, interests=[])
        svc = QueryService(Engine(mi.flush()), maintainer=mi,
                           adapter=AdaptationController(2))
        svc.adapter.propose = lambda stats, cur: [
            ("insert_interest", (0, 0, 0)),  # len 3 > k
            ("insert_interest", (0, 99)),  # label outside the alphabet
            ("insert_interest", (0, 0)),  # valid
        ]
        assert svc.adapt() == [("insert_interest", (0, 0))]
        q = instantiate_template("C2", [0, 0])
        assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q)  # drains
        assert (0, 0) in mi.index.interests
        assert svc.pending_updates == 0  # nothing stuck, nothing poisoned
