"""Sharded index layout tests — host-side partitioning and the
shard/gather round-trip.  These run on the single real CPU device: the
layout math (hash placement, per-shard CSR, grow-and-retry) is pure
numpy; device-mesh execution is covered by test_sharded_backend (1
shard, in-process) and test_distributed (8 fake devices, subprocess)."""

import numpy as np
import pytest

from repro.core import distributed as D
from repro.core import index as cindex
from repro.core import relational as R
from repro.core.graph import example_graph
from repro.core.sharded_index import (
    gather_index,
    hash_buckets,
    partition_rows,
    shard_index,
)


def _rand_rows(n, hi=40, arity=3, seed=0):
    rng = np.random.default_rng(seed)
    return np.unique(rng.integers(0, hi, (n, arity)).astype(np.int32), axis=0)


class TestPartitionRows:
    def test_matches_legacy_per_shard_loop(self):
        """The vectorized partitioner reproduces the original per-shard
        sort loop exactly: same placement (device-compatible hash), same
        within-shard lexicographic order, same padding."""
        rows = _rand_rows(300)
        n_shards = 8
        bucket = hash_buckets(rows, (0,), n_shards)
        blocks, counts, cap = partition_rows(rows, n_shards, 128)
        assert cap == 128
        for b in range(n_shards):
            rb = rows[bucket == b]
            rb = rb[np.lexsort((rb[:, 2], rb[:, 1], rb[:, 0]))]
            assert counts[b] == rb.shape[0]
            assert np.array_equal(blocks[b, : rb.shape[0]], rb)
            assert np.all(blocks[b, rb.shape[0]:] == R.SENTINEL)

    def test_indivisible_row_count_and_empty_shards(self):
        """n_shards neither divides the row count nor receives rows on
        every shard — tiny inputs leave some shards empty."""
        rows = _rand_rows(5, hi=4, seed=3)  # 5 rows over 8 shards
        blocks, counts, _ = partition_rows(rows, 8, 16)
        assert counts.sum() == rows.shape[0]
        assert (counts == 0).any()  # pigeonhole: at least 3 empty shards
        got = np.concatenate([blocks[s, : counts[s]] for s in range(8)])
        got = got[np.lexsort((got[:, 2], got[:, 1], got[:, 0]))]
        assert np.array_equal(got, rows[np.lexsort(
            (rows[:, 2], rows[:, 1], rows[:, 0]))])

    def test_zero_rows(self):
        blocks, counts, _ = partition_rows(np.zeros((0, 3), np.int32), 4, 8)
        assert blocks.shape == (4, 8, 3)
        assert counts.sum() == 0
        assert np.all(blocks == R.SENTINEL)

    def test_overflow_grows_and_retries(self):
        """A skewed shard outgrowing the requested capacity doubles the
        block capacity instead of failing (the host twin of the device
        overflow ladder); grow=False restores the fail-fast error."""
        rows = np.stack([np.full(50, 7, np.int32),  # all on one shard
                         np.arange(50, dtype=np.int32),
                         np.arange(50, dtype=np.int32)], axis=1)
        blocks, counts, cap = partition_rows(rows, 4, 16)
        assert cap == 64 and blocks.shape[1] == 64  # 16 -> 32 -> 64
        assert counts.max() == 50
        with pytest.raises(ValueError, match="shard overflow"):
            partition_rows(rows, 4, 16, grow=False)

    def test_shard_relation_wrapper_grows(self):
        rows = np.stack([np.full(40, 3, np.int32),
                         np.arange(40, dtype=np.int32)], axis=1)
        blocks, counts = D.shard_relation(rows, 4, 8)
        assert blocks.shape[1] == 64 and counts.max() == 40

    def test_multi_column_key_spreads_pair_table(self):
        """(v, u) hash-combined keys place rows with equal v on different
        shards (unlike key_col=0) while keeping every row exactly once."""
        v = np.zeros(64, np.int32)
        rows = np.stack([v, np.arange(64, dtype=np.int32)], axis=1)
        b0 = hash_buckets(rows, (0,), 8)
        b01 = hash_buckets(rows, (0, 1), 8)
        assert len(set(b0.tolist())) == 1
        assert len(set(b01.tolist())) > 1
        blocks, counts, _ = partition_rows(rows, 8, 64, key_cols=(0, 1))
        assert counts.sum() == 64


class TestHashParity:
    def test_host_bucket_matches_device_bucket(self):
        """The documented invariant 'host placement == device
        repartitioning': hash_buckets on the host and _bucket_of on the
        device must place every key identically (they share
        relational.mix32's constants and SHARD_SALT)."""
        import jax.numpy as jnp

        keys = np.concatenate([np.arange(512, dtype=np.int32),
                               np.array([0, 1, 2**30, 2**31 - 2], np.int32)])
        for n_shards in (1, 3, 8):
            host = hash_buckets(keys.reshape(-1, 1), (0,), n_shards)
            dev = np.asarray(D._bucket_of(jnp.asarray(keys), n_shards))
            assert np.array_equal(host, dev.astype(np.int64)), n_shards


class TestShardGatherRoundTrip:
    @pytest.mark.parametrize("n_shards", [1, 3, 8])
    def test_round_trip_is_bit_identical(self, n_shards):
        idx = cindex.build(example_graph(), 2)
        sharded = shard_index(idx, n_shards)
        assert sharded.n_shards == n_shards
        assert int(np.asarray(sharded.c2p_counts).sum()) == idx.n_pairs
        assert int(np.asarray(sharded.pair_counts).sum()) == idx.n_pairs
        back = gather_index(sharded, pair_cap=int(idx.arrays.c2p_v.shape[0]))
        for f in ("pair_v", "pair_u", "pair_cls", "c2p_cls", "c2p_v",
                  "c2p_u", "class_starts", "class_cyclic", "l2c_cls",
                  "seq_table", "seq_starts", "seq_ends"):
            a = np.asarray(getattr(idx.arrays, f))
            b = np.asarray(getattr(back, f))
            assert np.array_equal(a, b), f
        assert int(back.pair_count) == idx.n_pairs
        assert int(back.n_classes) == idx.n_classes

    def test_classes_stay_whole(self):
        """Class-hash sharding keeps each equivalence class on exactly
        one shard, and the per-shard CSR ranges tile each shard's rows."""
        idx = cindex.build(example_graph(), 2)
        sharded = shard_index(idx, 4)
        ccls = np.asarray(sharded.c2p_cls)
        counts = np.asarray(sharded.c2p_counts)
        owner: dict = {}
        for s in range(4):
            for c in np.unique(ccls[s, : counts[s]]):
                assert int(c) not in owner, "class split across shards"
                owner[int(c)] = s
        assert len(owner) == idx.n_classes
        starts = np.asarray(sharded.class_starts)
        for s in range(4):
            sizes = starts[s, 1:] - starts[s, :-1]
            assert sizes.sum() == counts[s]
            for c, sz in enumerate(sizes):
                if sz:
                    assert owner[c] == s
                    seg = ccls[s, starts[s, c]: starts[s, c + 1]]
                    assert np.all(seg == c)
