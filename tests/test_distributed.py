"""Distributed engine + sharded model tests — run in a subprocess with 8
forced host devices (the main test process must keep 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestDistributedEngine:
    def test_distributed_join_matches_ground_truth(self):
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.core import distributed as D

            mesh = compat.make_mesh((8,), ("engine",))
            rng = np.random.default_rng(0)
            A = np.unique(rng.integers(0, 30, (200, 2)).astype(np.int32), axis=0)
            B = np.unique(rng.integers(0, 30, (180, 2)).astype(np.int32), axis=0)
            gt = sorted({(int(v), int(u)) for v, m in A for m2, u in B if m == m2})
            a_blocks, a_counts = D.shard_relation(A, 8, 128, key_col=0)
            b_blocks, b_counts = D.shard_relation(B, 8, 128, key_col=1)
            a_cols = tuple(jnp.asarray(a_blocks[:, :, j]) for j in range(2))
            b_cols = tuple(jnp.asarray(b_blocks[:, :, j]) for j in range(2))
            join = D.make_distributed_join(mesh, "engine", 8, 2, 2,
                                           bucket_cap=128, out_cap=4096)
            with compat.set_mesh(mesh):
                oc, on, ovf = join(a_cols, jnp.asarray(a_counts),
                                   b_cols, jnp.asarray(b_counts))
            assert not np.asarray(ovf).any()
            ov, ou, cnt = np.asarray(oc[0]), np.asarray(oc[1]), np.asarray(on)
            rows = sorted({(int(ov[s, i]), int(ou[s, i]))
                           for s in range(8) for i in range(cnt[s])})
            assert rows == gt, (len(rows), len(gt))
            print("JOIN_OK", len(rows))
        """)
        assert "JOIN_OK" in out

    def test_distributed_query_step(self):
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.core import distributed as D
            from repro.core import relational as R

            mesh = compat.make_mesh((8,), ("engine",))
            rng = np.random.default_rng(1)
            n_cls = 40
            c2p = np.unique(rng.integers(0, 25, (300, 3)).astype(np.int32), axis=0)
            c2p[:, 0] = rng.integers(0, n_cls, c2p.shape[0])
            c2p = c2p[np.lexsort((c2p[:,2], c2p[:,1], c2p[:,0]))]
            ca = np.unique(rng.choice(n_cls, 10)).astype(np.int32)
            cb = np.unique(rng.choice(n_cls, 12)).astype(np.int32)
            inter = set(ca) & set(cb)
            gt = sorted({(int(r[1]), int(r[2])) for r in c2p if r[0] in inter})
            blocks, counts = D.shard_relation(c2p, 8, 128, key_col=0)
            cols = tuple(jnp.asarray(blocks[:, :, j]) for j in range(3))
            def padded(x, n):
                out = np.full(n, R.SENTINEL, np.int32); out[:len(x)] = x
                return jnp.asarray(out)
            step = D.make_distributed_query_step(mesh, "engine")
            with compat.set_mesh(mesh):
                (pv, pu), pc = step(padded(ca, 16), padded(cb, 16),
                                    cols[0], cols[1], cols[2],
                                    jnp.asarray(counts))
            pv, pu, pc = np.asarray(pv), np.asarray(pu), np.asarray(pc)
            got = sorted({(int(pv[s,i]), int(pu[s,i]))
                          for s in range(8) for i in range(pc[s])})
            assert got == gt
            print("QUERY_OK", len(got))
        """)
        assert "QUERY_OK" in out

    def test_compressed_allreduce(self):
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro import compat
            from repro.train import compress

            mesh = compat.make_mesh((8,), ("dp",))
            rng = np.random.default_rng(0)
            g_all = rng.normal(0, 1, (8, 1024)).astype(np.float32)
            state = compress.compress_init({"g": jnp.zeros(1024)})

            def body(g, res):
                mean, new_state = compress.compressed_psum_grads(
                    {"g": g}, compress.CompressState({"g": res}), "dp")
                return mean["g"], new_state.residual["g"]

            fn = jax.jit(compat.shard_map(body, mesh=mesh,
                                          in_specs=(P("dp"), P("dp")),
                                          out_specs=(P("dp"), P("dp"))))
            with compat.set_mesh(mesh):
                g_in = jnp.asarray(g_all.reshape(-1))
                res = jnp.zeros_like(g_in)
                mean, res = fn(g_in, res)
            mean = np.asarray(mean).reshape(8, 1024)
            true_mean = g_all.mean(0)
            # every shard holds the same (approximate) mean
            for s in range(8):
                rel = np.linalg.norm(mean[s] - true_mean) / np.linalg.norm(true_mean)
                assert rel < 0.05, rel
            print("COMPRESS_OK")
        """)
        assert "COMPRESS_OK" in out

    def test_sharded_lm_step_runs(self):
        """Tiny LM train step actually EXECUTES on an 8-device mesh with
        the production sharding rules (not just lowers)."""
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro import compat
            from repro.configs import get_arch
            from repro.launch import shardings as S
            from repro.models import transformer as T
            from repro.train.optim import adamw_init, adamw_update

            mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
            cfg = get_arch("gemma2-2b").smoke
            params = T.init_params(cfg, jax.random.PRNGKey(0))
            pspecs = S.lm_param_specs(cfg, mesh)
            shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
            params = jax.device_put(params, shard)
            opt = adamw_init(params)
            toks = jnp.zeros((8, 16), jnp.int32)

            def step(p, o, t):
                def lf(p):
                    return T.train_loss(cfg, p, t, t)
                (loss, _), g = jax.value_and_grad(lf, has_aux=True)(p)
                np_, no, _ = adamw_update(g, o, p, 1e-3)
                return np_, no, loss

            with compat.set_mesh(mesh):
                jstep = jax.jit(step)
                p2, o2, loss = jstep(params, opt, toks)
                p3, o3, loss2 = jstep(p2, o2, toks)
            assert np.isfinite(float(loss)) and float(loss2) < float(loss) + 1.0
            print("LM_SHARDED_OK", float(loss))
        """)
        assert "LM_SHARDED_OK" in out
