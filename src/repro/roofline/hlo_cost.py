"""Scan-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 64 transformer layers reports 1/64th of the real FLOPs
(verified by calibration; see EXPERIMENTS.md §Roofline-methodology).
This module re-derives the three roofline numerators from
``compiled.as_text()`` with loop multiplicities:

  * per-computation symbol tables (instruction name -> output shape) so
    dot FLOPs use true operand shapes;
  * call graph: while bodies/conditions (trip count from the while
    instruction's ``backend_config known_trip_count``), fusions, calls,
    conditionals;
  * multiplicity propagation from ENTRY;
  * per computation:
      - dot/convolution FLOPs,
      - HBM traffic = operand + output bytes of top-level instructions
        (fusion children excluded — the fusion is the traffic unit),
      - collective bytes by kind.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[^,()]+))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_KW = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# HBM-traffic model: the CPU-optimized HLO we analyze leaves elementwise
# chains unfused (a TPU build fuses them into their consumers), so traffic
# counts only *materialization points* — ops whose operands/outputs
# genuinely stream through HBM on TPU.  Elementwise/shape ops are assumed
# perfectly fused (optimistic); dots/reductions/gathers/scatters/
# dynamic-slices/collectives/fusions are counted with operands+outputs.
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "sort", "dynamic-slice", "dynamic-update-slice", "copy",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "transpose", "select-and-scatter", "cholesky", "triangular-solve",
    "rng", "rng-bit-generator", "iota", "pad", "concatenate", "slice",
    "reverse", "custom-call",
}


def _dims_prod(dims):
    n = 1
    for d in dims:
        n *= d
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        total += _dims_prod(dims) * _DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    return (m.group(1), [int(x) for x in m.group(2).split(",") if x])


@dataclasses.dataclass
class Comp:
    name: str
    symtab: dict  # instruction/param name -> type string
    instrs: list  # (name, out_type, opcode, args_str, full_rhs)
    callees: list  # (callee_name, via_opcode)
    whiles: list  # (body, cond, trips)
    is_fusion_child: bool = False


def _split_computations(hlo: str):
    """Yield (header_line, [body lines]) for each computation block."""
    lines = hlo.splitlines()
    i = 0
    while i < len(lines):
        line = lines[i]
        s = line.strip()
        if (s.endswith("{") and ("->" in s)
                and (s.startswith("%") or s.startswith("ENTRY"))):
            body = []
            i += 1
            while i < len(lines) and lines[i].strip() != "}":
                body.append(lines[i])
                i += 1
            yield line, body
        i += 1


def _parse_comp(header: str, body: list) -> Comp:
    is_entry = header.strip().startswith("ENTRY")
    name_m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)", header.strip())
    name = name_m.group(1) if name_m else "?"
    symtab: dict[str, str] = {}
    # parameters from the header signature
    sig = header[header.index("("): header.rindex("->")] if "->" in header else ""
    for pm in _PARAM_RE.finditer(sig):
        symtab[pm.group(1)] = pm.group(2)
    instrs = []
    callees = []
    whiles = []
    for line in body:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        iname, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.search(" " + rhs)
        opcode = om.group(1) if om else "?"
        # output type = everything before the opcode occurrence
        cut = rhs.find(f"{opcode}(")
        out_type = rhs[:cut].strip() if cut > 0 else rhs
        symtab[iname] = out_type
        paren = rhs.find("(", cut if cut >= 0 else 0)
        args = rhs[paren + 1: rhs.find(")", paren)] if paren >= 0 else ""
        instrs.append((iname, out_type, opcode, args, rhs))
        if opcode == "while":
            bm = re.search(r"body=%?([\w.\-]+)", rhs)
            cm = re.search(r"condition=%?([\w.\-]+)", rhs)
            tm = _TRIP_RE.search(rhs)
            trips = int(tm.group(1)) if tm else 1
            if bm and cm:
                whiles.append((bm.group(1), cm.group(1), trips))
        else:
            for cm in _CALL_KW.finditer(rhs):
                callees.append((cm.group(1), opcode))
            br = _BRANCHES.search(rhs)
            if br:
                for b in br.group(1).split(","):
                    callees.append((b.strip().lstrip("%"), "conditional"))
    return Comp(name, symtab, instrs, callees, whiles), is_entry


def _dot_flops(comp: Comp, out_type: str, args: str, rhs: str) -> float:
    out = _first_shape(out_type)
    if out is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    ops = re.findall(r"%([\w.\-]+)", args)
    if not m or not ops:
        return 0.0
    lhs_type = comp.symtab.get(ops[0], "")
    lhs = _first_shape(lhs_type)
    if lhs is None:
        return 0.0
    csize = 1
    for ci in (int(x) for x in m.group(1).split(",") if x):
        if ci < len(lhs[1]):
            csize *= lhs[1][ci]
    return 2.0 * _dims_prod(out[1]) * csize


def _conv_flops(comp: Comp, out_type: str, args: str, rhs: str) -> float:
    out = _first_shape(out_type)
    ops = re.findall(r"%([\w.\-]+)", args)
    if out is None or len(ops) < 2:
        return 0.0
    ker = _first_shape(comp.symtab.get(ops[1], ""))
    if ker is None:
        return 0.0
    return 2.0 * _dims_prod(out[1]) * _dims_prod(ker[1][:-1])


def _instr_traffic(comp: Comp, out_type: str, opcode: str, args: str) -> float:
    if opcode not in _TRAFFIC_OPS:
        return 0.0
    total = float(_type_bytes(out_type))
    for op in re.findall(r"%([\w.\-]+)", args):
        total += _type_bytes(comp.symtab.get(op, ""))
    return total


def analyze_hlo(hlo: str) -> dict:
    comps: dict[str, Comp] = {}
    entry = None
    for header, body in _split_computations(hlo):
        comp, is_entry = _parse_comp(header, body)
        comps[comp.name] = comp
        if is_entry:
            entry = comp.name
    if entry is None and comps:
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0,
                "collectives": {"bytes_by_kind": {}, "counts_by_kind": {},
                                "total_bytes": 0.0},
                "loops": [], "n_computations": 0}

    # mark fusion children (their instruction traffic is internal)
    for comp in comps.values():
        for callee, via in comp.callees:
            if via == "fusion" and callee in comps:
                comps[callee].is_fusion_child = True

    mult: dict[str, float] = defaultdict(float)
    loops = []

    def visit(name: str, k: float, depth=0):
        if name not in comps or depth > 60 or k <= 0:
            return
        comp = comps[name]
        mult[name] += k
        for body, cond, trips in comp.whiles:
            loops.append({"body": body, "trips": trips})
            visit(body, k * trips, depth + 1)
            visit(cond, k * (trips + 1), depth + 1)
        seen = set()
        for callee, via in comp.callees:
            if via in ("sort", "reduce", "reduce-window", "scatter",
                       "select-and-scatter", "map", "reduce-scatter",
                       "all-reduce"):
                continue  # comparators/reducers: no dots, per-element cost
            if callee in seen:
                continue
            seen.add(callee)
            visit(callee, k, depth + 1)

    visit(entry, 1.0)

    flops = 0.0
    traffic = 0.0
    coll: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, float] = defaultdict(float)
    for name, k in mult.items():
        comp = comps[name]
        for iname, out_type, opcode, args, rhs in comp.instrs:
            if opcode == "dot":
                flops += k * _dot_flops(comp, out_type, args, rhs)
            elif opcode == "convolution":
                flops += k * _conv_flops(comp, out_type, args, rhs)
            if not comp.is_fusion_child:
                traffic += k * _instr_traffic(comp, out_type, opcode, args)
            for kind in COLLECTIVES:
                if opcode == kind or opcode == f"{kind}-start":
                    b = _type_bytes(out_type)
                    coll[kind] += k * b
                    coll_counts[kind] += k
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collectives": {
            "bytes_by_kind": dict(coll),
            "counts_by_kind": {kk: int(v) for kk, v in coll_counts.items()},
            "total_bytes": float(sum(coll.values())),
        },
        "loops": loops,
        "n_computations": len(comps),
    }
