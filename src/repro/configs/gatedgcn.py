"""gatedgcn [arXiv:2003.00982; paper]: 16L d_hidden=70, gated edge
aggregation (benchmark-GNNs config)."""

import dataclasses

from repro.configs import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="gatedgcn", arch="gatedgcn", n_layers=16, d_hidden=70,
    d_in=64, d_out=1, d_edge_in=4,
)

SMOKE = dataclasses.replace(CONFIG, n_layers=3, d_hidden=16, d_in=8)

SPEC = ArchSpec(
    arch_id="gatedgcn", family="gnn", config=CONFIG, smoke=SMOKE,
    shapes=gnn_shapes(),
    notes="edge-gated aggregation; LN instead of BN (TPU-friendly).",
)
