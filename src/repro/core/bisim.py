"""Device-side k-path-bisimulation partition — Algorithm 1 on TPU.

The paper's CPQPATHPARTITION builds, per level i, the set

    S^i_{(v,u)} = { (b_{i-1}(v,m), b_1(m,u)) : m intermediate }

and assigns block id b_i(v,u) by grouping equal sets (plus the cycle
flag).  The C++ artifact sorts std::vectors of sets; here each set is
reduced to an order-invariant two-lane uint32 fingerprint (after exact
dedup of its elements) and block ids are *exact dense ranks* over
(cycle, fingerprint) — sorted with one multi-operand ``jax.lax.sort``.

Final class ids are dense ranks over the signature (cycle, b_1..b_k)
with b_i = -1 (Null) where the pair has no length-i path — exactly the
paper's hash-consed signature, made collision-aware: the only hashing is
the 64-bit set fingerprint (the paper hashes too, Alg. 2 line 4).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import relational as R
from .paths import DeviceGraph, _recap


class PartitionResult(NamedTuple):
    """Per-level pair tables + final classes.

    level_pairs : tuple of Relations, level i: (v, u, b_i) sorted by (v,u)
    pairs       : Relation (v, u, class_id) over P^{<=k}, sorted by (v, u)
    n_classes   : scalar int32
    overflow    : scalar bool
    """

    level_pairs: tuple
    pairs: R.Relation
    n_classes: jax.Array
    overflow: jax.Array


def _fp_cols(f1: jax.Array, f2: jax.Array) -> tuple:
    """Split two uint32 fingerprints into four non-negative int32 columns
    (so they can serve as sort keys under the SENTINEL convention)."""
    return (
        (f1 >> 16).astype(R.I32),
        (f1 & jnp.uint32(0xFFFF)).astype(R.I32),
        (f2 >> 16).astype(R.I32),
        (f2 & jnp.uint32(0xFFFF)).astype(R.I32),
    )


def _rank_pairs_by_set(rows: R.Relation, set_cols: tuple, salt: int):
    """Group sorted, deduped incidence rows (v, u, *set_item) into per-pair
    sets, fingerprint each set, and dense-rank pairs by
    (cycle, fingerprint).

    Returns Relation (v, u, b) sorted by (v, u) with one row per distinct
    pair, plus n_pairs."""
    cap = rows.capacity
    # segment ids per (v, u); segment id i == position i among unique pairs
    seg, n_pairs = R.dense_rank(rows, num_keys=2)
    h1, h2 = R.fingerprint_rows(set_cols, salt=salt)
    f1, f2 = R.segment_fingerprint(h1, h2, seg, cap, R.valid_mask(rows))
    # one representative row per pair (first occurrence = sorted order)
    pairs = R.rel_unique(rows, num_keys=2)  # (v, u, ...) count = n_pairs
    v = pairs.cols[0]
    u = pairs.cols[1]
    validm = jnp.arange(cap, dtype=R.I32) < n_pairs
    cyc = jnp.where(validm, (v == u).astype(R.I32), R.SENTINEL)
    fa, fb, fc, fd = _fp_cols(f1, f2)
    fa = jnp.where(validm, fa, R.SENTINEL)
    fb = jnp.where(validm, fb, R.SENTINEL)
    fc = jnp.where(validm, fc, R.SENTINEL)
    fd = jnp.where(validm, fd, R.SENTINEL)
    keyed = R.Relation((cyc, fa, fb, fc, fd, v, u), n_pairs, rows.overflow)
    keyed = R.rel_sort(keyed, num_keys=5)
    b, _ = R.dense_rank(keyed, num_keys=5)
    b = jnp.where(R.valid_mask(keyed), b, R.SENTINEL)
    out = R.Relation((keyed.cols[5], keyed.cols[6], b), n_pairs, rows.overflow)
    return R.rel_sort(out, num_keys=2), n_pairs


@functools.partial(jax.jit, static_argnames=("k", "caps", "pair_cap", "union_pair_cap"))
def path_partition(
    dg: DeviceGraph, k: int, caps: tuple, pair_cap: int,
    union_pair_cap: int | None = None,
) -> PartitionResult:
    """Algorithm 1: bottom-up block refinement, fully on device.

    ``caps[i-1]``: row capacity for the level-i S-set incidence relation;
    ``pair_cap``: capacity for P^{<=k} (and per-level pair tables);
    ``union_pair_cap``: capacity of the pre-dedup union of per-level pair
    tables (>= sum of per-level pair counts; defaults to k * pair_cap).
    """
    if union_pair_cap is None:
        union_pair_cap = k * pair_cap
    edges = dg.edges  # (src, dst, lbl) sorted
    # ---- level 1: sets of edge labels per pair ------------------------- #
    rows1 = _recap(R.rel_sort(edges, num_keys=3), caps[0])
    lvl1, n1 = _rank_pairs_by_set(rows1, (rows1.cols[2],), salt=1)
    lvl1 = _recap(lvl1, pair_cap)  # (v, u, b1) sorted by (v, u)
    level_pairs = [lvl1]

    # pairs1 sorted by m (first col) for the join: (m, u, b1)
    for i in range(2, k + 1):
        prev = level_pairs[-1]  # (v, m, b_{i-1}) sorted by (v, m)
        # join on prev.m == lvl1.v ; lvl1 already sorted by its first col
        joined = R.expansion_join(
            prev,
            lvl1,
            a_on=[1],
            out_cols=[("a", 0), ("b", 1), ("a", 2), ("b", 2)],
            out_capacity=caps[i - 1],
        )  # rows (v, u, b_prev, b1)
        joined = R.rel_unique(R.rel_sort(joined))
        lvl_i, _ = _rank_pairs_by_set(
            joined, (joined.cols[2], joined.cols[3]), salt=i
        )
        level_pairs.append(_recap(lvl_i, pair_cap))

    # ---- final signatures (cycle, b_1..b_k) ---------------------------- #
    # union of pairs over levels
    allp = R.Relation(level_pairs[0].cols[:2], level_pairs[0].count,
                      level_pairs[0].overflow)
    for lp in level_pairs[1:]:
        allp = R.rel_concat(
            allp, R.Relation(lp.cols[:2], lp.count, lp.overflow), union_pair_cap
        )
    allp = R.rel_unique(R.rel_sort(allp), 2)  # sorted distinct (v, u)
    allp = _recap(allp, pair_cap)

    sig_cols = []
    for lp in level_pairs:
        # b_i for each pair of allp; -1 (Null) where pair has no level-i path
        pos = R.lex_searchsorted(lp.cols[:2], allp.cols[:2], "left")
        posc = jnp.clip(pos, 0, lp.capacity - 1)
        hit = (
            (pos < lp.count)
            & (lp.cols[0][posc] == allp.cols[0])
            & (lp.cols[1][posc] == allp.cols[1])
        )
        b = jnp.where(hit, lp.cols[2][posc], jnp.int32(-1))
        b = jnp.where(R.valid_mask(allp), b, R.SENTINEL)
        sig_cols.append(b)

    validm = R.valid_mask(allp)
    cyc = jnp.where(validm, (allp.cols[0] == allp.cols[1]).astype(R.I32), R.SENTINEL)
    keyed = R.Relation(
        (cyc, *sig_cols, allp.cols[0], allp.cols[1]), allp.count, allp.overflow
    )
    keyed = R.rel_sort(keyed, num_keys=1 + k)
    cls, n_classes = R.dense_rank(keyed, num_keys=1 + k)
    cls = jnp.where(R.valid_mask(keyed), cls, R.SENTINEL)
    pairs = R.Relation((keyed.cols[1 + k], keyed.cols[2 + k], cls),
                       keyed.count, keyed.overflow)
    pairs = R.rel_sort(pairs, num_keys=2)

    overflow = pairs.overflow
    for lp in level_pairs:
        overflow = overflow | lp.overflow
    return PartitionResult(tuple(level_pairs), pairs, n_classes, overflow)
