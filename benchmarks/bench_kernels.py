"""Per-kernel microbenchmarks: Pallas (interpret on CPU / compiled on
TPU) vs the jnp reference path, across the engine's working sizes.
On CPU the relative numbers reflect interpret-mode overhead — the
correctness contract is what CI checks; on TPU this bench reports the
fusion win."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, timeit


def main() -> None:
    rng = np.random.default_rng(0)

    # sorted_intersect: class-id membership at paper-ish sizes
    for n_hay, n_q in [(1 << 10, 1 << 12), (1 << 14, 1 << 14)]:
        hay = np.sort(rng.choice(n_hay * 8, n_hay, replace=False)).astype(np.int32)
        q = rng.integers(0, n_hay * 8, n_q).astype(np.int32)
        hj, qj = jnp.asarray(hay), jnp.asarray(q)
        f_k = jax.jit(lambda h, q: ops.sorted_member_mask(h, n_hay, q))
        f_r = jax.jit(lambda h, q: ref.sorted_member_mask(h, n_hay, q))
        f_k(hj, qj).block_until_ready()
        f_r(hj, qj).block_until_ready()
        emit(f"kernels/sorted_intersect/{n_hay}x{n_q}/pallas",
             timeit(lambda: f_k(hj, qj).block_until_ready()), "")
        emit(f"kernels/sorted_intersect/{n_hay}x{n_q}/jnp_ref",
             timeit(lambda: f_r(hj, qj).block_until_ready()), "")

    # fingerprint: 2-column mix at build sizes
    n = 1 << 15
    cols = tuple(jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))
                 for _ in range(2))
    f_k = jax.jit(lambda a, b: ops.fingerprint_rows((a, b), 3))
    f_r = jax.jit(lambda a, b: ref.fingerprint_rows((a, b), 3))
    jax.block_until_ready(f_k(*cols))
    jax.block_until_ready(f_r(*cols))
    emit(f"kernels/fingerprint/{n}/pallas",
         timeit(lambda: jax.block_until_ready(f_k(*cols))), "")
    emit(f"kernels/fingerprint/{n}/jnp_ref",
         timeit(lambda: jax.block_until_ready(f_r(*cols))), "")

    # segment_softmax at GNN edge sizes
    e, d, nseg = 1 << 14, 8, 1 << 10
    scores = jnp.asarray(rng.normal(0, 1, (e, d)).astype(np.float32))
    seg = jnp.asarray(np.sort(rng.integers(0, nseg, e)).astype(np.int32))
    f_k = jax.jit(lambda s, g: ops.segment_softmax(s, g, nseg))
    f_r = jax.jit(lambda s, g: ref.segment_softmax(s, g, nseg))
    f_k(scores, seg).block_until_ready()
    f_r(scores, seg).block_until_ready()
    emit(f"kernels/segment_softmax/{e}x{d}/pallas",
         timeit(lambda: f_k(scores, seg).block_until_ready()), "")
    emit(f"kernels/segment_softmax/{e}x{d}/jnp_ref",
         timeit(lambda: f_r(scores, seg).block_until_ready()), "")
    jax.clear_caches()


if __name__ == "__main__":
    main()
