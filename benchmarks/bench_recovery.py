"""Kill-and-recover: time-to-first-correct-answer after a crash.

The zero-downtime lifecycle claim (lifecycle.py): restarting from a
committed checkpoint is **load + rebind**, an order of magnitude faster
than rebuilding the serving state from the raw graph.  Two recovery
paths are timed from the same committed state, each ending at the first
*served, correct* answer:

  restore   ``lifecycle.restore_service`` (load leaves, device-place,
            rebind, re-seed stats/mirror) + first answer
  rebuild   ``MaintainableIndex.build`` (host path enumeration +
            bisimulation) + ``flush`` (device serialization) +
            ``Engine`` + first answer — what a restart without a
            checkpoint has to do

gated on the two paths and the numpy oracle returning identical answers
for every probe, and (``--smoke``) on restore being >= 10x faster.

    PYTHONPATH=src python -m benchmarks.bench_recovery [--smoke]
                                                       [--json out.json]
"""

from __future__ import annotations

import argparse
import sys
import tempfile

import numpy as np

from repro.core import lifecycle, oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import TEMPLATE_ARITY, instantiate_template
from repro.core.service import QueryService

from .common import DATASETS, emit, timeit

GATE_SPEEDUP = 10.0


def _probes(g, rng, n: int = 4) -> list:
    names = ["C2", "T", "S", "C2i"]
    present = np.unique(g.lbl)
    return [instantiate_template(
        names[i % len(names)],
        rng.choice(present, TEMPLATE_ARITY[names[i % len(names)]]).tolist())
        for i in range(n)]


def bench_recovery(ds: str, n_updates: int, iters: int,
                   gate_speedup: bool) -> bool:
    """Returns True iff an acceptance gate FAILED."""
    g0 = DATASETS[ds]()
    rng = np.random.default_rng(13)

    # a lived-in service: build, serve, take updates, drain — then kill
    mi = MaintainableIndex.build(g0, 2)
    svc = QueryService(Engine(mi.flush()), maintainer=mi)
    probes = _probes(g0, rng)
    for q in probes:
        svc.query(q)
    base = mi.g._base_edges()
    batch = [("insert_edge", int(rng.integers(0, g0.n_vertices)),
              int(rng.integers(0, g0.n_vertices)),
              int(rng.integers(0, g0.n_labels)))
             for _ in range(n_updates // 2)]
    batch += [("delete_edge", *map(int, base[int(rng.integers(
        0, base.shape[0]))])) for _ in range(n_updates - n_updates // 2)]
    svc.apply_updates(batch)
    svc.flush()  # drain: mirror surgery + ONE flush/rebind
    g = svc.maintainer.g  # the graph the recovery must answer for
    truth = {q: oracle.cpq_eval(g, q) for q in probes}

    with tempfile.TemporaryDirectory() as d:
        svc.checkpoint(d)
        del svc  # the crash: the process's serving state is gone

        first = probes[0]
        got: dict = {}

        def recover_restore():
            replica = lifecycle.restore_service(d)
            got["restore"] = replica.query(first)
            got["restore_svc"] = replica

        def recover_rebuild():
            m = MaintainableIndex.build(g, 2)
            engine = Engine(m.flush())
            rebuilt = QueryService(engine, maintainer=m)
            got["rebuild"] = rebuilt.query(first)
            got["rebuild_svc"] = rebuilt

        # warm once untimed: jit executables compile (both paths reuse
        # them), so the timed runs measure recovery work, not XLA
        recover_restore()
        recover_rebuild()
        t_restore = timeit(recover_restore, warmup=0, iters=iters)
        t_rebuild = timeit(recover_rebuild, warmup=0, iters=max(1, iters - 1))

        # gate: both recovered services answer every probe like the oracle
        answers_ok = True
        for q in probes:
            a = {tuple(r) for r in got["restore_svc"].query(q).tolist()}
            b = {tuple(r) for r in got["rebuild_svc"].query(q).tolist()}
            if not (a == b == truth[q]):
                answers_ok = False
        identical_first = np.array_equal(got["restore"], got["rebuild"])

    speedup = t_rebuild / max(t_restore, 1e-9)
    emit(f"recovery/{ds}/restore_to_first_answer", t_restore, "")
    emit(f"recovery/{ds}/rebuild_to_first_answer", t_rebuild,
         f"speedup={speedup:.1f}x")
    ok = answers_ok and identical_first and (
        not gate_speedup or speedup >= GATE_SPEEDUP)
    emit(f"recovery/{ds}/acceptance", 0.0,
         f"restored==rebuilt==oracle={'PASS' if answers_ok else 'FAIL'}"
         f" speedup_gate{GATE_SPEEDUP:.0f}x="
         f"{'PASS' if (not gate_speedup or speedup >= GATE_SPEEDUP) else 'FAIL'}")
    return not ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: gmark-small, >= 10x gate on")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON")
    args, _ = ap.parse_known_args()

    if args.smoke:
        failed = bench_recovery("gmark-small", n_updates=8, iters=2,
                                gate_speedup=True)
    else:
        failed = bench_recovery("gmark-small", n_updates=16, iters=3,
                                gate_speedup=True)
        failed |= bench_recovery("robots-like", n_updates=16, iters=3,
                                 gate_speedup=False)
    if args.json:
        from .common import write_json

        write_json(args.json, bench="bench_recovery", smoke=args.smoke)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
