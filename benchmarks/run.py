"""Benchmark driver — one bench per paper table/figure (deliverable (d)).

Prints ``name,us_per_call,derived`` CSV rows.  CPU-scaled datasets from
the same generator families as the paper's suite; correctness gates
(all methods agree with the semantics oracle) run inside each bench.
``--json`` additionally serializes every emitted row (plus platform
metadata and the failure list) — CI uploads that file as the
perf-trajectory artifact.

    PYTHONPATH=src python -m benchmarks.run [--only fig6 table4 ...]
                                           [--json out.json]
"""

from __future__ import annotations

import argparse
import sys
import time

BENCHES = {
    "fig6": "benchmarks.bench_query",  # query time per template x method
    "table3": "benchmarks.bench_pruning",  # pruning power
    "table4": "benchmarks.bench_index",  # index size + build time
    "table5": "benchmarks.bench_update",  # maintenance (+ tables 6/7)
    "fig14": "benchmarks.bench_k",  # behavior in k (+ fig 15)
    "fig11": "benchmarks.bench_scalability",  # graph-size scaling
    "kernels": "benchmarks.bench_kernels",  # Pallas vs jnp ref + block sweeps
    "calibrate": "benchmarks.calibrate",  # device cost table artifact (PR 8)
    "throughput": "benchmarks.bench_throughput",  # serving qps (PR 1)
    "adaptive": "benchmarks.bench_adaptive",  # drifting-workload mining (PR 5)
    "recovery": "benchmarks.bench_recovery",  # kill-and-recover TTFCA (PR 6)
    "serving": "benchmarks.bench_serving",  # multi-tenant SLO serving (PR 7)
    "rpq": "benchmarks.bench_rpq",  # RPQ fixpoints + Cypher surface (PR 9)
    "cluster": "benchmarks.bench_cluster",  # worker-process fleet (PR 10)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {sorted(BENCHES)}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="serialize all emitted rows to PATH")
    args = ap.parse_args()
    todo = args.only or list(BENCHES)
    print("name,us_per_call,derived")
    t0 = time.time()
    failed = []
    for key in todo:
        mod_name = BENCHES[key]
        t1 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {key} done in {time.time()-t1:.1f}s", file=sys.stderr)
        except SystemExit as e:  # a bench's own acceptance gate tripped
            if e.code:
                failed.append(key)
                print(f"# {key} FAILED: exit {e.code}", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failed.append(key)
            print(f"# {key} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    elapsed = time.time() - t0
    print(f"# total {elapsed:.1f}s", file=sys.stderr)
    if args.json:
        from benchmarks.common import write_json

        write_json(args.json, benches=todo, failed=failed,
                   elapsed_s=round(elapsed, 1))
    if failed:
        raise SystemExit(f"benches failed: {failed}")


if __name__ == "__main__":
    main()
