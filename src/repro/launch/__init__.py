"""Launch layer: production meshes, sharding rules, per-arch step
functions, the multi-pod dry-run, and train/serve drivers."""
