"""Fault-tolerant training loop.

Production posture (1000+ nodes):
  * deterministic, stateless data (``TokenStream.batch_at(step)``) — any
    host can resume at any step with zero pipeline state;
  * checkpoint/restart: atomic async sharded checkpoints every
    ``ckpt_every`` steps + restore-on-start (elastic across mesh sizes —
    see checkpoint/);
  * straggler mitigation: per-step wall-time EWMA with a deadline
    multiplier; steps that exceed it are *recorded* and surfaced so the
    cluster layer can evict/replace the slow host (on a single process we
    log; the hook is the contract), plus optional step-skip logic;
  * gradient accumulation: ``accum`` microbatches per optimizer step via
    ``lax.scan`` (memory-flat);
  * non-finite-loss circuit breaker: NaN/inf steps are skipped (grads
    dropped), counted, and aborted after ``max_bad_steps`` in a row.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .optim import AdamWState, adamw_init, adamw_update
from .schedules import SCHEDULES


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    peak_lr: float = 3e-4
    warmup: int = 20
    schedule: str = "cosine"  # or "wsd" (minicpm)
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    accum: int = 1
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    max_bad_steps: int = 5


@dataclasses.dataclass
class StragglerStats:
    ewma: float = 0.0
    n_stragglers: int = 0
    worst: float = 0.0

    def observe(self, dt: float, factor: float) -> bool:
        if self.ewma == 0.0:
            self.ewma = dt
        is_straggler = dt > factor * self.ewma and self.ewma > 0
        self.ewma = 0.9 * self.ewma + 0.1 * dt
        self.worst = max(self.worst, dt)
        if is_straggler:
            self.n_stragglers += 1
        return is_straggler


def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """loss_fn(params, batch) -> (loss, aux).  Returns a jit-able
    step(params, opt_state, batch, step_idx) with grad accumulation."""
    sched = SCHEDULES[tcfg.schedule]

    def lr_at(step):
        if tcfg.schedule == "wsd":
            stable = int(tcfg.steps * 0.8) - tcfg.warmup
            decay = tcfg.steps - tcfg.warmup - stable
            return sched(step, peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                         stable=stable, decay=max(decay, 1))
        return sched(step, peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                     total=tcfg.steps)

    def step_fn(params, opt_state: AdamWState, batch, step_idx):
        if tcfg.accum == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            # microbatch over the leading axis: batch leaves are
            # (accum, micro, ...) — memory-flat scan
            def micro(carry, mb):
                acc = carry
                (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, a)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, auxes) = jax.lax.scan(micro, zeros, batch)
            grads = jax.tree.map(lambda g: g / tcfg.accum, grads)
            loss = jnp.mean(losses)
            aux = jax.tree.map(lambda x: jnp.mean(x), auxes)
        lr = lr_at(step_idx)
        finite = jnp.isfinite(loss)
        safe_grads = jax.tree.map(
            lambda g: jnp.where(finite, g, jnp.zeros_like(g)), grads)
        new_params, new_opt, om = adamw_update(
            safe_grads, opt_state, params, lr,
            weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm)
        # a non-finite step is a no-op on params
        new_params = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_params, params)
        metrics = {"loss": loss, "lr": lr, "finite": finite, **om}
        return new_params, new_opt, metrics

    return step_fn


def train(loss_fn: Callable, params, data_at: Callable, tcfg: TrainConfig,
          step_fn=None, on_metrics: Optional[Callable] = None,
          start_step: int = 0, opt_state: Optional[AdamWState] = None):
    """Single-process driver (the multi-pod path goes through
    launch/train.py, which jits the same step under a mesh).  Returns
    (params, opt_state, history)."""
    from repro.checkpoint import save_checkpoint  # local import (cycle)

    step_fn = step_fn or jax.jit(make_train_step(loss_fn, tcfg))
    opt_state = opt_state if opt_state is not None else adamw_init(params)
    history = []
    stats = StragglerStats()
    bad = 0
    for step in range(start_step, tcfg.steps):
        t0 = time.perf_counter()
        batch = data_at(step)
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jnp.asarray(step, jnp.int32))
        loss = float(m["loss"])
        dt = time.perf_counter() - t0
        straggle = stats.observe(dt, tcfg.straggler_factor)
        if not np.isfinite(loss):
            bad += 1
            if bad > tcfg.max_bad_steps:
                raise FloatingPointError(
                    f"{bad} consecutive non-finite losses at step {step}")
        else:
            bad = 0
        rec = {"step": step, "loss": loss, "lr": float(m["lr"]),
               "grad_norm": float(m["grad_norm"]), "dt": dt,
               "straggler": straggle}
        history.append(rec)
        if on_metrics and step % tcfg.log_every == 0:
            on_metrics(rec)
        if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
            save_checkpoint(tcfg.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            async_write=True)
    return params, opt_state, history
