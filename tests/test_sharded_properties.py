"""Hypothesis property test for the sharded execution backend: over
random graphs and random Fig. 5 query templates, ``ShardedBackend``
run_plan == ``LocalBackend`` == the numpy semantics oracle — bit-identical
arrays from both engines, set-identical answers vs the oracle.

Runs on an in-process mesh over every visible device: 1 in the plain
tier-1 run (every exchange a self-send), 8 in the CI distributed step
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the
acceptance property at n_shards ∈ {1, 8}."""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from conftest import random_graph
from repro import compat
from repro.core import index as cindex, oracle
from repro.core.engine import Engine
from repro.core.query import TEMPLATE_ARITY, TEMPLATES, instantiate_template

_TNAMES = sorted(TEMPLATES)
_MESH = None


def _mesh():
    global _MESH
    if _MESH is None:
        _MESH = compat.make_mesh((jax.device_count(),), ("engine",))
    return _MESH


@given(seed=st.integers(0, 2**31 - 1),
       tpick=st.lists(st.integers(0, len(_TNAMES) - 1), min_size=1,
                      max_size=3))
@settings(max_examples=6, deadline=None)
def test_sharded_equals_local_equals_oracle(seed, tpick):
    g = random_graph(seed, n_max=14, m_max=36)
    idx = cindex.build(g, 2)
    local, sharded = Engine(idx), Engine(idx, mesh=_mesh())
    rng = np.random.default_rng(seed ^ 0x5EED)
    present = np.unique(g.lbl)
    for t in tpick:
        name = _TNAMES[t]
        q = instantiate_template(
            name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
        a, b = local.execute(q), sharded.execute(q)
        assert a.shape == b.shape and np.array_equal(a, b), name
        assert {tuple(r) for r in b.tolist()} == oracle.cpq_eval(g, q), name
