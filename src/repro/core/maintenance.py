"""Lazy index maintenance under graph updates — paper Sec. IV-E.

The paper's update rule: on edge insert/delete, find the s-t pairs whose
label-sequence sets may have changed (everything within a k-hop
neighborhood of the edge), *remove* them from their blocks, and re-insert
each with a fresh class id — never merging, even if the pair is again
k-path-bisimilar to an existing block (Prop. 4.2 shows query answers stay
correct; the index merely loses some pruning power until a rebuild).

Adaptation note (DESIGN.md §2): the C++ artifact splices sorted vectors
in place.  On TPU, in-place scatter into sorted device arrays is not
idiomatic, so updates are applied to the host mirror (cheap dict/list
surgery, the same asymptotics as the paper: O(d·|P_u| + |P_u| log |P^k|))
and the device arrays are refreshed by re-serialization: ``flush``
re-serializes the lazily-split mirror into :class:`DeviceIndexArrays`
(``core.index.from_host_mirror``), preserving the lazy partition — a
fresh build would *merge* split classes — and reusing/geometrically
growing the previous flush's capacities so array shapes stay stable.
``apply_updates`` applies a whole batch with ONE union-of-affected-pairs
computation (the k-hop neighborhood BFS is amortized across the batch:
one adjacency build per graph version instead of one per edge).
Host-side queries (oracle evaluator) see updates immediately.

Label-sequence interest updates (Sec. V-C) are supported on iaCPQx
mirrors: deletion drops the ``l2c`` entry (classes stay split — lazy);
insertion enumerates the pairs realizing the new sequence and re-inserts
them with fresh classes.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from .graph import LabeledGraph
from . import oracle
from .oracle import Index


@dataclasses.dataclass
class MaintainableIndex:
    """Host mirror of a CPQx/iaCPQx index supporting lazy updates."""

    g: LabeledGraph
    index: Index
    next_class: int = 0
    n_splits: int = 0  # lazily-split classes since last rebuild (Table VII)
    _flush_caps: object = None  # FlushCaps of the last flush (grown, never shrunk)

    @staticmethod
    def build(g: LabeledGraph, k: int, interests=None) -> "MaintainableIndex":
        idx = (oracle.build_index(g, k) if interests is None
               else oracle.build_interest_index(g, k, interests))
        nc = (max(idx.c2p) + 1) if idx.c2p else 0
        return MaintainableIndex(g=g, index=idx, next_class=nc)

    # ------------------------------------------------------------------ #
    # neighborhood of an update — the pairs P_u of Thm. 4.6
    # ------------------------------------------------------------------ #
    @staticmethod
    def _adjacency(g: LabeledGraph) -> tuple:
        """(fwd, bwd) adjacency dicts — built once per graph version and
        shared by every ball expansion in a batch."""
        fwd: dict[int, list] = defaultdict(list)
        bwd: dict[int, list] = defaultdict(list)
        for s, d in zip(g.src, g.dst):
            fwd[int(s)].append(int(d))
            bwd[int(d)].append(int(s))
        return fwd, bwd

    def _affected_pairs(self, v: int, u: int, g: LabeledGraph | None = None,
                        adj: tuple | None = None) -> set:
        """All s-t pairs whose <=k-length path sets can include an edge
        between v and u (either direction, any label): sources reaching v
        (or u) within k-1 hops x targets reachable from u (or v) within
        k-1 hops, with total length <= k - 1."""
        k = self.index.k
        fwd, bwd = adj if adj is not None else self._adjacency(g or self.g)

        def ball(start: int, a, radius: int) -> dict[int, int]:
            dist = {start: 0}
            frontier = [start]
            for r in range(1, radius + 1):
                nxt = []
                for x in frontier:
                    for y in a[x]:
                        if y not in dist:
                            dist[y] = r
                            nxt.append(y)
                frontier = nxt
            return dist

        out: set = set()
        for a, b in ((v, u), (u, v)):  # the closure also has the inverse edge
            back = ball(a, bwd, k - 1)
            fore = ball(b, fwd, k - 1)
            for x, dx in back.items():
                for y, dy in fore.items():
                    if dx + dy + 1 <= k:
                        out.add((x, y))
        return out

    def _reinsert(self, pairs: set, new_graph: LabeledGraph) -> None:
        """Remove ``pairs`` from their classes and re-insert with fresh
        class ids keyed by their recomputed signature (lazy: one class per
        distinct new signature *within this batch*, never merged with
        pre-existing classes)."""
        idx = self.index
        k = idx.k
        # 1. remove from c2p (and remember emptied classes)
        cls_of: dict = {}
        for c, plist in idx.c2p.items():
            for p in plist:
                cls_of[p] = c
        touched_classes = set()
        for p in pairs:
            c = cls_of.get(p)
            if c is not None:
                idx.c2p[c] = [q for q in idx.c2p[c] if q != p]
                touched_classes.add(c)
        emptied = {c for c in touched_classes if not idx.c2p[c]}
        for c in emptied:
            del idx.c2p[c]
            del idx.cyclic[c]
        if emptied:
            for s in list(idx.l2c):
                kept = [c for c in idx.l2c[s] if c not in emptied]
                if kept:
                    idx.l2c[s] = kept
                else:
                    del idx.l2c[s]

        # 2. recompute signatures in the new graph (local enumeration)
        sigs = _local_signatures(new_graph, pairs, k)
        if idx.interests is not None:
            sigs = {p: frozenset(s for s in ss if s in idx.interests)
                    for p, ss in sigs.items()}
        # 3. fresh classes, one per (cycle, signature) in this batch
        by_sig: dict = defaultdict(list)
        for p, ss in sigs.items():
            if ss:
                by_sig[(p[0] == p[1], ss)].append(p)
        for (cyc, ss), plist in sorted(by_sig.items(), key=lambda kv: repr(kv[0])):
            c = self.next_class
            self.next_class += 1
            self.n_splits += 1
            idx.c2p[c] = sorted(plist)
            idx.cyclic[c] = cyc
            for s in ss:
                idx.l2c.setdefault(s, [])
                idx.l2c[s] = sorted(set(idx.l2c[s]) | {c})

    # ------------------------------------------------------------------ #
    # batched update application — one affected-pair union per batch
    # ------------------------------------------------------------------ #
    def apply_updates(self, updates: list) -> set:
        """Apply a whole batch of updates with ONE union-of-affected-pairs
        computation and ONE re-insertion pass.

        ``updates`` is a list of op tuples::

            ("insert_edge",  v, u, base_label)
            ("delete_edge",  v, u, base_label)
            ("change_label", v, u, old_label, new_label)
            ("delete_vertex", x)
            ("insert_vertex", [(v, u, base_label), ...])

        The batch is replayed on the host edge *set* to find the net
        removed/added edges; affected pairs are the union of the k-hop
        neighborhood balls of removed edges in the OLD graph (pairs that
        may lose sequences) and of added edges in the NEW graph (pairs
        that may gain them).  Because removing edges only shrinks balls,
        this union covers every pair a per-edge sequential application
        would touch whose signature can actually change — same
        correctness (Prop. 4.2), one BFS adjacency build per graph
        version instead of one per edge.  Returns the affected pair set.
        """
        old_base = {tuple(map(int, e)) for e in self.g._base_edges()}
        base = set(old_base)
        for op in updates:
            kind = op[0]
            if kind == "insert_edge":
                base.add((int(op[1]), int(op[2]), int(op[3])))
            elif kind == "delete_edge":
                base.discard((int(op[1]), int(op[2]), int(op[3])))
            elif kind == "change_label":
                base.discard((int(op[1]), int(op[2]), int(op[3])))
                base.add((int(op[1]), int(op[2]), int(op[4])))
            elif kind == "delete_vertex":
                x = int(op[1])
                base = {e for e in base if x not in e[:2]}
            elif kind == "insert_vertex":
                base |= {tuple(map(int, e)) for e in op[1]}
            else:
                raise ValueError(f"unknown update op {kind!r}")

        removed = old_base - base
        added = base - old_base
        if not removed and not added:
            return set()  # net no-op (e.g. deleting an isolated vertex)

        affected: set = set()
        if removed:
            old_adj = self._adjacency(self.g)
            for (v, u) in {e[:2] for e in removed}:
                affected |= self._affected_pairs(v, u, adj=old_adj)
        new_g = LabeledGraph.from_edges(
            self.g.n_vertices, self.g.n_labels, sorted(base),
            self.g.label_names,
        )
        if added:
            new_adj = self._adjacency(new_g)
            for (v, u) in {e[:2] for e in added}:
                affected |= self._affected_pairs(v, u, g=new_g, adj=new_adj)
        self.g = new_g
        self._reinsert(affected, new_g)
        return affected

    # ------------------------------------------------------------------ #
    # the five update operations of Sec. IV-E / V-C
    # ------------------------------------------------------------------ #
    def delete_edge(self, v: int, u: int, base_label: int) -> None:
        self.apply_updates([("delete_edge", v, u, base_label)])

    def insert_edge(self, v: int, u: int, base_label: int) -> None:
        self.apply_updates([("insert_edge", v, u, base_label)])

    def change_label(self, v: int, u: int, old_label: int, new_label: int) -> None:
        self.apply_updates([("change_label", v, u, old_label, new_label)])

    def delete_vertex(self, x: int) -> None:
        """Remove a vertex and its incident edges; a vertex with no
        incident edges is a no-op (``apply_updates`` sees an empty net
        change and skips re-insertion entirely)."""
        self.apply_updates([("delete_vertex", x)])

    def insert_vertex(self, edges: list) -> None:
        self.apply_updates([("insert_vertex", list(edges))])

    def _require_interest_aware(self, op: str) -> None:
        """Interest updates are an iaCPQx API — a real precondition for
        callers, not an internal invariant, so violating it raises
        ``ValueError`` (asserts vanish under ``python -O``)."""
        if self.index.interests is None:
            raise ValueError(
                f"{op} requires an interest-aware index — build with "
                "MaintainableIndex.build(g, k, interests=[...])")

    def delete_interest(self, seq: tuple) -> None:
        """Sec. V-C: drop one interest sequence — just remove the l2c entry
        (classes stay split; lazily correct)."""
        self.apply_interest_updates([("delete_interest", seq)])

    def insert_interest(self, seq: tuple) -> None:
        """Sec. V-C: add an interest sequence — enumerate its pairs and
        re-insert them with fresh (now seq-aware) classes."""
        self.apply_interest_updates([("insert_interest", seq)])

    def check_interest_op(self, op) -> None:
        """Validate one interest op tuple against this mirror — THE
        precondition set of ``apply_interest_updates``, shared with the
        service's enqueue-time check (one validator, so the two layers
        can never drift and a queued batch can never poison a coalesced
        drain).  Raises ``ValueError`` on violation."""
        self._require_interest_aware("interest updates")
        kind = op[0]
        if kind not in ("insert_interest", "delete_interest"):
            raise ValueError(f"unknown interest op {kind!r}")
        seq = tuple(int(x) for x in op[1])
        if kind == "insert_interest":
            k = self.index.k
            if not 1 <= len(seq) <= k:
                raise ValueError(
                    f"interest {seq} must have length in [1, {k}]")
            if any(not 0 <= x < self.g.alphabet_size for x in seq):
                raise ValueError(
                    f"interest {seq} has labels outside the alphabet")

    def apply_interest_updates(self, updates: list) -> None:
        """Apply a whole batch of interest updates with ONE path
        enumeration (Sec. V-C, batched the same way ``apply_updates``
        batches graph updates).

        ``updates`` is a list of ``("insert_interest", seq)`` /
        ``("delete_interest", seq)`` tuples, applied in order *logically*
        but executed as one net change: the final interest set is
        computed first, net-removed sequences drop their ``l2c`` entries
        (classes stay split — lazy), and the pairs realizing every
        net-added sequence are collected from a single
        ``oracle.enumerate_pairs`` pass and re-inserted with fresh
        classes under the final interest set.  An insert+delete of the
        same sequence in one batch is a net no-op, exactly as if the two
        calls had run back to back.  Answers depend only on (graph,
        interests), so executing the net change is answer-identical to
        the sequential execution — only the lazy partition (the pruning
        power before a rebuild) can differ.
        """
        self._require_interest_aware("interest updates")
        idx = self.index
        final = set(idx.interests)
        for op in updates:
            self.check_interest_op(op)
            seq = tuple(int(x) for x in op[1])
            if op[0] == "insert_interest":
                final.add(seq)
            else:
                final.discard(seq)
        removed = set(idx.interests) - final
        added = final - set(idx.interests)
        if not removed and not added:
            return
        for seq in removed:
            idx.l2c.pop(seq, None)
        idx.interests = frozenset(final)
        if added:
            seqs = oracle.enumerate_pairs(self.g, idx.k)
            affected = {p for p, ss in seqs.items() if ss & added}
            self._reinsert(affected, self.g)

    # ------------------------------------------------------------------ #
    def query(self, q) -> set:
        """Host-side evaluation against the (possibly lazily-split) mirror."""
        return oracle.query_with_index(self.g, self.index, q)

    def size_entries(self) -> tuple[int, int]:
        return self.index.size_entries()

    def flush(self, caps=None):
        """Re-serialize the mirror into device arrays (a fresh CPQxIndex
        build from the current graph would *merge* split classes; flushing
        keeps the lazy partition — it only refreshes the device image).

        Returns a :class:`repro.core.index.CPQxIndex` ready for
        ``Engine``/``Engine.rebind``.  Capacities are remembered across
        flushes and grown geometrically when the mirror outgrows them
        (``FlushCaps.grown_for``), so repeated flushes keep stable array
        shapes — and stable jit keys — until a doubling is needed."""
        from . import index as dindex  # lazy: keep this module jax-free

        flushed = dindex.from_host_mirror(
            k=self.index.k,
            n_vertices=self.g.n_vertices,
            l2c=self.index.l2c,
            c2p=self.index.c2p,
            cyclic=self.index.cyclic,
            caps=caps if caps is not None else self._flush_caps,
            interests=self.index.interests,
        )
        self._flush_caps = flushed.caps
        return flushed

    # ------------------------------------------------------------------ #
    # checkpoint codec — the mirror as flat numpy arrays.  Everything the
    # lazy partition depends on is captured, including dict/list ORDER:
    # the mirror's dicts are re-inserted in iteration order on restore so
    # a flush after restore is bit-identical to a flush before save.
    # ------------------------------------------------------------------ #
    def export_state(self) -> dict:
        """Flat ``{name: np.ndarray}`` snapshot of the whole mirror."""
        idx = self.index
        k = idx.k
        edges = np.asarray(self.g._base_edges(), dtype=np.int64).reshape(-1, 3)
        l2c_rows = []
        for seq, classes in idx.l2c.items():
            padded = list(seq) + [-1] * (k - len(seq))
            for c in classes:
                l2c_rows.append(padded + [int(c)])
        c2p_rows = []
        for c, plist in idx.c2p.items():
            for (v, u) in plist:
                c2p_rows.append([int(c), int(v), int(u)])
        cyc_rows = [[int(c), int(bool(f))] for c, f in idx.cyclic.items()]
        if idx.interests is None:
            interests = np.zeros((0, k), dtype=np.int64)
            has_interests = 0
        else:
            interests = np.array(
                [list(s) + [-1] * (k - len(s)) for s in sorted(idx.interests)],
                dtype=np.int64).reshape(-1, k)
            has_interests = 1
        from .capacity import encode_caps

        return {
            "meta": np.array(
                [k, self.g.n_vertices, self.g.n_labels, self.next_class,
                 self.n_splits, has_interests], dtype=np.int64),
            "edges": edges,
            "l2c": np.asarray(l2c_rows, dtype=np.int64).reshape(-1, k + 1),
            "c2p": np.asarray(c2p_rows, dtype=np.int64).reshape(-1, 3),
            "cyclic": np.asarray(cyc_rows, dtype=np.int64).reshape(-1, 2),
            "interests": interests,
            "flush_caps": encode_caps(self._flush_caps),
        }

    @classmethod
    def from_state(cls, state: dict, label_names=()) -> "MaintainableIndex":
        """Inverse of :meth:`export_state` — reconstructs the graph, the
        lazily-split :class:`Index`, and the remembered flush caps."""
        from .capacity import decode_caps

        meta = np.asarray(state["meta"], dtype=np.int64)
        k, n_vertices, n_labels, next_class, n_splits, has_interests = (
            int(x) for x in meta[:6])
        g = LabeledGraph.from_edges(
            n_vertices, n_labels,
            np.asarray(state["edges"], dtype=np.int64).reshape(-1, 3),
            label_names)
        # restore latency is the product here: rows of one class (one
        # seq) are contiguous by construction (export iterates the
        # dicts), so decode by segment with C-level zip instead of a
        # per-row Python loop — ~10x less interpreter work on the c2p
        # table, which dominates the mirror at realistic sizes
        l2c: dict = {}
        for row in np.asarray(state["l2c"], dtype=np.int64).reshape(
                -1, k + 1).tolist():
            seq = tuple(x for x in row[:k] if x >= 0)
            l2c.setdefault(seq, []).append(row[k])
        c2p_arr = np.asarray(state["c2p"], dtype=np.int64).reshape(-1, 3)
        cs = c2p_arr[:, 0]
        cut = np.flatnonzero(np.diff(cs)) + 1
        starts = np.concatenate([[0], cut]).tolist() if cs.size else []
        ends = np.concatenate([cut, [cs.size]]).tolist() if cs.size else []
        vs, us = c2p_arr[:, 1].tolist(), c2p_arr[:, 2].tolist()
        c2p: dict = {}
        for s, e in zip(starts, ends):
            c2p[int(cs[s])] = list(zip(vs[s:e], us[s:e]))
        cyclic = {c: bool(f) for c, f in
                  np.asarray(state["cyclic"],
                             dtype=np.int64).reshape(-1, 2).tolist()}
        interests = None
        if has_interests:
            interests = frozenset(
                tuple(int(x) for x in row if x >= 0)
                for row in np.asarray(state["interests"],
                                      dtype=np.int64).reshape(-1, k))
        idx = Index(k=k, l2c=l2c, c2p=c2p, cyclic=cyclic, interests=interests)
        return cls(g=g, index=idx, next_class=next_class, n_splits=n_splits,
                   _flush_caps=decode_caps(state["flush_caps"]))


def _local_signatures(g: LabeledGraph, pairs: set, k: int) -> dict:
    """L^{<=k}(v,u) for the requested pairs only — bounded BFS from each
    distinct source (cost O(d^k) per source, Thm. 4.6's d·|P_u| term)."""
    out_edges: dict[int, list] = defaultdict(list)
    for s, d, l in zip(g.src, g.dst, g.lbl):
        out_edges[int(s)].append((int(d), int(l)))
    sources = {p[0] for p in pairs}
    want = defaultdict(set)
    for (a, b) in pairs:
        want[a].add(b)
    sigs: dict = {p: set() for p in pairs}
    for a in sources:
        frontier: dict[int, set] = {a: {()}}
        for step in range(1, k + 1):
            nxt: dict[int, set] = defaultdict(set)
            for x, seqs in frontier.items():
                for (y, l) in out_edges[x]:
                    for sq in seqs:
                        nxt[y].add(sq + (l,))
            for y, seqs in nxt.items():
                if y in want[a]:
                    sigs[(a, y)].update(seqs)
            frontier = nxt
    return {p: frozenset(ss) for p, ss in sigs.items()}
