"""Workload-driven interest mining — the adaptive half of iaCPQx.

The paper's interest-aware index (Sec. V) assumes the interest set L_q
is *given*; this module closes the loop from the traffic the serving
layer actually sees back to that set, so an iaCPQx deployment tunes
itself: hot label sequences get indexed (one LOOKUP instead of an
expansion-join chain), cold ones get dropped (the index stays a fraction
of full CPQx).  Workload-adaptivity is where path indexes meet practice
— engines evaluate whatever path shapes traffic sends (PathFinder,
arXiv:2306.02194) — and every moving part it needs already exists:
``QueryService`` sees every AST, ``MaintainableIndex`` applies live
interest updates, and the optimizer's cost model prices a sequence's
evaluation with and without its index entry.  Three pieces:

* :class:`WorkloadSketch` — a bounded heavy-hitter summary (Space-Saving
  [Metwally et al. 2005]) over the label sequences harvested from every
  planned query.  ``harvest_sequences`` credits a query's *indexable
  segments*: every contiguous window of length 2..k of every maximal
  label run (length-1 sequences are always indexed, so they carry no
  signal).  A long chain therefore votes for each sequence that could
  serve one of its segments — no unbounded query log, O(capacity) state,
  and the classic Space-Saving guarantee (any sequence with true count
  > N/capacity is present).
* :class:`BenefitModel` — scores a candidate sequence by
  ``frequency x cost saved``, reusing the optimizer's cost model
  (:func:`repro.core.optimizer.estimate_plan` over
  :class:`~repro.core.stats.IndexStats`): cost saved is the estimated
  evaluation of the sequence as singleton-label expansion joins minus
  its evaluation as one indexed LOOKUP.  The same model prices the
  *size* of admitting a sequence (its estimated pair count) for the
  controller's budget.
* :class:`AdaptationController` — turns sketch + benefit into coalesced
  ``("insert_interest", seq)`` / ``("delete_interest", seq)`` update
  batches under a size budget, with **hysteresis** so the interest set
  cannot thrash: a challenger must beat a resident's benefit by
  ``swap_margin``, freshly-admitted interests are dwell-protected for a
  few rounds, and the sketch decays geometrically each round so a
  drifted-away workload releases its slots.

The controller is **multi-tenant** (PR 7): every tenant gets its own
Space-Saving sketch (one tenant's burst cannot evict another tenant's
counters) and its own ``cfg.budget`` of mined interests, while
``cfg.pair_budget`` stays one *global* footprint cap.  :meth:`propose`
arbitrates round-robin across tenants in deterministic (sorted-name)
order, one admission per tenant per pass, so a hot tenant cannot claim
the whole pair budget before a cold tenant's first candidate is even
considered.  A single-tenant deployment (everything funnels through
``DEFAULT_TENANT``) behaves exactly as before.

The controller never touches the index itself — it only *proposes* ops;
``QueryService`` drains them through its existing write path, so an
adaptation round shares one mirror batch + one flush/rebind + one epoch
bump with any queued graph updates, and the sharded backend reshards at
rebind exactly as it does for graph maintenance.  Misjudged proposals
can never change answers (Sec. V-C: any interest set is
answer-preserving; only pruning power and index size move).

Host-side only: no jax import.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .optimizer import estimate_plan
from .query import CPQ, Conj, Edge, Identity, Join, _flatten_join
from .stats import IndexStats

#: the tenant every untagged request is accounted to.
DEFAULT_TENANT = "default"


# ---------------------------------------------------------------------- #
# harvesting — AST -> candidate interest sequences
# ---------------------------------------------------------------------- #


def harvest_sequences(q: CPQ, k: int) -> list:
    """The candidate interest sequences one query votes for: every
    contiguous window of length 2..k of every maximal label run, over
    all join chains of the AST (conjunction operands recurse).

    Windows — not just maximal runs — because the planner may serve a
    long chain from *any* valid <= k segmentation: a hot ``a.b.c.d``
    workload at k=2 is evidence for (a,b), (b,c) and (c,d) alike, and
    the benefit model decides which segmentation is worth indexing.

    RPQ queries vote too: their maximal concatenation label runs (star
    and plus bodies included — a hot ``(a.b)*`` fixpoint hits the
    ``(a, b)`` lookup every iteration) go through the same window
    expansion."""
    from .rpq import RPQ, rpq_label_runs

    if isinstance(q, RPQ):
        runs = [list(r) for r in rpq_label_runs(q)]
        return _expand_windows(runs, k)
    runs: list[list[int]] = []

    def walk(node: CPQ) -> None:
        if isinstance(node, Edge):
            runs.append([node.label])
            return
        if isinstance(node, Identity):
            return
        if isinstance(node, Conj):
            walk(node.lhs)
            walk(node.rhs)
            return
        if isinstance(node, Join):
            run: list[int] = []
            for leaf in _flatten_join(node):
                if isinstance(leaf, Edge):
                    run.append(leaf.label)
                else:
                    if run:
                        runs.append(run)
                        run = []
                    if not isinstance(leaf, Identity):
                        walk(leaf)
            if run:
                runs.append(run)
            return
        raise TypeError(node)

    walk(q)
    return _expand_windows(runs, k)


def _expand_windows(runs: list, k: int) -> list:
    out: list = []
    for run in runs:
        for w in range(2, k + 1):
            for i in range(len(run) - w + 1):
                out.append(tuple(run[i: i + w]))
    return out


# ---------------------------------------------------------------------- #
# WorkloadSketch — bounded heavy hitters (Space-Saving)
# ---------------------------------------------------------------------- #


class WorkloadSketch:
    """Space-Saving heavy-hitter sketch over hashable items.

    At most ``capacity`` counters; an unmonitored arrival evicts the
    minimum counter and inherits its count (recorded as the new entry's
    ``error``, so ``count - error`` is a guaranteed lower bound on the
    true frequency).  ``decay`` scales every counter — called once per
    adaptation round, it turns the sketch into an exponentially-weighted
    view so drifted-away traffic fades instead of squatting."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.counts: dict = {}
        self.errors: dict = {}
        self.observed = 0.0  # total weight ever observed (pre-decay)

    def __len__(self) -> int:
        return len(self.counts)

    def observe(self, item, weight: float = 1.0) -> None:
        self.observed += weight
        if item in self.counts:
            self.counts[item] += weight
            return
        if len(self.counts) < self.capacity:
            self.counts[item] = weight
            self.errors[item] = 0.0
            return
        # evict the oldest minimum counter (dict order is insertion
        # order, so the tie-break is deterministic without touching
        # every key's repr on the serving hot path)
        floor = min(self.counts.values())
        victim = next(k for k, c in self.counts.items() if c == floor)
        self.counts.pop(victim)
        self.errors.pop(victim)
        self.counts[item] = floor + weight
        self.errors[item] = floor

    def observe_query(self, q: CPQ, k: int, weight: float = 1.0) -> int:
        """Harvest and record one query's candidate sequences with the
        given weight (the service passes the number of folded duplicate
        requests); returns how many sequence occurrences were
        credited."""
        seqs = harvest_sequences(q, k)
        for s in seqs:
            self.observe(s, weight)
        return len(seqs)

    def count(self, item) -> float:
        """Upper-bound frequency estimate (0 for unmonitored items)."""
        return self.counts.get(item, 0.0)

    def guaranteed(self, item) -> float:
        """Lower-bound frequency (count minus inherited error)."""
        return self.counts.get(item, 0.0) - self.errors.get(item, 0.0)

    def decay(self, factor: float, drop_below: float = 0.5) -> None:
        """Scale every counter by ``factor`` (and drop entries fading
        below ``drop_below`` — they are indistinguishable from noise and
        their slots should go to fresh traffic)."""
        if not 0.0 <= factor <= 1.0:
            raise ValueError("decay factor must be in [0, 1]")
        for item in list(self.counts):
            c = self.counts[item] * factor
            if c < drop_below:
                del self.counts[item]
                del self.errors[item]
            else:
                self.counts[item] = c
                self.errors[item] *= factor

    def heavy_hitters(self, min_count: float = 0.0) -> list:
        """(item, count, error) rows, heaviest first, ties broken
        deterministically by item repr."""
        rows = [(item, c, self.errors[item])
                for item, c in self.counts.items() if c >= min_count]
        rows.sort(key=lambda r: (-r[1], repr(r[0])))
        return rows

    # --------------------- checkpoint codec ------------------------- #
    # Row order == dict insertion order: the eviction tie-break walks
    # insertion order, so a restored sketch must replay it exactly to
    # evict the same victims the donor would.

    def export_state(self, width: int) -> dict:
        """Flat numpy snapshot; ``width`` pads every item (a label-seq
        tuple of length <= width) to fixed row size."""
        rows = [list(item) + [-1.0] * (width - len(item))
                + [self.counts[item], self.errors[item]]
                for item in self.counts]
        return {
            "meta": np.array([self.capacity, self.observed], np.float64),
            "rows": np.asarray(rows, np.float64).reshape(-1, width + 2),
        }

    @classmethod
    def from_state(cls, state: dict) -> "WorkloadSketch":
        meta = np.asarray(state["meta"], np.float64).ravel()
        sk = cls(capacity=int(meta[0]))
        sk.observed = float(meta[1])
        rows = np.asarray(state["rows"], np.float64)
        for row in rows.reshape(rows.shape[0], -1):
            item = tuple(int(x) for x in row[:-2] if x >= 0)
            sk.counts[item] = float(row[-2])
            sk.errors[item] = float(row[-1])
        return sk


# ---------------------------------------------------------------------- #
# BenefitModel — frequency x estimated cost saved
# ---------------------------------------------------------------------- #


class BenefitModel:
    """Prices candidate interest sequences against one statistics
    snapshot, reusing the optimizer's cost model end to end."""

    def __init__(self, stats: IndexStats):
        self.stats = stats

    def split_cost(self, seq: tuple) -> float:
        """Estimated cost of serving the sequence WITHOUT its index
        entry: singleton-label lookups folded through expansion joins —
        the exact plan the engine runs when the segment is absent."""
        plan = ("lookup", [(l,) for l in seq])
        return estimate_plan(plan, self.stats).cost

    def indexed_cost(self, seq: tuple) -> float:
        """Estimated cost WITH the entry: one LOOKUP whose
        materialization is the answer.  For a sequence the index already
        holds this is exact; otherwise its cardinality is estimated from
        the same join chain the split would run."""
        seq = tuple(seq)
        if self.stats.has_seq(seq):
            return estimate_plan(("lookup", [seq]), self.stats).cost
        return self.est_pairs(seq)

    def est_pairs(self, seq: tuple) -> float:
        """Estimated pair count of the sequence — its index footprint
        (the size-budget currency), exact when already indexed."""
        seq = tuple(seq)
        if self.stats.has_seq(seq):
            return float(self.stats.seq_pairs(seq))
        plan = ("lookup", [(l,) for l in seq])
        return estimate_plan(plan, self.stats).pairs

    def saved(self, seq: tuple) -> float:
        """Estimated evaluation cost saved per query touching ``seq``."""
        return max(0.0, self.split_cost(seq) - self.indexed_cost(seq))

    def benefit(self, seq: tuple, frequency: float) -> float:
        return frequency * self.saved(seq)


# ---------------------------------------------------------------------- #
# AdaptationController — hysteresis + budget -> coalesced interest ops
# ---------------------------------------------------------------------- #


@dataclasses.dataclass
class AdaptationConfig:
    """Knobs of the adaptation loop.

    ``budget``       — max resident mined (length >= 2) interests;
    ``pair_budget``  — cap on the summed estimated pair footprint of the
                       mined interests (None = count budget only);
    ``min_count``    — sketch frequency floor before a sequence is even
                       considered (guards against one-off queries);
    ``min_benefit``  — absolute benefit floor for admission, and the
                       eviction threshold for residents whose traffic
                       faded (a resident below this is dropped even
                       unchallenged);
    ``swap_margin``  — hysteresis: a challenger must beat a resident's
                       benefit by this factor to take its slot;
    ``dwell``        — adaptation rounds a fresh admission is protected
                       from eviction (prevents insert/delete churn while
                       the sketch stabilizes);
    ``decay``        — per-round geometric decay of the sketch.
    """

    budget: int = 8
    pair_budget: float | None = None
    min_count: float = 4.0
    min_benefit: float = 1.0
    swap_margin: float = 2.0
    dwell: int = 2
    decay: float = 0.5


class AdaptationController:
    """Turns observed traffic into coalesced interest-update batches.

    Stateless about the index itself: every :meth:`propose` call reads
    the *current* interest set and statistics, so the controller is
    correct under concurrent graph maintenance (a graph update changes
    the statistics; the next round simply re-prices).

    Sketches are per tenant (created lazily on first observe); the
    legacy ``.sketch`` attribute remains the :data:`DEFAULT_TENANT`
    view, so single-tenant callers and tests are unaffected."""

    def __init__(self, k: int, sketch_capacity: int = 256,
                 config: AdaptationConfig | None = None):
        self.k = k
        self.cfg = config or AdaptationConfig()
        self.sketch_capacity = sketch_capacity
        self.sketches: dict = {}  # tenant -> WorkloadSketch
        self.rounds = 0
        self._dwell: dict = {}  # seq -> protected-until round

    @property
    def sketch(self) -> WorkloadSketch:
        return self.sketch_for(DEFAULT_TENANT)

    @sketch.setter
    def sketch(self, sk: WorkloadSketch) -> None:
        self.sketches[DEFAULT_TENANT] = sk

    def sketch_for(self, tenant: str) -> WorkloadSketch:
        sk = self.sketches.get(tenant)
        if sk is None:
            sk = self.sketches[tenant] = WorkloadSketch(self.sketch_capacity)
        return sk

    # -------------------------- recording --------------------------- #

    def observe(self, q: CPQ, weight: float = 1.0,
                tenant: str = DEFAULT_TENANT) -> int:
        """Record one served query against its tenant's sketch
        (``weight`` > 1 credits folded duplicate requests); returns
        sequences credited."""
        return self.sketch_for(tenant).observe_query(q, self.k, weight)

    # -------------------------- proposing --------------------------- #

    def propose(self, stats: IndexStats, current_interests) -> list:
        """One adaptation round: returns a (possibly empty) list of
        ``("insert_interest", seq)`` / ``("delete_interest", seq)`` ops
        moving the mined interest set toward the current workload's
        top-benefit sequences, under the budget and hysteresis rules.

        Budgeting is per tenant for counts (each tenant may hold up to
        ``cfg.budget`` mined interests) and global for the pair
        footprint: admission round-robins across tenants in sorted-name
        order, one admission per tenant per pass, each tenant offering
        its own benefit-ranked candidates, until every tenant is out of
        budget, candidates, or global pair headroom.  A sequence two
        tenants both want is admitted once and charged to whichever
        tenant's turn came first — the others benefit free of charge.

        ``current_interests`` is the live interest set (length-1
        sequences are implicit in iaCPQx and ignored here)."""
        cfg = self.cfg
        self.rounds += 1
        model = BenefitModel(stats)
        resident = {tuple(s) for s in current_interests if len(s) >= 2}
        if not self.sketches:
            self.sketch_for(DEFAULT_TENANT)
        tenants = sorted(self.sketches)

        scored_by_tenant: dict = {}
        for tenant in tenants:
            sk = self.sketches[tenant]
            scored: dict = {}
            for seq, cnt, err in sk.heavy_hitters(cfg.min_count):
                if len(seq) < 2 or len(seq) > self.k:
                    continue
                if cnt - err < cfg.min_count:  # Space-Saving precision
                    continue  # guard: the count may be inherited, not earned
                scored[seq] = model.benefit(seq, cnt)
            for seq in resident:  # faded residents still get priced
                if seq not in scored:
                    scored[seq] = model.benefit(seq, sk.count(seq))
            scored_by_tenant[tenant] = scored

        protected = {s for s in resident
                     if self._dwell.get(s, -1) >= self.rounds}

        def eligible_for(tenant):
            sk = self.sketches[tenant]
            scored = scored_by_tenant[tenant]

            # hysteresis: residents defend their slot with a swap_margin
            # premium; challengers must clear both floors
            def rank(seq):
                bonus = cfg.swap_margin if seq in resident else 1.0
                return (-scored[seq] * bonus, repr(seq))

            elig = [s for s, b in scored.items()
                    if s not in protected
                    and b >= cfg.min_benefit
                    and (s in resident
                         or sk.guaranteed(s) >= cfg.min_count)]
            elig.sort(key=rank)
            return elig

        # dwell-protected residents keep their slots unconditionally,
        # charged to the tenant that drives them hardest
        desired: set = set()
        pair_spend = 0.0
        spent = {t: 0 for t in tenants}
        for s in sorted(protected, key=repr):
            payer = max(tenants, key=lambda t: self.sketches[t].count(s))
            desired.add(s)
            pair_spend += model.est_pairs(s)
            spent[payer] += 1

        elig = {t: eligible_for(t) for t in tenants}
        cursor = {t: 0 for t in tenants}
        progressed = True
        while progressed:
            progressed = False
            for t in tenants:
                if spent[t] >= cfg.budget:
                    continue
                lst, i = elig[t], cursor[t]
                while i < len(lst):
                    seq = lst[i]
                    i += 1
                    if seq in desired:
                        continue
                    cost = model.est_pairs(seq)
                    if (cfg.pair_budget is not None
                            and pair_spend + cost > cfg.pair_budget):
                        continue
                    desired.add(seq)
                    pair_spend += cost
                    spent[t] += 1
                    progressed = True
                    break
                cursor[t] = i

        ops = [("delete_interest", s)
               for s in sorted(resident - desired, key=repr)]
        inserts = sorted(desired - resident, key=repr)
        ops += [("insert_interest", s) for s in inserts]
        for s in inserts:
            self._dwell[s] = self.rounds + cfg.dwell
        for s in resident - desired:
            self._dwell.pop(s, None)
        for sk in self.sketches.values():
            sk.decay(cfg.decay)
        return ops

    # --------------------- checkpoint codec ------------------------- #

    def export_state(self) -> dict:
        """Flat numpy snapshot of the whole adaptation loop — per-tenant
        sketches, round counter, dwell protections, and config — so a
        restored replica keeps adapting where the donor stopped (no
        cold-start thrash of the interest set).  Tenant names travel as
        one newline-joined UTF-8 byte leaf (names may not contain
        newlines); sketch leaves are keyed ``sketch<i>.*`` in sorted
        tenant order."""
        cfg = self.cfg
        dwell_rows = [list(s) + [-1] * (self.k - len(s)) + [int(r)]
                      for s, r in self._dwell.items()]
        names = sorted(self.sketches)
        out = {
            "meta": np.array(
                [self.k, self.sketch_capacity, self.rounds, len(names)],
                np.int64),
            "config": np.array(
                [cfg.budget,
                 -1.0 if cfg.pair_budget is None else cfg.pair_budget,
                 cfg.min_count, cfg.min_benefit, cfg.swap_margin,
                 cfg.dwell, cfg.decay], np.float64),
            "tenants": np.frombuffer(
                "\n".join(names).encode("utf-8"), np.uint8).copy(),
            "dwell": np.asarray(dwell_rows, np.int64).reshape(-1, self.k + 1),
        }
        for i, t in enumerate(names):
            sk = self.sketches[t].export_state(self.k)
            out[f"sketch{i}.meta"] = sk["meta"]
            out[f"sketch{i}.rows"] = sk["rows"]
        return out

    @classmethod
    def from_state(cls, state: dict) -> "AdaptationController":
        meta = np.asarray(state["meta"], np.int64).ravel()
        k, cap, rounds = (int(x) for x in meta[:3])
        c = np.asarray(state["config"], np.float64).ravel()
        cfg = AdaptationConfig(
            budget=int(c[0]),
            pair_budget=None if c[1] < 0 else float(c[1]),
            min_count=float(c[2]), min_benefit=float(c[3]),
            swap_margin=float(c[4]), dwell=int(c[5]), decay=float(c[6]))
        ctl = cls(k, sketch_capacity=cap, config=cfg)
        ctl.rounds = rounds
        if "sketch.meta" in state:  # pre-multi-tenant layout
            ctl.sketches[DEFAULT_TENANT] = WorkloadSketch.from_state(
                {"meta": state["sketch.meta"], "rows": state["sketch.rows"]})
        else:
            raw = bytes(np.asarray(state["tenants"], np.uint8)).decode("utf-8")
            for i, t in enumerate(raw.split("\n") if raw else []):
                ctl.sketches[t] = WorkloadSketch.from_state(
                    {"meta": state[f"sketch{i}.meta"],
                     "rows": state[f"sketch{i}.rows"]})
        dwell = np.asarray(state["dwell"], np.int64).reshape(-1, k + 1)
        for row in dwell:
            seq = tuple(int(x) for x in row[:k] if x >= 0)
            ctl._dwell[seq] = int(row[k])
        return ctl
