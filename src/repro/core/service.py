"""CPQ query serving layer — continuous batching for index-backed query
traffic.

``launch/serve.py`` proved the slot/continuous-batching pattern for LM
decoding; this module adapts it to CPQ serving on top of
``Engine.execute_batch``:

* **request queue** — ``submit`` enqueues; nothing touches the device
  until a flush, so concurrent requests of the same plan shape ride one
  vmapped dispatch.
* **plan-shape buckets** — at flush time the queue is grouped by
  :func:`repro.core.query.plan_shape` (the jit key); every bucket is one
  device dispatch regardless of how many queries (or which labels) it
  holds.
* **bounded plan cache keyed by (graph epoch, query)** — AST -> physical
  plan memoization (planning is host work but repeated verbatim for
  recurring traffic); LRU beyond ``plan_cache_size``.  The epoch
  component matters since PR 4: plans come from the cost-based optimizer
  (``core.optimizer``), so they depend on the index *statistics*, not
  just the available sequences — any rebind bumps the epoch and every
  plan optimized against stale statistics becomes unreachable in O(1),
  exactly like stale results.
* **LRU result cache keyed by (graph epoch, query)** — repeat queries
  are answered host-side with zero device work.  The epoch component
  makes invalidation O(1): any graph mutation bumps the epoch and every
  cached answer for older epochs becomes unreachable (aging out of the
  LRU naturally).
* **admission/flush policy** — the queue admits up to ``max_batch``
  requests; submitting past that point flushes synchronously (unless
  ``auto_flush=False``, for callers that drive the drain themselves).
  ``query`` is the one-shot convenience wrapper (submit + flush).

Multi-tenant serving (PR 7): every request carries a ``tenant`` id
(defaulting to :data:`~repro.core.workload.DEFAULT_TENANT`), and

* **admission control** — with ``max_queue`` (and optionally
  ``max_queue_per_tenant``) set, a submit that would overflow the queue
  is *explicitly rejected*: the returned request comes back
  ``shed=True, done=True, result=None`` and is counted in per-tenant
  shed stats.  The shed decision happens only at ``submit`` — once a
  request is accepted it is never silently dropped: a failed flush
  requeues it, and it completes or the failure propagates.
* **fair drain** — ``flush`` drains the queue in rounds of at most
  ``max_batch``, selecting round-robin across tenants (submit order
  within a tenant), so one hot tenant cannot starve the rest no matter
  how it floods the queue.
* **pipelined drain** — each round is dispatched asynchronously
  (``Engine.dispatch_batch``) and the *next* round's host work (cache
  re-check, dedup, planning, capacity estimation) overlaps the device
  execution before the earlier round is harvested.  Duplicates fold
  *across* in-flight rounds too: a request whose query is already
  executing in the previous (dispatched, unharvested) round joins that
  round's result instead of re-executing — the join is pure host
  bookkeeping on the not-yet-finalized round, so the pipeline never
  re-serializes (``ServiceStats.cross_round_joins`` counts them).
* **SLO-aware shedding** — with ``slo_ns`` set (one budget, or a
  per-tenant dict) *and* a calibrated engine (``cost_table``), a submit
  is priced at its plan's predicted dispatch cost
  (:meth:`Engine.predict_cost_ns`); when the queue's predicted backlog
  plus this request exceeds the tenant's latency budget, the request is
  shed *by predicted cost* — an expensive query sheds where a cheap one
  still admits, instead of both counting 1 against queue depth.
  ``QueryRequest.shed_reason`` / ``TenantStats.shed_reasons`` say which
  gate fired (``"queue"``, ``"tenant_queue"``, ``"slo"``).  Without a
  cost table predictions are 0.0 and the SLO gate is inert.
* **union dispatch** — with ``union=True`` the engine fuses leftover
  sub-``min_bucket`` shape buckets into one union-executable dispatch
  (``core.backend.run_union_batch``), so heterogeneous tenant traffic
  stops serializing into per-shape dispatches.

RPQ serving (PR 9): requests whose query is an :class:`repro.core.rpq.RPQ`
ride the same queue, admission control, tenancy accounting, and
(epoch, query)-keyed result cache — RPQ nodes are frozen dataclasses, so
they are hashable cache keys like CPQ ASTs.  They skip the plan cache
(there is no single physical plan; the fixpoint re-plans its per-sequence
lookups each iteration) and are evaluated in ``_finalize_round`` after the
shaped CPQ batch, via :meth:`Engine.execute_rpq` — each fixpoint iteration
is itself an ``execute_batch`` of CPQx lookups, so RPQs reuse the capacity
ladder and device cost model rather than bypassing them.

A graph update re-enters the service two ways:

* **rebind path** — any fresh :class:`CPQxIndex` (a from-scratch rebuild
  or a maintenance flush) through :meth:`rebind`, which swaps the index
  into the engine, bumps the epoch, and drops the plan cache (plans
  depend on the index's available sequences).
* **write path** — :meth:`apply_updates` on a service constructed with a
  ``maintainer`` (:class:`repro.core.maintenance.MaintainableIndex`).
  Updates are *queued*, not applied: the epoch bumps immediately (stale
  cached answers become unreachable in O(1)) but the host-mirror surgery
  and the mirror→device flush are deferred and **coalesced** — the next
  query drain applies every queued update as ONE
  ``MaintainableIndex.apply_updates`` batch (one affected-pair union BFS)
  followed by ONE flush/rebind.  Reads submitted before a write are
  drained first — by ``apply_updates``, ``rebind`` AND ``adapt`` (an
  adaptation round is a write like any other; it draining the queue
  first is what PR 7's serializability fix restored) — so the service
  serves a serializable history: every query sees exactly the writes
  *accepted* before it was submitted, including queued-but-undrained
  ones, and never a later write.

Since PR 5 the write path also carries **interest updates** (Sec. V-C):
``("insert_interest", seq)`` / ``("delete_interest", seq)`` ops — from a
caller or from the adaptation loop below — queue exactly like graph
updates and drain in the SAME coalesced round: one mirror batch per op
kind, one flush, one rebind, one epoch bump, regardless of how graph
and interest writes interleave.  (Graph ops apply before interest ops
within a round; answers depend only on the final (graph, interest set),
so the reorder is answer-identical to sequential application — see
``MaintainableIndex.apply_interest_updates``.)

**The adaptation loop** (``core.workload``): a service constructed with
an ``adapter`` (:class:`~repro.core.workload.AdaptationController`)
becomes a self-tuning iaCPQx.  Every query reaching ``_plan`` is
harvested into the adapter's heavy-hitter sketch; every
``adapt_interval`` planned queries the controller prices the hot
sequences against the engine's live ``IndexStats`` and proposes
coalesced interest ops, which are *queued through the write path above*
— an adaptation round is indistinguishable from any other write batch
(same flush, same epoch-keyed invalidation, same reshard on a mesh
engine), and a misjudged proposal can only cost performance, never
answers.

The service is backend-agnostic: an ``Engine`` constructed with a mesh
(``Engine(index, mesh=...)`` — the sharded backend of
``core.distributed``) serves the identical API and answers through this
layer.  On the write path nothing changes either: ``Engine.rebind``
re-shards the flushed arrays, and the epoch/caching machinery here never
looks at the backend.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

import numpy as np

from .engine import Engine, QueryCaps
from .index import CPQxIndex
from .query import CPQ, plan_shape
from .rpq import RPQ
from .workload import DEFAULT_TENANT


_GRAPH_OPS = frozenset({"insert_edge", "delete_edge", "change_label",
                        "delete_vertex", "insert_vertex"})
_INTEREST_OPS = frozenset({"insert_interest", "delete_interest"})
_UPDATE_OPS = _GRAPH_OPS | _INTEREST_OPS


@dataclasses.dataclass
class QueryRequest:
    """One in-flight query: filled in place when its flush completes."""

    rid: int
    query: CPQ
    tenant: str = DEFAULT_TENANT
    result: np.ndarray | None = None
    done: bool = False
    from_cache: bool = False
    shed: bool = False  # rejected by admission control at submit
    shed_reason: str | None = None  # which gate: queue/tenant_queue/slo
    voted: bool = False  # already credited to the workload sketch
    predicted_ns: float = 0.0  # calibrated dispatch cost (SLO pricing)
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency(self) -> float:
        """Seconds from submit to completion (0.0 while in flight)."""
        return max(0.0, self.t_done - self.t_submit)


@dataclasses.dataclass
class TenantStats:
    submitted: int = 0
    served: int = 0
    shed: int = 0  # rejected at submit by admission control
    cache_hits: int = 0
    # which admission gate shed, and how often: "queue" (global depth),
    # "tenant_queue" (per-tenant depth), "slo" (predicted cost over the
    # tenant's latency budget)
    shed_reasons: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    served: int = 0
    cache_hits: int = 0
    executed: int = 0  # queries that reached the device
    deduped: int = 0  # in-flight duplicates folded into one execution
    cross_round_joins: int = 0  # requests that joined a query already
    # dispatched in the previous (unharvested) round
    flushes: int = 0
    drain_rounds: int = 0  # fair-share rounds across all flushes
    shed: int = 0  # requests rejected at submit (queue full)
    shape_buckets: int = 0  # distinct plan shapes across all flushes (the
    # device may dispatch more often: caps buckets and overflow retries)
    plan_hits: int = 0
    updates_applied: int = 0  # individual update ops through apply_updates
    update_batches: int = 0  # coalesced mirror/device maintenance rounds
    retry_rungs: int = 0  # capacity-ladder rungs climbed by this service's
    # traffic (delta of Engine.telemetry across flushes) — estimator
    # health beyond wall-clock
    sequences_observed: int = 0  # candidate seqs harvested into the sketch
    adapt_rounds: int = 0  # AdaptationController.propose invocations
    interests_inserted: int = 0  # mined interest insertions drained
    interests_deleted: int = 0  # mined interest deletions drained
    tenants: dict = dataclasses.field(default_factory=dict)

    def tenant(self, name: str) -> TenantStats:
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = TenantStats()
        return ts


@dataclasses.dataclass
class _Round:
    """One fair-share drain round in flight through the engine."""

    reqs: list  # every request taken this round (incl. cache hits)
    todo: list  # the subset needing device execution
    by_query: dict
    queries: list  # distinct CPQ queries (the shaped/union batch)
    plans: list
    rpq_queries: list  # distinct RPQ queries (fixpoint evaluation)
    handle: object = None


class QueryService:
    """Continuous-batching front end over a CPQx/iaCPQx engine."""

    def __init__(self, engine: Engine, *, max_batch: int = 64,
                 result_cache_size: int = 1024, plan_cache_size: int = 256,
                 caps: QueryCaps | None = None, max_retries: int = 10,
                 maintainer=None, adapter=None, adapt_interval: int = 64,
                 max_queue: int | None = None,
                 max_queue_per_tenant: int | None = None,
                 auto_flush: bool = True, union: bool = False,
                 slo_ns: float | dict | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.max_batch = max_batch
        self.caps = caps
        self.max_retries = max_retries
        # admission control: None = unbounded (the legacy behavior).
        # With auto_flush the queue never exceeds max_batch, so bounds
        # matter to callers that burst-submit with auto_flush=False.
        self.max_queue = max_queue
        self.max_queue_per_tenant = max_queue_per_tenant
        # SLO-aware shedding: a latency budget in device nanoseconds —
        # one float for every tenant, or {tenant: budget} (missing
        # tenants are unbudgeted).  Only bites on a calibrated engine:
        # without a cost table every prediction is 0.0.
        self.slo_ns = slo_ns
        self.auto_flush = auto_flush
        self.union = union  # fuse straggler shape buckets per round
        self.graph_epoch = 0
        self.stats = ServiceStats()
        self.maintainer = maintainer  # MaintainableIndex enabling the write path
        # AdaptationController turning traffic into interest proposals;
        # requires an interest-aware maintainer (the proposals ride the
        # write path)
        self.adapter = adapter
        self.adapt_interval = adapt_interval
        if adapter is not None:
            if maintainer is None or maintainer.index.interests is None:
                raise ValueError(
                    "an adapter requires an interest-aware maintainer — "
                    "MaintainableIndex.build(g, k, interests=[...])")
            if adapter.k > maintainer.index.k:
                raise ValueError(
                    f"adapter harvests windows up to k={adapter.k} but "
                    f"the index is k={maintainer.index.k} — its "
                    "proposals could never be indexed")
        self._next_rid = 0
        self._ckpt_step = 0  # next checkpoint step id (monotone)
        self._planned_since_adapt = 0
        self._rungs_seen = engine.telemetry.retry_rungs
        self._flushing = False  # reentrancy guard for the pipelined drain
        self._adapting = False  # reentrancy guard for adapt()
        self._queue: list[QueryRequest] = []
        self._pending_updates: list = []
        self._results: OrderedDict = OrderedDict()  # (epoch, query) -> rows
        self._result_cache_size = result_cache_size
        self._plans: OrderedDict = OrderedDict()  # (epoch, query) -> plan
        self._plan_cache_size = plan_cache_size

    # ------------------------------------------------------------------ #
    # request lifecycle
    # ------------------------------------------------------------------ #

    def submit(self, query: CPQ,
               tenant: str = DEFAULT_TENANT) -> QueryRequest:
        """Enqueue a query for ``tenant``.  Served straight from the
        result cache when possible; rejected (``shed=True, done=True,
        result=None``) when admission control finds the queue full;
        otherwise it completes on the next flush (which happens
        automatically once the queue holds ``max_batch`` requests, unless
        ``auto_flush=False``)."""
        req = QueryRequest(self._next_rid, query, tenant=tenant,
                           t_submit=time.perf_counter())
        self._next_rid += 1
        self.stats.submitted += 1
        tstats = self.stats.tenant(tenant)
        tstats.submitted += 1
        cached = self._cache_get(query)
        if cached is not None:
            req.result, req.done, req.from_cache = cached, True, True
            req.t_done = time.perf_counter()
            self.stats.cache_hits += 1
            self.stats.served += 1
            tstats.cache_hits += 1
            tstats.served += 1
            # a cache hit never reaches the planner, but it IS workload:
            # a hot template must keep voting while it is being served
            # for free, or the sketch would starve exactly when a
            # sequence is hottest
            self._observe(query, tenant=tenant)
            req.voted = True
            self._maybe_adapt()
            return req
        reason = self._admit(req)
        if reason is not None:
            # explicit shed at the door: the caller learns immediately
            # (and why), and an *accepted* request is never dropped later
            req.shed, req.done, req.shed_reason = True, True, reason
            req.t_done = time.perf_counter()
            self.stats.shed += 1
            tstats.shed += 1
            tstats.shed_reasons[reason] = \
                tstats.shed_reasons.get(reason, 0) + 1
            return req
        self._queue.append(req)
        if self.auto_flush and len(self._queue) >= self.max_batch:
            self.flush()
        return req

    def _admit(self, req: QueryRequest) -> str | None:
        """Admission control at the door: returns the shed reason, or
        None to admit."""
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            return "queue"
        if self.max_queue_per_tenant is not None:
            held = sum(r.tenant == req.tenant for r in self._queue)
            if held >= self.max_queue_per_tenant:
                return "tenant_queue"
        budget = self._slo_budget(req.tenant)
        if budget is not None and not isinstance(req.query, RPQ):
            # price THIS request (its plan's calibrated dispatch cost) on
            # top of the queue's predicted backlog; an expensive query
            # sheds where a cheap one still admits.  RPQs are exempt —
            # the fixpoint has no single plan to price.
            req.predicted_ns = self.engine.predict_cost_ns(
                self._plan(req.query))
            backlog = sum(r.predicted_ns for r in self._queue)
            if backlog + req.predicted_ns > budget:
                return "slo"
        return None

    def _slo_budget(self, tenant: str) -> float | None:
        if self.slo_ns is None:
            return None
        if isinstance(self.slo_ns, dict):
            return self.slo_ns.get(tenant)
        return float(self.slo_ns)

    def flush(self) -> list[QueryRequest]:
        """Drain the whole queue and return the completed requests.

        The drain runs in fair-share rounds of at most ``max_batch``:
        requests are picked round-robin across tenants (submit order
        within each tenant), duplicates within a round collapse onto one
        execution, and the engine groups the distinct queries by plan
        shape — each shape bucket is one vmapped device dispatch.  The
        rounds are *pipelined*: round N+1's host-side work (cache
        re-check, dedup, planning, capacity estimation) overlaps round
        N's device execution, riding JAX's async dispatch.

        Queued updates (``apply_updates`` / adaptation proposals) are
        drained first, so every query in this flush is answered on the
        post-update index.  On an engine failure every not-yet-completed
        request is requeued — accepted requests are never lost."""
        if self._flushing:
            return []
        self._flushing = True
        completed: list[QueryRequest] = []
        inflight: _Round | None = None
        nxt: _Round | None = None
        took = False
        try:
            self._drain_updates()
            while True:
                nxt = self._prepare_round(inflight)
                if nxt is None and inflight is None:
                    break
                took = took or nxt is not None
                if nxt is not None:
                    self._dispatch_round(nxt)
                if inflight is not None:
                    completed.extend(self._finalize_round(inflight))
                inflight, nxt = nxt, None
        except Exception:
            requeue = [r for rnd in (inflight, nxt) if rnd is not None
                       for r in rnd.todo if not r.done]
            self._queue = requeue + self._queue
            raise
        finally:
            self._flushing = False
        if took:
            self.stats.flushes += 1
            self._maybe_adapt()
        return completed

    def _take_round(self) -> list[QueryRequest]:
        """Pick up to ``max_batch`` queued requests, round-robin across
        tenants in first-arrival order (submit order within a tenant) —
        the fairness half of admission control: a tenant flooding the
        queue only delays itself."""
        if not self._queue:
            return []
        by_tenant: OrderedDict = OrderedDict()
        for r in self._queue:
            by_tenant.setdefault(r.tenant, []).append(r)
        lanes = list(by_tenant.values())
        take: list[QueryRequest] = []
        depth = 0
        while len(take) < self.max_batch:
            advanced = False
            for lane in lanes:
                if depth < len(lane):
                    take.append(lane[depth])
                    advanced = True
                    if len(take) >= self.max_batch:
                        break
            if not advanced:
                break
            depth += 1
        taken = {id(r) for r in take}
        self._queue = [r for r in self._queue if id(r) not in taken]
        return take

    def _prepare_round(self, inflight: _Round | None = None) -> _Round | None:
        """Host-side half of one drain round: cache re-check, dedup,
        voting, planning.  Runs while the previous round executes on
        device.

        ``inflight`` is the previous round, already dispatched but not
        yet harvested: a request whose query is executing there *joins
        that round* — pure host bookkeeping (append to its request
        lists; ``_finalize_round`` walks them at harvest time), so the
        duplicate neither re-executes nor stalls the pipeline."""
        batch = self._take_round()
        if not batch:
            return None
        todo: list[QueryRequest] = []
        for req in batch:
            cached = self._cache_get(req.query)
            if cached is not None:
                req.result, req.done, req.from_cache = cached, True, True
                req.t_done = time.perf_counter()
                self.stats.cache_hits += 1
                self.stats.tenant(req.tenant).cache_hits += 1
                if not req.voted:
                    self._observe(req.query, tenant=req.tenant)
                    req.voted = True  # served for free, still votes once
            else:
                todo.append(req)
        by_query: dict = {}
        for req in todo:
            by_query.setdefault(req.query, []).append(req)
        queries = list(by_query)
        # votes are idempotent per REQUEST (the ``voted`` flag): a round
        # requeued by an engine failure re-plans on retry but cannot
        # vote again, so flaky traffic no longer inflates the sketch.
        # Folded duplicates are workload too — each unvoted request
        # credits its own tenant, or a template submitted N times per
        # round would earn 1/N of its true frequency.
        for q, reqs in by_query.items():
            fresh = [r for r in reqs if not r.voted]
            per_tenant: OrderedDict = OrderedDict()
            for r in fresh:
                per_tenant[r.tenant] = per_tenant.get(r.tenant, 0) + 1
                r.voted = True
            first = True
            for t, w in per_tenant.items():
                self._observe(q, weight=w, tick=first, tenant=t)
                first = False
        # cross-round dedup: queries already dispatched in the previous
        # round move their requests over to it (they complete when that
        # round harvests) instead of dispatching the same query twice
        if inflight is not None:
            moved: set = set()
            for q in [q for q in queries if q in inflight.by_query]:
                joiners = by_query.pop(q)
                inflight.by_query[q].extend(joiners)
                inflight.todo.extend(joiners)
                inflight.reqs.extend(joiners)
                moved.update(id(r) for r in joiners)
                self.stats.cross_round_joins += len(joiners)
            if moved:
                batch = [r for r in batch if id(r) not in moved]
                todo = [r for r in todo if id(r) not in moved]
                queries = list(by_query)
        if not batch:  # every request joined the in-flight round
            return None
        self.stats.drain_rounds += 1
        cpq_queries = [q for q in queries if not isinstance(q, RPQ)]
        rpq_queries = [q for q in queries if isinstance(q, RPQ)]
        plans = [self._plan(q) for q in cpq_queries]
        return _Round(batch, todo, by_query, cpq_queries, plans, rpq_queries)

    def _dispatch_round(self, rnd: _Round) -> None:
        if rnd.queries:
            rnd.handle = self.engine.dispatch_batch(
                rnd.queries, caps=self.caps, plans=rnd.plans,
                union=self.union)

    def _finalize_round(self, rnd: _Round) -> list[QueryRequest]:
        """Device-side half: harvest the dispatched round (driving the
        overflow ladder), publish results to caches and requests."""
        if rnd.queries or rnd.rpq_queries:
            rows = []
            if rnd.queries:
                rows = self.engine.harvest_batch(
                    rnd.handle, max_retries=self.max_retries)
                self.stats.shape_buckets += len({plan_shape(p)
                                                 for p in rnd.plans})
            # RPQ fixpoints run after the shaped batch: each iteration's
            # frontier expansion is itself a batch of per-sequence CPQx
            # lookups through the same capacity ladder, so they reuse the
            # device path rather than bypassing it.
            rpq_rows = [self.engine.execute_rpq(q) for q in rnd.rpq_queries]
            self.stats.executed += len(rnd.queries) + len(rnd.rpq_queries)
            self.stats.deduped += (len(rnd.todo) - len(rnd.queries)
                                   - len(rnd.rpq_queries))
            now = time.perf_counter()
            for q, res in zip(rnd.queries + rnd.rpq_queries,
                              list(rows) + rpq_rows):
                self._cache_put(q, res)
                for req in rnd.by_query[q]:
                    req.result, req.done, req.t_done = res, True, now
            # ladder telemetry: fold the engine's rung delta into the
            # service view (estimator health is a serving-layer signal)
            rungs = self.engine.telemetry.retry_rungs
            self.stats.retry_rungs += rungs - self._rungs_seen
            self._rungs_seen = rungs
        self.stats.served += len(rnd.reqs)
        for req in rnd.reqs:
            self.stats.tenant(req.tenant).served += 1
        return rnd.reqs

    def query(self, query: CPQ, tenant: str = DEFAULT_TENANT) -> np.ndarray:
        """One-shot convenience: submit + flush, returns the (n, 2) rows.
        Raises if admission control shed the request (one-shot callers
        have no request handle to poll)."""
        req = self.submit(query, tenant=tenant)
        if not req.done:
            self.flush()
        if req.shed:
            raise RuntimeError(
                "request shed by admission control — the queue is full")
        return req.result

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def pending_updates(self) -> int:
        return len(self._pending_updates)

    # ------------------------------------------------------------------ #
    # graph mutation / epoch handling
    # ------------------------------------------------------------------ #

    def apply_updates(self, updates: list) -> None:
        """The write path: queue a batch of graph and/or interest updates
        (op tuples in ``MaintainableIndex.apply_updates`` /
        ``apply_interest_updates`` form, e.g. ``("insert_edge", v, u,
        lbl)`` or ``("insert_interest", (l1, l2))``).

        Reads already queued are drained first (they targeted the
        pre-update graph), then the updates are queued and the epoch
        bumps — O(1) invalidation of every cached answer.  The expensive
        work (mirror surgery + mirror→device flush) is deferred to the
        next query drain, so consecutive ``apply_updates`` calls —
        graph, interest, or mixed — coalesce into one batched
        maintenance round with a single flush + rebind."""
        if self.maintainer is None:
            raise RuntimeError(
                "no maintainer bound — construct the service with "
                "QueryService(engine, maintainer=MaintainableIndex.build(...))"
            )
        if not updates:
            return
        for op in updates:  # reject malformed ops at enqueue, not drain
            if not op or op[0] not in _UPDATE_OPS:
                raise ValueError(f"unknown update op {op!r}")
            if op[0] in _INTEREST_OPS:
                self._check_interest_op(op)
        if self._queue:
            self.flush()  # reads before the write see the pre-update graph
        self._pending_updates.extend(updates)
        self.bump_epoch()

    def insert_interest(self, seq) -> None:
        """Queue one interest insertion (Sec. V-C) through the write
        path — coalesces with any queued graph updates into the same
        flush + rebind instead of forcing its own."""
        self.apply_updates([("insert_interest", tuple(seq))])

    def delete_interest(self, seq) -> None:
        """Queue one interest deletion through the write path."""
        self.apply_updates([("delete_interest", tuple(seq))])

    def _check_interest_op(self, op) -> None:
        """Enqueue-time validation of an interest op: everything the
        mirror would reject at drain time is rejected here instead —
        the SAME validator the mirror runs
        (``MaintainableIndex.check_interest_op``), so a queued interest
        batch can never poison a coalesced round."""
        self.maintainer.check_interest_op(op)

    def _drain_updates(self) -> None:
        """Coalesce every queued update into one maintenance round — one
        graph mirror batch + one interest mirror batch + ONE
        mirror→device flush — and rebind the engine to the flushed
        arrays.

        Graph ops apply before interest ops regardless of enqueue order:
        answers depend only on the final (graph, interest set), and the
        interest batch enumerates pairs on the post-batch graph, so the
        net effect is answer-identical to sequential application (only
        the lazy partition — pruning power until a rebuild — can
        differ)."""
        if not self._pending_updates:
            return
        ups, self._pending_updates = self._pending_updates, []
        graph_ops = [op for op in ups if op[0] in _GRAPH_OPS]
        int_ops = [op for op in ups if op[0] in _INTEREST_OPS]
        try:
            if graph_ops:
                self.maintainer.apply_updates(graph_ops)
        except Exception:
            # the mirror validates before mutating, so a failed batch left
            # it untouched: requeue so ops coalesced into this batch
            # aren't silently dropped
            self._pending_updates = ups + self._pending_updates
            raise
        try:
            if int_ops:
                self.maintainer.apply_interest_updates(int_ops)
        except Exception:
            # every interest precondition was validated at enqueue, so
            # this is a bug path — but the graph half already applied:
            # requeue only the interest half and publish the graph half
            self._pending_updates = int_ops + self._pending_updates
            self.engine.rebind(self.maintainer.flush())
            self.stats.updates_applied += len(graph_ops)
            self.stats.update_batches += 1
            raise
        self.engine.rebind(self.maintainer.flush())
        self.stats.updates_applied += len(ups)
        self.stats.update_batches += 1
        self.stats.interests_inserted += sum(
            op[0] == "insert_interest" for op in int_ops)
        self.stats.interests_deleted += sum(
            op[0] == "delete_interest" for op in int_ops)

    def rebind(self, index: CPQxIndex) -> None:
        """Swap in a rebuilt index (after ``core.maintenance`` mirror
        surgery or a from-scratch rebuild).  Bumps the graph epoch so
        every cached result — and every cached plan, which since PR 4 is
        optimized against the old index's statistics — is dead."""
        if self._queue:
            self.flush()  # drain against the index the requests targeted
        self.engine.rebind(index)
        self.bump_epoch()

    def bump_epoch(self) -> None:
        """O(1) invalidation: results *and* plans are keyed by epoch, so
        stale entries become unreachable and age out of their LRUs."""
        self.graph_epoch += 1

    # ------------------------------------------------------------------ #
    # lifecycle: checkpoint / warm restart (core.lifecycle)
    # ------------------------------------------------------------------ #

    def checkpoint(self, ckpt_dir: str, step: int | None = None) -> int:
        """Snapshot the full serving state as one atomic committed step;
        returns the step id.

        Consistency: the queue is drained first — the SAME
        ``_drain_updates`` one-batch round every query drain runs — so
        the snapshot is taken at a quiescent epoch where device arrays,
        host mirror, interest set and sketch all agree.  A crash during
        the write leaves the previous committed step intact (the
        checkpoint layer's rename-commit + LATEST-pointer contract)."""
        from . import lifecycle  # lazy: service must import without it

        self.flush()  # drain pending writes AND reads at one epoch
        if step is None:
            step = self._ckpt_step
        # a cluster backend checkpoints through a barrier: every worker
        # acks and reports the coordinator's state epoch (catching any
        # missed state instruction) before the snapshot is cut, and the
        # committed step becomes the fleet's respawn base
        quiesce = getattr(self.engine.backend, "quiesce", None)
        if quiesce is not None:
            quiesce(step)
        leaves, extra = lifecycle.service_leaves(self)
        lifecycle.save_checkpoint(ckpt_dir, step, leaves, extra=extra)
        committed = getattr(self.engine.backend, "checkpoint_committed",
                            None)
        if committed is not None:
            committed(ckpt_dir, step)
        self._ckpt_step = step + 1
        return step

    def restore(self, ckpt_dir: str, step: int | None = None) -> int:
        """Warm-restart THIS service from a committed checkpoint (latest
        unless ``step`` pins one): rebind the engine to the restored
        arrays (pre-warmed statistics), swap in the restored mirror and
        adapter, and bump the epoch PAST both the live one and the
        checkpoint's — every cached answer and plan from any pre-restore
        state becomes unreachable in O(1).  In-flight reads/writes are
        flushed first so they complete against the state they targeted.
        Returns the restored step id."""
        from . import lifecycle

        self.flush()  # complete in-flight work on the pre-restore state
        state = lifecycle.load_state(ckpt_dir, step)
        self.engine.rebind(state.index, stats=state.stats)
        self.maintainer = state.maintainer
        self.adapter = state.adapter
        self.graph_epoch = max(self.graph_epoch, state.epoch) + 1
        self._ckpt_step = max(self._ckpt_step, state.step + 1)
        self._pending_updates = []
        self._planned_since_adapt = 0
        self._rungs_seen = self.engine.telemetry.retry_rungs
        return state.step

    # ------------------------------------------------------------------ #
    # the adaptation loop (core.workload)
    # ------------------------------------------------------------------ #

    def _maybe_adapt(self) -> None:
        if self.adapter is None:
            return
        if self._planned_since_adapt < self.adapt_interval:
            return
        self.adapt()

    def adapt(self) -> list:
        """Run one adaptation round NOW: price the sketch's heavy
        hitters against the engine's live statistics and queue the
        controller's interest proposals on the write path (they drain —
        one flush, one rebind, one epoch bump — with whatever else is
        queued at the next query drain).  Returns the proposed ops.

        An adaptation round is a *write*: like ``apply_updates`` it
        drains queued reads first, so a read submitted before the round
        executes on the pre-adaptation index (interest swaps are
        answer-preserving, but the serializable history must hold at
        the execution level too — a queued read must never run against
        state from a later-accepted write).  Re-entrant calls (the
        drain's own traffic re-triggering ``_maybe_adapt``) are no-ops.

        Called automatically from ``flush`` every ``adapt_interval``
        planned queries; callable directly for checkpoint-style control
        (benchmarks, tests)."""
        if self.adapter is None:
            raise RuntimeError(
                "no adapter bound — construct the service with "
                "QueryService(engine, maintainer=..., "
                "adapter=AdaptationController(k))")
        if self._adapting:
            return []
        self._adapting = True
        try:
            if self._queue:
                self.flush()  # reads before the round see the old index
            self._planned_since_adapt = 0
            self.stats.adapt_rounds += 1
            ops = self.adapter.propose(
                self.engine.stats, self.maintainer.index.interests)
            # the queue invariant holds for the controller too: a proposal
            # the mirror would reject (e.g. mined from a query over labels
            # outside the alphabet) is dropped, never queued — one bad
            # proposal must not poison every later coalesced round
            valid = []
            for op in ops:
                try:
                    self._check_interest_op(op)
                except ValueError:
                    continue
                valid.append(op)
            if valid:
                self._pending_updates.extend(valid)
                self.bump_epoch()
            return valid
        finally:
            self._adapting = False

    # ------------------------------------------------------------------ #
    # caches
    # ------------------------------------------------------------------ #

    def _cache_get(self, query: CPQ):
        key = (self.graph_epoch, query)
        if key in self._results:
            self._results.move_to_end(key)
            return self._results[key]
        return None

    def _cache_put(self, query: CPQ, rows: np.ndarray) -> None:
        # the same array is handed to every requester and to future cache
        # hits — freeze it so no caller can corrupt the shared answer
        rows.setflags(write=False)
        key = (self.graph_epoch, query)
        self._results[key] = rows
        self._results.move_to_end(key)
        while len(self._results) > self._result_cache_size:
            self._results.popitem(last=False)

    def _observe(self, query: CPQ, weight: float = 1.0, tick: bool = True,
                 tenant: str = DEFAULT_TENANT) -> None:
        """Feed one served query into its tenant's adaptation sketch
        (``weight`` credits folded duplicates; ``tick`` advances the
        adapt-interval clock)."""
        if self.adapter is None:
            return
        self.stats.sequences_observed += self.adapter.observe(
            query, weight, tenant=tenant)
        if tick:
            self._planned_since_adapt += 1

    def _plan(self, query: CPQ):
        # planning is pure: voting happens per REQUEST in the drain
        # (``_prepare_round``), guarded by the ``voted`` flag, so a
        # requeued-and-replanned round cannot inflate the sketch
        key = (self.graph_epoch, query)
        if key in self._plans:
            self._plans.move_to_end(key)
            self.stats.plan_hits += 1
            return self._plans[key]
        plan = self.engine.plan(query)
        self._plans[key] = plan
        while len(self._plans) > self._plan_cache_size:
            self._plans.popitem(last=False)
        return plan
