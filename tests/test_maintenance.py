"""Lazy maintenance (Sec. IV-E / V-C): query answers stay exact after
edge / vertex / interest updates (Prop. 4.2); index growth stays bounded."""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import oracle
from repro.core.maintenance import MaintainableIndex


def _validate(mi, seed=9, trials=12):
    rng = np.random.default_rng(seed)
    for _ in range(trials):
        q = oracle.random_cpq(rng, mi.g, 3)
        assert mi.query(q) == oracle.cpq_eval(mi.g, q)


class TestEdgeUpdates:
    def test_delete_then_correct(self):
        g = random_graph(5, n_max=16, m_max=40)
        mi = MaintainableIndex.build(g, 2)
        base = mi.g._base_edges()
        for i in range(3):
            v, u, l = map(int, base[i * 2])
            mi.delete_edge(v, u, l)
        _validate(mi)
        assert mi.n_splits > 0  # lazy splits happened, never merges

    def test_insert_then_correct(self):
        g = random_graph(6, n_max=16, m_max=30)
        mi = MaintainableIndex.build(g, 2)
        rng = np.random.default_rng(0)
        for _ in range(3):
            mi.insert_edge(int(rng.integers(0, g.n_vertices)),
                           int(rng.integers(0, g.n_vertices)),
                           int(rng.integers(0, g.n_labels)))
        _validate(mi)

    def test_delete_insert_roundtrip_semantics(self):
        """Deleting and re-inserting the same edge must restore ⟦q⟧ even
        though the partition is now lazily split."""
        g = random_graph(7, n_max=14, m_max=30)
        mi = MaintainableIndex.build(g, 2)
        v, u, l = map(int, mi.g._base_edges()[0])
        before = {}
        rng = np.random.default_rng(1)
        queries = [oracle.random_cpq(rng, g, 3) for _ in range(8)]
        for i, q in enumerate(queries):
            before[i] = oracle.cpq_eval(g, q)
        mi.delete_edge(v, u, l)
        mi.insert_edge(v, u, l)
        for i, q in enumerate(queries):
            assert mi.query(q) == before[i]

    def test_vertex_delete(self):
        g = random_graph(8, n_max=14, m_max=30)
        mi = MaintainableIndex.build(g, 2)
        mi.delete_vertex(2)
        _validate(mi)
        for s, d in zip(mi.g.src, mi.g.dst):
            assert 2 not in (int(s), int(d))

    def test_size_growth_bounded(self):
        """Table VII: modest growth under a batch of updates."""
        g = random_graph(9, n_max=16, m_max=40)
        mi = MaintainableIndex.build(g, 2)
        l2c0, c2p0 = mi.size_entries()
        rng = np.random.default_rng(2)
        base = mi.g._base_edges()
        for i in range(2):
            v, u, l = map(int, base[i])
            mi.delete_edge(v, u, l)
            mi.insert_edge(v, u, l)
        l2c1, c2p1 = mi.size_entries()
        assert c2p1 <= c2p0 * 2 + 10
        assert l2c1 <= l2c0 * 3 + 10


class TestInterestUpdates:
    def test_interest_ops_require_interest_aware_index(self):
        """API precondition, not an internal invariant: a full-CPQx
        mirror rejects interest updates with ValueError (survives
        ``python -O``, unlike the old bare assert)."""
        g = random_graph(10, n_max=16, m_max=40)
        mi = MaintainableIndex.build(g, 2)  # no interests
        with pytest.raises(ValueError, match="interest-aware"):
            mi.delete_interest((0, 1))
        with pytest.raises(ValueError, match="interest-aware"):
            mi.insert_interest((0, 1))

    def test_interest_delete_insert(self):
        g = random_graph(10, n_max=16, m_max=40)
        mi = MaintainableIndex.build(g, 2, interests=[(0, 1), (1, 1)])
        mi.delete_interest((0, 1))
        _validate(mi)
        mi.insert_interest((2, 0))
        _validate(mi)

    def test_mixed_graph_and_interest_updates(self):
        g = random_graph(12, n_max=14, m_max=30)
        mi = MaintainableIndex.build(g, 2, interests=[(0, 0)])
        v, u, l = map(int, mi.g._base_edges()[0])
        mi.delete_edge(v, u, l)
        mi.insert_interest((1, 0))
        mi.insert_edge(v, u, l)
        _validate(mi)
