"""Device-side enumeration of P^{<=k}: distinct labeled paths per level.

Level i holds the relation of distinct rows (v, u, s_1, ..., s_i) — one row
per *distinct label sequence* realized from v to u by some length-i path
(path multiplicity is deduped away; CPQ semantics are set-based).

Level 1 is the edge relation; level i is the capacity-padded expansion
join of level i-1 with the edges on the shared intermediate vertex,
followed by sort + exact dedup.  This same relation *is* the
language-unaware path index [14] (label sequence -> s-t pairs), so the
baseline and CPQx share one enumeration.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import relational as R
from .graph import LabeledGraph


class DeviceGraph(NamedTuple):
    """Edge relation on device, sorted by (src, dst, lbl)."""

    edges: R.Relation  # cols (src, dst, lbl)
    n_vertices: int  # static
    n_labels: int  # static (base labels; alphabet is 2x)


def device_graph(g: LabeledGraph, capacity: int | None = None) -> DeviceGraph:
    rows = np.stack([g.src, g.dst, g.lbl], axis=1)
    order = np.lexsort((rows[:, 2], rows[:, 1], rows[:, 0]))
    rows = rows[order]
    cap = capacity or max(1, rows.shape[0])
    return DeviceGraph(R.from_numpy(rows, cap), g.n_vertices, g.n_labels)


@functools.partial(jax.jit, static_argnames=("k", "caps"))
def enumerate_path_levels(dg: DeviceGraph, k: int, caps: tuple) -> tuple:
    """Compute levels 1..k.  ``caps[i-1]`` is the row capacity of level i.

    Returns a tuple of Relations; level i has cols (v, u, s_1..s_i),
    sorted by (v, u, s_1..s_i), exactly deduped.  Overflow flags are
    sticky through the pipeline.
    """
    assert len(caps) == k
    edges = dg.edges  # sorted by (src, dst, lbl)
    lvl1 = R.rel_sort(
        R.Relation(edges.cols, edges.count, edges.overflow), num_keys=3
    )
    # re-embed at requested capacity
    lvl1 = _recap(lvl1, caps[0])
    levels = [lvl1]
    for i in range(2, k + 1):
        prev = levels[-1]  # (v, m, s_1..s_{i-1}) sorted by (v, m, ...)
        # join key: prev's col 1 (m) against edges' src
        prev_by_m = R.rel_sort(prev, num_keys=prev.arity)  # ensure sorted
        # we need prev sorted by m for nothing — expansion join only needs
        # *edges* sorted on the key; prev rows are streamed.
        out_cols = (
            [("a", 0), ("b", 1)]
            + [("a", j) for j in range(2, prev.arity)]
            + [("b", 2)]
        )
        joined = R.expansion_join(
            prev_by_m, edges, a_on=[1], out_cols=out_cols, out_capacity=caps[i - 1]
        )
        joined = R.rel_sort(joined)
        joined = R.rel_unique(joined)
        levels.append(joined)
    return tuple(levels)


def _recap(rel: R.Relation, cap: int) -> R.Relation:
    """Re-embed a relation at a (>= count) capacity."""
    if rel.capacity == cap:
        return rel
    idx = jnp.arange(cap, dtype=R.I32)
    m = idx < rel.count
    src = jnp.clip(idx, 0, rel.capacity - 1)
    cols = tuple(jnp.where(m, c[src], R.SENTINEL) for c in rel.cols)
    overflow = rel.overflow | (rel.count > cap)
    return R.Relation(cols, jnp.minimum(rel.count, cap).astype(R.I32), overflow)


def pairs_of_levels(levels: tuple, cap: int, union_cap: int | None = None) -> R.Relation:
    """Distinct s-t pairs across all levels: P^{<=k} (cols v, u).
    ``union_cap`` must hold the pre-dedup union (defaults to sum of level
    capacities)."""
    if union_cap is None:
        union_cap = sum(lvl.capacity for lvl in levels)
    acc = None
    for lvl in levels:
        pairs = R.Relation(lvl.cols[:2], lvl.count, lvl.overflow)
        pairs = R.rel_unique(R.rel_sort(pairs), 2)
        acc = pairs if acc is None else R.rel_concat(acc, pairs, union_cap)
    acc = R.rel_unique(R.rel_sort(acc), 2)
    return _recap(acc, cap)


def seq_rows_of_levels(levels: tuple, k: int, cap: int) -> R.Relation:
    """All (s_1..s_k [padded -1], v, u) incidence rows across levels.

    The sequence columns come first so the result can be sorted/grouped by
    sequence; shorter sequences are padded with -1 (sorts before any real
    label)."""
    parts = []
    for i, lvl in enumerate(levels, start=1):
        v, u = lvl.cols[0], lvl.cols[1]
        seq = list(lvl.cols[2:])
        validm = R.valid_mask(lvl)
        pad = jnp.where(validm, jnp.int32(-1), R.SENTINEL)
        seq = seq + [pad] * (k - i)
        parts.append(R.Relation(tuple(seq) + (v, u), lvl.count, lvl.overflow))
    acc = parts[0]
    for p in parts[1:]:
        acc = R.rel_concat(acc, p, cap)
    return R.rel_unique(R.rel_sort(_recap(acc, cap)))
