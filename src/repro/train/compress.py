"""Gradient compression for slow/contended interconnects: blockwise int8
quantization with error feedback (EF-SGD style), plus a shard_map
all-reduce that moves int8 over the wire — the collective-bytes lever of
§Perf (4x fewer bytes than f32 ring all-reduce, 2x fewer than bf16).

Semantics: quantize(g + residual) -> all_reduce int8 blocks (summed in
int32, scales combined) -> dequantize; the quantization error is carried
to the next step (error feedback keeps convergence unbiased in practice).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 256


class CompressState(NamedTuple):
    residual: dict  # error-feedback carry, same tree as grads (f32)


def compress_init(grads_like) -> CompressState:
    return CompressState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                              grads_like)
    )


def _quantize(x: jax.Array):
    """Blockwise symmetric int8: returns (q int8 (n/B, B), scale f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def _dequantize(q, scale, n, shape):
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return out.reshape(shape)


def quantize_with_feedback(g: jax.Array, residual: jax.Array):
    """Returns (q, scale, n, new_residual)."""
    target = g.astype(jnp.float32) + residual
    q, scale, n = _quantize(target)
    approx = _dequantize(q, scale, n, g.shape)
    return (q, scale, n), target - approx


def compressed_psum_grads(grads: dict, state: CompressState, axis: str):
    """Inside shard_map: int8 all-reduce of a gradient tree over ``axis``
    with error feedback.  Returns (mean grads f32, new state).

    Algorithm (the EF-compressed ring equivalent):
      1. quantize(g + residual) locally — int8 blocks + f32 block scales;
      2. all_to_all the blocks: each device receives its OWNED slice from
         every peer (int8 on the wire) with the peers' scales;
      3. exact dequantized reduction of the owned slice (each peer's
         contribution uses its OWN scale — no averaged-scale bias);
      4. re-quantize the reduced slice, all_gather int8 + scales.

    Wire bytes/elem: 1 (all_to_all) + 1 (all_gather) + scales = ~2.03
    vs 8 for the f32 ring all-reduce — a ~3.9x collective-bytes cut."""
    n_dev = jax.lax.psum(1, axis)

    def one(g, r):
        (q, scale, n), new_r = quantize_with_feedback(g, r)
        nb = q.shape[0]
        pad = (-nb) % n_dev
        if pad:
            q = jnp.concatenate([q, jnp.zeros((pad, BLOCK), q.dtype)], 0)
            scale = jnp.concatenate(
                [scale, jnp.ones((pad, 1), scale.dtype)], 0)
        nbp = q.shape[0]
        m = nbp // n_dev
        # 2. reduce-scatter leg: int8 on the wire
        q_rs = jax.lax.all_to_all(q.reshape(n_dev, m, BLOCK), axis, 0, 0,
                                  tiled=False)
        s_rs = jax.lax.all_to_all(scale.reshape(n_dev, m, 1), axis, 0, 0,
                                  tiled=False)
        # 3. exact per-peer dequantized reduction of my slice
        part = jnp.sum(q_rs.astype(jnp.float32) * s_rs, axis=0)  # (m, BLOCK)
        # 4. re-quantize the reduced slice; all_gather int8
        s_out = jnp.max(jnp.abs(part), axis=1, keepdims=True) / 127.0 + 1e-12
        q_out = jnp.clip(jnp.round(part / s_out), -127, 127).astype(jnp.int8)
        q_full = jax.lax.all_gather(q_out, axis, axis=0, tiled=True)
        s_full = jax.lax.all_gather(s_out, axis, axis=0, tiled=True)
        total = (q_full.astype(jnp.float32) * s_full).reshape(-1)[: n]
        return (total / n_dev).reshape(g.shape).astype(jnp.float32), new_r

    out = jax.tree.map(one, grads, state.residual)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return mean, CompressState(res)
