"""Paper Tables V/VI/VII: maintenance — edge insert/delete and interest
insert/delete times, plus the index-growth ratio under lazy updates."""

from __future__ import annotations

import numpy as np

from repro.core.maintenance import MaintainableIndex

from .bench_query import interests_for
from .common import DATASETS, emit, timeit


def main() -> None:
    for ds in ["robots-like", "gmark-small"]:
        g = DATASETS[ds]()
        ints = interests_for(g)
        rng = np.random.default_rng(0)

        mi = MaintainableIndex.build(g, 2)
        base = mi.g._base_edges()
        size0 = sum(mi.size_entries())

        def del_edge():
            e = base[int(rng.integers(0, base.shape[0]))]
            try:
                mi.delete_edge(int(e[0]), int(e[1]), int(e[2]))
            except Exception:
                pass

        us = timeit(del_edge, warmup=0, iters=5)
        emit(f"table5/{ds}/edge_deletion", us, "")

        def ins_edge():
            mi.insert_edge(int(rng.integers(0, g.n_vertices)),
                           int(rng.integers(0, g.n_vertices)),
                           int(rng.integers(0, g.n_labels)))

        us = timeit(ins_edge, warmup=0, iters=5)
        emit(f"table5/{ds}/edge_insertion", us, "")
        growth = sum(mi.size_entries()) / max(size0, 1)
        emit(f"table7/{ds}/size_ratio_after_10_updates", growth * 1000,
             f"ratio={growth:.3f} splits={mi.n_splits}")

        mia = MaintainableIndex.build(g, 2, interests=ints)
        us = timeit(lambda: mia.delete_interest(ints[0]), warmup=0, iters=1)
        emit(f"table6/{ds}/interest_deletion", us, "")
        us = timeit(lambda: mia.insert_interest(ints[0]), warmup=0, iters=1)
        emit(f"table6/{ds}/interest_insertion", us, "")


if __name__ == "__main__":
    main()
