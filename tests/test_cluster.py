"""Cluster runtime (core.cluster + launch.workers): the in-process
thread twin of the exchange fabric, multi-process parity at 1/2/4
workers against the local engine and the oracle, the service stack
(pipelined drain, maintenance flush, interest rounds, checkpoints) over
worker processes, fault injection (mid-round, pre-rebind-ack,
mid-checkpoint, hard kill) with oracle-identical recovery and no lost
accepted requests, and elastic RESHARD."""

import threading
import time

import numpy as np
import pytest

from conftest import random_graph
from repro.core import cluster as cl
from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import (TEMPLATE_ARITY, TEMPLATES,
                              instantiate_template, plan_shape)
from repro.core.rpq import RAlt, RConcat, RStar, RSym
from repro.core.service import QueryService


def _rows(arr) -> set:
    return {tuple(r) for r in arr.tolist()}


def _queries(g, names, seed=11):
    rng = np.random.default_rng(seed)
    return [instantiate_template(
        n, rng.integers(0, g.alphabet_size, TEMPLATE_ARITY[n]).tolist())
        for n in names]


@pytest.fixture(scope="module")
def fleet_graph():
    return random_graph(5, n_max=20, m_max=55)


@pytest.fixture(scope="module")
def fleet(fleet_graph):
    """One shared 2-worker fleet (max_workers=4 for the resize test at
    the end).  Spawning + per-worker jax init is seconds — tests share
    the fleet and derive ground truth from the maintainer's live graph,
    so earlier mutations never invalidate later assertions."""
    maint = MaintainableIndex.build(fleet_graph, 2)
    engine = Engine(maint.flush(), cluster=2)
    yield {"maint": maint, "engine": engine}
    engine.backend.shutdown()


# ---------------------------------------------------------------------- #
# the exchange fabric + ClusterOps, in-process (threads, no spawn cost)
# ---------------------------------------------------------------------- #


def _thread_cluster_run(idx, n, shape, caps, ranges):
    """Drive the real ClusterOps/WorkerState over thread fabrics — the
    exact worker code path minus the processes."""
    slices = cl.make_slices(idx, n)
    fabrics, _abort = cl.make_thread_fabrics(n)
    parts = [None] * n
    errs = []

    def run(r):
        try:
            st = cl.WorkerState(r, fabrics[r].inboxes, fabrics[r].outboxes,
                                fabrics[r].abort)
            st._apply_slice(slices[r])
            parts[r] = st._execute(
                1, {"shape": shape, "caps": caps, "ranges": ranges})
        except Exception as e:  # pragma: no cover - surfaced via errs
            errs.append(e)

    threads = [threading.Thread(target=run, args=(r,)) for r in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errs, errs
    assert all(p is not None for p in parts)
    return cl.merge_partitions(parts, np.asarray(ranges).shape[0])


class TestThreadFabric:
    def test_plan_walk_matches_local(self, fleet_graph):
        from repro.core import index as cindex

        idx = cindex.build(fleet_graph, 2)
        eng = Engine(idx)
        for q in _queries(fleet_graph, ["C2", "TT", "S", "Ti"], seed=3):
            plan = eng.plan(q)
            ranges = eng.lookup_ranges(plan)
            shape = plan_shape(plan)
            caps = eng.estimate_caps(ranges, shape, plan)
            expect = eng.execute(q)  # local reference (ladder included)
            results, ovf = _thread_cluster_run(
                idx, 3, shape, caps, ranges[None])
            if not ovf[0]:
                assert np.array_equal(results[0], expect), q
            else:
                # advisory flag fired: legal, the ladder would retry —
                # a doubled rung must then land exactly on local
                results, ovf = _thread_cluster_run(
                    idx, 3, shape, caps.doubled().doubled(), ranges[None])
                assert not ovf[0] and np.array_equal(results[0], expect), q

    def test_exchange_tags_drop_stale_rounds(self):
        fabrics, _abort = cl.make_thread_fabrics(2)
        a, b = fabrics
        stale = np.zeros((1, 2), np.int32)
        fresh = np.ones((2, 2), np.int32)
        # a message from an aborted round (older seq) sits in the queue;
        # the receiver must skip it and deliver the current tag
        b.outboxes[0].put((1, 0, 1, stale))
        b.outboxes[0].put((2, 0, 1, fresh))
        a.begin(2)
        got = a._recv(1, 0)
        assert np.array_equal(got, fresh)

    def test_abort_unblocks_a_waiting_receive(self):
        fabrics, abort = cl.make_thread_fabrics(2)
        f = fabrics[0]
        f.begin(7)
        abort.set()
        with pytest.raises(cl.RoundAborted):
            f._recv(1, 0)
        abort.clear()


# ---------------------------------------------------------------------- #
# multi-process parity
# ---------------------------------------------------------------------- #


class TestClusterParity:
    def test_two_workers_full_template_suite(self, fleet):
        maint, eng = fleet["maint"], fleet["engine"]
        local = Engine(maint.flush())
        for q in _queries(maint.g, sorted(TEMPLATES)):
            a, b = local.execute(q), eng.execute(q)
            assert np.array_equal(a, b), q
            assert _rows(b) == oracle.cpq_eval(maint.g, q), q

    def test_one_and_four_workers(self, fleet):
        maint = fleet["maint"]
        idx = maint.flush()
        local = Engine(idx)
        qs = _queries(maint.g, ["C2", "TT", "S", "Ti"], seed=5)
        for n in (1, 4):
            eng = Engine(idx, cluster=n)
            try:
                for q in qs:
                    assert np.array_equal(local.execute(q),
                                          eng.execute(q)), (n, q)
            finally:
                eng.backend.shutdown()

    def test_rpq_fixpoint_through_the_cluster(self, fleet):
        maint, eng = fleet["maint"], fleet["engine"]
        local = Engine(maint.flush())
        q = RConcat(RStar(RAlt(RSym(0), RSym(1))), RSym(2))
        assert np.array_equal(local.execute_rpq(q), eng.execute_rpq(q))


# ---------------------------------------------------------------------- #
# the service stack over worker processes
# ---------------------------------------------------------------------- #


class TestClusterService:
    def test_pipelined_drain_uses_dispatch_harvest(self, fleet):
        maint, eng = fleet["maint"], fleet["engine"]
        runtime = eng.backend.runtime
        before = runtime.instructions[cl.DISPATCH]
        svc = QueryService(eng, max_batch=3, auto_flush=False)
        qs = _queries(maint.g, sorted(TEMPLATES), seed=13)
        reqs = [svc.submit(q) for q in qs]
        svc.flush()
        for q, r in zip(qs, reqs):
            assert r.done and not r.shed
            assert _rows(r.result) == oracle.cpq_eval(maint.g, q), q
        assert runtime.instructions[cl.DISPATCH] > before
        assert runtime.instructions[cl.HARVEST] >= \
            runtime.instructions[cl.DISPATCH] - before

    def test_maintenance_flush_broadcasts_one_rebind(self, fleet):
        maint, eng = fleet["maint"], fleet["engine"]
        runtime = eng.backend.runtime
        before = runtime.instructions[cl.FLUSH_REBIND]
        svc = QueryService(eng, maintainer=maint)
        svc.apply_updates([("insert_edge", 0, 1, 0),
                           ("insert_edge", 1, 2, 1)])
        for q in _queries(maint.g, ["C2", "TT", "T"], seed=17):
            got = svc.query(q)  # first query drains the coalesced batch
            assert _rows(got) == oracle.cpq_eval(maint.g, q), q
        assert runtime.instructions[cl.FLUSH_REBIND] == before + 1

    def test_interest_round_broadcasts_as_instruction(self, fleet_graph):
        mi = MaintainableIndex.build(fleet_graph, 2,
                                     interests=[(0,), (1,), (0, 1)])
        eng = Engine(mi.flush(), cluster=2)
        try:
            svc = QueryService(eng, maintainer=mi)
            q = instantiate_template("C2", [0, 1])
            svc.insert_interest((1, 0))
            got = svc.query(q)
            assert _rows(got) == oracle.cpq_eval(fleet_graph, q)
            assert eng.backend.runtime.instructions[cl.INTEREST_BATCH] == 1
        finally:
            eng.backend.shutdown()


# ---------------------------------------------------------------------- #
# fault injection
# ---------------------------------------------------------------------- #


class TestFaultRecovery:
    def _assert_serving(self, fleet, seed):
        maint, eng = fleet["maint"], fleet["engine"]
        for q in _queries(maint.g, ["C2", "TT", "S"], seed=seed):
            assert _rows(eng.execute(q)) == oracle.cpq_eval(maint.g, q), q

    def test_hard_kill_detected_and_respawned(self, fleet):
        eng = fleet["engine"]
        runtime = eng.backend.runtime
        before = runtime.recoveries
        runtime._workers[1].proc.kill()
        time.sleep(0.2)
        self._assert_serving(fleet, seed=19)
        assert runtime.recoveries > before

    def test_crash_mid_round(self, fleet):
        # CRASH sits in rank 0's FIFO ahead of the next EXECUTE_BATCH:
        # the worker dies *inside* the round, peers block in the
        # exchange, the abort/quiesce/respawn path must re-issue
        runtime = fleet["engine"].backend.runtime
        before = runtime.recoveries
        runtime.inject_crash(0)
        self._assert_serving(fleet, seed=23)
        assert runtime.recoveries > before

    def test_crash_between_rebind_broadcast_and_ack(self, fleet):
        maint, eng = fleet["maint"], fleet["engine"]
        runtime = eng.backend.runtime
        before = runtime.recoveries
        runtime.inject_crash(1)
        # rank 1 dies before acking the FLUSH_REBIND; the instruction is
        # re-issued after recovery and survivors re-apply idempotently
        eng.rebind(maint.flush())
        self._assert_serving(fleet, seed=29)
        assert runtime.recoveries > before

    def test_crash_during_checkpoint_and_recover_from_it(self, fleet,
                                                         tmp_path):
        maint, eng = fleet["engine"].backend, fleet["engine"]
        runtime = eng.backend.runtime
        svc = QueryService(eng, maintainer=fleet["maint"])
        runtime.inject_crash(0)  # dies before the CHECKPOINT barrier ack
        step = svc.checkpoint(str(tmp_path))
        assert runtime._ckpt == (str(tmp_path), step)
        # next death respawns from the committed checkpoint base
        before = runtime.recoveries
        runtime._workers[1].proc.kill()
        time.sleep(0.2)
        self._assert_serving(fleet, seed=31)
        assert runtime.recoveries > before

    def test_no_lost_accepted_requests_across_a_crash(self, fleet):
        maint, eng = fleet["maint"], fleet["engine"]
        runtime = eng.backend.runtime
        svc = QueryService(eng, max_batch=2, auto_flush=False)
        qs = _queries(maint.g, ["C2", "TT", "S", "T", "Si", "St"], seed=37)
        reqs = [svc.submit(q) for q in qs]
        assert all(not r.shed for r in reqs)
        runtime.inject_crash(1)
        done = svc.flush()
        assert len(done) == len([r for r in reqs if not r.from_cache]) or \
            all(r.done for r in reqs)
        for q, r in zip(qs, reqs):
            assert r.done and not r.shed
            assert _rows(r.result) == oracle.cpq_eval(maint.g, q), q


# ---------------------------------------------------------------------- #
# elastic reshard (last: resizes the shared fleet and restores it)
# ---------------------------------------------------------------------- #


class TestReshard:
    def test_resize_up_down_stays_oracle_identical(self, fleet):
        maint, eng = fleet["maint"], fleet["engine"]
        qs = _queries(maint.g, ["C2", "TT", "S"], seed=41)
        truth = [oracle.cpq_eval(maint.g, q) for q in qs]
        for n in (4, 1, 2):
            eng.backend.resize(n)
            assert eng.backend.runtime.n_shards == n
            for q, t in zip(qs, truth):
                assert _rows(eng.execute(q)) == t, (n, q)

    def test_resize_past_max_workers_is_rejected(self, fleet):
        with pytest.raises(ValueError):
            fleet["engine"].backend.resize(
                fleet["engine"].backend.runtime.max_workers + 1)
