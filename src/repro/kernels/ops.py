"""Jitted public wrappers for the Pallas kernels, with automatic padding
and a jnp fallback when the problem exceeds the kernels' VMEM-resident
assumptions (or when ``REPRO_DISABLE_PALLAS=1``).

The engine calls these; tests sweep them against ``ref.py``.

Two process-wide knobs feed this module from the device cost table
(``core.costmodel``), both inert by default:

* the **VMEM ceiling** — :func:`vmem_words` derives the broadcast-operand
  residency budget from the backend (env ``REPRO_VMEM_WORDS`` wins, then
  a table override installed by :func:`set_vmem_words_override`, then a
  per-backend default) instead of a hard-coded constant;
* **tuned block shapes** — :func:`set_tuned_blocks` installs the
  autotuner's per-capacity-rung ``block_q``/``block_t`` winners
  (``kernels.autotune``), consulted before the power-of-two heuristic.

They are process-wide (not arguments) because these wrappers are called
from inside jitted plan walkers where no host-side context can flow; a
change only affects *future* traces — jit caches compiled with other
blocks stay valid, just differently tuned.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import expand_join as _ej
from . import fingerprint as _fp
from . import ref
from . import segment_softmax as _ss
from . import sorted_intersect as _si

SENTINEL = np.int32(2**31 - 1)

# Fallback VMEM-residency ceiling for the broadcast operands (int32
# words) when neither the env override nor the backend probe decides;
# beyond the ceiling the ops fall back to the XLA path, which tiles
# through HBM.
_DEFAULT_VMEM_WORDS = 1_000_000

# TPU cores carry ~16 MiB VMEM; budget half of it for the broadcast
# operands (the other half covers the blocked operand, accumulators and
# double-buffering) -> 8 MiB / 4 B.
_TPU_VMEM_WORDS = (8 * 1024 * 1024) // 4

_vmem_override: int | None = None  # set_vmem_words_override (cost table)
_vmem_probed: int | None = None  # cached backend probe
_tuned_block_q: dict[int, int] | None = None  # rung -> block
_tuned_block_t: dict[int, int] | None = None


def set_vmem_words_override(words: int | None) -> None:
    """Install (or with None clear) a cost-table-provided VMEM ceiling.
    The ``REPRO_VMEM_WORDS`` env var still wins — it is the operator's
    explicit knob."""
    global _vmem_override
    _vmem_override = None if words is None else int(words)


def vmem_words() -> int:
    """The broadcast-operand residency ceiling, in int32 words.

    Resolution order: ``REPRO_VMEM_WORDS`` env (read live, so tests can
    monkeypatch it per-case), then the installed cost-table override,
    then a cached per-backend default (TPU budgets half a core's ~16 MiB
    VMEM; CPU/GPU interpret or re-tile, so the conservative historical
    ceiling stands).
    """
    env = os.environ.get("REPRO_VMEM_WORDS")
    if env:
        return int(env)
    if _vmem_override is not None:
        return _vmem_override
    global _vmem_probed
    if _vmem_probed is None:
        _vmem_probed = (_TPU_VMEM_WORDS if jax.default_backend() == "tpu"
                        else _DEFAULT_VMEM_WORDS)
    return _vmem_probed


def set_tuned_blocks(block_q: dict[int, int] | None,
                     block_t: dict[int, int] | None) -> None:
    """Install the autotuner's per-rung block winners ({pow2 rung ->
    block size}, from ``DeviceCostTable.block_q``/``block_t``); None/None
    clears back to the power-of-two heuristic."""
    global _tuned_block_q, _tuned_block_t
    _tuned_block_q = dict(block_q) if block_q else None
    _tuned_block_t = dict(block_t) if block_t else None


def _tuned(table: dict[int, int] | None, rung: int) -> int | None:
    """Winner at the smallest tuned rung >= ``rung`` (capacities
    quantize onto the pow2 ladder, so that neighbor is exact for ladder
    traffic), else the largest tuned rung's winner."""
    if not table:
        return None
    geq = [r for r in table if r >= rung]
    return table[min(geq)] if geq else table[max(table)]


def _pallas_enabled() -> bool:
    return os.environ.get("REPRO_DISABLE_PALLAS", "0") != "1"


def _pad_to(x: jax.Array, n: int, fill) -> jax.Array:
    if x.shape[0] == n:
        return x
    pad = jnp.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def sorted_member_mask(hay, hay_count, queries, block_q: int = 1024):
    """0/1 membership of queries in sorted hay[:hay_count]."""
    if not _pallas_enabled() or hay.shape[0] > vmem_words():
        return ref.sorted_member_mask(hay, hay_count, queries)
    n_q = queries.shape[0]
    rung = max(8, 1 << (n_q - 1).bit_length())
    tuned = _tuned(_tuned_block_q, rung)
    blk = min(tuned if tuned is not None else block_q, rung)
    n_pad = ((n_q + blk - 1) // blk) * blk
    q = _pad_to(queries, n_pad, SENTINEL)
    out = _si.sorted_member_mask(hay, hay_count, q, block_q=blk)
    return out[:n_q]


def expand_join_gather(ends, lo, a_payload, b_v, b_u, total, out_capacity,
                       block_t: int = 1024):
    if (not _pallas_enabled()
            or ends.shape[0] + 2 * b_v.shape[0] > vmem_words()):
        return ref.expand_join_gather(ends, lo, a_payload, b_v, b_u, total,
                                      out_capacity)
    rung = max(8, 1 << (out_capacity - 1).bit_length())
    tuned = _tuned(_tuned_block_t, rung)
    blk = min(tuned if tuned is not None else block_t, rung)
    cap = ((out_capacity + blk - 1) // blk) * blk
    ov, ou, oa = _ej.expand_join_gather(ends, lo, a_payload, b_v, b_u, total,
                                        cap, block_t=blk)
    return ov[:out_capacity], ou[:out_capacity], oa[:out_capacity]


def fingerprint_rows(cols: tuple, salt: int = 0):
    n = cols[0].shape[0]
    if not _pallas_enabled():
        return ref.fingerprint_rows(cols, salt)
    return _fp.fingerprint_rows(tuple(cols), salt=salt)


def segment_softmax(scores, segment_ids, num_segments, eps: float = 1e-9):
    e = scores.shape[0]
    if (not _pallas_enabled() or num_segments * scores.shape[1] > vmem_words()
            or e % min(512, e) != 0):
        return ref.segment_softmax(scores, segment_ids, num_segments, eps)
    return _ss.segment_softmax(scores, segment_ids, num_segments, eps=eps)
