"""Zero-downtime index lifecycle — checkpoint, warm restart, promotion.

The paper's life cycle (Sec. V: construction, maintenance, query
processing) stops at in-process maintenance; this module closes the gap
to restarts and failover.  The entire serving state is snapshotted as
ONE flat pytree of numpy leaves through ``repro.checkpoint`` (atomic
rename commit + LATEST pointer + fsync durability):

    index.arrays.*      the 16 :class:`DeviceIndexArrays` leaves
    index.meta/caps/…   k, n_vertices, capacity ladder, interest set
    mirror.*            the :class:`MaintainableIndex` host mirror —
                        graph edges, lazy partition, FlushCaps
    adapter.*           the :class:`AdaptationController` — sketch
                        counters, dwell protections, config, round clock
    stats.endpoints     the priced entries of the IndexStats endpoint
                        cache (restored engines plan warm)
    costtable.blob      the engine's :class:`DeviceCostTable` (one uint8
                        JSON leaf) — restored engines price plans with
                        their calibrated device constants immediately
    service.meta        the graph epoch
    sharded.*           per-shard leaves of a :class:`ShardedBackend`
                        (saved separately; restorable at a different
                        shard count)

so a restart is **load + rebind** instead of the multi-second device
rebuild, and a cold replica can be promoted mid-traffic
(:func:`restore_service`).  Restore always *bumps the epoch past the
checkpoint's* — the service's (epoch, query) cache keys make every
answer cached against any pre-restore state unreachable in O(1).

Consistency contract: ``QueryService.checkpoint`` drains the write
queue first (the same one-batch ``_drain_updates`` semantics every
query drain uses), so a snapshot is always taken at a quiescent epoch —
device arrays, host mirror, and interest set agree, and the
fault-injection suite (tests/test_checkpoint_lifecycle.py) holds the
stronger property: a crash at ANY point leaves the last *committed*
step restorable, never a half-state.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..checkpoint import latest_step, load_checkpoint_items, save_checkpoint
from .capacity import FlushCaps, decode_caps, encode_caps
from .costmodel import DeviceCostTable
from .engine import Engine
from .index import CPQxIndex, DeviceIndexArrays, _pull_seq_ranges
from .maintenance import MaintainableIndex
from .service import QueryService
from .stats import IndexStats
from .workload import AdaptationController

FORMAT = "cpqx-lifecycle-v1"


# ---------------------------------------------------------------------- #
# small codecs
# ---------------------------------------------------------------------- #


def _pack_seqs(seqs, k: int) -> np.ndarray:
    """Sorted label-sequence tuples -> (n, k) int64 rows padded with -1."""
    rows = [list(s) + [-1] * (k - len(s)) for s in sorted(seqs)]
    return np.asarray(rows, np.int64).reshape(-1, k)


def _unpack_seqs(rows: np.ndarray) -> frozenset:
    rows = np.asarray(rows, np.int64)
    return frozenset(
        tuple(int(x) for x in row if x >= 0)
        for row in rows.reshape(rows.shape[0], -1))


def _resolve_step(ckpt_dir: str, step: Optional[int]) -> int:
    if step is not None:
        return int(step)
    s = latest_step(ckpt_dir)
    if s is None:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir!r}")
    return s


# ---------------------------------------------------------------------- #
# index <-> leaves
# ---------------------------------------------------------------------- #


def index_leaves(index: CPQxIndex) -> dict:
    """The index as flat numpy leaves.  ``seq_ranges`` is NOT a leaf —
    it is a pure function of the arrays (``_pull_seq_ranges``) and is
    recomputed on restore, so it can never drift from them."""
    out = {f"index.arrays.{f}": np.asarray(getattr(index.arrays, f))
           for f in DeviceIndexArrays._fields}
    out["index.meta"] = np.array(
        [index.k, index.n_vertices, 0 if index.interests is None else 1],
        np.int64)
    out["index.caps"] = encode_caps(index.caps)
    out["index.interests"] = (
        np.zeros((0, index.k), np.int64) if index.interests is None
        else _pack_seqs(index.interests, index.k))
    return out


def index_from_leaves(items: dict) -> CPQxIndex:
    """Rebuild a :class:`CPQxIndex` from :func:`index_leaves` items —
    device placement happens here (``jnp.asarray`` per leaf)."""
    meta = np.asarray(items["index.meta"], np.int64)
    k, n_vertices, has_interests = (int(x) for x in meta[:3])
    arrays = DeviceIndexArrays(**{
        f: jnp.asarray(items[f"index.arrays.{f}"])
        for f in DeviceIndexArrays._fields})
    return CPQxIndex(
        k=k, n_vertices=n_vertices, arrays=arrays,
        seq_ranges=_pull_seq_ranges(arrays, k),
        caps=decode_caps(items["index.caps"]),
        interests=(_unpack_seqs(items["index.interests"])
                   if has_interests else None))


def save_index(index: CPQxIndex, ckpt_dir: str, step: int = 0) -> str:
    """``CPQxIndex.save``: one atomic committed step; returns its dir."""
    return save_checkpoint(ckpt_dir, step, index_leaves(index),
                           extra={"format": FORMAT, "kind": "index"})


def restore_index(ckpt_dir: str, step: Optional[int] = None) -> CPQxIndex:
    """``CPQxIndex.restore``: latest committed step unless pinned."""
    items, _, _ = load_checkpoint_items(ckpt_dir, _resolve_step(ckpt_dir, step))
    return index_from_leaves(items)


# ---------------------------------------------------------------------- #
# full serving state <-> leaves
# ---------------------------------------------------------------------- #


def service_leaves(svc: QueryService) -> tuple[dict, dict]:
    """(leaves, extra) snapshotting everything a warm restart needs.
    Call only on a drained service (``QueryService.checkpoint`` drains
    first) — a snapshot with queued writes would commit an epoch whose
    device arrays and mirror disagree."""
    leaves = index_leaves(svc.engine.index)
    label_names: list = []
    if svc.maintainer is not None:
        for key, arr in svc.maintainer.export_state().items():
            leaves[f"mirror.{key}"] = arr
        label_names = list(svc.maintainer.g.label_names)
    if svc.adapter is not None:
        for key, arr in svc.adapter.export_state().items():
            leaves[f"adapter.{key}"] = arr
    endpoints = svc.engine.stats.export_endpoints()
    if endpoints is not None:
        leaves["stats.endpoints"] = endpoints
    if getattr(svc.engine, "cost_table", None) is not None:
        leaves["costtable.blob"] = svc.engine.cost_table.export_state()
    leaves["service.meta"] = np.array([svc.graph_epoch], np.int64)
    extra = {"format": FORMAT, "kind": "service",
             "label_names": label_names}
    return leaves, extra


@dataclasses.dataclass
class RestoredState:
    """One committed serving state, loaded and device-placed."""

    index: CPQxIndex
    stats: IndexStats  # endpoint cache pre-warmed from the donor
    maintainer: MaintainableIndex | None
    adapter: AdaptationController | None
    epoch: int  # the donor's graph epoch AT the snapshot
    step: int
    cost_table: DeviceCostTable | None = None  # absent in old checkpoints


def load_state(ckpt_dir: str, step: Optional[int] = None) -> RestoredState:
    """Load one committed step into live objects (no engine yet)."""
    step = _resolve_step(ckpt_dir, step)
    items, extra, _ = load_checkpoint_items(ckpt_dir, step)
    index = index_from_leaves(items)
    stats = IndexStats.from_index(index)
    if "stats.endpoints" in items:
        stats.seed_endpoints(items["stats.endpoints"])
    label_names = tuple((extra or {}).get("label_names", ()))
    maintainer = None
    mirror = {key[len("mirror."):]: arr for key, arr in items.items()
              if key.startswith("mirror.")}
    if mirror:
        maintainer = MaintainableIndex.from_state(mirror, label_names)
    adapter = None
    adp = {key[len("adapter."):]: arr for key, arr in items.items()
           if key.startswith("adapter.")}
    if adp:
        adapter = AdaptationController.from_state(adp)
    epoch = int(np.asarray(items.get("service.meta", [0]), np.int64)[0])
    # legacy checkpoints predate the cost table: the leaf is simply
    # absent and the restored engine prices by rows, exactly as the
    # donor did
    cost_table = (DeviceCostTable.from_state(items["costtable.blob"])
                  if "costtable.blob" in items else None)
    return RestoredState(index=index, stats=stats, maintainer=maintainer,
                         adapter=adapter, epoch=epoch, step=step,
                         cost_table=cost_table)


def restore_service(ckpt_dir: str, step: Optional[int] = None, mesh=None,
                    **service_kwargs) -> QueryService:
    """Cold-replica promotion: build a fully-warm :class:`QueryService`
    from a committed checkpoint — load + bind, no graph rebuild, no
    mirror rebuild, no sketch cold start.  The epoch resumes PAST the
    donor's, so any answer a stale client cached against the donor can
    never be confused with this replica's."""
    state = load_state(ckpt_dir, step)
    engine = Engine(state.index, mesh=mesh, cost_table=state.cost_table)
    warm = state.stats.export_endpoints()
    if warm is not None:
        engine.stats.seed_endpoints(warm)
    svc = QueryService(engine, maintainer=state.maintainer,
                       adapter=state.adapter, **service_kwargs)
    svc.graph_epoch = state.epoch + 1
    svc._ckpt_step = state.step + 1
    return svc


# ---------------------------------------------------------------------- #
# sharded backend <-> leaves (elastic: restore at any shard count)
# ---------------------------------------------------------------------- #


def save_sharded(sharded, n_vertices: int, k: Optional[int],
                 ckpt_dir: str, step: int = 0) -> str:
    """``ShardedBackend.save``: per-shard leaves + layout metadata."""
    from .sharded_index import ShardedIndexArrays

    leaves = {f"sharded.{f}": np.asarray(getattr(sharded, f))
              for f in ShardedIndexArrays._fields}
    leaves["sharded.meta"] = np.array(
        [sharded.n_shards, n_vertices, -1 if k is None else k], np.int64)
    return save_checkpoint(ckpt_dir, step, leaves,
                           extra={"format": FORMAT, "kind": "sharded"})


def load_sharded_arrays(ckpt_dir: str, step: Optional[int] = None,
                        n_shards: Optional[int] = None):
    """Load checkpointed shard leaves, optionally RE-sharded to a
    different count.  Returns ``(ShardedIndexArrays, n_vertices, k)``.

    Same count: the saved leaves are device_put verbatim.  Different
    count: the restore is literally ``gather_index`` followed by
    ``shard_index`` at the new count, so the result is bit-identical to
    resharding the live index — the round-trip tests pin this."""
    from .sharded_index import ShardedIndexArrays, gather_index, shard_index

    items, _, _ = load_checkpoint_items(ckpt_dir, _resolve_step(ckpt_dir, step))
    meta = np.asarray(items["sharded.meta"], np.int64)
    saved_shards, n_vertices, k = (int(x) for x in meta[:3])
    sharded = ShardedIndexArrays(**{
        f: jnp.asarray(items[f"sharded.{f}"])
        for f in ShardedIndexArrays._fields})
    if n_shards is None or n_shards == saved_shards:
        return sharded, n_vertices, (None if k < 0 else k)
    gathered = gather_index(sharded)
    wrapper = CPQxIndex(
        k=max(k, 1), n_vertices=n_vertices, arrays=gathered,
        seq_ranges=_pull_seq_ranges(gathered, max(k, 1)),
        caps=FlushCaps(pair_cap=int(gathered.c2p_v.shape[0]),
                       l2c_cap=int(gathered.l2c_cls.shape[0]),
                       seq_cap=int(gathered.seq_table.shape[0])))
    return (shard_index(wrapper, n_shards), n_vertices,
            (None if k < 0 else k))


def restore_sharded_backend(ckpt_dir: str, mesh, step: Optional[int] = None,
                            axis: str = "engine"):
    """``ShardedBackend.restore``: a live backend on ``mesh``, resharding
    the saved leaves if the mesh axis size differs from the saved count."""
    from .distributed import ShardedBackend

    n_shards = int(dict(mesh.shape)[axis])
    sharded, n_vertices, k = load_sharded_arrays(ckpt_dir, step, n_shards)
    return ShardedBackend(sharded, mesh, n_vertices, axis=axis, k=k)
