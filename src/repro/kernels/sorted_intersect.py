"""Pallas TPU kernel: sorted set intersection membership — the
CONJUNCTION hot spot (Prop. 4.1: class-id list intersection).

For each element of a sorted query tile, a branch-free vectorized binary
search probes the (VMEM-resident) sorted haystack; the output is a 0/1
membership mask which the caller compacts with one XLA sort.  The search
is O(log n) fori_loop steps over full VPU lanes — the TPU-native
replacement for the paper's two-pointer merge intersection (which is
inherently sequential and hostile to 8x128 vector lanes).

Tiling: queries are blocked along the grid (``block_q`` per program,
8x128-aligned); the haystack is broadcast to every program in one VMEM
block (class-id lists are small — that is the paper's point; for
haystacks beyond VMEM the op falls back to the jnp path which XLA tiles
through HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 1024  # 8 sublanes x 128 lanes


def _intersect_kernel(hay_ref, count_ref, q_ref, out_ref, *, steps: int):
    """One program: membership of a query block in the full haystack."""
    hay = hay_ref[...]
    hay_count = count_ref[0]
    q = q_ref[...]
    n = hay.shape[0]

    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, hay_count, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        v = hay[jnp.clip(mid, 0, n - 1)]
        go_right = v < q
        active = lo < hi
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & (~go_right), mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    # found iff insertion point holds the query value
    found = (lo < hay_count) & (hay[jnp.clip(lo, 0, n - 1)] == q)
    out_ref[...] = found.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_q",))
def sorted_member_mask(
    hay: jax.Array, hay_count: jax.Array, queries: jax.Array,
    block_q: int = DEFAULT_BLOCK_Q,
) -> jax.Array:
    """0/1 mask: queries[i] present among the first ``hay_count`` entries
    of sorted ``hay``.  Shapes must be multiples of ``block_q`` (callers
    pad with SENTINEL, which never matches)."""
    n_q = queries.shape[0]
    assert n_q % block_q == 0, (n_q, block_q)
    steps = max(1, int(hay.shape[0]).bit_length())
    kernel = functools.partial(_intersect_kernel, steps=steps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_q,), jnp.int32),
        grid=(n_q // block_q,),
        in_specs=[
            pl.BlockSpec(hay.shape, lambda i: (0,), memory_space=pltpu.VMEM),
            pl.BlockSpec((1,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_q,), lambda i: (i,), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,), memory_space=pltpu.VMEM),
        interpret=jax.default_backend() == "cpu",
    )(hay, jnp.asarray(hay_count, jnp.int32).reshape(1), queries)
