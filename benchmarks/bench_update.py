"""Paper Tables V/VI/VII: maintenance — edge insert/delete and interest
insert/delete times, plus the index-growth ratio under lazy updates.

PR-2 extension: **update→queryable latency** — after a batch of updates,
how long until the device can answer queries on the new graph?  Two
paths are timed, gated on bit-identical answers:

  flush    ``MaintainableIndex.apply_updates`` (one affected-pair union
           per batch) + ``flush`` (mirror→device re-serialization,
           preserving the lazy partition) + ``Engine.rebind``
  rebuild  the same mirror surgery + a from-scratch device build
           (``cindex.build`` — path enumeration + bisimulation) + rebind

    PYTHONPATH=src python -m benchmarks.bench_update [--smoke]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import index as cindex
from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import TEMPLATE_ARITY, instantiate_template

from .bench_query import interests_for
from .common import DATASETS, emit, timeit


def _update_batch(g, rng, n_ops: int) -> list:
    """A realistic mixed batch: inserts, deletes of existing edges, and
    relabels."""
    base = g._base_edges()
    ops = []
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.5 or base.shape[0] == 0:
            ops.append(("insert_edge", int(rng.integers(0, g.n_vertices)),
                        int(rng.integers(0, g.n_vertices)),
                        int(rng.integers(0, g.n_labels))))
        elif roll < 0.8:
            e = base[int(rng.integers(0, base.shape[0]))]
            ops.append(("delete_edge", int(e[0]), int(e[1]), int(e[2])))
        else:
            e = base[int(rng.integers(0, base.shape[0]))]
            ops.append(("change_label", int(e[0]), int(e[1]), int(e[2]),
                        (int(e[2]) + 1) % g.n_labels))
    return ops


def _probe_queries(g, rng, n: int = 6) -> list:
    names = ["C2", "T", "C2i", "S"]
    out = []
    present = np.unique(g.lbl)
    for i in range(n):
        name = names[i % len(names)]
        labels = rng.choice(present, TEMPLATE_ARITY[name]).tolist()
        out.append(instantiate_template(name, labels))
    return out


def bench_update_to_queryable(ds: str, n_ops: int, rounds: int) -> bool:
    """Time apply+flush+rebind vs apply+rebuild+rebind per update batch.
    Returns True iff flush beat rebuild on every timed round AND both
    paths (and the host oracle) agreed on every probe query."""
    g = DATASETS[ds]()
    rng = np.random.default_rng(7)
    mi = MaintainableIndex.build(g, 2)
    engine = Engine(mi.flush())  # warm: executables + flush caps
    ok = True
    for r in range(rounds):
        batch = _update_batch(mi.g, rng, n_ops)
        built = {}  # capture the timed indexes for the answer gate below

        def flush_and_rebind():
            built["flushed"] = mi.flush()
            engine.rebind(built["flushed"])

        def rebuild_and_rebind():
            built["rebuilt"] = cindex.build(mi.g, 2)
            engine.rebind(built["rebuilt"])

        t0 = timeit(lambda: mi.apply_updates(batch), warmup=0, iters=1)
        t_flush = timeit(flush_and_rebind, warmup=0, iters=1)
        t_rebuild = timeit(rebuild_and_rebind, warmup=0, iters=1)
        # gate: flushed arrays, rebuilt arrays and the host oracle agree
        flushed, rebuilt = built["flushed"], built["rebuilt"]
        for q in _probe_queries(mi.g, rng):
            engine.rebind(flushed)
            a = {tuple(x) for x in engine.execute(q).tolist()}
            engine.rebind(rebuilt)
            b = {tuple(x) for x in engine.execute(q).tolist()}
            truth = oracle.cpq_eval(mi.g, q)
            assert a == truth, f"flush path diverged from oracle on {q}"
            assert b == truth, f"rebuild path diverged from oracle on {q}"
        engine.rebind(flushed)
        speedup = (t0 + t_rebuild) / max(t0 + t_flush, 1e-9)
        ok = ok and t_flush < t_rebuild
        emit(f"update/{ds}/batch{n_ops}/round{r}/apply", t0,
             f"splits={mi.n_splits}")
        emit(f"update/{ds}/batch{n_ops}/round{r}/flush", t_flush, "")
        emit(f"update/{ds}/batch{n_ops}/round{r}/rebuild", t_rebuild,
             f"queryable_speedup={speedup:.2f}x")
    emit(f"update/{ds}/batch{n_ops}/acceptance", 0.0,
         f"flush_faster_than_rebuild={'PASS' if ok else 'FAIL'}")
    return ok


def bench_paper_tables(datasets: list, iters: int) -> None:
    for ds in datasets:
        g = DATASETS[ds]()
        ints = interests_for(g)
        rng = np.random.default_rng(0)

        mi = MaintainableIndex.build(g, 2)
        base = mi.g._base_edges()
        size0 = sum(mi.size_entries())

        def del_edge():
            e = base[int(rng.integers(0, base.shape[0]))]
            try:
                mi.delete_edge(int(e[0]), int(e[1]), int(e[2]))
            except Exception:
                pass

        us = timeit(del_edge, warmup=0, iters=iters)
        emit(f"table5/{ds}/edge_deletion", us, "")

        def ins_edge():
            mi.insert_edge(int(rng.integers(0, g.n_vertices)),
                           int(rng.integers(0, g.n_vertices)),
                           int(rng.integers(0, g.n_labels)))

        us = timeit(ins_edge, warmup=0, iters=iters)
        emit(f"table5/{ds}/edge_insertion", us, "")
        growth = sum(mi.size_entries()) / max(size0, 1)
        emit(f"table7/{ds}/size_ratio_after_10_updates", growth * 1000,
             f"ratio={growth:.3f} splits={mi.n_splits}")

        mia = MaintainableIndex.build(g, 2, interests=ints)
        us = timeit(lambda: mia.delete_interest(ints[0]), warmup=0, iters=1)
        emit(f"table6/{ds}/interest_deletion", us, "")
        us = timeit(lambda: mia.insert_interest(ints[0]), warmup=0, iters=1)
        emit(f"table6/{ds}/interest_insertion", us, "")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, minimal rounds (CI)")
    args, _ = ap.parse_known_args()

    if args.smoke:
        bench_paper_tables(["example"], iters=2)
        bench_update_to_queryable("example", n_ops=4, rounds=1)
        return
    bench_paper_tables(["robots-like", "gmark-small"], iters=5)
    bench_update_to_queryable("gmark-small", n_ops=16, rounds=3)


if __name__ == "__main__":
    main()
