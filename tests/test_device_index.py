"""Device build pipeline vs the numpy oracle: path enumeration,
k-path-bisimulation partition, CPQx / iaCPQx / Path index construction."""

from collections import defaultdict

import numpy as np
import pytest

from conftest import random_graph
from repro.core import baselines, capacity, interest, oracle
from repro.core import index as cindex
from repro.core import relational as R
from repro.core.bisim import path_partition
from repro.core.graph import example_graph
from repro.core.paths import device_graph, enumerate_path_levels

SEEDS = [0, 1, 2, 3, 7]


def _partition_isomorphic(dev_pairs, opart) -> bool:
    dev_groups = defaultdict(set)
    for r in dev_pairs:
        dev_groups[int(r[2])].add((int(r[0]), int(r[1])))
    dev_set = {frozenset(s) for s in dev_groups.values()}
    o_set = {frozenset(map(tuple, ps)) for ps in opart.classes.values()}
    return dev_set == o_set


class TestDevicePaths:
    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize("k", [2, 3])
    def test_levels_match_host(self, seed, k):
        g = random_graph(seed)
        caps = capacity.estimate_build_caps(g, k)
        levels = enumerate_path_levels(device_graph(g), k, caps.level_rows)
        host = capacity.path_level_counts(g, k)
        for lvl, hrows in zip(levels, host):
            assert not bool(lvl.overflow)
            dev = R.to_numpy(lvl)
            hr = hrows[
                np.lexsort(tuple(hrows[:, j] for j in range(hrows.shape[1] - 1, -1, -1)))
            ]
            assert dev.shape == hr.shape
            assert (dev == hr).all()


class TestDeviceBisim:
    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize("k", [1, 2, 3])
    def test_partition_matches_oracle(self, seed, k):
        g = random_graph(seed)
        caps = capacity.estimate_build_caps(g, k)
        part = path_partition(device_graph(g), k, caps.level_rows,
                              caps.pair_cap, caps.union_pair_cap)
        assert not bool(part.overflow)
        opart = oracle.path_partition(g, k)
        dev_pairs = R.to_numpy(part.pairs)
        assert dev_pairs.shape[0] == len(opart.pairs)
        assert int(part.n_classes) == len(opart.classes)
        assert _partition_isomorphic(dev_pairs, opart)

    def test_example_graph_class_count(self, ex_graph):
        """Fig. 3: the example partitions into 27 classes with paths at k=2
        (the figure's 30 includes the path-less {id} and {} blocks, which
        the index does not store — Sec. IV-B)."""
        caps = capacity.estimate_build_caps(ex_graph, 2)
        part = path_partition(device_graph(ex_graph), 2, caps.level_rows,
                              caps.pair_cap, caps.union_pair_cap)
        assert int(part.n_classes) == 27


class TestDeviceIndexBuild:
    @pytest.mark.parametrize("seed", SEEDS[:3])
    def test_cpqx_matches_oracle_index(self, seed):
        g = random_graph(seed)
        idx = cindex.build(g, 2)
        oidx = oracle.build_index(g, 2)
        assert idx.n_classes == oidx.n_classes
        assert idx.size_entries() == (
            sum(len(v) for v in oidx.l2c.values()),
            sum(len(v) for v in oidx.c2p.values()),
        )
        # every oracle sequence is present with the same number of classes
        for s, cs in oidx.l2c.items():
            lo, hi = idx.lookup_range(s)
            assert hi - lo == len(cs), f"seq {s}"

    @pytest.mark.parametrize("seed", SEEDS[:3])
    def test_iacpqx_matches_oracle(self, seed):
        g = random_graph(seed)
        ints = [(0, 1), (1, 0)]
        ia = interest.build_interest(g, 2, ints)
        oia = oracle.build_interest_index(g, 2, ints)
        assert ia.n_classes == oia.n_classes
        for s, cs in oia.l2c.items():
            lo, hi = ia.lookup_range(s)
            assert hi - lo == len(cs), f"seq {s}"

    @pytest.mark.parametrize("seed", SEEDS[:3])
    def test_path_index_matches_oracle(self, seed):
        g = random_graph(seed)
        pi = baselines.build_path(g, 2)
        opi = oracle.build_path_index(g, 2)
        assert pi.size_entries() == opi.size_entries()
        for s, ps in opi.l2p.items():
            lo, hi = pi.lookup_range(s)
            assert hi - lo == len(ps), f"seq {s}"

    def test_size_comparison_thm42(self):
        """CPQx stores each pair once in I_c2p; Path stores gamma copies."""
        g = example_graph()
        idx = cindex.build(g, 2)
        pi = baselines.build_path(g, 2)
        l2c, c2p = idx.size_entries()
        assert c2p < pi.size_entries()  # strict on this graph (gamma > 1)

    def test_interest_index_smaller(self):
        g = example_graph()
        idx = cindex.build(g, 2)
        ia = interest.build_interest(g, 2, [(0, 0)])
        assert ia.n_classes < idx.n_classes
        assert sum(ia.size_entries()) < sum(idx.size_entries())
