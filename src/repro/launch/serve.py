"""Serving driver (deliverable (b)): batched-request LM inference with
slot-based continuous batching.

A fixed pool of batch slots; each incoming request claims a slot, gets
prefilled (padded prompt into its cache rows), then joins the shared
one-token-per-step decode loop; finished slots are reused immediately —
continuous batching at the step granularity, the vLLM scheduling idea
reduced to its JAX-native static-shape core: one compiled decode_step
serves a mixed pool of requests at different positions.

Per-slot positions: every slot decodes at its own ``pos`` (the decode
mask is per-example), so no head-of-line blocking.

Local mode runs the smoke config on CPU; the production path jits the
same step under the mesh (proved by the dry-run decode cells).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _decode_step_multi(cfg, params, cache, tokens, positions):
    """decode_step with *per-slot* positions (B,) — the continuous
    batching variant: each slot attends to its own prefix length."""
    b = tokens.shape[0]
    max_len = cache["k"].shape[2]
    cos, sin = T.L.rope_table(cfg.head_dim, cfg.max_seq_len, cfg.rope_theta)
    t = jnp.arange(max_len)[None, :]
    gmask = t <= positions[:, None]
    lmask = gmask & (t > (positions[:, None] - cfg.window))
    masks = {"global": gmask, "local": lmask}  # (B, T) -> per-example
    x = T._embed(cfg, params, tokens)
    kinds = T._kind_codes(cfg)
    pos3 = positions[:, None]

    def body(x, inp):
        lp, kind, ck, cv = inp
        b_, s_, d_ = x.shape
        a_in = T._norm(x, lp["attn_norm"], cfg)
        q = (a_in @ lp["wq"]).reshape(b_, 1, cfg.n_heads, cfg.head_dim)
        kk = (a_in @ lp["wk"]).reshape(b_, 1, cfg.n_kv_heads, cfg.head_dim)
        vv = (a_in @ lp["wv"]).reshape(b_, 1, cfg.n_kv_heads, cfg.head_dim)
        q = T.L.apply_rope(q, cos, sin, pos3)
        kk = T.L.apply_rope(kk, cos, sin, pos3)
        # per-slot cache write at its own position: one-hot scatter-free
        onehot = (jnp.arange(max_len)[None, :] == positions[:, None])
        ck = jnp.where(onehot[:, :, None, None], kk.astype(ck.dtype), ck)
        cv = jnp.where(onehot[:, :, None, None], vv.astype(cv.dtype), cv)
        mask = jnp.where(kind == 0, masks["global"], masks["local"])
        att = T.L.gqa_attention(q, ck, cv, mask[:, None, :].swapaxes(1, 1),
                                scale=cfg.head_dim ** -0.5,
                                softcap=cfg.attn_softcap)
        att = att.reshape(b_, 1, -1) @ lp["wo"]
        if cfg.gemma_norms:
            att = T._norm(att, lp["post_attn_norm"], cfg)
        x = x + att
        m_in = T._norm(x, lp["mlp_norm"], cfg)
        if cfg.is_moe:
            dims = T.L.MoEDims(cfg.n_experts, cfg.top_k,
                               T.L.moe_capacity(1, cfg.top_k, cfg.n_experts,
                                                cfg.capacity_factor))
            mlp, _ = T.L.moe_ffn(m_in, lp["router"], lp["w_gate"],
                                 lp["w_up"], lp["w_down"], dims,
                                 cfg.activation)
        else:
            mlp = T.L.gated_mlp(m_in, lp["w_gate"], lp["w_up"],
                                lp["w_down"], cfg.activation)
        if cfg.gemma_norms:
            mlp = T._norm(mlp, lp["post_mlp_norm"], cfg)
        x = x + mlp
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], kinds, cache["k"],
                                cache["v"]))
    logits = T._unembed(cfg, params, x)
    return logits[:, 0], {"k": nk, "v": nv}


class Server:
    def __init__(self, cfg, params, n_slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = T.make_cache(cfg, n_slots, max_len)
        self.positions = np.full(n_slots, -1, np.int64)  # -1 = free
        self.slot_req: list = [None] * n_slots
        self._step = jax.jit(
            lambda p, c, t, pos: _decode_step_multi(cfg, p, c, t, pos))
        self.steps = 0

    def _free_slots(self):
        return [i for i in range(self.n_slots) if self.positions[i] < 0]

    def admit(self, req: Request) -> bool:
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        # prefill: feed prompt tokens one by one through the decode step
        # (simple + always correct; bulk prefill is the batched path the
        # dry-run prefill cells cover)
        self.slot_req[slot] = req
        self.positions[slot] = 0
        for i, tok in enumerate(req.prompt):
            self._one_step_for(slot, tok)
        return True

    def _one_step_for(self, slot, tok):
        toks = np.zeros((self.n_slots, 1), np.int32)
        toks[slot, 0] = tok
        pos = np.maximum(self.positions, 0).astype(np.int32)
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(toks),
                                        jnp.asarray(pos))
        self.positions[slot] += 1
        self.steps += 1
        return np.asarray(logits[slot])

    def step_all(self):
        """One decode step for every active slot (continuous batching)."""
        active = [i for i in range(self.n_slots)
                  if self.positions[i] > 0 and self.slot_req[i] is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            req = self.slot_req[i]
            last = req.out[-1] if req.out else req.prompt[-1]
            toks[i, 0] = last
        pos = np.maximum(self.positions, 0).astype(np.int32)
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(toks), jnp.asarray(pos))
        self.steps += 1
        logits = np.asarray(logits)
        for i in active:
            req = self.slot_req[i]
            nxt = int(np.argmax(logits[i]))
            req.out.append(nxt)
            self.positions[i] += 1
            if len(req.out) >= req.max_new or self.positions[i] >= self.max_len - 1:
                req.done = True
                self.slot_req[i] = None
                self.positions[i] = -1  # slot free for the next request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    server = Server(cfg, params, args.slots, args.max_len)

    rng = np.random.default_rng(0)
    pending = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 8).tolist(),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    while pending or any(server.slot_req):
        while pending and server.admit(pending[0]):
            req = pending.pop(0)
            print(f"[serve] admitted request {req.rid}")
        server.step_all()
    print(f"[serve] all {args.requests} requests done in {server.steps} steps "
          f"with {args.slots} slots (continuous batching)")


if __name__ == "__main__":
    main()
