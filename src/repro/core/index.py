"""CPQx index construction on device — Algorithm 2.

The index is two inverted maps materialized as sorted capacity-padded
arrays (Def. 4.3):

    I_l2c : label sequence  -> sorted list of class ids
    I_c2p : class id        -> sorted list of s-t pairs

Build pipeline (one jit):
    1. ``bisim.path_partition``        -> (v, u, class) over P^{<=k}
    2. ``paths.enumerate_path_levels`` -> distinct (v, u, seq) per level
    3. seq rows joined with the pair->class map (vectorized binary search)
    4. sort + dedup (seq, class)       -> I_l2c  (CSR: seq table + offsets)
    5. sort pairs by (class, v, u)     -> I_c2p  (CSR: class offsets)

The host wrapper (:class:`CPQxIndex`) owns the device arrays plus the tiny
host-side metadata needed at query time (the seq -> row-range dict — query
*planning* is host work; all set/join work stays on device).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import relational as R
from .bisim import path_partition
from .capacity import BuildCaps, FlushCaps, estimate_build_caps
from .graph import LabeledGraph
from .paths import DeviceGraph, device_graph, enumerate_path_levels, seq_rows_of_levels, _recap


class DeviceIndexArrays(NamedTuple):
    """All device-resident arrays of a built index (a pytree)."""

    # pair table sorted by (v, u):  P^{<=k} with class ids
    pair_v: jax.Array
    pair_u: jax.Array
    pair_cls: jax.Array
    pair_count: jax.Array
    # I_c2p: same pairs sorted by (class, v, u) + CSR offsets per class
    c2p_cls: jax.Array
    c2p_v: jax.Array
    c2p_u: jax.Array
    class_starts: jax.Array  # (class_cap + 1,)
    class_cyclic: jax.Array  # (class_cap,) int32 0/1
    n_classes: jax.Array
    # I_l2c: unique seq table (n_seq_cap, k) + per-seq class ranges
    seq_table: jax.Array  # (n_seq_cap, k) padded with -1
    seq_count: jax.Array
    seq_starts: jax.Array  # (n_seq_cap,) start into l2c_cls
    seq_ends: jax.Array  # (n_seq_cap,)
    l2c_cls: jax.Array  # (l2c_cap,) class ids, ascending within a seq block
    l2c_count: jax.Array
    overflow: jax.Array


@functools.partial(jax.jit, static_argnames=("k", "caps_key"))
def build_index_arrays(dg: DeviceGraph, k: int, caps_key: tuple) -> DeviceIndexArrays:
    caps = BuildCaps(*caps_key)
    part = path_partition(dg, k, caps.level_rows, caps.pair_cap, caps.union_pair_cap)
    levels = enumerate_path_levels(dg, k, caps.level_rows)
    seq_rows = seq_rows_of_levels(levels, k, caps.seq_rows)  # (s1..sk, v, u)
    overflow = part.overflow
    for lvl in levels:
        overflow = overflow | lvl.overflow
    return _assemble(part.pairs, part.n_classes, seq_rows, k, caps, overflow)


def _assemble(pairs: R.Relation, n_classes, seq_rows: R.Relation, k: int,
              caps: BuildCaps, overflow) -> DeviceIndexArrays:
    """Shared tail of CPQx / iaCPQx construction: given the classified pair
    table (sorted by (v,u)) and the (seq..., v, u) incidence rows, build
    both inverted maps."""
    # ---------------- I_c2p ---------------- #
    bypair = pairs  # (v, u, cls) sorted by (v, u)
    c2p = R.rel_sort(
        R.Relation((pairs.cols[2], pairs.cols[0], pairs.cols[1]),
                   pairs.count, pairs.overflow),
        num_keys=3,
    )
    class_cap = bypair.capacity
    cls_ids = jnp.arange(class_cap + 1, dtype=R.I32)
    class_starts = jnp.searchsorted(c2p.cols[0], cls_ids, side="left").astype(R.I32)
    first = jnp.clip(class_starts[:-1], 0, class_cap - 1)
    class_cyclic = jnp.where(
        cls_ids[:-1] < n_classes,
        (c2p.cols[1][first] == c2p.cols[2][first]).astype(R.I32),
        0,
    )

    # ---------------- I_l2c ---------------- #
    # class of each row's (v, u)
    pos = R.lex_searchsorted(bypair.cols[:2], (seq_rows.cols[k], seq_rows.cols[k + 1]),
                             "left")
    posc = jnp.clip(pos, 0, bypair.capacity - 1)
    hit = (
        (pos < bypair.count)
        & (bypair.cols[0][posc] == seq_rows.cols[k])
        & (bypair.cols[1][posc] == seq_rows.cols[k + 1])
    )
    cls_of_row = jnp.where(hit, bypair.cols[2][posc], R.SENTINEL)
    l2c = R.Relation(
        tuple(seq_rows.cols[:k]) + (cls_of_row,), seq_rows.count,
        seq_rows.overflow,
    )
    l2c = R.rel_unique(R.rel_sort(l2c))  # (seq..., cls) distinct, sorted
    l2c = _recap(l2c, caps.l2c_rows)

    # unique sequences + their row ranges
    seqs = R.rel_unique(l2c, num_keys=k)
    seqs = _recap(R.Relation(seqs.cols[:k], seqs.count, seqs.overflow),
                  caps.n_seqs)
    starts = R.lex_searchsorted(l2c.cols[:k], seqs.cols, "left").astype(R.I32)
    ends = R.lex_searchsorted(l2c.cols[:k], seqs.cols, "right").astype(R.I32)
    validm = R.valid_mask(seqs)
    starts = jnp.where(validm, starts, 0)
    ends = jnp.where(validm, ends, 0)

    overflow = (overflow | pairs.overflow | l2c.overflow | seqs.overflow
                | seq_rows.overflow)

    return DeviceIndexArrays(
        pair_v=bypair.cols[0], pair_u=bypair.cols[1], pair_cls=bypair.cols[2],
        pair_count=bypair.count,
        c2p_cls=c2p.cols[0], c2p_v=c2p.cols[1], c2p_u=c2p.cols[2],
        class_starts=class_starts, class_cyclic=class_cyclic,
        n_classes=n_classes,
        seq_table=jnp.stack(seqs.cols, axis=1), seq_count=seqs.count,
        seq_starts=starts, seq_ends=ends,
        l2c_cls=l2c.cols[k], l2c_count=l2c.count,
        overflow=overflow,
    )


# ---------------------------------------------------------------------- #
# host wrapper
# ---------------------------------------------------------------------- #


@dataclasses.dataclass
class CPQxIndex:
    """Host handle: device arrays + query-time metadata.

    ``seq_ranges`` maps a label-sequence tuple to its (start, end) row
    range in ``l2c_cls`` — the only host-side lookup structure (query
    planning is host work by design)."""

    k: int
    n_vertices: int
    arrays: DeviceIndexArrays
    seq_ranges: dict
    caps: BuildCaps | FlushCaps
    interests: frozenset | None = None  # None => full CPQx

    @property
    def n_classes(self) -> int:
        return int(self.arrays.n_classes)

    @property
    def n_pairs(self) -> int:
        return int(self.arrays.pair_count)

    def size_entries(self) -> tuple[int, int]:
        """(|I_l2c|, |I_c2p|) valid entries — paper's size measure."""
        return int(self.arrays.l2c_count), int(self.arrays.pair_count)

    def lookup_range(self, seq: tuple) -> tuple[int, int]:
        return self.seq_ranges.get(tuple(seq), (0, 0))

    def available_seqs(self) -> set:
        return set(self.seq_ranges)

    # ---------------------- lifecycle (checkpoint) --------------------- #

    def save(self, ckpt_dir: str, step: int = 0) -> str:
        """Snapshot this index as one atomic committed checkpoint step
        (``repro.checkpoint`` rename-commit layout); returns the step
        dir.  ``restore`` + ``Engine.rebind`` replaces a from-graph
        rebuild — see :mod:`repro.core.lifecycle`."""
        from . import lifecycle  # lazy: keep import cost off the build path

        return lifecycle.save_index(self, ckpt_dir, step)

    @staticmethod
    def restore(ckpt_dir: str, step: int | None = None) -> "CPQxIndex":
        """Load the latest committed step (or ``step``) back into a
        ready-to-bind index: arrays device-placed, ``seq_ranges``
        recomputed from the arrays, caps decoded."""
        from . import lifecycle

        return lifecycle.restore_index(ckpt_dir, step)


def _pull_seq_ranges(arrays: DeviceIndexArrays, k: int) -> dict:
    """Host dict of seq -> (start, end) — on the build path and every
    maintenance flush.  Vectorized: per-seq lengths come from one numpy
    column reduction and the int conversion from one bulk ``tolist``
    (python ints in C), instead of ~n*k numpy-scalar casts in a loop."""
    n = int(arrays.seq_count)
    table = np.asarray(arrays.seq_table)[:n]
    lengths = (table >= 0).sum(axis=1).tolist()
    rows = table.tolist()
    starts = np.asarray(arrays.seq_starts)[:n].tolist()
    ends = np.asarray(arrays.seq_ends)[:n].tolist()
    return {
        tuple(row[:ln]): (s, e)
        for row, ln, s, e in zip(rows, lengths, starts, ends)
    }


def from_host_mirror(
    k: int,
    n_vertices: int,
    l2c: Mapping,
    c2p: Mapping,
    cyclic: Mapping,
    caps: FlushCaps | None = None,
    interests: frozenset | None = None,
) -> CPQxIndex:
    """Serialize a host-form index (the ``oracle.Index`` dict triple) into
    :class:`DeviceIndexArrays` — the mirror→device half of lazy maintenance
    (Sec. IV-E).

    Class ids are *renumbered densely* (in ascending old-id order, so every
    sorted class list stays sorted under the order-preserving remap) but the
    partition itself is untouched: lazily-split classes are serialized
    exactly as the mirror holds them, never merged back.  ``caps`` lets a
    caller reuse (and geometrically grow) the capacities of a previous
    flush so array shapes — and the jit executables keyed on them — stay
    stable while the mirror fits.
    """
    old_ids = sorted(c for c, ps in c2p.items() if ps)
    remap = {c: i for i, c in enumerate(old_ids)}
    n_classes = len(old_ids)

    pair_rows = np.array(
        [(v, u, remap[c]) for c in old_ids for (v, u) in c2p[c]],
        np.int64,
    ).reshape(-1, 3)
    n_pairs = pair_rows.shape[0]
    seqs = sorted(tuple(s) for s in l2c)
    n_l2c = sum(len(l2c[s]) for s in seqs)
    caps = (caps or FlushCaps.for_sizes(n_pairs, n_l2c, len(seqs)))
    caps = caps.grown_for(n_pairs, n_l2c, len(seqs))

    def pad_col(values, cap, fill=int(R.SENTINEL)):
        buf = np.full(cap, fill, np.int32)
        buf[: len(values)] = values
        return buf

    # ---------------- pair table, sorted by (v, u) ---------------- #
    byp = pair_rows[np.lexsort((pair_rows[:, 1], pair_rows[:, 0]))]
    pair_v = pad_col(byp[:, 0], caps.pair_cap)
    pair_u = pad_col(byp[:, 1], caps.pair_cap)
    pair_cls = pad_col(byp[:, 2], caps.pair_cap)

    # ------------- I_c2p: sorted by (class, v, u) + CSR ------------- #
    byc = pair_rows[np.lexsort((pair_rows[:, 1], pair_rows[:, 0], pair_rows[:, 2]))]
    c2p_cls = pad_col(byc[:, 2], caps.pair_cap)
    c2p_v = pad_col(byc[:, 0], caps.pair_cap)
    c2p_u = pad_col(byc[:, 1], caps.pair_cap)
    class_starts = np.searchsorted(
        c2p_cls.astype(np.int64), np.arange(caps.pair_cap + 1), side="left"
    ).astype(np.int32)
    class_cyclic = np.zeros(caps.pair_cap, np.int32)
    for c in old_ids:
        class_cyclic[remap[c]] = 1 if cyclic[c] else 0

    # ------------- I_l2c: seq table + per-seq class ranges ------------- #
    seq_table = np.full((caps.seq_cap, k), -1, np.int32)
    seq_starts = np.zeros(caps.seq_cap, np.int32)
    seq_ends = np.zeros(caps.seq_cap, np.int32)
    l2c_flat: list[int] = []
    seq_ranges: dict = {}
    for i, s in enumerate(seqs):
        seq_table[i, : len(s)] = s
        start = len(l2c_flat)
        l2c_flat.extend(sorted(remap[c] for c in l2c[s]))
        seq_starts[i] = start
        seq_ends[i] = len(l2c_flat)
        seq_ranges[s] = (start, len(l2c_flat))
    l2c_cls = pad_col(l2c_flat, caps.l2c_cap)

    arrays = DeviceIndexArrays(
        pair_v=jnp.asarray(pair_v), pair_u=jnp.asarray(pair_u),
        pair_cls=jnp.asarray(pair_cls),
        pair_count=jnp.asarray(n_pairs, R.I32),
        c2p_cls=jnp.asarray(c2p_cls), c2p_v=jnp.asarray(c2p_v),
        c2p_u=jnp.asarray(c2p_u),
        class_starts=jnp.asarray(class_starts),
        class_cyclic=jnp.asarray(class_cyclic),
        n_classes=jnp.asarray(n_classes, R.I32),
        seq_table=jnp.asarray(seq_table),
        seq_count=jnp.asarray(len(seqs), R.I32),
        seq_starts=jnp.asarray(seq_starts), seq_ends=jnp.asarray(seq_ends),
        l2c_cls=jnp.asarray(l2c_cls),
        l2c_count=jnp.asarray(n_l2c, R.I32),
        overflow=jnp.asarray(False),
    )
    return CPQxIndex(
        k=k, n_vertices=n_vertices, arrays=arrays, seq_ranges=seq_ranges,
        caps=caps, interests=interests,
    )


def build(g: LabeledGraph, k: int, caps: BuildCaps | None = None) -> CPQxIndex:
    """Build CPQx for graph ``g`` at diameter ``k`` (paper default k=2)."""
    if caps is None:
        caps = estimate_build_caps(g, k)
    dg = device_graph(g)
    arrays = build_index_arrays(dg, k, caps.key())
    if bool(arrays.overflow):
        raise RuntimeError(
            "index build overflow — estimator undersized a relation "
            "(should not happen with the exact estimator)"
        )
    return CPQxIndex(
        k=k, n_vertices=g.n_vertices, arrays=arrays,
        seq_ranges=_pull_seq_ranges(arrays, k), caps=caps,
    )
