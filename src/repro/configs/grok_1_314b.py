"""grok-1-314b [hf:xai-org/grok-1; unverified]: 64L d_model=6144 48H
(GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts top-2."""

import dataclasses

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    capacity_factor=1.25,
    attn_pattern=("global",),
    rope_theta=10_000.0,
    activation="gelu",
    tie_embeddings=True,
    max_seq_len=32768 * 16 + 64,
    remat=True,
    q_chunk=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, n_experts=4, top_k=2, max_seq_len=128,
    param_dtype="float32",
)

SPEC = ArchSpec(
    arch_id="grok-1-314b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=lm_shapes(long_ok=False, arch="grok-1-314b"),
    notes="MoE: sort-based per-group top-2 dispatch (capacity factor 1.25).",
)
