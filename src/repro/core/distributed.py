"""Distributed CPQx — the engine's pair tables sharded over a mesh axis,
with all_to_all hash repartitioning for joins (shard_map manual
collectives; DESIGN.md §5).

Data layout
-----------
A *sharded relation* is a Relation whose column arrays carry a leading
``shards`` axis sharded over the mesh: cols (n_shards, cap, ...), count
(n_shards,).  Rows live on the shard that owns their partition key
(``mix32(key) % n_shards``), except "replicated" relations (class-id
lists — small by the paper's central observation) which are identical on
every shard.

Operators (all inside one shard_map):
  * ``exchange``            fixed-capacity all_to_all bucket shuffle
  * ``sharded_join``        repartition by join key -> local expansion join
  * ``sharded_conjunction`` replicated class intersect -> sharded
                            materialize -> local intersection
  * ``build_level``         one level of Algorithm 1's path join at scale

The fixed bucket capacity is the static-shape contract: each exchange
moves (n_shards, bucket_cap, arity) per shard; overflow is flagged and
the host retries with doubled capacity exactly like the local engine.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from . import relational as R

I32 = jnp.int32


# ---------------------------------------------------------------------- #
# local helpers (run per shard inside shard_map)
# ---------------------------------------------------------------------- #


def _bucket_of(key: jax.Array, n_shards: int) -> jax.Array:
    return (R.mix32(key, 0xB0C4) % jnp.uint32(n_shards)).astype(I32)


def _pack_buckets(cols: tuple, valid: jax.Array, bucket: jax.Array,
                  n_shards: int, bucket_cap: int):
    """Arrange local rows into (n_shards, bucket_cap, arity) by bucket —
    sort by bucket then slot-gather (the MoE-dispatch pattern: no
    scatter).  Returns (packed cols tuple, per-bucket counts, overflow)."""
    cap = cols[0].shape[0]
    bkey = jnp.where(valid, bucket, n_shards)  # invalid -> trash bucket
    order = jax.lax.sort((bkey, jnp.arange(cap, dtype=I32)), num_keys=1,
                         is_stable=True)[1]
    sorted_cols = tuple(c[order] for c in cols)
    sorted_b = bkey[order]
    offs = jnp.searchsorted(sorted_b, jnp.arange(n_shards, dtype=I32),
                            side="left").astype(I32)
    ends = jnp.searchsorted(sorted_b, jnp.arange(n_shards, dtype=I32),
                            side="right").astype(I32)
    sizes = ends - offs
    overflow = jnp.any(sizes > bucket_cap)
    b = jnp.arange(n_shards * bucket_cap, dtype=I32) // bucket_cap
    slot = jnp.arange(n_shards * bucket_cap, dtype=I32) % bucket_cap
    src = jnp.clip(offs[b] + slot, 0, cap - 1)
    ok = slot < sizes[b]
    packed = tuple(
        jnp.where(ok, c[src], R.SENTINEL).reshape(n_shards, bucket_cap)
        for c in sorted_cols
    )
    return packed, jnp.minimum(sizes, bucket_cap).astype(I32), overflow


def _exchange(packed: tuple, counts: jax.Array, axis: str):
    """all_to_all: bucket b of shard s -> shard b.  packed cols are
    (n_shards, bucket_cap); returns (n_shards, bucket_cap) = one row-block
    from each peer, plus the per-peer counts."""
    out = tuple(
        jax.lax.all_to_all(c, axis, split_axis=0, concat_axis=0, tiled=True)
        for c in packed
    )
    cnt = jax.lax.all_to_all(counts, axis, split_axis=0, concat_axis=0,
                             tiled=True)
    return out, cnt


def _flatten_received(received: tuple, counts: jax.Array):
    """(n_shards, bucket_cap) blocks -> flat relation (sorted, compacted)."""
    flat = tuple(c.reshape(-1) for c in received)
    total = jnp.sum(counts)
    rel = R.Relation(flat, jnp.asarray(flat[0].shape[0], I32),
                     jnp.asarray(False))
    # SENTINEL-padded rows inside each block sort to the end
    rel = R.rel_sort(rel)
    return R.Relation(rel.cols, total.astype(I32), rel.overflow)


# ---------------------------------------------------------------------- #
# sharded operators
# ---------------------------------------------------------------------- #


def repartition(cols: tuple, count: jax.Array, key_col: int, n_shards: int,
                bucket_cap: int, axis: str):
    """Move every row to the shard owning hash(key).  Local view in/out.
    Returns (cols, count, overflow) with capacity n_shards*bucket_cap."""
    valid = jnp.arange(cols[0].shape[0], dtype=I32) < count
    bucket = _bucket_of(cols[key_col], n_shards)
    packed, sizes, ovf = _pack_buckets(cols, valid, bucket, n_shards,
                                       bucket_cap)
    received, cnt = _exchange(packed, sizes, axis)
    rel = _flatten_received(received, cnt)
    return rel.cols, rel.count, ovf | rel.overflow


def sharded_join_local(a_cols, a_count, b_cols, b_count, out_cap: int,
                       b_sorted: bool = False):
    """Local leg of the distributed join: both sides already partitioned
    by the join key (a's key col 1, b's key col 0).  ``b_sorted``: skip
    the build-side sort when the producer already emits sorted rows
    (repartition's _flatten_received does — §Perf iteration: the double
    sort was ~40% of the join's local traffic)."""
    a = R.Relation(a_cols, a_count, jnp.asarray(False))
    b = R.Relation(b_cols, b_count, jnp.asarray(False))
    if not b_sorted:
        b = R.rel_sort(b)
    out_cols = [("a", 0), ("b", 1)] + [("a", j) for j in range(2, len(a_cols))] \
        + [("b", j) for j in range(2, len(b_cols))]
    out = R.expansion_join(a, b, a_on=[1], out_cols=out_cols,
                           out_capacity=out_cap)
    out = R.rel_unique(R.rel_sort(out))
    return out.cols, out.count, out.overflow


# ---------------------------------------------------------------------- #
# jitted entry points (shard_map over one flat engine axis)
# ---------------------------------------------------------------------- #


def make_distributed_join(mesh, axis: str, n_shards: int, a_arity: int,
                          b_arity: int, bucket_cap: int, out_cap: int):
    """Factory: global (v,m,...) ⋈ (m,u,...) over one mesh axis.

    Inputs are sharded relations: cols (n_shards, cap), counts (n_shards,).
    Hash-repartitions both sides on the join key via all_to_all, joins
    locally, returns sharded output cols + counts + overflow.  This is
    Algorithm 1's level join at scale."""

    def body(ac, an, bc, bn):
        ac = tuple(c[0] for c in ac)
        bc = tuple(c[0] for c in bc)
        an, bn = an[0], bn[0]
        ac, an, ovf_a = repartition(ac, an, 1, n_shards, bucket_cap, axis)
        bc, bn, ovf_b = repartition(bc, bn, 0, n_shards, bucket_cap, axis)
        # b arrives fully sorted from the exchange (_flatten_received) —
        # skip the redundant build-side sort (§Perf engine iteration)
        oc, on, ovf_j = sharded_join_local(ac, an, bc, bn, out_cap,
                                           b_sorted=True)
        ovf = ovf_a | ovf_b | ovf_j
        return (tuple(c[None] for c in oc), on[None], ovf[None])

    spec = P(axis)
    out_arity = a_arity + b_arity - 2
    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(
            tuple(spec for _ in range(a_arity)), spec,
            tuple(spec for _ in range(b_arity)), spec,
        ),
        out_specs=(tuple(spec for _ in range(out_arity)), spec, spec),
    )
    return jax.jit(fn)


def shard_relation(rows: np.ndarray, n_shards: int, cap: int,
                   key_col: int = 0):
    """Host-side: partition rows by hash(key) into (n_shards, cap, arity)
    numpy blocks (the initial distribution of the pair table)."""
    key = rows[:, key_col].astype(np.uint32)
    h = key ^ np.uint32(0xB0C4)
    h = (h ^ (h >> np.uint32(16))) * np.uint32(0x7FEB352D)
    h = (h ^ (h >> np.uint32(15))) * np.uint32(0x846CA68B)
    h = h ^ (h >> np.uint32(16))
    bucket = (h % np.uint32(n_shards)).astype(np.int64)
    arity = rows.shape[1]
    out = np.full((n_shards, cap, arity), R.SENTINEL, np.int32)
    counts = np.zeros(n_shards, np.int32)
    for b in range(n_shards):
        rb = rows[bucket == b]
        rb = rb[np.lexsort(tuple(rb[:, j] for j in range(arity - 1, -1, -1)))]
        if rb.shape[0] > cap:
            raise ValueError(f"shard {b} overflows: {rb.shape[0]} > {cap}")
        out[b, : rb.shape[0]] = rb
        counts[b] = rb.shape[0]
    return out, counts


# ---------------------------------------------------------------------- #
# distributed conjunction query step (the paper's hot query path at scale)
# ---------------------------------------------------------------------- #


def make_distributed_query_step(mesh, axis: str):
    """Returns a jitted step: (classes_a, classes_b replicated;
    c2p shards) -> sharded result pairs of (⟦q_a⟧ ∩ ⟦q_b⟧).

    Class intersection runs replicated (tiny — the paper's point);
    materialization runs sharded: each shard scans only its own slice of
    I_c2p, so result rows are produced where they live (zero shuffle)."""
    spec = P(axis)

    def body(ca, cb, c2p_cls, c2p_v, c2p_u, c2p_count):
        # ca/cb replicated (full) SENTINEL-padded sorted class lists
        c2p_cls, c2p_v, c2p_u = c2p_cls[0], c2p_v[0], c2p_u[0]
        n = c2p_count[0]
        ra = R.Relation((ca,), jnp.sum(ca != R.SENTINEL).astype(I32),
                        jnp.asarray(False))
        rb = R.Relation((cb,), jnp.sum(cb != R.SENTINEL).astype(I32),
                        jnp.asarray(False))
        inter = R.rel_intersect(ra, rb, 1)
        # local materialize: my slice of c2p filtered to surviving classes
        local = R.Relation((c2p_cls, c2p_v, c2p_u), n, jnp.asarray(False))
        keep = R.lex_count_matches((inter.cols[0],), (c2p_cls,),
                                   inter.count) > 0
        out = R.rel_compact(local, keep)
        return (out.cols[1][None], out.cols[2][None]), out.count[None]

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), spec, spec, spec, spec),
        out_specs=((spec, spec), spec),
    )
    return jax.jit(fn)
