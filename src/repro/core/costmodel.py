"""Self-calibrating device cost model — the pricing half of the
pricing-to-silicon loop.

The optimizer's row-count objective (``core.optimizer``) is exact about
*sizes* but silent about what the device actually charges: every plan
stage — a LOOKUP, a materialization, a join — pays a fixed dispatch/
launch constant on top of its per-row work, and at CI scale those
constants dominate (ROADMAP's ``C4`` case: the 3-leaf split that wins on
rows loses 0.3–0.6x on wall-clock to per-stage overhead).  PathFinder
(arxiv 2306.02194) makes the same observation for vectorized RPQ
engines: cardinality-optimal plans lose to operator-constant-aware ones.

This module closes the loop with a :class:`DeviceCostTable` — a small
versioned JSON artifact holding

* **per-operator affine stage constants** ``cost_ns(op, rows) = fixed +
  per_row * rows`` for every :class:`~repro.core.backend.PlanOps`
  operator (lookup / materialize / conjoin / join / identity) plus the
  union executable's per-step overhead, fitted by least squares from the
  micro-calibration harness (:func:`calibrate`) which times each
  operator at a grid of capacity rungs;
* **autotuned Pallas block shapes** per (capacity rung, dtype) — the
  winners of :mod:`repro.kernels.autotune`'s sweep, read back by
  ``kernels/ops.py`` once the table is :func:`activate`\\ d;
* a **global calibration scale** corrected online: real traffic
  (:func:`refine_with_engine`, driven by ``Engine.telemetry``) and the
  CI ``BENCH_*.json`` trajectory (:func:`DeviceCostTable.
  refine_from_trajectory` — calibrated bench rows carry their
  ``predicted_ns``) both blend measured-vs-predicted ratios into the
  synthetic fit, so every bench run is training data for the next one.

The table is *advisory by construction*: the optimizer only consults it
through :meth:`DeviceCostTable.stage_ns`, and with no table present the
row-count model is the exact fallback — plans are byte-identical to the
pre-table golden snapshots, and a wrong table can only change
capacities/plan choice, never answers (the overflow ladder's contract,
see ``core.backend``).

Consumers: ``optimizer.estimate_plan``/``optimize_query`` (cost_ns
channel), ``Engine.estimate_caps`` (minimal expected-cost rung
selection), ``kernels/ops.py`` (tuned block shapes + the VMEM ceiling),
and ``core.lifecycle`` (the table rides service checkpoints as one
uint8 leaf).

Host-side: numpy + json only; jax is imported lazily inside the
calibration harness.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re

import numpy as np

#: JSON artifact format version — bumped on incompatible layout changes;
#: :meth:`DeviceCostTable.from_json` rejects unknown majors.
FORMAT_VERSION = 1

#: The plan-stage operators the calibration grid times.  ``union_step``
#: prices ONE step of the union executable's opcode program (every step
#: evaluates all candidate operators — see ``core.backend``).
OPERATORS = ("lookup", "materialize", "conjoin", "join", "identity",
             "union_step")


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


# ---------------------------------------------------------------------- #
# affine stage constants
# ---------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class OpCost:
    """One operator's affine cost: fixed dispatch/launch constant plus a
    per-row slope, both in nanoseconds (rows = the operator's *capacity*
    — relations are capacity-padded, so device work scales with the
    rung, not the live row count)."""

    fixed_ns: float
    per_row_ns: float

    def ns(self, rows: float) -> float:
        return self.fixed_ns + self.per_row_ns * max(0.0, float(rows))


def fit_affine(rows, times_ns) -> OpCost:
    """Least-squares affine fit ``t = a + b * rows`` with both
    coefficients clamped non-negative (a negative dispatch constant or
    slope is always measurement noise, and would let the optimizer
    price work below zero)."""
    r = np.asarray(rows, np.float64).ravel()
    t = np.asarray(times_ns, np.float64).ravel()
    if r.size == 0:
        return OpCost(0.0, 0.0)
    if r.size == 1 or np.ptp(r) == 0:
        return OpCost(float(max(0.0, t.mean())), 0.0)
    design = np.stack([np.ones_like(r), r], axis=1)
    (a, b), *_ = np.linalg.lstsq(design, t, rcond=None)
    if b < 0.0:  # slope noise: all mass into the constant
        return OpCost(float(max(0.0, t.mean())), 0.0)
    if a < 0.0:  # constant noise: pure per-row fit through the origin
        b = float((r @ t) / (r @ r))
        return OpCost(0.0, max(0.0, b))
    return OpCost(float(a), float(b))


# ---------------------------------------------------------------------- #
# the device cost table
# ---------------------------------------------------------------------- #


@dataclasses.dataclass
class DeviceCostTable:
    """Fitted stage constants + autotuned kernel block shapes for ONE
    device kind — the shared artifact the optimizer, the capacity
    estimator and the kernels all read.

    ``scale`` is the online-refinement knob: synthetic micro-benchmarks
    overstate fused in-plan stage costs (each is timed as its own
    dispatch), so measured-vs-predicted ratios from real traffic blend
    into this single multiplier (geometric EMA) instead of re-fitting
    every constant from sparse data.
    """

    device_kind: str = "cpu"
    version: int = FORMAT_VERSION
    scale: float = 1.0
    dispatch_floor_ns: float = 0.0  # telemetry-refined per-dispatch floor
    ops: dict = dataclasses.field(default_factory=dict)  # name -> OpCost
    block_q: dict = dataclasses.field(default_factory=dict)  # rung -> block
    block_t: dict = dataclasses.field(default_factory=dict)  # rung -> block
    vmem_words: int | None = None
    samples: dict = dataclasses.field(default_factory=dict)  # name -> [[rows, ns]]

    # ---- pricing (what the optimizer calls) ---- #

    def stage_ns(self, op: str, rows: float) -> float:
        """Price one plan stage: ``scale * (fixed + per_row * rows)``.
        Unknown operators price as zero — an old table stays usable when
        a new operator kind appears."""
        c = self.ops.get(op)
        if c is None:
            return 0.0
        return self.scale * c.ns(rows)

    def plan_dispatch_ns(self, cap: int) -> float:
        """Rough cost of one whole-plan dispatch at pair capacity
        ``cap`` — the capacity-proportional work of the dominant pair-
        space stages plus the telemetry-refined floor.  Used only to
        *compare rungs* in ``Engine.estimate_caps``, so the absolute
        level cancels; the shape (fixed + linear-in-cap) is what
        matters."""
        return max(self.dispatch_floor_ns,
                   self.stage_ns("join", cap) + self.stage_ns("materialize", cap))

    def expected_dispatch_ns(self, cap: int, est_rows: float,
                             risky: bool) -> float:
        """Expected cost of *starting* the ladder at ``cap``: the run at
        this rung plus the overflow-risk-weighted retry at the next.
        Risk decays with headroom (cap / estimate); join-bearing plans
        (``risky``) carry estimate error, conjunction bounds are sound,
        so their risk constants differ (mirroring the headroom split the
        stats-only estimator uses)."""
        risk0 = 1.0 if risky else 0.25
        p = min(1.0, risk0 * max(1.0, float(est_rows)) / max(1, cap))
        return self.plan_dispatch_ns(cap) + p * self.plan_dispatch_ns(2 * cap)

    # ---- autotuned kernel blocks ---- #

    def tuned_block(self, kind: str, rung: int) -> int | None:
        """Winner block for ``kind`` in {"block_q", "block_t"} at the
        smallest tuned rung >= ``rung`` (capacities quantize onto the
        pow2 ladder, so the next rung up is the right neighbor); None
        when nothing relevant was tuned."""
        table = self.block_q if kind == "block_q" else self.block_t
        if not table:
            return None
        geq = [r for r in table if r >= rung]
        return table[min(geq)] if geq else table[max(table)]

    # ---- online refinement ---- #

    def observe(self, op: str, rows: float, ns: float) -> None:
        """Append one real measurement to the operator's sample set (the
        raw training data every calibration run extends)."""
        self.samples.setdefault(op, []).append([float(rows), float(ns)])

    def refit(self, op: str) -> OpCost:
        """Re-fit one operator's constants from its full sample set."""
        pts = np.asarray(self.samples.get(op, []), np.float64).reshape(-1, 2)
        cost = fit_affine(pts[:, 0], pts[:, 1])
        self.ops[op] = cost
        return cost

    def refine_scale(self, measured_ns: float, predicted_ns: float,
                     weight: float = 0.5) -> float:
        """Blend one measured-vs-predicted ratio into the global scale
        (geometric EMA — ratios are multiplicative).  Non-positive
        inputs are ignored; the scale is clamped to [1/64, 64] so one
        corrupt bench row cannot zero the model."""
        if measured_ns <= 0.0 or predicted_ns <= 0.0:
            return self.scale
        ratio = measured_ns / predicted_ns
        new = self.scale * math.exp(weight * math.log(ratio))
        self.scale = float(min(64.0, max(1.0 / 64.0, new)))
        return self.scale

    def refine_from_telemetry(self, telemetry, elapsed_ns: float,
                              weight: float = 0.5) -> float:
        """Correct the per-dispatch floor from an engine's lifetime
        counters: ``elapsed_ns / dispatches`` is the average real
        dispatch (retry rungs included — they are real traffic too).
        ``telemetry`` is any object with a ``dispatches`` attribute
        (an :class:`~repro.core.engine.LadderTelemetry` or a snapshot)."""
        n = int(getattr(telemetry, "dispatches", 0))
        if n <= 0 or elapsed_ns <= 0.0:
            return self.dispatch_floor_ns
        avg = elapsed_ns / n
        self.dispatch_floor_ns = float(
            (1.0 - weight) * self.dispatch_floor_ns + weight * avg)
        return self.dispatch_floor_ns

    def refine_from_trajectory(self, payloads, weight: float = 0.25) -> int:
        """Consume CI ``BENCH_*.json`` payloads: every row whose
        ``derived`` carries a ``predicted_ns=...`` tag (the calibrated
        bench legs emit them) contributes its measured ``us_per_call``
        against that prediction.  Returns the number of rows consumed.

        This is the trajectory half of the refinement loop: the table
        that planned run N is corrected by run N's measurements before
        pricing run N+1."""
        used = 0
        for payload in payloads:
            for row in payload.get("rows", []):
                m = re.search(r"predicted_ns=([0-9.eE+\-]+)",
                              row.get("derived", ""))
                if not m:
                    continue
                predicted = float(m.group(1))
                measured = float(row.get("us_per_call", 0.0)) * 1e3
                self.refine_scale(measured, predicted, weight=weight)
                used += 1
        return used

    # ---- JSON artifact codec ---- #

    def to_json(self) -> dict:
        return {
            "format": "cpqx-cost-table",
            "version": self.version,
            "device_kind": self.device_kind,
            "scale": self.scale,
            "dispatch_floor_ns": self.dispatch_floor_ns,
            "ops": {k: [v.fixed_ns, v.per_row_ns]
                    for k, v in sorted(self.ops.items())},
            "block_q": {str(r): b for r, b in sorted(self.block_q.items())},
            "block_t": {str(r): b for r, b in sorted(self.block_t.items())},
            "vmem_words": self.vmem_words,
            "samples": {k: v for k, v in sorted(self.samples.items())},
        }

    @classmethod
    def from_json(cls, payload: dict) -> "DeviceCostTable":
        if payload.get("format") != "cpqx-cost-table":
            raise ValueError(f"not a cost table: {payload.get('format')!r}")
        if int(payload.get("version", -1)) > FORMAT_VERSION:
            raise ValueError(f"cost table version {payload['version']} is "
                             f"newer than supported {FORMAT_VERSION}")
        return cls(
            device_kind=str(payload.get("device_kind", "cpu")),
            version=int(payload.get("version", FORMAT_VERSION)),
            scale=float(payload.get("scale", 1.0)),
            dispatch_floor_ns=float(payload.get("dispatch_floor_ns", 0.0)),
            ops={k: OpCost(float(a), float(b))
                 for k, (a, b) in payload.get("ops", {}).items()},
            block_q={int(r): int(b)
                     for r, b in payload.get("block_q", {}).items()},
            block_t={int(r): int(b)
                     for r, b in payload.get("block_t", {}).items()},
            vmem_words=(None if payload.get("vmem_words") is None
                        else int(payload["vmem_words"])),
            samples={k: [[float(r), float(t)] for r, t in v]
                     for k, v in payload.get("samples", {}).items()},
        )

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "DeviceCostTable":
        with open(path) as fh:
            return cls.from_json(json.load(fh))

    # ---- checkpoint codec (core.lifecycle) ---- #

    def export_state(self) -> np.ndarray:
        """The table as ONE uint8 leaf (UTF-8 JSON) — checkpoints are
        flat pytrees of numpy arrays, and the table is small."""
        blob = json.dumps(self.to_json(), sort_keys=True).encode("utf-8")
        return np.frombuffer(blob, dtype=np.uint8).copy()

    @classmethod
    def from_state(cls, leaf: np.ndarray) -> "DeviceCostTable":
        blob = np.asarray(leaf, np.uint8).tobytes().decode("utf-8")
        return cls.from_json(json.loads(blob))


def activate(table: DeviceCostTable | None) -> None:
    """Install (or, with None, uninstall) the table's kernel-facing
    halves — tuned block shapes and the VMEM ceiling override — into
    ``repro.kernels.ops``.  Pricing stays explicit (tables are passed to
    engines), but kernels are called from inside jitted plan walkers, so
    their tuning rides a process-wide registry."""
    from repro.kernels import ops as kops  # lazy: host-only module otherwise

    if table is None:
        kops.set_tuned_blocks(None, None)
        kops.set_vmem_words_override(None)
        return
    kops.set_tuned_blocks(dict(table.block_q), dict(table.block_t))
    kops.set_vmem_words_override(table.vmem_words)


# ---------------------------------------------------------------------- #
# micro-calibration harness (jax; times real device operators)
# ---------------------------------------------------------------------- #

#: Default capacity-rung grid for the synthetic fit; callers pass the
#: engine's real caps-ladder rungs when they have one (``ladder_rungs``).
DEFAULT_RUNGS = (256, 1024, 4096)


def ladder_rungs(engine, queries=(), max_rungs: int = 4) -> list[int]:
    """The pow2 capacity rungs this engine actually starts plans at:
    the estimated ``pair_cap`` of each probe query plus the worst-case
    default — the grid the calibration and the block-shape sweeps key
    on, so the table prices the rungs real traffic dispatches."""
    from .query import plan_shape

    rungs = {int(engine._default_caps.pair_cap)}
    for q in queries:
        plan = engine.plan(q)
        caps = engine.estimate_caps(engine.lookup_ranges(plan),
                                    plan_shape(plan),
                                    plan if engine.optimize else None)
        rungs.add(int(caps.pair_cap))
    out = sorted(rungs)
    if len(out) > max_rungs:  # keep the extremes, thin the middle
        keep = {out[0], out[-1]}
        step = max(1, len(out) // max_rungs)
        keep.update(out[::step])
        out = sorted(keep)[:max_rungs]
    return out


def _time_ns(fn, repeats: int, warmup: int = 1) -> float:
    import time

    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e9)
    return float(np.median(ts))


def calibrate(rungs=None, repeats: int = 3, n_vertices: int = 1 << 16,
              device_kind: str | None = None) -> DeviceCostTable:
    """Time every :class:`~repro.core.backend.PlanOps` operator at a
    grid of capacity rungs against synthetic rung-sized index arrays and
    fit the per-operator affine stage constants.

    Synthetic arrays (one pair per class, ids ascending) make every
    operator's input exactly rung-sized, so the fit sees a clean
    (capacity -> wall-clock) signal; what the constants *mean* on real
    fused plans is corrected afterwards by the refinement passes
    (:func:`refine_with_engine` / :meth:`DeviceCostTable.
    refine_from_trajectory`).  Timings include the jit dispatch — that
    is the point: dispatch overhead is exactly what the row-count model
    cannot see.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import vmem_words

    from . import relational as R
    from .backend import (OP_CONJ_ID, OP_LOOKUP, LocalOps, QueryCaps,
                          run_union_batch)
    from .index import DeviceIndexArrays

    table = DeviceCostTable(
        device_kind=device_kind or jax.default_backend(),
        vmem_words=int(vmem_words()))
    rungs = sorted(int(r) for r in (rungs or DEFAULT_RUNGS))

    def arrays_for(r: int) -> DeviceIndexArrays:
        """Synthetic index: r classes of one pair each, sorted ids."""
        ar = jnp.arange(r, dtype=R.I32)
        fields = dict.fromkeys(DeviceIndexArrays._fields)
        fields.update(
            l2c_cls=ar, class_starts=jnp.arange(r + 1, dtype=R.I32),
            c2p_v=ar, c2p_u=ar, class_cyclic=jnp.ones((r,), R.I32))
        for f, v in fields.items():
            if v is None:  # leaves the walker never touches
                fields[f] = jnp.zeros((1,), R.I32)
        return DeviceIndexArrays(**fields)

    for r in rungs:
        ops = LocalOps(arrays_for(r), min(n_vertices, r))
        ids = jnp.arange(r, dtype=R.I32)
        rel1 = R.Relation((ids,), jnp.asarray(r, R.I32), jnp.asarray(False))
        pairs = R.Relation((ids, ids), jnp.asarray(r, R.I32),
                           jnp.asarray(False))

        timed = {
            "lookup": jax.jit(
                lambda lo, ln, _o=ops, _r=r:
                    _o.lookup_classes(lo, ln, _r).cols[0]),
            "materialize": jax.jit(
                lambda rel, _o=ops, _r=r:
                    _o.materialize(rel, _r).cols[0]),
            "conjoin": jax.jit(
                lambda a, b, _o=ops: _o.conj_classes(a, b).cols[0]),
            "join": jax.jit(
                lambda a, b, _o=ops, _r=r:
                    _o.join_pairs(a, b, 2 * _r, _r).cols[0]),
            "identity": jax.jit(
                lambda _, _o=ops, _r=r: _o.identity_pairs(_r).cols[0]),
        }
        args = {
            "lookup": (jnp.asarray(0, R.I32), jnp.asarray(r, R.I32)),
            "materialize": (rel1,),
            "conjoin": (rel1, rel1),
            "join": (pairs, pairs),
            "identity": (jnp.asarray(0, R.I32),),
        }
        for op, fn in timed.items():
            ns = _time_ns(lambda f=fn, a=args[op]:
                          jax.block_until_ready(f(*a)), repeats)
            table.observe(op, r, ns)

        # union-program step overhead: a T-step vs T'-step program of the
        # same shape isolates the per-step price (every step evaluates
        # all candidate operators — see core.backend)
        caps = QueryCaps(class_cap=_pow2(r), pair_cap=_pow2(r),
                         join_cap=2 * _pow2(r))
        union_arrays = arrays_for(_pow2(r))
        per_lane = {}
        for steps in (2, 6):
            opc = np.full((1, steps), OP_CONJ_ID, np.int32)
            opc[0, 0] = OP_LOOKUP
            rng_rows = np.zeros((1, steps, 2), np.int32)
            rng_rows[0, 0] = (0, r)
            fn = lambda o=jnp.asarray(opc), g=jnp.asarray(rng_rows): \
                jax.block_until_ready(run_union_batch(
                    union_arrays, caps, 2, min(n_vertices, r), o, g)[0].cols[0])
            per_lane[steps] = _time_ns(fn, repeats)
        per_step = max(0.0, (per_lane[6] - per_lane[2]) / 4.0)
        table.observe("union_step", r, per_step)

    for op in OPERATORS:
        table.refit(op)
    return table


def refine_with_engine(table: DeviceCostTable, engine, queries,
                       repeats: int = 3, weight: float = 0.5) -> float:
    """Online refinement against REAL plans: execute each probe query on
    ``engine``, compare measured wall-clock to the table's predicted
    ``cost_ns``, and blend the ratios into ``table.scale``; the engine's
    :class:`~repro.core.engine.LadderTelemetry` corrects the dispatch
    floor from the same traffic.  Returns the refined scale.

    Synthetic micro-benchmarks time each operator as its own dispatch,
    which overstates fused in-plan stage costs — one multiplicative
    correction from end-to-end measurements fixes the level while the
    fitted *ratios* between operators (the part that orders plans) keep
    their synthetic precision."""
    from .optimizer import estimate_plan

    total_ns = 0.0
    before = engine.telemetry.snapshot()
    for q in queries:
        plan = engine.plan(q)
        predicted = estimate_plan(plan, engine.stats, cost_table=table).cost_ns
        measured = _time_ns(lambda _q=q: engine.execute(_q), repeats)
        total_ns += measured * repeats
        if predicted > 0.0:
            table.refine_scale(measured, predicted, weight=weight)
    after = engine.telemetry.snapshot()
    delta = dataclasses.replace(
        after, dispatches=after.dispatches - before.dispatches)
    table.refine_from_telemetry(delta, total_ns)
    return table.scale
