"""Per-(architecture x input-shape) cells: the step function, abstract
input specs (ShapeDtypeStruct — zero allocation), and the sharding trees
that ``dryrun.py`` lowers and ``train.py``/``serve.py`` execute.

Every cell is a ``Cell(fn, args, in_shardings)``; ``jax.jit(fn,
in_shardings=...).lower(*args).compile()`` must succeed on the production
meshes — that is deliverable (e).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ArchSpec, ShapeSpec
from repro.models import gnn as G
from repro.models import recsys as RS
from repro.models import transformer as T
from repro.train.optim import adamw_init, adamw_update
from . import shardings as S

I32 = jnp.int32
F32 = jnp.float32


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple  # ShapeDtypeStructs (pytrees)
    in_shardings: tuple  # matching pytrees of NamedSharding
    static_argnums: tuple = ()
    donate_argnums: tuple = ()  # params/opt for train, cache for serve
    description: str = ""


# per-arch gradient-accumulation factor at the assigned train_4k shape —
# sized so per-device saved activations (full remat) fit v5e HBM
TRAIN_ACCUM = {
    "grok-1-314b": 16,
    "mistral-nemo-12b": 8,
    "gemma2-2b": 4,
    "minicpm-2b": 4,
    "granite-moe-3b-a800m": 4,
}


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _named_tree(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# ---------------------------------------------------------------------- #
# LM cells
# ---------------------------------------------------------------------- #


def _lm_train_step(cfg, accum: int = 1):
    """Train step with internal gradient accumulation: the global batch
    splits into ``accum`` microbatches scanned sequentially (memory-flat
    with per-layer remat), then one AdamW update."""

    def lf(p, toks, labels):
        loss, aux = T.train_loss(cfg, p, toks, labels)
        return loss, aux

    def step(params, opt_state, tokens, labels):
        if accum == 1:
            (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(
                params, tokens, labels)
        else:
            gb, seq = tokens.shape
            mb = gb // accum
            toks = tokens.reshape(accum, mb, seq)
            labs = labels.reshape(accum, mb, seq)

            def micro(acc, xs):
                t, l = xs
                (loss, _), g = jax.value_and_grad(lf, has_aux=True)(
                    params, t, l)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0.0)), (toks, labs))
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               lr=3e-4)
        return new_params, new_opt, {"loss": loss, **om}

    return step


def _lm_layout_from_env(mesh):
    """§Perf hillclimb knobs, switchable without code edits:
    REPRO_EMBED_FSDP=0   keep embedding d_model replicated (kills the
                         GSPMD involuntary-remat of the token gather)
    REPRO_FSDP_AXES=pod,data   widen FSDP (param/opt sharding) axes
    REPRO_CONTEXT_PARALLEL=1   seq/time-shard attention over "model" when
                         n_heads doesn't divide it (else it replicates)
    REPRO_MOE_TOKEN_TP=1 shard MoE expert-buffer capacity over "model"
                         with F-replicated expert weights (tiny-F MoE)"""
    import os

    embed_fsdp = os.environ.get("REPRO_EMBED_FSDP", "1") == "1"
    axes_env = os.environ.get("REPRO_FSDP_AXES", "data")
    axes = tuple(a for a in axes_env.split(",") if a in mesh.axis_names)
    fsdp = axes if len(axes) > 1 else (axes[0] if axes else None)
    # context-parallel attention is default-ON: it only activates when
    # n_heads doesn't divide the TP axis, where the baseline layout
    # replicates attention (42.7x traffic on minicpm — §Perf iter. 1)
    cp = os.environ.get("REPRO_CONTEXT_PARALLEL", "1") == "1"
    moe_tp = os.environ.get("REPRO_MOE_TOKEN_TP", "0") == "1"
    return fsdp, embed_fsdp, cp, moe_tp


def lm_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = spec.config
    dims = shape.dims
    fsdp, embed_fsdp, cp, moe_tp = _lm_layout_from_env(mesh)
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    if cp and cfg.n_heads % tp_size != 0:
        cfg = dataclasses.replace(cfg, attn_batch_axes=batch_axes,
                                  attn_seq_axes=("model",))
    if moe_tp and cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_c_axes=("model",),
                                  attn_batch_axes=batch_axes)
    pspecs = S.lm_param_specs(cfg, mesh, fsdp=fsdp, embed_fsdp=embed_fsdp)
    if moe_tp and cfg.is_moe:
        # expert weights: F replicated (full-width matmuls per shard),
        # d_model FSDP only
        nl, e, d, f = cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_ff
        pspecs["layers"]["w_gate"] = S._spec(mesh, (nl, None), (e, None),
                                             (d, fsdp), (f, None))
        pspecs["layers"]["w_up"] = S._spec(mesh, (nl, None), (e, None),
                                           (d, fsdp), (f, None))
        pspecs["layers"]["w_down"] = S._spec(mesh, (nl, None), (e, None),
                                             (f, None), (d, fsdp))
    params_abs = T.abstract_params(cfg)
    bspec = S.lm_batch_spec(mesh)
    n_batch_axes = np.prod(
        [dict(zip(mesh.axis_names, mesh.devices.shape))[a]
         for a in mesh.axis_names if a != "model"]
    )

    if shape.kind == "train":
        gb, seq = dims["global_batch"], dims["seq_len"]
        accum = TRAIN_ACCUM.get(spec.arch_id, 1)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        ospecs = S.lm_opt_specs(pspecs)
        args = (
            params_abs, opt_abs,
            _sds((gb, seq), I32), _sds((gb, seq), I32),
        )
        shard = (
            _named_tree(mesh, pspecs), _named_tree(mesh, ospecs),
            NamedSharding(mesh, bspec), NamedSharding(mesh, bspec),
        )
        return Cell(spec.arch_id, shape.name, _lm_train_step(cfg, accum),
                    args, shard, donate_argnums=(0, 1),
                    description=f"train {gb}x{seq} accum={accum}")

    b, seq = dims["global_batch"], dims["seq_len"]
    cache_len = seq
    cache_abs = T.abstract_cache(cfg, b, cache_len)
    if b % n_batch_axes == 0:
        cspecs = S.lm_cache_specs(cfg, mesh)
        tok_spec = bspec
    else:
        # tiny-batch long-context: shard the KV *time* axis over the whole
        # mesh (flash-decoding style); batch replicated
        total = int(np.prod(mesh.devices.shape))
        assert cache_len % total == 0, (cache_len, total)
        cspecs = {"k": P(None, None, tuple(mesh.axis_names), None, None),
                  "v": P(None, None, tuple(mesh.axis_names), None, None)}
        tok_spec = P(None, None)

    if shape.kind == "prefill":
        def fn(params, tokens, cache):
            return T.prefill(cfg, params, tokens, cache)

        args = (params_abs, _sds((b, seq), I32), cache_abs)
        shard = (_named_tree(mesh, pspecs), NamedSharding(mesh, tok_spec),
                 _named_tree(mesh, cspecs))
        return Cell(spec.arch_id, shape.name, fn, args, shard,
                    donate_argnums=(2,),
                    description=f"prefill {b}x{seq}")

    if shape.kind == "decode":
        def fn(params, cache, tokens, pos):
            return T.decode_step(cfg, params, cache, tokens, pos)

        args = (params_abs, cache_abs, _sds((b, 1), I32), _sds((), I32))
        shard = (_named_tree(mesh, pspecs), _named_tree(mesh, cspecs),
                 NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()))
        return Cell(spec.arch_id, shape.name, fn, args, shard,
                    donate_argnums=(1,),
                    description=f"decode b={b} kv={seq}")
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------- #
# GNN cells
# ---------------------------------------------------------------------- #


def _gnn_graph_abs(mesh, n_nodes, n_edges, d_feat, d_edge, need_pos,
                   pad_to_mesh=True):
    total = int(np.prod(mesh.devices.shape))
    n = _round_up(n_nodes, total) if pad_to_mesh else n_nodes
    e = _round_up(n_edges, total) if pad_to_mesh else n_edges
    g = G.GraphBatch(
        node_feat=_sds((n, d_feat), F32),
        edge_feat=_sds((e, max(d_edge, 1)), F32) if d_edge else None,
        senders=_sds((e,), I32),
        receivers=_sds((e,), I32),
        node_mask=_sds((n,), jnp.bool_),
        edge_mask=_sds((e,), jnp.bool_),
        positions=_sds((n, 3), F32) if need_pos else None,
        graph_ids=_sds((n,), I32),
        n_graphs=1,
    )
    specs = S.gnn_batch_specs(mesh, n, e)
    gspec = G.GraphBatch(
        node_feat=NamedSharding(mesh, specs["node_feat"]),
        edge_feat=(NamedSharding(mesh, specs["edge_feat"]) if d_edge else None),
        senders=NamedSharding(mesh, specs["senders"]),
        receivers=NamedSharding(mesh, specs["receivers"]),
        node_mask=NamedSharding(mesh, specs["node_mask"]),
        edge_mask=NamedSharding(mesh, specs["edge_mask"]),
        positions=(NamedSharding(mesh, specs["positions"]) if need_pos else None),
        graph_ids=NamedSharding(mesh, specs["graph_ids"]),
        n_graphs=None,
    )
    return g, gspec, n


def gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    dims = shape.dims
    d_feat = dims.get("d_feat", spec.config.d_in)
    cfg = dataclasses.replace(spec.config, d_in=d_feat)
    need_pos = cfg.arch in ("egnn", "mace")

    if shape.kind == "sampled":
        n_nodes, n_edges = dims["pad_nodes"], dims["pad_edges"]
    elif shape.kind == "batched_graphs":
        n_nodes = dims["n_nodes"] * dims["batch"]
        n_edges = dims["n_edges"] * dims["batch"]
    else:
        n_nodes, n_edges = dims["n_nodes"], dims["n_edges"]

    g_abs, g_shard, n_pad = _gnn_graph_abs(
        mesh, n_nodes, n_edges, d_feat, cfg.d_edge_in, need_pos)
    params_abs = jax.eval_shape(
        lambda k: G.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = jax.tree.map(lambda _: P(), params_abs)
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    ospecs = jax.eval_shape(adamw_init, params_abs)
    ospecs = jax.tree.map(lambda _: P(), ospecs)

    def step(params, opt_state, g, targets):
        def lf(p):
            return G.train_loss(cfg, p, g, targets)

        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               lr=1e-3)
        return new_params, new_opt, {"loss": loss, **om}

    targets_abs = _sds((n_pad, cfg.d_out), F32)
    tspec = g_shard.node_feat
    args = (params_abs, opt_abs, g_abs, targets_abs)
    shard = (_named_tree(mesh, pspecs), _named_tree(mesh, ospecs), g_shard,
             tspec)
    return Cell(spec.arch_id, shape.name, step, args, shard,
                donate_argnums=(0, 1),
                description=f"gnn train N={n_nodes} E={n_edges}")


# ---------------------------------------------------------------------- #
# recsys cells
# ---------------------------------------------------------------------- #


def bst_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = spec.config
    dims = shape.dims
    pspecs = S.bst_param_specs(cfg, mesh)
    params_abs = jax.eval_shape(
        lambda k: RS.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    bspec = S.bst_batch_spec(mesh)
    f = cfg.n_context_fields

    def batch_abs(b):
        return RS.BSTBatch(
            item_ids=_sds((b, cfg.seq_len), I32),
            cat_ids=_sds((b, cfg.seq_len), I32),
            ctx_ids=_sds((b * f,), I32),
            ctx_segs=_sds((b * f,), I32),
            labels=_sds((b,), I32),
        )

    def batch_shard(b):
        bs = NamedSharding(mesh, bspec)
        row2 = NamedSharding(mesh, P(bspec[0], None))
        return RS.BSTBatch(item_ids=row2, cat_ids=row2, ctx_ids=bs,
                           ctx_segs=bs, labels=bs)

    if shape.kind == "train":
        b = dims["batch"]
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        ospecs = jax.tree.map(lambda s: s, pspecs)

        def step(params, opt_state, batch):
            def lf(p):
                return RS.train_loss(cfg, p, batch)

            (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
            new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                                   lr=1e-3)
            return new_params, new_opt, {"loss": loss, **om}

        opt_shard = S_opt_like(pspecs)
        args = (params_abs, opt_abs, batch_abs(b))
        shard = (_named_tree(mesh, pspecs), _named_tree(mesh, opt_shard),
                 batch_shard(b))
        return Cell(spec.arch_id, shape.name, step, args, shard,
                    donate_argnums=(0, 1),
                    description=f"bst train b={b}")

    if shape.kind == "serve":
        b = dims["batch"]

        def fn(params, batch):
            return RS.forward(cfg, params, batch)

        args = (params_abs, batch_abs(b))
        shard = (_named_tree(mesh, pspecs), batch_shard(b))
        return Cell(spec.arch_id, shape.name, fn, args, shard,
                    description=f"bst serve b={b}")

    if shape.kind == "retrieval":
        nc = _round_up(dims["n_candidates"], int(np.prod(mesh.devices.shape)))

        def fn(params, item_ids, cat_ids, ctx_ids, ctx_segs, cand_ids):
            return RS.retrieval_topk(cfg, params, item_ids, cat_ids, ctx_ids,
                                     ctx_segs, cand_ids, k=128)

        args = (params_abs, _sds((1, cfg.seq_len), I32),
                _sds((1, cfg.seq_len), I32), _sds((f,), I32), _sds((f,), I32),
                _sds((nc,), I32))
        rep = NamedSharding(mesh, P())
        cand_spec = NamedSharding(mesh, P(tuple(mesh.axis_names)))
        shard = (_named_tree(mesh, pspecs), rep, rep, rep, rep, cand_spec)
        return Cell(spec.arch_id, shape.name, fn, args, shard,
                    description=f"bst retrieval 1x{nc}")
    raise ValueError(shape.kind)


def S_opt_like(pspecs):
    from repro.train.optim import AdamWState

    return AdamWState(step=P(), mu=jax.tree.map(lambda s: s, pspecs),
                      nu=jax.tree.map(lambda s: s, pspecs))


# ---------------------------------------------------------------------- #
# engine cells (the paper's workload at scale — bonus dry-run rows)
# ---------------------------------------------------------------------- #


def engine_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    from repro.core import distributed as D

    dims = shape.dims
    total = int(np.prod(mesh.devices.shape))
    axes = tuple(mesh.axis_names)

    if shape.name.startswith("build"):
        per = dims["n_pairs"] // total
        bucket = max(per // total * 4, 1024)
        join = D.make_distributed_join(mesh, axes, total, 3, 3,
                                       bucket_cap=bucket, out_cap=4 * per)

        def fn(a0, a1, a2, an, b0, b1, b2, bn):
            return join((a0, a1, a2), an, (b0, b1, b2), bn)

        col = _sds((total, per), I32)
        cnt = _sds((total,), I32)
        args = (col, col, col, cnt, col, col, col, cnt)
        cs = NamedSharding(mesh, P(axes, None))
        ns = NamedSharding(mesh, P(axes))
        shard = (cs, cs, cs, ns, cs, cs, cs, ns)
        return Cell(spec.arch_id, shape.name, fn, args, shard,
                    description=f"engine level join {dims['n_pairs']} pairs")

    # query cell: replicated class intersect + sharded materialize
    per = dims["n_pairs"] // total
    step = D.make_distributed_query_step(mesh, axes)

    def fn(ca, cb, c0, c1, c2, cn):
        return step(ca, cb, c0, c1, c2, cn)

    lc = dims["lookup_classes"]
    args = (_sds((lc,), I32), _sds((lc,), I32),
            _sds((total, per), I32), _sds((total, per), I32),
            _sds((total, per), I32), _sds((total,), I32))
    rep = NamedSharding(mesh, P())
    cs = NamedSharding(mesh, P(axes, None))
    ns = NamedSharding(mesh, P(axes))
    shard = (rep, rep, cs, cs, cs, ns)
    return Cell(spec.arch_id, shape.name, fn, args, shard,
                description=f"engine conjunction query {dims['n_pairs']} pairs")


# ---------------------------------------------------------------------- #
# dispatch
# ---------------------------------------------------------------------- #


def build_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    if spec.family == "lm":
        return lm_cell(spec, shape, mesh)
    if spec.family == "gnn":
        return gnn_cell(spec, shape, mesh)
    if spec.family == "recsys":
        return bst_cell(spec, shape, mesh)
    if spec.family == "engine":
        return engine_cell(spec, shape, mesh)
    raise ValueError(spec.family)
