"""Serving throughput (PR 1 tentpole): queries/sec of the three execution
paths on the Fig. 5 template workload —

  sequential  one ``Engine.execute`` dispatch per query
  batched     ``Engine.execute_batch`` (plan-shape groups, one vmapped
              dispatch per group)
  service     ``QueryService`` (queue + shape buckets + result cache);
              reported cold (unique queries) and warm (repeat traffic)

The headline claim measured here: a batch of >= 16 same-template queries
through ``execute_batch`` sustains >= 2x the queries/sec of the
sequential loop (amortizing per-dispatch host/device overhead over the
one compiled executable all the queries share).  Correctness is gated
inside the bench: every path must return bit-identical answers.

    PYTHONPATH=src python -m benchmarks.bench_throughput [--smoke]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import index as cindex, oracle
from repro.core.engine import Engine
from repro.core.query import TEMPLATE_ARITY, instantiate_template
from repro.core.service import QueryService

from .common import DATASETS, TEMPLATE_NAMES, emit

SAME_TEMPLATE = "T"  # triangle: conjunction-heavy, the paper's hot shape


def _queries(g, templates, n_per, seed=11):
    rng = np.random.default_rng(seed)
    present = np.unique(g.lbl)
    out = []
    for name in templates:
        for _ in range(n_per):
            labels = rng.choice(present, TEMPLATE_ARITY[name]).tolist()
            out.append(instantiate_template(name, labels))
    return out


def _time(fn, iters):
    """Best-of-N wall time: the minimum is the denoised estimate of the
    true cost (scheduler preemption only ever adds time, identically to
    every path being compared)."""
    fn()  # warmup: compile + caches
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def _rows_equal(a, b):
    return len(a) == len(b) and all(
        x.shape == y.shape and bool(np.all(x == y)) for x, y in zip(a, b))


def run_dataset(ds: str, n_same: int, n_per_template: int, iters: int,
                templates, check_oracle: bool) -> float:
    """Benchmark one dataset; returns the same-template batched speedup."""
    g = DATASETS[ds]()
    engine = Engine(cindex.build(g, 2))

    # ---- same-template batch: the acceptance workload ---------------- #
    batch = _queries(g, [SAME_TEMPLATE], n_same)
    seq_s = _time(lambda: [engine.execute(q) for q in batch], iters)
    bat_s = _time(lambda: engine.execute_batch(batch), iters)
    seq_res = [engine.execute(q) for q in batch]
    bat_res = engine.execute_batch(batch)
    assert _rows_equal(seq_res, bat_res), "batched != sequential"
    speedup = seq_s / bat_s
    n = len(batch)
    emit(f"throughput/{ds}/same{n}/sequential", seq_s / n * 1e6,
         f"qps={n / seq_s:.1f}")
    emit(f"throughput/{ds}/same{n}/batched", bat_s / n * 1e6,
         f"qps={n / bat_s:.1f};speedup={speedup:.2f}x")

    # ---- mixed-template workload through all three paths ------------- #
    mixed = _queries(g, templates, n_per_template, seed=23)
    n = len(mixed)
    seq_s = _time(lambda: [engine.execute(q) for q in mixed], iters)
    bat_s = _time(lambda: engine.execute_batch(mixed), iters)

    def serve_cold():
        svc = QueryService(engine, max_batch=len(mixed))
        for q in mixed:
            svc.submit(q)
        return svc.flush()

    svc_s = _time(serve_cold, iters)

    warm = QueryService(engine, max_batch=len(mixed))
    for q in mixed:
        warm.submit(q)
    warm.flush()

    def serve_warm():
        for q in mixed:
            warm.submit(q)
        return warm.flush()

    warm_s = _time(serve_warm, iters)

    emit(f"throughput/{ds}/mixed{n}/sequential", seq_s / n * 1e6,
         f"qps={n / seq_s:.1f}")
    emit(f"throughput/{ds}/mixed{n}/batched", bat_s / n * 1e6,
         f"qps={n / bat_s:.1f};speedup={seq_s / bat_s:.2f}x")
    emit(f"throughput/{ds}/mixed{n}/service", svc_s / n * 1e6,
         f"qps={n / svc_s:.1f};speedup={seq_s / svc_s:.2f}x")
    emit(f"throughput/{ds}/mixed{n}/service-warm", warm_s / n * 1e6,
         f"qps={n / warm_s:.1f};speedup={seq_s / warm_s:.2f}x")

    # correctness gate: all three paths agree (and with the oracle when
    # the graph is small enough to afford it)
    bat_res = engine.execute_batch(mixed)
    svc = QueryService(engine, max_batch=len(mixed))
    reqs = [svc.submit(q) for q in mixed]
    svc.flush()
    for q, b, r in zip(mixed, bat_res, reqs):
        sb = {tuple(x) for x in b.tolist()}
        assert sb == {tuple(x) for x in r.result.tolist()}, q
        if check_oracle:
            assert sb == oracle.cpq_eval(g, q), q
    jax.clear_caches()
    return speedup


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, minimal iterations (CI)")
    ap.add_argument("--batch", type=int, default=16,
                    help="same-template batch size (acceptance: >= 16)")
    args, _ = ap.parse_known_args()

    if args.smoke:
        run_dataset("example", n_same=max(4, args.batch // 2),
                    n_per_template=1, iters=1,
                    templates=TEMPLATE_NAMES[:4], check_oracle=True)
        return
    speedup = run_dataset("gmark-small", n_same=max(1, args.batch),
                          n_per_template=8, iters=7,
                          templates=TEMPLATE_NAMES, check_oracle=False)
    emit("throughput/gmark-small/acceptance", 0.0,
         f"batched_speedup={speedup:.2f}x;target=2x;"
         f"{'PASS' if speedup >= 2.0 else 'FAIL'}")


if __name__ == "__main__":
    main()
