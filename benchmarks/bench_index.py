"""Paper Table IV: index size (entries) and construction time for
CPQx / iaCPQx / Path / iaPath, plus the Thm. 4.2 size comparison."""

from __future__ import annotations

import jax

from repro.core import baselines, capacity, interest
from repro.core import index as cindex

from .bench_query import interests_for
from .common import DATASETS, emit, timeit


def main() -> None:
    for ds in ["robots-like", "advogato-like", "gmark-small", "gmark-medium"]:
        g = DATASETS[ds]()
        ints = interests_for(g)
        caps = capacity.estimate_build_caps(g, 2)
        stats = capacity.graph_stats(g, 2)

        us = timeit(lambda: cindex.build(g, 2, caps), warmup=1, iters=2)
        idx = cindex.build(g, 2, caps)
        l2c, c2p = idx.size_entries()
        emit(f"table4/{ds}/CPQx_IT", us,
             f"IS={l2c + c2p} classes={idx.n_classes} pairs={idx.n_pairs} "
             f"gamma={stats['gamma']:.2f}")

        us = timeit(lambda: interest.build_interest(g, 2, ints, caps),
                    warmup=1, iters=2)
        ia = interest.build_interest(g, 2, ints, caps)
        l2c_i, c2p_i = ia.size_entries()
        emit(f"table4/{ds}/iaCPQx_IT", us,
             f"IS={l2c_i + c2p_i} classes={ia.n_classes}")

        us = timeit(lambda: baselines.build_path(g, 2, caps=caps),
                    warmup=1, iters=2)
        pi = baselines.build_path(g, 2, caps=caps)
        emit(f"table4/{ds}/Path_IT", us, f"IS={pi.size_entries()}")

        us = timeit(lambda: baselines.build_path(g, 2, interests=ints,
                                                 caps=caps),
                    warmup=1, iters=2)
        iapi = baselines.build_path(g, 2, interests=ints, caps=caps)
        emit(f"table4/{ds}/iaPath_IT", us, f"IS={iapi.size_entries()}")

        # Thm. 4.2: CPQx never larger than Path; interest-aware smaller
        assert c2p <= pi.size_entries()
        assert l2c_i + c2p_i <= l2c + c2p
        jax.clear_caches()


if __name__ == "__main__":
    main()
