"""Deterministic behavior-sequence stream for BST training/serving."""

from __future__ import annotations

import numpy as np

from repro.models.recsys import BSTBatch, BSTConfig


def batch_at(cfg: BSTConfig, batch: int, step: int, seed: int = 0) -> BSTBatch:
    rng = np.random.default_rng((seed, step))
    f = cfg.n_context_fields
    # zipf-ish item popularity
    w = 1.0 / np.arange(1, cfg.n_items + 1) ** 1.1
    p = w / w.sum()
    items = rng.choice(cfg.n_items, size=(batch, cfg.seq_len), p=p)
    cats = (items % cfg.n_cats).astype(np.int64)
    ctx = rng.integers(0, cfg.n_context, batch * f)
    segs = np.repeat(np.arange(batch), f)
    # clicks correlate with matching category between target and history
    match = (cats[:, -1:] == cats[:, :-1]).mean(1)
    labels = (rng.random(batch) < (0.2 + 0.6 * match)).astype(np.int32)
    return BSTBatch(
        item_ids=items.astype(np.int32), cat_ids=cats.astype(np.int32),
        ctx_ids=ctx.astype(np.int32), ctx_segs=segs.astype(np.int32),
        labels=labels,
    )
