"""Distributed CPQx — the engine's pair tables sharded over a mesh axis,
with all_to_all hash repartitioning for joins (shard_map manual
collectives; DESIGN.md §5).

Data layout
-----------
A *sharded relation* is a Relation whose column arrays carry a leading
``shards`` axis sharded over the mesh: cols (n_shards, cap, ...), count
(n_shards,).  Rows live on the shard that owns their partition key
(``mix32(key) % n_shards``), except "replicated" relations (class-id
lists — small by the paper's central observation) which are identical on
every shard.

Operators (all inside one shard_map):
  * ``exchange``            fixed-capacity all_to_all bucket shuffle
  * ``sharded_join``        repartition by join key -> local expansion join
  * ``sharded_conjunction`` replicated class intersect -> sharded
                            materialize -> local intersection
  * ``build_level``         one level of Algorithm 1's path join at scale

The fixed bucket capacity is the static-shape contract: each exchange
moves (n_shards, bucket_cap, arity) per shard; overflow is flagged and
resolved by the ONE overflow-ladder contract specified in the
``core.backend`` module docstring — this module adds nothing to it
beyond psum-reducing the per-shard sticky flags so every shard and the
host agree on a retry.

Whole-plan execution
--------------------
:class:`ShardedBackend` promotes these operators to a full execution
backend: the *same* plan walker the local engine runs
(``core.backend.run_plan_ops``) executes inside ONE ``shard_map`` over
the mesh axis, against :class:`ShardedOps` — class-space relations
replicated, pair-space relations hash-partitioned by source vertex (the
canonical distribution: conjunctions and identity filters are then
exchange-free; a join repartitions its probe side by the join key and
its output back to canonical).  Per-shard sticky overflow flags are
psum-reduced so every shard — and the host — agrees on retry.

Planning (and the cost-based optimizer) stays a host concern: the
backend carries the replicated :class:`~repro.core.stats.IndexStats`
(``sharded_index.replicated_stats``) so any planner colocated with a
shard sees the exact statistics the local engine would — plans, and
therefore executables, are identical across backends.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from . import backend as B
from . import relational as R
from .paths import _recap
from .sharded_index import (
    ShardedIndexArrays,
    index_specs,
    partition_rows,
    replicated_stats,
    shard_index,
)

I32 = jnp.int32


# ---------------------------------------------------------------------- #
# local helpers (run per shard inside shard_map)
# ---------------------------------------------------------------------- #


def _bucket_of(key: jax.Array, n_shards: int) -> jax.Array:
    return (R.mix32(key, R.SHARD_SALT) % jnp.uint32(n_shards)).astype(I32)


def _pack_buckets(cols: tuple, valid: jax.Array, bucket: jax.Array,
                  n_shards: int, bucket_cap: int):
    """Arrange local rows into (n_shards, bucket_cap, arity) by bucket —
    sort by bucket then slot-gather (the MoE-dispatch pattern: no
    scatter).  Returns (packed cols tuple, per-bucket counts, overflow)."""
    cap = cols[0].shape[0]
    bkey = jnp.where(valid, bucket, n_shards)  # invalid -> trash bucket
    order = jax.lax.sort((bkey, jnp.arange(cap, dtype=I32)), num_keys=1,
                         is_stable=True)[1]
    sorted_cols = tuple(c[order] for c in cols)
    sorted_b = bkey[order]
    offs = jnp.searchsorted(sorted_b, jnp.arange(n_shards, dtype=I32),
                            side="left").astype(I32)
    ends = jnp.searchsorted(sorted_b, jnp.arange(n_shards, dtype=I32),
                            side="right").astype(I32)
    sizes = ends - offs
    overflow = jnp.any(sizes > bucket_cap)
    b = jnp.arange(n_shards * bucket_cap, dtype=I32) // bucket_cap
    slot = jnp.arange(n_shards * bucket_cap, dtype=I32) % bucket_cap
    src = jnp.clip(offs[b] + slot, 0, cap - 1)
    ok = slot < sizes[b]
    packed = tuple(
        jnp.where(ok, c[src], R.SENTINEL).reshape(n_shards, bucket_cap)
        for c in sorted_cols
    )
    return packed, jnp.minimum(sizes, bucket_cap).astype(I32), overflow


def _exchange(packed: tuple, counts: jax.Array, axis: str):
    """all_to_all: bucket b of shard s -> shard b.  packed cols are
    (n_shards, bucket_cap); returns (n_shards, bucket_cap) = one row-block
    from each peer, plus the per-peer counts."""
    out = tuple(
        jax.lax.all_to_all(c, axis, split_axis=0, concat_axis=0, tiled=True)
        for c in packed
    )
    cnt = jax.lax.all_to_all(counts, axis, split_axis=0, concat_axis=0,
                             tiled=True)
    return out, cnt


def _flatten_received(received: tuple, counts: jax.Array):
    """(n_shards, bucket_cap) blocks -> flat relation (sorted, compacted)."""
    flat = tuple(c.reshape(-1) for c in received)
    total = jnp.sum(counts)
    rel = R.Relation(flat, jnp.asarray(flat[0].shape[0], I32),
                     jnp.asarray(False))
    # SENTINEL-padded rows inside each block sort to the end
    rel = R.rel_sort(rel)
    return R.Relation(rel.cols, total.astype(I32), rel.overflow)


# ---------------------------------------------------------------------- #
# sharded operators
# ---------------------------------------------------------------------- #


def repartition(cols: tuple, count: jax.Array, key_col: int, n_shards: int,
                bucket_cap: int, axis: str):
    """Move every row to the shard owning hash(key).  Local view in/out.
    Returns (cols, count, overflow) with capacity n_shards*bucket_cap."""
    valid = jnp.arange(cols[0].shape[0], dtype=I32) < count
    bucket = _bucket_of(cols[key_col], n_shards)
    packed, sizes, ovf = _pack_buckets(cols, valid, bucket, n_shards,
                                       bucket_cap)
    received, cnt = _exchange(packed, sizes, axis)
    rel = _flatten_received(received, cnt)
    return rel.cols, rel.count, ovf | rel.overflow


def sharded_join_local(a_cols, a_count, b_cols, b_count, out_cap: int,
                       b_sorted: bool = False):
    """Local leg of the distributed join: both sides already partitioned
    by the join key (a's key col 1, b's key col 0).  ``b_sorted``: skip
    the build-side sort when the producer already emits sorted rows
    (repartition's _flatten_received does — §Perf iteration: the double
    sort was ~40% of the join's local traffic)."""
    a = R.Relation(a_cols, a_count, jnp.asarray(False))
    b = R.Relation(b_cols, b_count, jnp.asarray(False))
    if not b_sorted:
        b = R.rel_sort(b)
    out_cols = [("a", 0), ("b", 1)] + [("a", j) for j in range(2, len(a_cols))] \
        + [("b", j) for j in range(2, len(b_cols))]
    out = R.expansion_join(a, b, a_on=[1], out_cols=out_cols,
                           out_capacity=out_cap)
    out = R.rel_unique(R.rel_sort(out))
    return out.cols, out.count, out.overflow


# ---------------------------------------------------------------------- #
# jitted entry points (shard_map over one flat engine axis)
# ---------------------------------------------------------------------- #


def make_distributed_join(mesh, axis: str, n_shards: int, a_arity: int,
                          b_arity: int, bucket_cap: int, out_cap: int):
    """Factory: global (v,m,...) ⋈ (m,u,...) over one mesh axis.

    Inputs are sharded relations: cols (n_shards, cap), counts (n_shards,).
    Hash-repartitions both sides on the join key via all_to_all, joins
    locally, returns sharded output cols + counts + overflow.  This is
    Algorithm 1's level join at scale."""

    def body(ac, an, bc, bn):
        ac = tuple(c[0] for c in ac)
        bc = tuple(c[0] for c in bc)
        an, bn = an[0], bn[0]
        ac, an, ovf_a = repartition(ac, an, 1, n_shards, bucket_cap, axis)
        bc, bn, ovf_b = repartition(bc, bn, 0, n_shards, bucket_cap, axis)
        # b arrives fully sorted from the exchange (_flatten_received) —
        # skip the redundant build-side sort (§Perf engine iteration)
        oc, on, ovf_j = sharded_join_local(ac, an, bc, bn, out_cap,
                                           b_sorted=True)
        ovf = ovf_a | ovf_b | ovf_j
        return (tuple(c[None] for c in oc), on[None], ovf[None])

    spec = P(axis)
    out_arity = a_arity + b_arity - 2
    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(
            tuple(spec for _ in range(a_arity)), spec,
            tuple(spec for _ in range(b_arity)), spec,
        ),
        out_specs=(tuple(spec for _ in range(out_arity)), spec, spec),
    )
    return jax.jit(fn)


def shard_relation(rows: np.ndarray, n_shards: int, cap: int,
                   key_col: int | tuple = 0, grow: bool = True):
    """Host-side: partition rows by hash(key) into (n_shards, cap, arity)
    numpy blocks (the initial distribution of the pair table), each
    shard's rows sorted lexicographically.

    Vectorized — one lexsort + searchsorted boundaries + one flat
    scatter, no per-shard Python loop.  A shard outgrowing ``cap``
    doubles the block capacity and retries (the host-side twin of the
    device operators' flagged grow-and-retry); the returned blocks'
    ``shape[1]`` is the possibly-grown capacity.  ``grow=False`` restores
    the old fail-fast ``ValueError``.  ``key_col`` may be a tuple to
    hash-combine several columns (e.g. ``(0, 1)`` for the (v, u) pair
    table)."""
    key_cols = key_col if isinstance(key_col, tuple) else (key_col,)
    blocks, counts, _ = partition_rows(rows, n_shards, cap,
                                       key_cols=key_cols, grow=grow)
    return blocks, counts


# ---------------------------------------------------------------------- #
# distributed conjunction query step (the paper's hot query path at scale)
# ---------------------------------------------------------------------- #


def make_distributed_query_step(mesh, axis: str):
    """Returns a jitted step: (classes_a, classes_b replicated;
    c2p shards) -> sharded result pairs of (⟦q_a⟧ ∩ ⟦q_b⟧).

    Class intersection runs replicated (tiny — the paper's point);
    materialization runs sharded: each shard scans only its own slice of
    I_c2p, so result rows are produced where they live (zero shuffle)."""
    spec = P(axis)

    def body(ca, cb, c2p_cls, c2p_v, c2p_u, c2p_count):
        # ca/cb replicated (full) SENTINEL-padded sorted class lists
        c2p_cls, c2p_v, c2p_u = c2p_cls[0], c2p_v[0], c2p_u[0]
        n = c2p_count[0]
        ra = R.Relation((ca,), jnp.sum(ca != R.SENTINEL).astype(I32),
                        jnp.asarray(False))
        rb = R.Relation((cb,), jnp.sum(cb != R.SENTINEL).astype(I32),
                        jnp.asarray(False))
        inter = R.rel_intersect(ra, rb, 1)
        # local materialize: my slice of c2p filtered to surviving classes
        local = R.Relation((c2p_cls, c2p_v, c2p_u), n, jnp.asarray(False))
        keep = R.lex_count_matches((inter.cols[0],), (c2p_cls,),
                                   inter.count) > 0
        out = R.rel_compact(local, keep)
        return (out.cols[1][None], out.cols[2][None]), out.count[None]

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), spec, spec, spec, spec),
        out_specs=((spec, spec), spec),
    )
    return jax.jit(fn)


# ---------------------------------------------------------------------- #
# whole-plan sharded execution (the backend behind Engine(index, mesh=...))
# ---------------------------------------------------------------------- #


class ShardedOps(B.PlanOps):
    """The plan-operator protocol over one shard's local index view.

    Conventions (per relation kind):
      * class-space relations are **replicated** — every shard computes
        the identical sorted class list from the replicated l2c arrays,
        so LOOKUP / class-CONJUNCTION / IDENTITY-flag ops inherit the
        local math unchanged;
      * pair-space relations are **canonical sharded**: partitioned by
        ``mix32(v) % n_shards`` and locally sorted by (v, u).  Rows of a
        pair are globally unique, so concatenating shards reconstructs
        the exact local-engine relation.

    Producers restore the canonical distribution on exit: materialize
    expands the shard's own classes (I_c2p is class-hash sharded) and
    repartitions by v; a join repartitions its probe side by the join key
    (the build side is already keyed on v), joins locally, repartitions
    the output by v, and dedupes — the same (v, y) can be witnessed via
    intermediates on different shards.  Capacities are the *global*
    QueryCaps, so any answer the local engine can hold fits per shard
    too and the overflow ladder is shared."""

    def __init__(self, view: ShardedIndexArrays, n_vertices: int,
                 n_shards: int, axis: str):
        self.l2c_cls = view.l2c_cls
        self.class_starts = view.class_starts
        self.c2p_v = view.c2p_v
        self.c2p_u = view.c2p_u
        self.class_cyclic = view.class_cyclic
        self.n_vertices = n_vertices
        self.n_shards = n_shards
        self.axis = axis

    def _bucket_cap(self, pair_cap: int) -> int:
        """Exchange block capacity: ~2x the balanced per-peer share, so
        the received relation is capacity ~2*pair_cap per shard — flat in
        n_shards (memory *shards down* with the mesh instead of up).
        Hash skew past a block trips the sticky flag and rides the same
        double-and-retry ladder as every other capacity."""
        balanced = -(-2 * pair_cap // self.n_shards)  # ceil
        return min(pair_cap, 1 << (max(64, balanced) - 1).bit_length())

    def _canonical(self, rel: R.Relation, pair_cap: int,
                   unique: bool = False) -> R.Relation:
        """Repartition a pair relation by hash(v) and re-embed at
        ``pair_cap`` (exchange skew past a block or pair_cap trips the
        sticky flag)."""
        cols, cnt, ovf = repartition(rel.cols, rel.count, 0, self.n_shards,
                                     self._bucket_cap(pair_cap), self.axis)
        out = R.Relation(cols, cnt, rel.overflow | ovf)
        if unique:
            out = R.rel_unique(out)
        return _recap(out, pair_cap)

    def materialize(self, classes: R.Relation, pair_cap: int) -> R.Relation:
        local = super().materialize(classes, pair_cap)  # my classes only
        return self._canonical(local, pair_cap)

    def join_pairs(self, a: R.Relation, b: R.Relation, join_cap: int,
                   pair_cap: int) -> R.Relation:
        # probe side to the shard owning its join key u; the build side
        # is canonical — already partitioned by its key v
        ac, an, ovf = repartition(a.cols, a.count, 1, self.n_shards,
                                  self._bucket_cap(pair_cap), self.axis)
        a2 = R.Relation(ac, an, a.overflow | ovf)
        out = B._join_pairs(a2, b, join_cap, pair_cap)
        return self._canonical(out, pair_cap, unique=True)

    def identity_pairs(self, pair_cap: int) -> R.Relation:
        base = super().identity_pairs(pair_cap)
        mine = _bucket_of(base.cols[0], self.n_shards) == jax.lax.axis_index(
            self.axis)
        return R.rel_compact(base, mine)

    def finish(self, pairs: R.Relation):
        # every shard's sticky flag counts: reduce so the host (and all
        # shards) agree on retry with one scalar read
        ovf = jax.lax.psum(pairs.overflow.astype(I32), self.axis) > 0
        return pairs, ovf


class ShardedBackend(B.ExecutionBackend):
    """Whole-plan distributed execution: ``core.backend.run_plan_ops``
    — the exact walker the local engine compiles — inside one
    ``shard_map`` over ``axis``, against :class:`ShardedOps`.

    One executable per (plan shape, caps), cached; answers are gathered
    from the shards and lexsorted, which reproduces the local engine's
    output bit-for-bit (canonical pair rows are globally distinct)."""

    def __init__(self, sharded: ShardedIndexArrays, mesh, n_vertices: int,
                 axis: str = "engine", k: int | None = None):
        n_mesh = int(dict(mesh.shape)[axis])
        if sharded.n_shards != n_mesh:
            raise ValueError(
                f"index sharded {sharded.n_shards}-way but mesh axis "
                f"{axis!r} has {n_mesh} devices")
        self.sharded = sharded
        self.mesh = mesh
        self.axis = axis
        self.n_vertices = n_vertices
        self.n_shards = sharded.n_shards
        self.k = k
        self._stats = None  # lazy: see the `stats` property
        self._specs = index_specs(axis)
        self._cache: dict = {}

    @property
    def stats(self):
        """The optimizer's statistics, reconstructed lazily from the
        replicated leaves alone — identical to the local engine's (see
        ``sharded_index.replicated_stats``; ``Engine`` plans from the
        index it was bound to, so this view exists for planners that
        only hold the sharded layout — a migration target, a remote
        planner — and for the parity tests).  None when ``k`` is
        unknown; invalidated by ``reshard``."""
        if self._stats is None and self.k is not None:
            self._stats = replicated_stats(self.sharded, self.n_vertices,
                                           self.k)
        return self._stats

    @classmethod
    def from_index(cls, index, mesh, axis: str = "engine") -> "ShardedBackend":
        n_shards = int(dict(mesh.shape)[axis])
        return cls(shard_index(index, n_shards), mesh, index.n_vertices,
                   axis=axis, k=index.k)

    # ---------------------- lifecycle (checkpoint) --------------------- #

    def save(self, ckpt_dir: str, step: int = 0) -> str:
        """Snapshot the per-shard leaves + layout metadata as one atomic
        committed step (see :mod:`repro.core.lifecycle`)."""
        from .lifecycle import save_sharded  # lazy: one-way dependency

        return save_sharded(self.sharded, self.n_vertices, self.k,
                            ckpt_dir, step)

    @classmethod
    def restore(cls, ckpt_dir: str, mesh, step: int | None = None,
                axis: str = "engine") -> "ShardedBackend":
        """Rebuild a live backend on ``mesh`` from a saved step.  If the
        mesh axis size differs from the saved shard count the leaves are
        resharded (``gather_index`` -> ``shard_index``) — elastic
        restore at any scale."""
        from .lifecycle import restore_sharded_backend

        return restore_sharded_backend(ckpt_dir, mesh, step, axis=axis)

    def reshard(self, index) -> None:
        """Re-shard a flushed/rebuilt index *into this backend* so the
        compiled executables survive a maintenance rebind: the cached
        shard_map functions take the arrays as arguments, so as long as
        the shard capacities are stable (they derive from the flush
        capacities) the new arrays hit the existing traces.  The cache
        must drop only when ``n_vertices`` moves — it is baked into the
        traced bodies (IDENTITY).  The replicated statistics view is
        invalidated with the arrays, mirroring ``Engine.rebind``."""
        self.sharded = shard_index(index, self.n_shards)
        self.k = index.k
        self._stats = None
        if index.n_vertices != self.n_vertices:
            self.n_vertices = index.n_vertices
            self._cache.clear()

    def _compiled(self, shape, caps):
        key = (shape, caps)
        fn = self._cache.get(key)
        if fn is not None:
            return fn
        n_shards, axis, n_vertices = self.n_shards, self.axis, self.n_vertices
        specs = self._specs

        def body(arrs: ShardedIndexArrays, ranges):
            local = ShardedIndexArrays(*[
                leaf[0] if spec == P(axis) else leaf
                for leaf, spec in zip(arrs, specs)])
            ops = ShardedOps(local, n_vertices, n_shards, axis)
            pairs, ovf = B.run_plan_ops(ops, shape, caps, ranges)
            return (tuple(c[None] for c in pairs.cols), pairs.count[None],
                    ovf[None])

        sh = P(axis)
        fn = jax.jit(compat.shard_map(
            body, mesh=self.mesh, in_specs=(specs, P()),
            out_specs=((sh, sh), sh, sh)))
        self._cache[key] = fn
        return fn

    def run(self, shape, caps: B.QueryCaps, ranges: np.ndarray):
        fn = self._compiled(shape, caps)
        with compat.set_mesh(self.mesh):
            cols, counts, ovf = fn(self.sharded, jnp.asarray(ranges))
        if np.asarray(ovf).any():
            return None, True
        return self._gather_rows(cols, counts), False

    def run_batch(self, shape, caps: B.QueryCaps, ranges: np.ndarray):
        # lanes share one compiled executable; each dispatches its own
        # shard_map (collectives don't vmap portably across jax versions)
        results, overflow = [], []
        for lane in range(ranges.shape[0]):
            rows, ovf = self.run(shape, caps, ranges[lane])
            results.append(rows)
            overflow.append(ovf)
        return results, np.asarray(overflow, bool)

    def _gather_rows(self, cols, counts) -> np.ndarray:
        v, u = np.asarray(cols[0]), np.asarray(cols[1])
        cnt = np.asarray(counts)
        rows = np.concatenate([
            np.stack([v[s, :cnt[s]], u[s, :cnt[s]]], axis=1)
            for s in range(self.n_shards)]) if self.n_shards else \
            np.zeros((0, 2), np.int32)
        return rows[np.lexsort((rows[:, 1], rows[:, 0]))]
