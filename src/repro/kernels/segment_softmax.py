"""Pallas TPU kernel: fused segment-softmax normalization — the GNN
substrate hot spot (GatedGCN edge gates; GAT-style edge attention).

Segment softmax over E edge scores grouped by destination node:
    out_e = exp(x_e - max_{e' in seg(e)} x_{e'}) / sum_{e'} exp(...)

The two segment reductions (max, sum-of-exp) stay in XLA (segment ops
lower to efficient sorted-segment reductions); the *normalization* pass —
two gathers, one exp, one divide over E elements and D feature lanes —
is the fused kernel: one VMEM pass instead of four HBM round-trips.

Layout: scores are (E, D) with D vector-lane-aligned (gates per feature
channel for GatedGCN; D=1 for scalar attention).  Segment tables
(max/denominator, (N, D)) are VMEM-resident blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_E = 512


def _norm_kernel(x_ref, seg_ref, mx_ref, den_ref, o_ref, *, eps: float):
    x = x_ref[...]  # (block_e, D)
    seg = seg_ref[...]  # (block_e,)
    mx = mx_ref[...]  # (N, D)
    den = den_ref[...]  # (N, D)
    n = mx.shape[0]
    s = jnp.clip(seg, 0, n - 1)
    o_ref[...] = jnp.exp(x - mx[s]) / (den[s] + eps)


@functools.partial(jax.jit, static_argnames=("num_segments", "block_e", "eps"))
def segment_softmax(
    scores: jax.Array,  # (E, D) float32/bfloat16
    segment_ids: jax.Array,  # (E,) int32, values in [0, num_segments)
    num_segments: int,
    block_e: int = DEFAULT_BLOCK_E,
    eps: float = 1e-9,
) -> jax.Array:
    """Numerically-stable segment softmax along axis 0."""
    e, d = scores.shape
    seg = segment_ids.astype(jnp.int32)
    mx = jax.ops.segment_max(scores, seg, num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)  # empty segments
    ex = jnp.exp(scores - mx[jnp.clip(seg, 0, num_segments - 1)])
    den = jax.ops.segment_sum(ex, seg, num_segments)

    blk = min(block_e, e)
    assert e % blk == 0, (e, blk)
    kernel = functools.partial(_norm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((e, d), scores.dtype),
        grid=(e // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((blk,), lambda i: (i,), memory_space=pltpu.VMEM),
            pl.BlockSpec((num_segments, d), lambda i: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((num_segments, d), lambda i: (0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0),
                               memory_space=pltpu.VMEM),
        interpret=jax.default_backend() == "cpu",
    )(scores, seg, mx, den)
