"""The paper's contribution: CPQ-aware path indexing (CPQx / iaCPQx),
the capacity-padded relational substrate, the backend-agnostic query
engine (``backend`` — local; ``distributed`` — whole plans inside
shard_map over a ``sharded_index`` layout), lazy maintenance, baselines,
and the semantics oracle."""
