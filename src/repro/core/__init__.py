"""The paper's contribution: CPQ-aware path indexing (CPQx / iaCPQx),
the capacity-padded relational substrate, the device query engine, lazy
maintenance, baselines, the semantics oracle, and shard_map distribution."""
