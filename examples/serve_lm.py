"""Batched-request LM serving example: slot-based continuous batching
over the gemma2 (smoke) model — requests arrive, claim slots, decode at
their own positions, and finished slots are reused immediately.

    PYTHONPATH=src python examples/serve_lm.py
"""

import subprocess
import sys


def main() -> None:
    raise SystemExit(subprocess.call([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "gemma2-2b", "--requests", "6", "--slots", "3",
        "--max-new", "8", "--max-len", "48",
    ]))


if __name__ == "__main__":
    main()
