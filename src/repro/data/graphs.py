"""Labeled-graph generators for the engine benchmarks.

``gmark_citation`` mirrors the paper's synthetic scalability datasets
(Sec. VI "Datasets"): citation networks with three vertex types
(researcher, venue, city) and six edge labels — cites, supervises,
livesIn, worksIn, publishesIn, heldIn — with the same roles/directions.
``powerlaw_graph`` models the SNAP-style unlabeled graphs with
exponentially distributed labels (lambda = 0.5, as the paper assigns to
ego-Facebook / WebGoogle / WikiTalk / CitPatents)."""

from __future__ import annotations

import numpy as np

from repro.core.graph import LabeledGraph

CITATION_LABELS = ("cites", "supervises", "livesIn", "worksIn",
                   "publishesIn", "heldIn")


def gmark_citation(n_vertices: int, avg_degree: float = 8.0,
                   seed: int = 0) -> LabeledGraph:
    """gMark-style citation schema.  Vertex roles: 80% researchers, 15%
    venues, 5% cities.  Labels target the right role pairs."""
    rng = np.random.default_rng(seed)
    n_res = int(n_vertices * 0.80)
    n_ven = int(n_vertices * 0.15)
    n_city = n_vertices - n_res - n_ven
    res = np.arange(n_res)
    ven = np.arange(n_res, n_res + n_ven)
    city = np.arange(n_res + n_ven, n_vertices)
    m = int(n_vertices * avg_degree / 2)

    def pick(pool, size, zipf=False):
        if zipf:
            # preferential attachment-ish: zipf-weighted choice
            w = 1.0 / (np.arange(1, len(pool) + 1) ** 0.8)
            w /= w.sum()
            return rng.choice(pool, size=size, p=w)
        return rng.choice(pool, size=size)

    edges = []
    # cites: researcher -> researcher (zipf targets: famous papers)
    k = int(m * 0.45)
    edges.append(np.stack([pick(res, k), pick(res, k, zipf=True),
                           np.full(k, 0)], 1))
    # supervises: researcher -> researcher
    k = int(m * 0.1)
    edges.append(np.stack([pick(res, k), pick(res, k), np.full(k, 1)], 1))
    # livesIn / worksIn: researcher -> city
    k = int(m * 0.1)
    edges.append(np.stack([pick(res, k), pick(city, k), np.full(k, 2)], 1))
    k = int(m * 0.1)
    edges.append(np.stack([pick(res, k), pick(city, k), np.full(k, 3)], 1))
    # publishesIn: researcher -> venue (zipf: big venues)
    k = int(m * 0.2)
    edges.append(np.stack([pick(res, k), pick(ven, k, zipf=True),
                           np.full(k, 4)], 1))
    # heldIn: venue -> city
    k = max(1, int(m * 0.05))
    edges.append(np.stack([pick(ven, k), pick(city, k), np.full(k, 5)], 1))
    e = np.concatenate(edges, 0)
    return LabeledGraph.from_edges(n_vertices, 6, e,
                                   label_names=CITATION_LABELS)


def powerlaw_graph(n_vertices: int, n_edges: int, n_labels: int = 8,
                   seed: int = 0, label_lambda: float = 0.5) -> LabeledGraph:
    """Preferential-attachment-ish labeled graph; labels exponentially
    distributed (lambda=0.5), following the paper's SNAP preparation."""
    rng = np.random.default_rng(seed)
    w = 1.0 / (np.arange(1, n_vertices + 1) ** 0.9)
    w /= w.sum()
    src = rng.choice(n_vertices, size=n_edges, p=w)
    dst = rng.choice(n_vertices, size=n_edges)
    lbl = np.minimum(
        rng.exponential(1.0 / label_lambda, n_edges).astype(np.int64),
        n_labels - 1,
    )
    e = np.stack([src, dst, lbl], 1)
    return LabeledGraph.from_edges(n_vertices, n_labels, e)


def skewed_labeled_graph(n_vertices: int = 160, n_labels: int = 6,
                         wave: int = 50, rare_edges: int = 40,
                         seed: int = 0) -> LabeledGraph:
    """Hub-and-spoke *label-skewed* graph — the optimizer's adversarial
    workload (and the regime real knowledge graphs live in: a couple of
    hub predicates carry almost all edges).

    Label 0 ("hub") is three complete bipartite waves over vertex groups
    A -> B -> C -> A of ``wave`` vertices each, so hub sequences are
    enormous in *pair* space (``p(0) = 3·wave²``, ``p(0,0)`` likewise)
    while the *class* space stays tiny — within a wave every pair is
    k-path-bisimilar, which is exactly the paper's size asymmetry.
    Labels 1..5 are rare (``rare_edges`` each) and placed so the Fig. 5
    conjunction templates keep non-empty answers:

    * label 1 — direct A -> C edges (chords of hub 2-paths: triangles
      ``(0.0) & 1`` close);
    * labels 2, 3 — an A -> pool -> C bridge through 5 shared B-pool
      vertices (squares ``(0.0) & (2.3)`` close, and ``(0, 2)`` is a far
      smaller segment than ``(1, 0)`` — the split-choice material);
    * labels 4, 5 — parallel copies of a shared pool of hub edges plus
      random A -> B edges (multi-label stars ``0 & 4 & 5`` are
      non-empty).

    A syntactic planner sizes every one of these queries off its
    *largest* lookup (a hub sequence) while the true answer tracks the
    *smallest* conjunct (a rare label); the cost-based optimizer closes
    that gap, and ``benchmarks/bench_query.py`` gates a >= 2x win here."""
    if n_labels < 6 or n_vertices < 3 * wave:
        raise ValueError("need n_labels >= 6 and n_vertices >= 3*wave")
    rng = np.random.default_rng(seed)
    A = np.arange(0, wave)
    B = np.arange(wave, 2 * wave)
    C = np.arange(2 * wave, 3 * wave)

    def complete(src_pool, dst_pool):
        s, d = np.meshgrid(src_pool, dst_pool, indexing="ij")
        return np.stack([s.ravel(), d.ravel(),
                         np.zeros(s.size, np.int64)], 1)

    def sample(src_pool, dst_pool, lbl, n):
        return np.stack([rng.choice(src_pool, n), rng.choice(dst_pool, n),
                         np.full(n, lbl)], 1)

    hub = np.concatenate([complete(A, B), complete(B, C), complete(C, A)])
    b_pool = B[:5]  # the S-template bridge vertices
    par_pool = complete(A, B)[: 20]  # shared hub edges for parallel labels
    n_par = max(1, rare_edges // 3)

    def parallel(lbl):
        par = par_pool[rng.integers(0, len(par_pool), n_par)].copy()
        par[:, 2] = lbl
        return np.concatenate([par, sample(A, B, lbl, rare_edges - n_par)])

    edges = np.concatenate([
        hub,
        sample(A, C, 1, rare_edges),  # triangle chords
        sample(A, b_pool, 2, rare_edges),  # square bridge, first hop
        sample(b_pool, C, 3, rare_edges),  # square bridge, second hop
        parallel(4), parallel(5),
    ])
    return LabeledGraph.from_edges(n_vertices, n_labels, edges)


def drifting_workload(g: LabeledGraph, phases, n_per_phase: int,
                      hot_fraction: float = 0.85, seed: int = 0,
                      tenants=None):
    """A phased query stream whose hot set *drifts* — the adaptive
    iaCPQx benchmark workload (and the regime adaptive indexing exists
    for: traffic concentrates on a few templates, then moves).

    ``phases`` is a list of phases, each a list of ``(template_name,
    labels)`` hot templates.  Every phase yields ``n_per_phase`` queries:
    a ``hot_fraction`` share drawn uniformly from the phase's hot
    templates (the repetition IS the signal a workload sketch must
    catch) and the rest background noise — random Fig. 5 templates over
    labels present in the graph, so the miner has to *reject* plausible
    but cold sequences, not just rank the only thing it ever saw.

    Returns a list of per-phase query lists (deterministic in ``seed``).

    **Multi-tenant mode** (``tenants`` set): ``tenants`` maps a tenant
    name to ``(phases, weight)`` — its own drifting hot-template
    schedule (every tenant must have the same phase count; ``phases``
    is ignored, pass ``None``) and its share of the traffic.  Each
    phase then yields ``n_per_phase`` ``(tenant, query)`` pairs, the
    tenant of each slot drawn by weight, its query drawn from that
    tenant's hot set for the phase — interleaved traffic whose hot
    sets differ per tenant AND drift over time, which is exactly what
    per-tenant sketches exist to keep apart."""
    from repro.core.query import TEMPLATE_ARITY, instantiate_template

    rng = np.random.default_rng(seed)
    present = np.unique(g.lbl)
    names = sorted(TEMPLATE_ARITY)

    def draw(hot):
        if rng.random() < hot_fraction:
            name, labels = hot[int(rng.integers(0, len(hot)))]
            return instantiate_template(name, list(labels))
        name = names[int(rng.integers(0, len(names)))]
        labels = rng.choice(present, TEMPLATE_ARITY[name]).tolist()
        return instantiate_template(name, labels)

    if tenants is None:
        return [[draw(hot) for _ in range(n_per_phase)] for hot in phases]

    tnames = sorted(tenants)
    n_phases = {len(tenants[t][0]) for t in tnames}
    if len(n_phases) != 1:
        raise ValueError("every tenant needs the same number of phases")
    weights = np.array([float(tenants[t][1]) for t in tnames])
    weights = weights / weights.sum()
    out = []
    for pi in range(n_phases.pop()):
        slot = []
        for _ in range(n_per_phase):
            t = tnames[int(rng.choice(len(tnames), p=weights))]
            slot.append((t, draw(tenants[t][0][pi])))
        out.append(slot)
    return out


def random_queries_for_graph(g: LabeledGraph, template_names, n_per: int,
                             seed: int = 0):
    """The paper's query workload: per template, n queries with random
    labels drawn from sequences that actually occur (so intermediate
    results are non-empty 'mostly', Sec. VI)."""
    from repro.core.query import TEMPLATE_ARITY, instantiate_template

    rng = np.random.default_rng(seed)
    present = np.unique(g.lbl)
    out = []
    for name in template_names:
        for _ in range(n_per):
            labels = rng.choice(present, TEMPLATE_ARITY[name]).tolist()
            out.append((name, instantiate_template(name, labels)))
    return out
