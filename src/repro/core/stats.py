"""Host-side statistics view over a built CPQx/iaCPQx index.

The index already *is* a statistics store: the ``I_l2c`` row range of a
label sequence gives its exact class count, and the ``I_c2p`` CSR
offsets give the exact pair count of every class.  This module pulls
those few-KB arrays to the host ONCE per bind/rebind and turns them into
O(1) per-sequence cardinality queries via two prefix sums over the l2c
rows — the raw material of the cost-based optimizer
(:mod:`repro.core.optimizer`) and of the engine's capacity estimator.

Three constructors cover every index form in the repo:

* :meth:`IndexStats.from_index` — a device :class:`~repro.core.index.CPQxIndex`
  (one device sync; called by ``Engine.rebind``, so maintenance flushes
  refresh the statistics automatically);
* :meth:`IndexStats.from_host_arrays` — raw numpy arrays; used by
  :func:`repro.core.sharded_index.replicated_stats` to derive the same
  view from a sharded layout's replicated leaves (sharded planning must
  match local planning bit-for-bit);
* :meth:`IndexStats.from_oracle` — the dict-form ``oracle.Index`` mirror,
  keeping the optimizer testable without jax.

Since PR 5 the view also carries the *pair columns* of ``I_c2p`` (when
the constructor has them), which unlock per-sequence **endpoint
statistics** — distinct sources/targets and max out/in fanout — computed
lazily per queried sequence and cached (:meth:`IndexStats.seq_endpoints`).
These refine the optimizer's join cardinalities from the uniform
``|A|·|B| / |V|`` guess to the classic distinct-value estimate with
sound fanout upper bounds, which is what keeps skewed hub fanout from
laddering the capacity retry schedule.

This module is host-only: numpy, no jax import.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


class SeqEndpoints(NamedTuple):
    """Endpoint statistics of one sequence's pair set (all exact)."""

    d_src: int  # distinct source endpoints
    d_dst: int  # distinct target endpoints
    max_out: int  # max pairs sharing one source (out-fanout)
    max_in: int  # max pairs sharing one target (in-fanout)


@dataclasses.dataclass
class IndexStats:
    """Exact per-sequence cardinalities of one index snapshot.

    ``seq_ranges`` maps a label-sequence tuple to its (lo, hi) row range
    in the l2c class column; the three cumulative arrays turn any range
    into class / pair / cyclic-pair counts in O(1).
    """

    n_vertices: int
    n_classes: int
    total_pairs: int
    seq_ranges: dict
    class_sizes: np.ndarray  # (>= n_classes,) pairs per class id
    l2c_cls: np.ndarray  # (l2c_count,) valid l2c class-column rows
    _pairs_cum: np.ndarray  # (l2c_count + 1,) prefix sum of row class sizes
    _cyc_cum: np.ndarray  # (l2c_count + 1,) same, cyclic classes only
    # I_c2p, host-side: class CSR + pair columns sorted by (class, v, u).
    # The columns are *lazy*: constructors pass a zero-arg fetch callable
    # and nothing is pulled off device (or reassembled from shards) until
    # the first seq_endpoints() call — a rebind that never prices a join
    # stays a few-KB sync.  A view built with neither columns nor fetch
    # degrades seq_endpoints() to None (the uniform assumption).
    _class_starts: np.ndarray | None = None
    _c2p_v: np.ndarray | None = None
    _c2p_u: np.ndarray | None = None
    _c2p_fetch: object = None  # () -> (c2p_v, c2p_u), resolved once
    _endpoints: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #

    @classmethod
    def from_host_arrays(
        cls,
        *,
        n_vertices: int,
        n_classes: int,
        total_pairs: int,
        seq_ranges: dict,
        class_starts: np.ndarray,
        l2c_cls: np.ndarray,
        l2c_count: int,
        class_cyclic: np.ndarray,
        c2p_v: np.ndarray | None = None,
        c2p_u: np.ndarray | None = None,
        c2p_fetch=None,
    ) -> "IndexStats":
        starts = np.asarray(class_starts, np.int64)
        sizes = starts[1:] - starts[:-1]
        cyc = np.asarray(class_cyclic, np.int64)
        rows = np.asarray(l2c_cls, np.int64)[: int(l2c_count)]
        safe = np.clip(rows, 0, sizes.shape[0] - 1)
        row_sizes = np.where(rows < sizes.shape[0], sizes[safe], 0)
        row_cyc = row_sizes * np.where(rows < cyc.shape[0], cyc[safe], 0)
        zero = np.zeros(1, np.int64)
        return cls(
            n_vertices=int(n_vertices),
            n_classes=int(n_classes),
            total_pairs=int(total_pairs),
            seq_ranges=dict(seq_ranges),
            class_sizes=sizes,
            l2c_cls=rows,
            _pairs_cum=np.concatenate([zero, np.cumsum(row_sizes)]),
            _cyc_cum=np.concatenate([zero, np.cumsum(row_cyc)]),
            _class_starts=starts,
            _c2p_v=None if c2p_v is None else np.asarray(c2p_v, np.int64),
            _c2p_u=None if c2p_u is None else np.asarray(c2p_u, np.int64),
            _c2p_fetch=c2p_fetch,
        )

    @classmethod
    def from_index(cls, index) -> "IndexStats":
        """Pull the statistics mirrors off a :class:`~repro.core.index.
        CPQxIndex` (a few KB; the one device sync of a rebind)."""
        a = index.arrays
        return cls.from_host_arrays(
            n_vertices=index.n_vertices,
            n_classes=int(a.n_classes),
            total_pairs=int(a.pair_count),
            seq_ranges=index.seq_ranges,
            class_starts=np.asarray(a.class_starts),
            l2c_cls=np.asarray(a.l2c_cls),
            l2c_count=int(a.l2c_count),
            class_cyclic=np.asarray(a.class_cyclic),
            # deferred: the pair columns are O(pair_cap), not "a few KB"
            # — only a seq_endpoints() call (pricing a join) pays for
            # the device pull, not every rebind
            c2p_fetch=lambda: (np.asarray(a.c2p_v), np.asarray(a.c2p_u)),
        )

    @classmethod
    def from_oracle(cls, oindex, n_vertices: int) -> "IndexStats":
        """Build the same view from the dict-form ``oracle.Index`` (or a
        :class:`~repro.core.maintenance.MaintainableIndex` mirror).  Class
        ids are densified in ascending order, exactly like
        ``index.from_host_mirror``, so the derived statistics match a
        flush of the same mirror."""
        ids = sorted(c for c, ps in oindex.c2p.items() if ps)
        remap = {c: i for i, c in enumerate(ids)}
        sizes = np.array([len(oindex.c2p[c]) for c in ids] or [0], np.int64)
        cyclic = np.array(
            [1 if oindex.cyclic[c] else 0 for c in ids] or [0], np.int64)
        seq_ranges: dict = {}
        flat: list[int] = []
        for s in sorted(tuple(t) for t in oindex.l2c):
            lo = len(flat)
            flat.extend(sorted(remap[c] for c in oindex.l2c[s] if c in remap))
            seq_ranges[s] = (lo, len(flat))
        c2p = {c: list(oindex.c2p[c]) for c in ids}  # snapshot: the
        # mirror may mutate after this view is taken

        def fetch():
            rows = [p for c in ids for p in c2p[c]]
            return (np.array([p[0] for p in rows] or [0], np.int64),
                    np.array([p[1] for p in rows] or [0], np.int64))

        return cls.from_host_arrays(
            n_vertices=n_vertices,
            n_classes=len(ids),
            total_pairs=int(sizes.sum()) if ids else 0,
            seq_ranges=seq_ranges,
            class_starts=np.concatenate([np.zeros(1, np.int64),
                                         np.cumsum(sizes)]),
            l2c_cls=np.asarray(flat, np.int64),
            l2c_count=len(flat),
            class_cyclic=cyclic,
            c2p_fetch=fetch,
        )

    # ------------------------------------------------------------------ #
    # O(1) per-sequence cardinalities (all exact)
    # ------------------------------------------------------------------ #

    def has_seq(self, seq) -> bool:
        return tuple(seq) in self.seq_ranges

    def seq_classes(self, seq) -> int:
        """Number of classes in the sequence's l2c list (LOOKUP output)."""
        lo, hi = self.seq_ranges.get(tuple(seq), (0, 0))
        return hi - lo

    def seq_pairs(self, seq) -> int:
        """Total s-t pairs across the sequence's classes — the exact size
        of materializing this LOOKUP."""
        lo, hi = self.seq_ranges.get(tuple(seq), (0, 0))
        return int(self._pairs_cum[hi] - self._pairs_cum[lo])

    def seq_cyclic_pairs(self, seq) -> int:
        """Pairs in cycle-pure classes only — the exact size of
        ``lookup(seq) ∩ id`` (classes are cycle-pure by construction)."""
        lo, hi = self.seq_ranges.get(tuple(seq), (0, 0))
        return int(self._cyc_cum[hi] - self._cyc_cum[lo])

    def seq_endpoints(self, seq) -> SeqEndpoints | None:
        """Exact endpoint statistics of the sequence's pair set — distinct
        sources/targets and max out/in fanout — or None when this view was
        built without the pair columns (the optimizer then falls back to
        the uniform-endpoint assumption).

        One vectorized gather over the sequence's class ranges in the
        ``I_c2p`` pair columns (fetched off device on the FIRST call,
        not at rebind), computed lazily per queried sequence and cached
        for the life of this snapshot (a rebind rebuilds the view, so
        the cache can never serve stale statistics).  Classes partition
        the pair space, so the gather is a disjoint union and the
        distinct counts over it are exact."""
        if self._c2p_v is None:
            if self._c2p_fetch is None:
                return None
            v, u = self._c2p_fetch()
            self._c2p_v = np.asarray(v, np.int64)
            self._c2p_u = np.asarray(u, np.int64)
            self._c2p_fetch = None
        seq = tuple(seq)
        hit = self._endpoints.get(seq)
        if hit is not None:
            return hit
        lo, hi = self.seq_ranges.get(seq, (0, 0))
        cls = self.l2c_cls[lo:hi]
        cls = cls[cls < self.class_sizes.shape[0]]
        if cls.size == 0:
            res = SeqEndpoints(0, 0, 0, 0)
        else:
            s_, e_ = self._class_starts[cls], self._class_starts[cls + 1]
            lens = e_ - s_
            offs = np.concatenate(
                [np.zeros(1, np.int64), np.cumsum(lens)[:-1]])
            idx = np.repeat(s_ - offs, lens) + np.arange(int(lens.sum()))
            vs, us = self._c2p_v[idx], self._c2p_u[idx]
            _, out_cnt = np.unique(vs, return_counts=True)
            _, in_cnt = np.unique(us, return_counts=True)
            res = SeqEndpoints(
                d_src=int(out_cnt.shape[0]), d_dst=int(in_cnt.shape[0]),
                max_out=int(out_cnt.max(initial=0)),
                max_in=int(in_cnt.max(initial=0)))
        self._endpoints[seq] = res
        return res

    # ------------------------------------------------------------------ #
    # checkpoint codec for the endpoint cache — a restored engine starts
    # with the donor's priced sequences pre-warmed, so the first query
    # after a warm restart plans without a device pull.
    # ------------------------------------------------------------------ #

    def export_endpoints(self) -> np.ndarray | None:
        """Cached ``seq_endpoints`` results as int64 rows
        ``[seq padded with -1 | d_src d_dst max_out max_in]``; None when
        nothing has been priced yet."""
        if not self._endpoints:
            return None
        width = max(len(s) for s in self._endpoints)
        rows = [list(s) + [-1] * (width - len(s)) + list(e)
                for s, e in self._endpoints.items()]
        return np.asarray(rows, np.int64).reshape(-1, width + 4)

    def seed_endpoints(self, rows) -> None:
        """Pre-warm the endpoint cache from :meth:`export_endpoints` rows.
        Only sequences still present in this snapshot are accepted — a
        stale row from another index cannot poison the cache."""
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return
        for row in rows.reshape(rows.shape[0], -1):
            seq = tuple(int(x) for x in row[:-4] if x >= 0)
            if seq in self.seq_ranges:
                self._endpoints[seq] = SeqEndpoints(
                    *(int(x) for x in row[-4:]))
