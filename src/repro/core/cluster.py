"""Cluster runtime — persistent multi-process CPQx serving over a typed
instruction stream.

``ShardedBackend`` (``core.distributed``) proved the distributed *math*:
the one plan walker over hash-partitioned pair relations, exchanges on
materialize/join, per-shard sticky overflow flags reduced so every party
agrees on retry.  But it lives in one process — ``shard_map`` over fake
devices is a contract check, not scale-out.  This module ports exactly
that math to a coordinator + N persistent **worker processes**:

* **instruction stream** — the coordinator drives workers over per-worker
  ``multiprocessing`` queues with typed instructions
  (:data:`EXECUTE_BATCH`, :data:`DISPATCH`/:data:`HARVEST` for the
  service's pipelined drain, :data:`FLUSH_REBIND` /
  :data:`INTEREST_BATCH` / :data:`RESHARD` for the write path,
  :data:`CHECKPOINT`, :data:`PROMOTE`, :data:`SHUTDOWN`).  Every
  instruction carries a monotone sequence number; replies return on one
  shared result queue tagged with it.
* **shard ownership** — worker *r* holds rank r's slice of
  ``sharded_index.shard_index(index, n)``: its c2p rows + per-shard CSR,
  plus the replicated class-space metadata.  Pair relations are
  canonical-sharded by ``mix32(v) % n`` exactly as in ``ShardedOps`` —
  the per-worker partitions are globally disjoint, so the coordinator's
  rank-order concat + lexsort reproduces the local engine's answer
  bit-for-bit.
* **SPMD plan walk, queue exchange** — every worker executes the same
  ``core.backend.run_plan_ops`` walk against :class:`ClusterOps`, whose
  repartitions are host-mediated: bucket rows with the numpy twin of the
  device hash (``sharded_index.hash_buckets``) and swap them peer-to-peer
  over an :class:`ExchangeFabric` of ``mp.Queue`` pairs.  The exchange
  count is a function of the plan *shape* only (overflow is sticky data,
  never control flow), so workers stay in lockstep; messages are tagged
  ``(seq, xid)`` and stale tags from aborted rounds are dropped on
  receipt.
* **singleton executable cache** — the heavy local operators are
  module-level ``jax.jit`` kernels keyed on static capacities, so each
  worker process compiles an operator once per (op, caps) for its
  lifetime; a plan shape's first execution warms every kernel it touches
  and every later execution — and every retry rung, which lands on the
  power-of-two caps ladder — hits the cache.
* **fault tolerance** — liveness is heartbeats (a shared double each
  worker refreshes from a daemon thread) plus ``Process.is_alive``.  On a
  death the coordinator aborts the round (a shared event every blocked
  exchange polls), waits for all live workers to settle, drains the
  fabric, respawns the dead rank, and :data:`PROMOTE`\\ s it from the
  latest committed checkpoint (``core.lifecycle``) plus a replay of the
  state-instruction suffix logged since — then re-issues the interrupted
  instruction under a fresh sequence number.  Queries are pure functions
  of (slice state, instruction), so re-execution is answer-identical.
* **serializability across processes** — the coordinator is the single
  writer: the host mirror lives with it, and every flush/rebind or
  interest round is ONE state instruction broadcast under one sequence
  number and acknowledged by every worker before any later read
  dispatches.  Per-worker queues are FIFO, so each worker observes the
  coordinator's total order; reads between two state instructions
  execute against exactly the earlier state on every worker.  The
  :data:`CHECKPOINT` barrier asserts the invariant: all workers must
  report the coordinator's state epoch.

:class:`ClusterBackend` packages the runtime as an ordinary
``core.backend.ExecutionBackend`` (``Engine(index, cluster=n)``), so the
service layer — caches, tenancy, admission control, the RPQ fixpoint —
runs unchanged on a process fleet.
"""

from __future__ import annotations

import contextlib
import functools
import multiprocessing as mp
import queue as _queue
import time
from collections import Counter, OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import backend as B
from . import relational as R
from .paths import _recap
from .sharded_index import hash_buckets, shard_index


# ---------------------------------------------------------------------- #
# the instruction set
# ---------------------------------------------------------------------- #

EXECUTE_BATCH = "EXECUTE_BATCH"  # run lanes synchronously, reply rows
DISPATCH = "DISPATCH"  # run lanes, buffer results under a batch id
HARVEST = "HARVEST"  # reply a buffered batch (None if not held)
FLUSH_REBIND = "FLUSH_REBIND"  # install a new shard slice (maintenance)
INTEREST_BATCH = "INTEREST_BATCH"  # slice install from an interest round
CHECKPOINT = "CHECKPOINT"  # barrier: ack + report the state epoch
PROMOTE = "PROMOTE"  # (re)build worker state: base + replay suffix
RESHARD = "RESHARD"  # slice install that also moves n_shards
SHUTDOWN = "SHUTDOWN"  # ack and exit the worker loop
CRASH = "CRASH"  # test-only fault injection: hard-exit the process

#: instructions that mutate worker state — logged for respawn replay
STATE_KINDS = frozenset({FLUSH_REBIND, INTEREST_BATCH, RESHARD})


class ClusterError(RuntimeError):
    """A cluster instruction failed in a way recovery cannot repair."""


class RoundAborted(Exception):
    """Raised inside a worker's exchange when the coordinator aborts the
    in-flight round (a peer died); the worker replies ``aborted`` and
    returns to its instruction queue."""


class _WorkersDied(Exception):
    """Internal: the coordinator observed worker deaths mid-instruction."""

    def __init__(self, dead, partial):
        super().__init__(f"workers died: {sorted(dead)}")
        self.dead = set(dead)
        self.partial = partial


# ---------------------------------------------------------------------- #
# worker-side executable cache: module-level jitted local operators
# ---------------------------------------------------------------------- #


class WorkerView(NamedTuple):
    """One worker's device-resident slice (a pytree the kernels take)."""

    l2c_cls: jax.Array  # replicated
    class_starts: jax.Array  # this rank's CSR over global class ids
    c2p_v: jax.Array  # this rank's c2p pair columns
    c2p_u: jax.Array
    class_cyclic: jax.Array  # replicated


def _ops_of(view: WorkerView, n_vertices: int = 0) -> B.PlanOps:
    ops = B.PlanOps()
    ops.l2c_cls = view.l2c_cls
    ops.class_starts = view.class_starts
    ops.c2p_v = view.c2p_v
    ops.c2p_u = view.c2p_u
    ops.class_cyclic = view.class_cyclic
    ops.n_vertices = n_vertices
    return ops


@functools.partial(jax.jit, static_argnames=("cap",))
def _k_lookup(view: WorkerView, start, length, cap: int):
    return _ops_of(view).lookup_classes(start, length, cap)


@jax.jit
def _k_conj_classes(a: R.Relation, b: R.Relation):
    return B.PlanOps().conj_classes(a, b)


@jax.jit
def _k_conj_id_classes(class_cyclic, classes: R.Relation):
    ops = B.PlanOps()
    ops.class_cyclic = class_cyclic
    return ops.conj_id_classes(classes)


@functools.partial(jax.jit, static_argnames=("pair_cap",))
def _k_materialize(view: WorkerView, classes: R.Relation, pair_cap: int):
    """Expand this rank's own classes only — I_c2p is class-hash sharded,
    classes are disjoint in pair space, so no cross-worker duplicates."""
    return _ops_of(view).materialize(classes, pair_cap)


@functools.partial(jax.jit, static_argnames=("join_cap", "pair_cap"))
def _k_join(a: R.Relation, b: R.Relation, join_cap: int, pair_cap: int):
    return B._join_pairs(a, b, join_cap, pair_cap)


@jax.jit
def _k_conj_pairs(a: R.Relation, b: R.Relation):
    return R.rel_intersect(a, b, 2)


@jax.jit
def _k_conj_id_pairs(pairs: R.Relation):
    return R.rel_compact(pairs, pairs.cols[0] == pairs.cols[1])


@functools.partial(jax.jit, static_argnames=("pair_cap", "n_vertices",
                                             "n_shards", "rank"))
def _k_identity(pair_cap: int, n_vertices: int, n_shards: int, rank: int):
    """The identity relation restricted to this rank's canonical keys —
    same filter as ``ShardedOps.identity_pairs``."""
    ops = B.PlanOps()
    ops.n_vertices = n_vertices
    base = ops.identity_pairs(pair_cap)
    mine = (R.mix32(base.cols[0], R.SHARD_SALT)
            % jnp.uint32(n_shards)).astype(R.I32) == rank
    return R.rel_compact(base, mine)


@functools.partial(jax.jit, static_argnames=("unique", "out_cap"))
def _k_embed(cols, count, overflow, unique: bool, out_cap: int):
    """Re-embed exchanged host rows as a sorted (optionally deduped)
    device relation at ``out_cap`` — the device half of an exchange."""
    rel = R.rel_sort(R.Relation(cols, count, overflow))
    if unique:
        rel = R.rel_unique(rel)
    return _recap(rel, out_cap)


# ---------------------------------------------------------------------- #
# the exchange fabric (worker side)
# ---------------------------------------------------------------------- #


class ExchangeFabric:
    """Peer-to-peer all-to-all over one queue per (src, dst) pair.

    Messages are ``(seq, xid, src, rows)``: ``seq`` is the instruction's
    sequence number, ``xid`` counts exchanges within it.  Both sides of
    an exchange derive the same ``(seq, xid)`` because every worker walks
    the same plan shapes in the same order; a *stale* tag (from a round
    the coordinator aborted) is dropped on receipt, a *future* tag is a
    protocol bug and raises.  ``abort`` (a shared event) converts a
    blocked receive into :class:`RoundAborted` so a dead peer can never
    wedge the fleet.  Works identically over ``mp.Queue`` (the cluster)
    and ``queue.Queue`` (the in-process thread twin the tests use)."""

    def __init__(self, rank: int, inboxes, outboxes, abort):
        self.rank = rank
        self.inboxes = inboxes  # inboxes[src]: queue into this rank
        self.outboxes = outboxes  # outboxes[dst]: queue out of this rank
        self.abort = abort
        self.seq = -1
        self.xid = 0

    def begin(self, seq: int) -> None:
        """Start the exchange stream of one instruction."""
        self.seq = seq
        self.xid = 0

    def all_to_all(self, parts: list) -> list:
        """Swap ``parts[dst]`` (numpy row blocks) with every peer; returns
        the received blocks in rank order (own part passes through)."""
        xid = self.xid
        self.xid += 1
        n = len(parts)
        for dst in range(n):
            if dst != self.rank:
                self.outboxes[dst].put((self.seq, xid, self.rank, parts[dst]))
        received = [None] * n
        received[self.rank] = parts[self.rank]
        for src in range(n):
            if src != self.rank:
                received[src] = self._recv(src, xid)
        return received

    def _recv(self, src: int, xid: int):
        want = (self.seq, xid)
        while True:
            if self.abort.is_set():
                raise RoundAborted()
            try:
                mseq, mxid, msrc, rows = self.inboxes[src].get(timeout=0.05)
            except _queue.Empty:
                continue
            got = (mseq, mxid)
            if got < want:
                continue  # leftover from an aborted round: drop
            if got != want:
                raise ClusterError(
                    f"exchange out of order: rank {self.rank} expected "
                    f"{want} from {src}, got {got}")
            return rows


def make_thread_fabrics(n: int):
    """In-process twin of the cluster fabric: ``n`` fabrics over
    ``queue.Queue`` pairs + the shared abort event — lets tests drive
    :class:`ClusterOps` with real exchanges on threads, no processes."""
    import queue
    import threading

    mat = [[queue.Queue() for _ in range(n)] for _ in range(n)]
    abort = threading.Event()
    fabrics = [
        ExchangeFabric(r, [mat[s][r] for s in range(n)],
                       [mat[r][d] for d in range(n)], abort)
        for r in range(n)
    ]
    return fabrics, abort


# ---------------------------------------------------------------------- #
# the plan operators (worker side)
# ---------------------------------------------------------------------- #


class ClusterOps(B.PlanOps):
    """``ShardedOps``' math with host-mediated queue exchanges.

    Class-space operators inherit the protocol's local bodies (wrapped in
    the module-level jit kernels); pair-space producers restore the
    canonical ``mix32(v) % n`` distribution through the fabric.  The
    received buffer is fixed at ``2 * pair_cap`` — the same invariant as
    ``ShardedOps._bucket_cap`` (n_shards blocks of ~2x the balanced
    share) — so exchange skew past it trips the sticky flag and rides the
    ordinary double-and-retry ladder, and the jit cache keys stay stable.
    ``finish`` returns the *local* flag; the coordinator ORs the
    per-worker flags per lane, which is exactly the psum-reduce of the
    sharded backend."""

    def __init__(self, view: WorkerView, n_vertices: int, n_shards: int,
                 rank: int, fabric: ExchangeFabric):
        self.view = view
        self.n_vertices = n_vertices
        self.n_shards = n_shards
        self.rank = rank
        self.fabric = fabric

    # ---- class space (replicated, local kernels) ---- #

    def lookup_classes(self, start, length, cap: int) -> R.Relation:
        return _k_lookup(self.view, jnp.asarray(start, R.I32),
                         jnp.asarray(length, R.I32), cap)

    def conj_classes(self, a, b):
        return _k_conj_classes(a, b)

    def conj_id_classes(self, classes):
        return _k_conj_id_classes(self.view.class_cyclic, classes)

    # ---- pair space (canonical sharded, exchanges through the fabric) -- #

    def materialize(self, classes: R.Relation, pair_cap: int) -> R.Relation:
        local = _k_materialize(self.view, classes, pair_cap)
        return self._exchange(local, 0, pair_cap, recap=True)

    def join_pairs(self, a: R.Relation, b: R.Relation, join_cap: int,
                   pair_cap: int) -> R.Relation:
        # probe side to the shard owning its join key u; the build side
        # is canonical — already partitioned by its key v
        a2 = self._exchange(a, 1, pair_cap)
        out = _k_join(a2, b, join_cap, pair_cap)
        return self._exchange(out, 0, pair_cap, unique=True, recap=True)

    def conj_pairs(self, a, b):
        return _k_conj_pairs(a, b)

    def conj_id_pairs(self, pairs):
        return _k_conj_id_pairs(pairs)

    def identity_pairs(self, pair_cap: int) -> R.Relation:
        return _k_identity(pair_cap, self.n_vertices, self.n_shards,
                           self.rank)

    def finish(self, pairs: R.Relation):
        return pairs, pairs.overflow  # coordinator ORs per-worker flags

    # ---- the exchange ---- #

    def _exchange(self, rel: R.Relation, key_col: int, pair_cap: int,
                  unique: bool = False, recap: bool = False) -> R.Relation:
        """Repartition ``rel`` by ``hash(cols[key_col])``: pull the valid
        prefix to host, bucket with the numpy twin of the device hash,
        swap blocks through the fabric, re-embed sorted on device."""
        cnt = int(rel.count)
        ovf = bool(rel.overflow)
        cols = [np.asarray(c[:cnt]) for c in rel.cols]
        rows = (np.stack(cols, axis=1) if cols else
                np.zeros((0, 0), np.int32)).astype(np.int32, copy=False)
        if self.n_shards > 1:
            bucket = hash_buckets(rows, (key_col,), self.n_shards)
            parts = [np.ascontiguousarray(rows[bucket == d])
                     for d in range(self.n_shards)]
            rows = np.concatenate(self.fabric.all_to_all(parts))
        buf_cap = 2 * pair_cap
        if rows.shape[0] > buf_cap:
            ovf = True
            rows = rows[:buf_cap]
        arity = len(rel.cols)
        buf = np.full((buf_cap, arity), int(R.SENTINEL), np.int32)
        buf[:rows.shape[0]] = rows
        return _k_embed(
            tuple(jnp.asarray(buf[:, j]) for j in range(arity)),
            jnp.asarray(rows.shape[0], R.I32), jnp.asarray(ovf),
            unique=unique, out_cap=(pair_cap if recap else buf_cap))


# ---------------------------------------------------------------------- #
# slices
# ---------------------------------------------------------------------- #


def merge_partitions(parts_by_rank: list, n_lanes: int):
    """Merge per-worker partial answers: concat the canonical (globally
    disjoint) partitions in rank order + lexsort ==
    ``ShardedBackend._gather_rows`` == the local engine, bit for bit;
    per-lane overflow is the OR of the per-worker sticky flags (the
    queue-world psum)."""
    results: list = [None] * n_lanes
    overflow = np.zeros(n_lanes, bool)
    for lane in range(n_lanes):
        chunks = []
        for part in parts_by_rank:
            rows, ovf = part[lane]
            if ovf:
                overflow[lane] = True
            elif rows is not None:
                chunks.append(rows)
        if not overflow[lane]:
            rows = (np.concatenate(chunks) if chunks
                    else np.zeros((0, 2), np.int32))
            results[lane] = rows[np.lexsort((rows[:, 1], rows[:, 0]))]
    return results, overflow


def make_slices(index, n_shards: int) -> list:
    """Per-rank worker slice payloads of ``shard_index(index, n)`` —
    deterministic in (index, n), which is what makes checkpoint-based
    respawn land on the exact slice the dead worker held."""
    sharded = shard_index(index, n_shards)
    common = {
        "l2c_cls": np.asarray(sharded.l2c_cls),
        "class_cyclic": np.asarray(sharded.class_cyclic),
        "n_vertices": int(index.n_vertices),
        "n_shards": int(n_shards),
    }
    return [
        dict(common,
             rank=r,
             c2p_v=np.asarray(sharded.c2p_v[r]),
             c2p_u=np.asarray(sharded.c2p_u[r]),
             class_starts=np.asarray(sharded.class_starts[r]))
        for r in range(n_shards)
    ]


# ---------------------------------------------------------------------- #
# the worker (runs inside the spawned process; see launch/workers.py)
# ---------------------------------------------------------------------- #


class WorkerState:
    """One worker's whole mutable state: the device slice, the exchange
    fabric, the DISPATCH result buffer, and the adopted state epoch."""

    def __init__(self, rank: int, inboxes, outboxes, abort):
        self.rank = rank
        self.fabric = ExchangeFabric(rank, inboxes, outboxes, abort)
        self.view: WorkerView | None = None
        self.n_vertices = 0
        self.n_shards = 1
        self.epoch = -1
        self._buffers: OrderedDict = OrderedDict()

    # -- instruction dispatch -- #

    def handle(self, seq: int, kind: str, payload):
        if kind == PROMOTE:
            return self._promote(payload)
        if kind in STATE_KINDS:
            self._apply_slice(payload)
            return {"epoch": self.epoch}
        if kind == EXECUTE_BATCH:
            return self._execute(seq, payload)
        if kind == DISPATCH:
            out = self._execute(seq, payload)
            self._buffers[payload["batch"]] = out
            while len(self._buffers) > 16:  # bound leaks from aborted rounds
                self._buffers.popitem(last=False)
            return None
        if kind == HARVEST:
            return self._buffers.pop(payload["batch"], None)
        if kind == CHECKPOINT:
            return {"epoch": self.epoch}
        raise ValueError(f"unknown instruction kind {kind!r}")

    # -- state installation -- #

    def _apply_slice(self, slc: dict) -> None:
        self.view = WorkerView(
            l2c_cls=jnp.asarray(slc["l2c_cls"]),
            class_starts=jnp.asarray(slc["class_starts"]),
            c2p_v=jnp.asarray(slc["c2p_v"]),
            c2p_u=jnp.asarray(slc["c2p_u"]),
            class_cyclic=jnp.asarray(slc["class_cyclic"]))
        self.n_vertices = int(slc["n_vertices"])
        self.n_shards = int(slc["n_shards"])
        self.epoch = int(slc.get("epoch", self.epoch))

    def _promote(self, payload: dict) -> dict:
        base_kind, base = payload["base"]
        if base_kind == "checkpoint":
            # warm start from the last committed lifecycle step: rebuild
            # this rank's slice from the restored index (shard_index is
            # deterministic), then replay the state suffix logged since
            from .lifecycle import load_state

            state = load_state(base["dir"], base["step"])
            slc = make_slices(state.index, payload["n_shards"])[
                payload["rank"]]
            self._apply_slice(slc)
        else:
            self._apply_slice(base)
        for _kind, slc in payload.get("replay", ()):
            self._apply_slice(slc)
        self.epoch = int(payload["epoch"])
        return {"epoch": self.epoch, "devices": jax.device_count()}

    # -- query execution -- #

    def _execute(self, seq: int, payload: dict) -> list:
        """Walk every lane's plan over this rank's slice.  The exchange
        stream restarts at (seq, 0); overflow is sticky data, so the
        exchange count per lane depends only on the plan shape and the
        fleet stays in lockstep even when a lane overflows locally."""
        shape, caps = payload["shape"], payload["caps"]
        ranges = np.asarray(payload["ranges"], np.int32)
        self.fabric.begin(seq)
        out = []
        for lane in range(ranges.shape[0]):
            ops = ClusterOps(self.view, self.n_vertices, self.n_shards,
                             self.rank, self.fabric)
            rel, ovf = B.run_plan_ops(ops, shape, caps, ranges[lane])
            if bool(ovf):
                out.append((None, True))
            else:
                cnt = int(rel.count)
                rows = np.stack([np.asarray(rel.cols[0][:cnt]),
                                 np.asarray(rel.cols[1][:cnt])],
                                axis=1).astype(np.int32, copy=False)
                out.append((rows, False))
        return out


# ---------------------------------------------------------------------- #
# the coordinator
# ---------------------------------------------------------------------- #


class _Worker(NamedTuple):
    rank: int
    proc: object
    iq: object  # instruction queue (coordinator -> worker)
    hb: object  # heartbeat (shared double, worker refreshes)


class ClusterRuntime:
    """Coordinator of N persistent worker processes.

    Owns the instruction sequence (the total order every worker observes
    through its FIFO queue), the authoritative slice state, the state
    log + checkpoint pointer that recovery replays from, and the merge
    of per-worker partial answers.  Single-threaded by design: the
    service layer above already serializes reads and writes, and one
    writer is the serializability story."""

    def __init__(self, index=None, n_workers: int = 1, *,
                 max_workers: int | None = None,
                 heartbeat_timeout: float = 30.0,
                 reply_timeout: float = 600.0,
                 spawn_timeout: float = 120.0,
                 ilog_keep: int = 8):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.n_shards = int(n_workers)
        # the peer-exchange matrix is plumbed into worker processes at
        # spawn, so the elastic ceiling is fixed up front; default to 2x
        # the initial fleet so RESHARD can double without re-plumbing
        self.max_workers = max(self.n_shards,
                               int(max_workers or 2 * self.n_shards))
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.reply_timeout = float(reply_timeout)
        self.spawn_timeout = float(spawn_timeout)
        self.ilog_keep = int(ilog_keep)
        self._ctx = mp.get_context("spawn")
        self._abort = self._ctx.Event()
        self._rq = self._ctx.Queue()
        # full peer matrix at max_workers so RESHARD can grow the fleet
        # without re-plumbing queues into live processes
        self._peer = [[self._ctx.Queue() for _ in range(self.max_workers)]
                      for _ in range(self.max_workers)]
        self._workers: dict[int, _Worker] = {}
        self._outstanding: dict[int, set] = {}
        self._seq = 0
        self._bid = 0
        self._batches: dict[int, dict] = {}
        self._slices: list = []
        self._ilog: list = []  # [(kind, payloads_by_rank)] since checkpoint
        self._ckpt: tuple | None = None  # (dir, step) of last committed
        self._state_epoch = 0
        self.index = None
        self.n_vertices = 0
        self.started = False
        self.recoveries = 0  # respawn count (tests/bench assert on this)
        self.instructions: Counter = Counter()
        if index is not None:
            self.start(index)

    # ------------------------- lifecycle ------------------------------ #

    def start(self, index) -> None:
        if self.started:
            raise ClusterError("cluster already started")
        self._bind_host(index)
        for r in range(self.n_shards):
            self._spawn(r)
        self._state_epoch += 1
        payloads = {r: self._promote_payload(r) for r in range(self.n_shards)}
        self._run_instruction(PROMOTE, payloads)
        self.started = True

    def shutdown(self) -> None:
        for w in list(self._workers.values()):
            with contextlib.suppress(Exception):
                w.iq.put((self._next_seq(), SHUTDOWN, None))
        for w in list(self._workers.values()):
            w.proc.join(timeout=3)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2)
        self._workers.clear()
        self._outstanding.clear()
        self.started = False

    def __del__(self):  # best-effort: don't leak worker processes
        with contextlib.suppress(Exception):
            if self._workers:
                self.shutdown()

    def _bind_host(self, index) -> None:
        self.index = index
        self.n_vertices = int(index.n_vertices)
        self._slices = make_slices(index, self.n_shards)

    # ------------------------- write path ----------------------------- #

    def rebind(self, index) -> None:
        """Broadcast a maintenance flush (or interest round) as ONE state
        instruction: the single-writer host mirror stays with the
        coordinator; workers install their new slice and ack before any
        later read dispatches — the cross-process half of the service's
        strict-serializability contract."""
        prev = getattr(self.index, "interests", None)
        kind = INTEREST_BATCH if getattr(index, "interests", None) != prev \
            else FLUSH_REBIND
        self._bind_host(index)
        self._broadcast_state(kind)

    def resize(self, n_workers: int) -> None:
        """Elastic RESHARD to ``n_workers`` (<= ``max_workers``): grow by
        spawning fresh ranks (their first instruction is the RESHARD
        slice install), shrink by retiring the top ranks after the
        survivors rebase."""
        n = int(n_workers)
        if n < 1 or n > self.max_workers:
            raise ValueError(
                f"n_workers must be in [1, {self.max_workers}]")
        if n == self.n_shards:
            return
        old = self.n_shards
        self.n_shards = n
        self._slices = make_slices(self.index, n)
        for r in range(old, n):
            self._spawn(r)
        self._broadcast_state(RESHARD)
        for r in range(n, old):
            w = self._workers.pop(r, None)
            self._outstanding.pop(r, None)
            if w is not None:
                with contextlib.suppress(Exception):
                    w.iq.put((self._next_seq(), SHUTDOWN, None))
                w.proc.join(timeout=3)
                if w.proc.is_alive():
                    w.proc.terminate()

    def _broadcast_state(self, kind: str) -> None:
        self._state_epoch += 1
        payloads = {r: dict(self._slices[r], epoch=self._state_epoch)
                    for r in range(self.n_shards)}
        self._run_instruction(kind, payloads, state=True)

    # ------------------------- checkpoints ---------------------------- #

    def checkpoint_barrier(self, step: int) -> None:
        """Quiesce for a checkpoint: every worker acks and reports its
        adopted state epoch; a mismatch means a worker missed a state
        instruction — the serializability invariant — and is fatal."""
        replies = self._run_instruction(
            CHECKPOINT, {r: {"step": int(step)}
                         for r in range(self.n_shards)})
        epochs = {r: replies[r][1]["epoch"] for r in replies}
        if set(epochs.values()) != {self._state_epoch}:
            raise ClusterError(
                f"state epoch drift at checkpoint: coordinator "
                f"{self._state_epoch}, workers {epochs}")

    def checkpoint_committed(self, ckpt_dir: str, step: int) -> None:
        """A lifecycle checkpoint holding this cluster's index committed:
        future respawns warm-start from it and the replay log resets."""
        self._ckpt = (str(ckpt_dir), int(step))
        self._ilog.clear()

    # ------------------------- read path ------------------------------ #

    def execute(self, shape, caps, ranges: np.ndarray):
        """Synchronous batch: broadcast EXECUTE_BATCH, merge per-worker
        partitions.  Returns (list of rows-or-None per lane, (B,) bool
        overflow) — the ``ExecutionBackend.run_batch`` contract."""
        ranges = np.asarray(ranges, np.int32)
        payload = {"shape": shape, "caps": caps, "ranges": ranges}
        replies = self._run_instruction(
            EXECUTE_BATCH, {r: payload for r in range(self.n_shards)})
        return self._merge([replies[r][1] for r in range(self.n_shards)],
                           ranges.shape[0])

    def dispatch(self, shape, caps, ranges: np.ndarray) -> int:
        """Asynchronous half of the pipelined drain: enqueue a DISPATCH
        and return a batch id immediately — workers execute while the
        coordinator (and the service above it) plans the next round."""
        ranges = np.asarray(ranges, np.int32)
        bid = self._bid
        self._bid += 1
        payload = {"shape": shape, "caps": caps, "ranges": ranges,
                   "batch": bid}
        self._batches[bid] = payload
        dead = self._dead_ranks()
        if dead:
            self._recover(dead)
        seq = self._next_seq()
        self.instructions[DISPATCH] += 1
        for r in range(self.n_shards):
            self._workers[r].iq.put((seq, DISPATCH, payload))
            self._outstanding[r].add(seq)
        return bid

    def harvest(self, bid: int):
        """Blocking half: collect the buffered batch.  A worker that lost
        its buffer (death or abort between dispatch and harvest) replies
        None and the whole batch re-executes synchronously — execution is
        deterministic, so survivors' answers are reproduced exactly."""
        payload = self._batches.pop(bid)
        replies = self._run_instruction(
            HARVEST, {r: {"batch": bid} for r in range(self.n_shards)})
        parts = [replies[r][1] for r in range(self.n_shards)]
        if all(p is not None for p in parts):
            return self._merge(parts, payload["ranges"].shape[0])
        replies = self._run_instruction(
            EXECUTE_BATCH, {r: payload for r in range(self.n_shards)})
        return self._merge([replies[r][1] for r in range(self.n_shards)],
                           payload["ranges"].shape[0])

    def _merge(self, parts_by_rank: list, n_lanes: int):
        return merge_partitions(parts_by_rank, n_lanes)

    # ------------------------- fault injection ------------------------ #

    def inject_crash(self, rank: int, code: int = 3) -> None:
        """Test/bench seam: enqueue a CRASH so worker ``rank`` hard-exits
        when it reaches this point of its instruction stream — i.e.
        *before* whatever is enqueued after it (mid-round, pre-rebind-ack,
        mid-checkpoint kills are all orderings of this primitive)."""
        w = self._workers[rank]
        w.iq.put((self._next_seq(), CRASH, {"code": int(code)}))

    # ------------------------- internals ------------------------------ #

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _spawn(self, rank: int) -> _Worker:
        from repro.launch.workers import worker_main  # lazy: one-way dep

        iq = self._ctx.Queue()
        hb = self._ctx.Value("d", time.time())
        inboxes = [self._peer[s][rank] for s in range(self.max_workers)]
        outboxes = [self._peer[rank][d] for d in range(self.max_workers)]
        proc = self._ctx.Process(
            target=worker_main,
            args=(rank, iq, self._rq, inboxes, outboxes, hb, self._abort),
            daemon=True, name=f"cpqx-worker-{rank}")
        proc.start()
        w = _Worker(rank, proc, iq, hb)
        self._workers[rank] = w
        self._outstanding[rank] = set()
        return w

    def _dead_ranks(self) -> set:
        now = time.time()
        dead = set()
        for r, w in self._workers.items():
            if not w.proc.is_alive():
                dead.add(r)
            elif now - w.hb.value > self.heartbeat_timeout:
                dead.add(r)
        return dead

    def _run_instruction(self, kind: str, payloads: dict,
                         state: bool = False, max_attempts: int = 6):
        """Broadcast one instruction under one sequence number and await
        every active worker's reply; on worker death, recover (abort +
        quiesce + respawn/promote) and re-issue under a fresh number."""
        for _ in range(max_attempts):
            dead = self._dead_ranks()
            if dead:
                self._recover(dead)
            ranks = list(range(self.n_shards))
            seq = self._next_seq()
            self.instructions[kind] += 1
            for r in ranks:
                self._workers[r].iq.put((seq, kind, payloads[r]))
                self._outstanding[r].add(seq)
            try:
                replies = self._collect(seq, ranks)
                if state:
                    self._log_state(kind, payloads)
                return replies
            except _WorkersDied as e:
                self._recover(e.dead)
        raise ClusterError(
            f"{kind} still failing after {max_attempts} recovery attempts")

    def _collect(self, seq: int, ranks: list) -> dict:
        got: dict = {}
        want = set(ranks)
        deadline = time.monotonic() + self.reply_timeout
        while set(got) < want:
            dead = self._dead_ranks()
            if dead:
                raise _WorkersDied(dead, got)
            try:
                rank, mseq, status, payload = self._rq.get(timeout=0.1)
            except _queue.Empty:
                if time.monotonic() > deadline:
                    raise ClusterError(
                        f"timed out waiting for replies to seq {seq}")
                continue
            self._outstanding.get(rank, set()).discard(mseq)
            if mseq != seq or rank not in want:
                continue  # stale reply from a superseded round
            if status == "error":
                self._fail_round()
                raise ClusterError(f"worker {rank} failed:\n{payload}")
            if status == "aborted":
                # only possible while recovery owns the abort event — a
                # stray abort here means a peer died under us: recover
                raise _WorkersDied(self._dead_ranks(), got)
            got[rank] = (status, payload)
        return got

    def _fail_round(self) -> None:
        """A worker errored mid-round: its exchange peers may be blocked
        on data that will never come.  Abort + settle so the fleet is
        reusable before the error propagates to the caller."""
        with contextlib.suppress(Exception):
            self._quiesce(set())

    def _recover(self, dead: set) -> None:
        """The recovery protocol: abort the in-flight round, wait for
        every live worker to settle, drain the fabric, then respawn each
        dead rank and PROMOTE it from the latest committed checkpoint
        plus the logged state suffix (or the live slice when no
        checkpoint exists)."""
        dead = set(dead)
        for _ in range(1 + self.max_workers):
            dead |= self._quiesce(dead)
            try:
                for rank in sorted(r for r in dead if r < self.n_shards):
                    self._respawn(rank)
            except _WorkersDied as e:
                dead |= e.dead
                continue
            dead = self._dead_ranks()
            if not dead:
                return
        raise ClusterError("cluster failed to stabilize after recoveries")

    def _quiesce(self, dead: set) -> set:
        """Set the abort event, then consume replies until no live worker
        has an outstanding instruction (each blocked exchange converts to
        an ``aborted`` reply).  Clears the event and drains the exchange
        queues — after this the fleet is idle and re-issuable."""
        dead = set(dead)
        self._abort.set()
        try:
            deadline = time.monotonic() + self.reply_timeout
            while True:
                dead |= self._dead_ranks()
                pending = [r for r, s in self._outstanding.items()
                           if r not in dead and s]
                if not pending:
                    break
                try:
                    rank, mseq, _status, _payload = self._rq.get(timeout=0.1)
                    self._outstanding.get(rank, set()).discard(mseq)
                except _queue.Empty:
                    if time.monotonic() > deadline:
                        raise ClusterError(
                            f"workers {pending} failed to quiesce")
        finally:
            self._abort.clear()
        for r in dead:
            self._outstanding.get(r, set()).clear()
        self._drain_fabric()
        return dead

    def _drain_fabric(self) -> None:
        # hygiene: bound queue growth from aborted rounds.  Correctness
        # never depends on this — receivers drop stale (seq, xid) tags.
        for row in self._peer:
            for q in row:
                while True:
                    try:
                        q.get_nowait()
                    except _queue.Empty:
                        break

    def _respawn(self, rank: int) -> None:
        old = self._workers.pop(rank, None)
        if old is not None:
            with contextlib.suppress(Exception):
                old.proc.terminate()
                old.proc.join(timeout=2)
        self._outstanding.pop(rank, None)
        w = self._spawn(rank)
        seq = self._next_seq()
        self.instructions[PROMOTE] += 1
        w.iq.put((seq, PROMOTE, self._promote_payload(rank)))
        self._outstanding[rank].add(seq)
        deadline = time.monotonic() + self.spawn_timeout
        while True:
            if not w.proc.is_alive():
                raise _WorkersDied({rank}, {})
            try:
                r2, mseq, status, payload = self._rq.get(timeout=0.1)
            except _queue.Empty:
                if time.monotonic() > deadline:
                    raise ClusterError(
                        f"worker {rank} failed to promote in time")
                continue
            self._outstanding.get(r2, set()).discard(mseq)
            if r2 != rank or mseq != seq:
                continue
            if status != "ok":
                raise ClusterError(
                    f"worker {rank} promote failed: {payload}")
            self.recoveries += 1
            return

    def _promote_payload(self, rank: int) -> dict:
        if self._ckpt is not None:
            base = ("checkpoint", {"dir": self._ckpt[0],
                                   "step": self._ckpt[1]})
            replay = [(kind, payloads[rank])
                      for kind, payloads in self._ilog if rank in payloads]
        else:
            base = ("inline", dict(self._slices[rank],
                                   epoch=self._state_epoch))
            replay = []
        return {"rank": rank, "n_shards": self.n_shards, "base": base,
                "replay": replay, "epoch": self._state_epoch}

    def _log_state(self, kind: str, payloads: dict) -> None:
        self._ilog.append((kind, payloads))
        # state payloads carry full slices, so replay is last-wins — old
        # entries are redundant and the log stays bounded
        while len(self._ilog) > self.ilog_keep:
            self._ilog.pop(0)


# ---------------------------------------------------------------------- #
# the backend (what Engine drives)
# ---------------------------------------------------------------------- #


class ClusterBackend(B.ExecutionBackend):
    """:class:`ClusterRuntime` behind the ordinary
    ``core.backend.ExecutionBackend`` contract — ``Engine(index,
    cluster=n)`` serves the identical API (and bit-identical answers)
    off a process fleet, and the service layer above never knows.

    No union executable (``supports_union = False``): mixed-shape lanes
    would need data-dependent exchange counts, breaking lockstep — the
    engine transparently falls back to per-shape dispatch."""

    supports_union = False

    def __init__(self, runtime: ClusterRuntime):
        self.runtime = runtime
        self.n_vertices = runtime.n_vertices

    @classmethod
    def from_index(cls, index, n_workers: int, **kw) -> "ClusterBackend":
        return cls(ClusterRuntime(index, n_workers, **kw))

    @property
    def n_shards(self) -> int:
        return self.runtime.n_shards

    def run(self, shape, caps: B.QueryCaps, ranges: np.ndarray):
        results, ovf = self.runtime.execute(
            shape, caps, np.asarray(ranges, np.int32)[None])
        return results[0], bool(ovf[0])

    def run_batch(self, shape, caps: B.QueryCaps, ranges: np.ndarray):
        return self.runtime.execute(shape, caps, ranges)

    def run_batch_async(self, shape, caps: B.QueryCaps, ranges: np.ndarray):
        return ("cluster", self.runtime.dispatch(shape, caps, ranges))

    def harvest_batch(self, handle):
        if handle[0] != "cluster":
            return super().harvest_batch(handle)
        return self.runtime.harvest(handle[1])

    # -- maintenance / lifecycle (Engine.rebind + service checkpoint) -- #

    def reshard(self, index) -> None:
        self.runtime.rebind(index)
        self.n_vertices = self.runtime.n_vertices

    def resize(self, n_workers: int) -> None:
        self.runtime.resize(n_workers)

    def quiesce(self, step: int) -> None:
        self.runtime.checkpoint_barrier(step)

    def checkpoint_committed(self, ckpt_dir: str, step: int) -> None:
        self.runtime.checkpoint_committed(ckpt_dir, step)

    def shutdown(self) -> None:
        self.runtime.shutdown()
