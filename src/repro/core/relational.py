"""Capacity-padded sorted relational algebra on device — the TPU-native
substrate of the CPQx engine.

The paper's C++ artifact manipulates dynamically-sized ``std::vector``s of
s-t pairs with pointer-walking sort-merge joins.  XLA needs static shapes,
so every relation here is a fixed-capacity set of int32 columns where the
valid rows occupy ``[0, count)`` and the padding rows are filled with
``SENTINEL`` (``2^31 - 1``), which sorts to the end.  Every operator
returns ``(relation, overflow)``-style results; the host driver sizes
capacities with the numpy estimator and retries on overflow.

Design notes (hardware adaptation, see DESIGN.md §2):

* multi-column lexicographic sort  -> one ``jax.lax.sort`` with num_keys
* pointer-walk merge join          -> branch-free *vectorized binary
  search* (fixed trip count = bit-length of capacity) + capacity-padded
  expansion join (cumsum + searchsorted row recovery)
* hash maps                        -> dense ranks (exact, collision-free)
* per-pair signature sets          -> order-invariant two-lane uint32
  fingerprints (sum of avalanche-mixed rows after exact dedup)

Everything is int32 on the hot path (TPU x64 off); values must be
``< SENTINEL``.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.int32(2**31 - 1)
I32 = jnp.int32
U32 = jnp.uint32


class Relation(NamedTuple):
    """A capacity-padded relation: parallel int32 columns + valid count.

    ``cols``     tuple of (cap,) int32 arrays; rows >= count are SENTINEL.
    ``count``    scalar int32 — number of valid rows.
    ``overflow`` scalar bool — sticky flag: some producer dropped rows.
    """

    cols: tuple
    count: jax.Array
    overflow: jax.Array

    @property
    def capacity(self) -> int:
        return self.cols[0].shape[0]

    @property
    def arity(self) -> int:
        return len(self.cols)


def make_relation(cols: Sequence[jax.Array], count=None, overflow=None) -> Relation:
    cols = tuple(jnp.asarray(c, I32) for c in cols)
    if count is None:
        count = jnp.asarray(cols[0].shape[0], I32)
    if overflow is None:
        overflow = jnp.asarray(False)
    return Relation(cols, jnp.asarray(count, I32), jnp.asarray(overflow))


def from_numpy(rows: np.ndarray, capacity: int) -> Relation:
    """Host rows (n, arity) -> padded device relation."""
    rows = np.asarray(rows, np.int32).reshape(rows.shape[0], -1)
    n, a = rows.shape
    if n > capacity:
        raise ValueError(f"{n} rows exceed capacity {capacity}")
    buf = np.full((capacity, a), SENTINEL, np.int32)
    buf[:n] = rows
    return make_relation(tuple(buf[:, j] for j in range(a)), count=n)


def to_numpy(rel: Relation) -> np.ndarray:
    """Valid rows as a host (count, arity) array."""
    n = int(rel.count)
    return np.stack([np.asarray(c)[:n] for c in rel.cols], axis=1)


def valid_mask(rel: Relation) -> jax.Array:
    return jnp.arange(rel.capacity, dtype=I32) < rel.count


# ---------------------------------------------------------------------- #
# batched (vmapped) relations: cols (batch, cap), count (batch,)
# ---------------------------------------------------------------------- #


def batch_to_numpy(rel: Relation, lanes=None) -> list[np.ndarray]:
    """Lanes of a vmapped relation as host (count_j, arity) arrays —
    all of them, or just the ``lanes`` indices.

    One device->host transfer per column (not per lane)."""
    cols = [np.asarray(c) for c in rel.cols]
    counts = np.asarray(rel.count)
    if lanes is None:
        lanes = range(counts.shape[0])
    return [
        np.stack([c[j, : counts[j]] for c in cols], axis=1)
        for j in lanes
    ]


# ---------------------------------------------------------------------- #
# sorting / compaction / dedup / ranks
# ---------------------------------------------------------------------- #


def rel_sort(rel: Relation, num_keys: int | None = None) -> Relation:
    """Sort rows lexicographically by the first ``num_keys`` columns.
    SENTINEL padding rows sort to the end (values < SENTINEL invariant)."""
    nk = num_keys if num_keys is not None else rel.arity
    sorted_cols = jax.lax.sort(rel.cols, num_keys=nk, is_stable=True)
    return Relation(tuple(sorted_cols), rel.count, rel.overflow)


def rel_compact(rel: Relation, keep: jax.Array) -> Relation:
    """Stable-move rows with keep=True to the front; drop the rest.

    Implemented as a stable sort on the boolean key — branch-free, no
    scatter."""
    keep = keep & valid_mask(rel)
    key = jnp.where(keep, jnp.int32(0), jnp.int32(1))
    out = jax.lax.sort((key,) + rel.cols, num_keys=1, is_stable=True)
    new_count = jnp.sum(keep, dtype=I32)
    m = jnp.arange(rel.capacity, dtype=I32) < new_count
    cols = tuple(jnp.where(m, c, SENTINEL) for c in out[1:])
    return Relation(cols, new_count, rel.overflow)


def rel_unique(rel: Relation, num_keys: int | None = None) -> Relation:
    """Dedup a *sorted* relation on its first ``num_keys`` columns
    (keeps the first row of each group)."""
    nk = num_keys if num_keys is not None else rel.arity
    first = _new_group_mask(rel.cols[:nk])
    return rel_compact(rel, first)


def _new_group_mask(cols: Sequence[jax.Array]) -> jax.Array:
    """True where a row differs from its predecessor (row 0 always True)."""
    neq = jnp.zeros(cols[0].shape, dtype=bool)
    for c in cols:
        neq = neq | (c != jnp.concatenate([c[:1] - 1, c[:-1]]))
    return neq


def dense_rank(rel: Relation, num_keys: int | None = None):
    """Dense rank of each row of a *sorted* relation over its first
    ``num_keys`` cols.  Returns (ranks (cap,) int32 with SENTINEL on padding,
    n_unique int32).  Exact — no hashing."""
    nk = num_keys if num_keys is not None else rel.arity
    first = _new_group_mask(rel.cols[:nk]) & valid_mask(rel)
    ranks = jnp.cumsum(first.astype(I32)) - 1
    n_unique = jnp.sum(first, dtype=I32)
    ranks = jnp.where(valid_mask(rel), ranks, SENTINEL)
    return ranks, n_unique


# ---------------------------------------------------------------------- #
# vectorized lexicographic binary search
# ---------------------------------------------------------------------- #


def _lex_lt(a: Sequence[jax.Array], b: Sequence[jax.Array]) -> jax.Array:
    """Lexicographic a < b over parallel column tuples (broadcasting)."""
    lt = jnp.zeros(jnp.broadcast_shapes(a[0].shape, b[0].shape), bool)
    eq = jnp.ones_like(lt)
    for x, y in zip(a, b):
        lt = lt | (eq & (x < y))
        eq = eq & (x == y)
    return lt


def _lex_le(a, b) -> jax.Array:
    lt = jnp.zeros(jnp.broadcast_shapes(a[0].shape, b[0].shape), bool)
    eq = jnp.ones_like(lt)
    for x, y in zip(a, b):
        lt = lt | (eq & (x < y))
        eq = eq & (x == y)
    return lt | eq


def lex_searchsorted(
    hay: Sequence[jax.Array], needles: Sequence[jax.Array], side: str = "left"
) -> jax.Array:
    """Vectorized binary search over rows sorted lexicographically.

    ``hay``: tuple of (n,) sorted columns; ``needles``: tuple of (m,)
    columns.  Returns (m,) int32 insertion positions.  Branch-free with a
    fixed trip count (bit length of n) — VPU-lane parallel on TPU."""
    n = hay[0].shape[0]
    steps = max(1, int(n).bit_length())
    lo = jnp.zeros(needles[0].shape, I32)
    hi = jnp.full(needles[0].shape, n, I32)

    cmp = _lex_lt if side == "left" else _lex_le

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        row = tuple(h[jnp.clip(mid, 0, n - 1)] for h in hay)
        go_right = cmp(row, needles)  # hay[mid] < needle (or <= for right)
        active = lo < hi  # converged lanes must not move (mid would read OOB)
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & (~go_right), mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def lex_count_matches(hay, needles, hay_count) -> jax.Array:
    """Number of hay rows equal to each needle row (0 for SENTINEL
    needles / rows beyond hay_count)."""
    left = lex_searchsorted(hay, needles, "left")
    right = lex_searchsorted(hay, needles, "right")
    cnt = right - left
    # guard the sentinel zone: positions >= hay_count are padding
    cnt = jnp.where(left < hay_count, cnt, 0)
    needle_ok = needles[0] != SENTINEL
    return jnp.where(needle_ok, cnt, 0).astype(I32)


# ---------------------------------------------------------------------- #
# set operations on sorted relations
# ---------------------------------------------------------------------- #


def rel_intersect(a: Relation, b: Relation, num_keys: int | None = None) -> Relation:
    """a ∩ b on the first num_keys columns; both must be sorted+unique on
    those columns.  Keeps a's rows (incl. extra payload columns).
    b's overflow is sticky on the result (an undersized b means missing
    matches — the caller must retry, not silently under-answer)."""
    nk = num_keys if num_keys is not None else min(a.arity, b.arity)
    cnt = lex_count_matches(b.cols[:nk], a.cols[:nk], b.count)
    out = rel_compact(a, cnt > 0)
    return Relation(out.cols, out.count, out.overflow | b.overflow)


def rel_difference(a: Relation, b: Relation, num_keys: int | None = None) -> Relation:
    nk = num_keys if num_keys is not None else min(a.arity, b.arity)
    cnt = lex_count_matches(b.cols[:nk], a.cols[:nk], b.count)
    out = rel_compact(a, cnt == 0)
    return Relation(out.cols, out.count, out.overflow | b.overflow)


def rel_concat(a: Relation, b: Relation, capacity: int) -> Relation:
    """Union-all into a fresh capacity (rows beyond capacity overflow)."""
    assert a.arity == b.arity
    total = a.count + b.count
    overflow = a.overflow | b.overflow | (total > capacity)
    cols = []
    idx = jnp.arange(capacity, dtype=I32)
    for ca, cb in zip(a.cols, b.cols):
        from_a = idx < a.count
        ai = jnp.clip(idx, 0, a.capacity - 1)
        bi = jnp.clip(idx - a.count, 0, b.capacity - 1)
        col = jnp.where(from_a, ca[ai], cb[bi])
        col = jnp.where(idx < total, col, SENTINEL)
        cols.append(col)
    return Relation(tuple(cols), jnp.minimum(total, capacity).astype(I32), overflow)


# ---------------------------------------------------------------------- #
# capacity-padded expansion join
# ---------------------------------------------------------------------- #


def expansion_join(
    a: Relation,
    b: Relation,
    a_on: Sequence[int],
    out_cols: Sequence[tuple],
    out_capacity: int,
) -> Relation:
    """Join a with b where ``a.cols[a_on] == b.cols[:len(a_on)]``.

    ``b`` must be sorted on its first len(a_on) columns.  ``out_cols`` is a
    list of ("a"|"b", col_index) selectors for the output projection.

    The classic TPU-native expansion join: per-a-row match counts from two
    binary searches, exclusive cumsum for output offsets, then output-row
    recovery with one more searchsorted over the cumsum — no dynamic
    shapes, no scatter."""
    nk = len(a_on)
    a_keys = tuple(a.cols[i] for i in a_on)
    lo = lex_searchsorted(b.cols[:nk], a_keys, "left")
    hi = lex_searchsorted(b.cols[:nk], a_keys, "right")
    cnt = jnp.where(valid_mask(a) & (lo < b.count), hi - lo, 0).astype(I32)
    ends = jnp.cumsum(cnt, dtype=I32)  # inclusive
    total = ends[-1] if a.capacity > 0 else jnp.int32(0)
    starts = ends - cnt

    t = jnp.arange(out_capacity, dtype=I32)
    # a-row index of output row t: first i with ends[i] > t
    ai = jnp.searchsorted(ends, t, side="right").astype(I32)
    ai_c = jnp.clip(ai, 0, a.capacity - 1)
    bj = lo[ai_c] + (t - starts[ai_c])
    bj = jnp.clip(bj, 0, b.capacity - 1)
    out_valid = t < total

    cols = []
    for which, ci in out_cols:
        src = a.cols[ci][ai_c] if which == "a" else b.cols[ci][bj]
        cols.append(jnp.where(out_valid, src, SENTINEL))
    overflow = a.overflow | b.overflow | (total > out_capacity)
    return Relation(tuple(cols), jnp.minimum(total, out_capacity).astype(I32), overflow)


# ---------------------------------------------------------------------- #
# order-invariant fingerprints (for signature *sets*)
# ---------------------------------------------------------------------- #

_MIX_A = np.uint32(0x7FEB352D)
_MIX_B = np.uint32(0x846CA68B)

# the one shard-placement salt: device repartitioning (distributed) and
# host partitioning (sharded_index) must hash identically
SHARD_SALT = 0xB0C4


def mix32(x: jax.Array, salt: int) -> jax.Array:
    """splitmix-style avalanche mix on uint32 lanes (wrapping arithmetic)."""
    h = x.astype(U32) ^ jnp.uint32(salt)
    h = (h ^ (h >> 16)) * _MIX_A
    h = (h ^ (h >> 15)) * _MIX_B
    h = h ^ (h >> 16)
    return h


def fingerprint_rows(cols: Sequence[jax.Array], salt: int = 0) -> tuple:
    """Two independent uint32 fingerprints per row (64 effective bits)."""
    h1 = jnp.full(cols[0].shape, np.uint32(0x9E3779B9), U32)
    h2 = jnp.full(cols[0].shape, np.uint32(0x85EBCA6B), U32)
    for j, c in enumerate(cols):
        h1 = mix32(c.astype(U32) ^ (h1 * np.uint32(31)), salt * 2 + 101 + j)
        h2 = mix32(c.astype(U32) ^ (h2 * np.uint32(37)), salt * 2 + 202 + j)
    return h1, h2


def segment_fingerprint(
    h1: jax.Array, h2: jax.Array, segment_ids: jax.Array, num_segments: int,
    valid: jax.Array,
) -> tuple:
    """Order-invariant per-segment fingerprint: wrapping uint32 sums of the
    row mixes.  Rows must be exactly deduped beforehand (set == multiset).
    Invalid rows contribute 0.  SENTINEL segment ids are routed to a trash
    segment (caller sizes num_segments accordingly or clips)."""
    sid = jnp.clip(segment_ids, 0, num_segments - 1).astype(I32)
    z = jnp.uint32(0)
    f1 = jax.ops.segment_sum(jnp.where(valid, h1, z), sid, num_segments)
    f2 = jax.ops.segment_sum(jnp.where(valid, h2, z), sid, num_segments)
    return f1.astype(U32), f2.astype(U32)
