"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-*-base; hf]: 32L
d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40 experts
top-8.  (The assignment lists both "40e" and "32 experts"; we follow the
config field: 40 experts.)"""

import dataclasses

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    capacity_factor=1.25,
    attn_pattern=("global",),
    rope_theta=10_000.0,
    activation="silu",
    tie_embeddings=True,
    max_seq_len=32768 * 16 + 64,
    remat=True,
    q_chunk=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256, n_experts=8, top_k=4, max_seq_len=128,
    param_dtype="float32",
)

SPEC = ArchSpec(
    arch_id="granite-moe-3b-a800m",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=lm_shapes(long_ok=False, arch="granite-moe-3b-a800m"),
    notes="fine-grained MoE: 40 tiny experts, top-8 routing.",
)
