"""Labeled directed multigraph — the data model of the CPQx engine.

A graph is G = (V, E, L) with E ⊆ V × V × L (paper Sec. III-A). To support
inverse traversal, the label alphabet is closed under inversion: label ids
live in [0, 2·n_labels); ``inv(l) = l + n_labels (mod 2·n_labels)`` and for
every stored edge (v, u, l) the inverse edge (u, v, inv(l)) is materialized.

The canonical representation is three parallel int32 numpy arrays
(src, dst, lbl), deduplicated and sorted lexicographically by
(lbl, src, dst).  Device-side consumers (``core.relational``,
``core.paths``) pull these arrays as jnp constants; host-side consumers
(oracle, samplers, benchmarks) use them directly.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

INT = np.int32


def inverse_label(lbl: np.ndarray | int, n_labels: int):
    """Map label id(s) to their inverse.  Labels [0, L) are forward,
    [L, 2L) are inverses; the map is an involution."""
    return (lbl + n_labels) % (2 * n_labels)


@dataclasses.dataclass(frozen=True)
class LabeledGraph:
    """Immutable labeled directed multigraph with inverse-label closure.

    Attributes
    ----------
    n_vertices : int
    n_labels   : int           number of *base* labels; alphabet size is 2·n_labels
    src, dst, lbl : np.ndarray int32 parallel edge arrays (closure included),
                               deduped, sorted by (lbl, src, dst)
    label_names : tuple[str]   optional human-readable base-label names
    """

    n_vertices: int
    n_labels: int
    src: np.ndarray
    dst: np.ndarray
    lbl: np.ndarray
    label_names: tuple = ()

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(
        n_vertices: int,
        n_labels: int,
        edges: Iterable[tuple[int, int, int]],
        label_names: Sequence[str] = (),
    ) -> "LabeledGraph":
        """Build from (src, dst, base_label) triples.  Adds the inverse
        closure, dedupes, sorts."""
        e = np.asarray(list(edges), dtype=INT).reshape(-1, 3)
        if e.size and (e[:, 2].max(initial=0) >= n_labels or e[:, 2].min(initial=0) < 0):
            raise ValueError("base labels must be in [0, n_labels)")
        if e.size and (e[:, :2].max(initial=0) >= n_vertices):
            raise ValueError("vertex ids must be in [0, n_vertices)")
        fwd = e
        bwd = np.stack(
            [e[:, 1], e[:, 0], inverse_label(e[:, 2], n_labels)], axis=1
        ).astype(INT)
        alle = np.concatenate([fwd, bwd], axis=0)
        alle = np.unique(alle, axis=0)  # dedupe multi-edges w/ same label
        order = np.lexsort((alle[:, 1], alle[:, 0], alle[:, 2]))
        alle = alle[order]
        return LabeledGraph(
            n_vertices=int(n_vertices),
            n_labels=int(n_labels),
            src=np.ascontiguousarray(alle[:, 0]),
            dst=np.ascontiguousarray(alle[:, 1]),
            lbl=np.ascontiguousarray(alle[:, 2]),
            label_names=tuple(label_names),
        )

    # ------------------------------------------------------------------ #
    # basic accessors
    # ------------------------------------------------------------------ #
    @property
    def n_edges(self) -> int:
        """Number of edges including the inverse closure."""
        return int(self.src.shape[0])

    @property
    def alphabet_size(self) -> int:
        return 2 * self.n_labels

    def edges_with_label(self, lbl: int) -> np.ndarray:
        """(m, 2) array of (src, dst) pairs carrying label ``lbl`` (closure id)."""
        m = self.lbl == lbl
        return np.stack([self.src[m], self.dst[m]], axis=1)

    def label_name(self, lbl: int) -> str:
        if not self.label_names:
            base = f"l{lbl % self.n_labels}"
        else:
            base = self.label_names[lbl % self.n_labels]
        return base + ("⁻¹" if lbl >= self.n_labels else "")

    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices).astype(INT)

    def max_degree(self) -> int:
        return int(self.out_degree().max(initial=0))

    # ------------------------------------------------------------------ #
    # CSR view (over the closed alphabet) — shared substrate with the GNN
    # message-passing layers and the neighbor sampler.
    # ------------------------------------------------------------------ #
    def csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR adjacency over all edges (closure included), rows = src.

        Returns (indptr[n_vertices+1], dst, lbl) where the edges of row v
        are dst[indptr[v]:indptr[v+1]] sorted by (dst, lbl)."""
        order = np.lexsort((self.lbl, self.dst, self.src))
        s, d, l = self.src[order], self.dst[order], self.lbl[order]
        indptr = np.zeros(self.n_vertices + 1, dtype=np.int64)
        np.add.at(indptr, s + 1, 1)
        np.cumsum(indptr, out=indptr)
        return indptr, d, l

    # ------------------------------------------------------------------ #
    # mutation (functional) — used by core.maintenance
    # ------------------------------------------------------------------ #
    def with_edges_added(self, edges: Iterable[tuple[int, int, int]]) -> "LabeledGraph":
        base = self._base_edges()
        new = np.asarray(list(edges), dtype=INT).reshape(-1, 3)
        return LabeledGraph.from_edges(
            self.n_vertices, self.n_labels, np.concatenate([base, new], axis=0),
            self.label_names,
        )

    def with_edges_removed(self, edges: Iterable[tuple[int, int, int]]) -> "LabeledGraph":
        base = self._base_edges()
        kill = {tuple(map(int, e)) for e in edges}
        keep = np.array(
            [i for i in range(base.shape[0]) if tuple(map(int, base[i])) not in kill],
            dtype=np.int64,
        )
        return LabeledGraph.from_edges(
            self.n_vertices, self.n_labels, base[keep] if keep.size else base[:0],
            self.label_names,
        )

    def _base_edges(self) -> np.ndarray:
        m = self.lbl < self.n_labels
        return np.stack([self.src[m], self.dst[m], self.lbl[m]], axis=1)

    # ------------------------------------------------------------------ #
    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"LabeledGraph(|V|={self.n_vertices}, |E|={self.n_edges} (incl. inverse), "
            f"|L|={self.alphabet_size} (incl. inverse))"
        )


# ---------------------------------------------------------------------- #
# The running example of the paper (Fig. 1): 12 users + 2 blogs,
# labels f ("follows") and v ("visits").  Used by tests and quickstart.
# ---------------------------------------------------------------------- #
def example_graph() -> LabeledGraph:
    names = [
        "sue", "joe", "zoe", "tim", "ada", "tom", "bob", "kim",
        "amy", "ben", "eva", "max", "blog123", "blog987",
    ]
    ix = {n: i for i, n in enumerate(names)}
    f, v = 0, 1
    E = [
        # the triad sue -> joe -> zoe -> sue (query ff ∩ f⁻¹ answer)
        (ix["sue"], ix["joe"], f),
        (ix["joe"], ix["zoe"], f),
        (ix["zoe"], ix["sue"], f),
        # followers / follow chains
        (ix["tim"], ix["sue"], f),
        (ix["ada"], ix["tim"], f),
        (ix["tom"], ix["tim"], f),
        (ix["bob"], ix["joe"], f),
        (ix["kim"], ix["zoe"], f),
        (ix["amy"], ix["kim"], f),
        (ix["ben"], ix["bob"], f),
        (ix["eva"], ix["max"], f),
        # blog visits
        (ix["ada"], ix["blog123"], v),
        (ix["tim"], ix["blog123"], v),
        (ix["tom"], ix["blog123"], v),
        (ix["eva"], ix["blog987"], v),
        (ix["max"], ix["blog987"], v),
        (ix["sue"], ix["blog987"], v),
    ]
    return LabeledGraph.from_edges(14, 2, E, label_names=("f", "v"))
