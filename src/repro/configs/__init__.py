"""Assigned-architecture registry: one module per architecture, exact
configs from the public-literature pool, each with a reduced smoke config
and its own input-shape set (every (arch x shape) cell is well-defined).

Select with ``--arch <id>`` in the launchers.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

ARCH_IDS = [
    # LM family (5)
    "grok-1-314b",
    "granite-moe-3b-a800m",
    "gemma2-2b",
    "minicpm-2b",
    "mistral-nemo-12b",
    # GNN family (4)
    "mace",
    "egnn",
    "gatedgcn",
    "graphcast",
    # recsys (1)
    "bst",
    # the paper's own engine as a distributed workload (bonus cell)
    "cpqx-engine",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell for an architecture."""

    name: str
    kind: str  # train | prefill | decode | serve | retrieval | full_graph | sampled | batched_graphs | engine
    dims: dict
    skip: str | None = None  # non-None => documented skip (reason)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | engine
    config: Any
    smoke: Any  # reduced config for CPU smoke tests
    shapes: tuple  # tuple[ShapeSpec]
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name}")


def get_arch(arch_id: str) -> ArchSpec:
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_')}"
    )
    return mod.SPEC


def all_archs() -> list:
    return [get_arch(a) for a in ARCH_IDS]


# ---------------------------------------------------------------------- #
# the shared LM shape set (seq_len x global_batch per assignment)
# ---------------------------------------------------------------------- #


def lm_shapes(long_ok: bool, arch: str) -> tuple:
    skip = (
        None
        if long_ok
        else (
            f"{arch} is pure full attention; a 524k-token KV cache has no "
            "sub-quadratic path — skipped per assignment (see DESIGN.md)"
        )
    )
    return (
        ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
        ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
        ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
        ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1},
                  skip=skip),
    )


def gnn_shapes() -> tuple:
    return (
        ShapeSpec("full_graph_sm", "full_graph",
                  {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
        ShapeSpec("minibatch_lg", "sampled",
                  {"n_nodes": 232_965, "n_edges": 114_615_892,
                   "batch_nodes": 1024, "fanout": (15, 10), "d_feat": 602,
                   # padded subgraph sizes the sampler guarantees
                   "pad_nodes": 1024 + 1024 * 15 + 1024 * 150,
                   "pad_edges": 1024 * 15 + 1024 * 15 * 10}),
        ShapeSpec("ogb_products", "full_graph",
                  {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100}),
        ShapeSpec("molecule", "batched_graphs",
                  {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 32}),
    )


def recsys_shapes() -> tuple:
    return (
        ShapeSpec("train_batch", "train", {"batch": 65_536}),
        ShapeSpec("serve_p99", "serve", {"batch": 512}),
        ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
        ShapeSpec("retrieval_cand", "retrieval",
                  {"batch": 1, "n_candidates": 1_000_000}),
    )
