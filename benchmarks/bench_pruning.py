"""Paper Table III: pruning power — the number of class identifiers
(CPQx / iaCPQx) vs s-t pairs (iaPath) involved in evaluating S queries.
Smaller = stronger pruning; the paper's point is |C| << |P|.

The skew section (PR 4) measures the same quantities on the
``skewed-hub`` generator, where labels are deliberately *not* uniform:
per gated optimizer probe it emits the largest/smallest conjunct pair
counts and their imbalance ratio — the headroom the cost-based
optimizer converts into wall-clock wins in ``bench_query.py``.  On the
uniform-label datasets that ratio hovers near 1 and optimizer wins are
washed out; here it reaches orders of magnitude."""

from __future__ import annotations

import numpy as np

from repro.core import baselines, interest
from repro.core import index as cindex
from repro.core.query import instantiate_template, plan_lookup_seqs, plan_query
from repro.core.stats import IndexStats

from .bench_query import OPT_GATED, interests_for
from .common import DATASETS, emit


def main() -> None:
    rng = np.random.default_rng(3)
    for ds in ["robots-like", "advogato-like", "gmark-small"]:
        g = DATASETS[ds]()
        ints = interests_for(g)
        idx = cindex.build(g, 2)
        ia = interest.build_interest(g, 2, ints)
        pi = baselines.build_path(g, 2, interests=ints)
        # S queries drawn FROM the interest set (the paper evaluates
        # queries over the indexed interests)
        n_cls_cpqx, n_cls_ia, n_pairs_path, n_q = 0, 0, 0, 0
        for _ in range(5):
            s1 = ints[int(rng.integers(0, len(ints)))]
            s2 = ints[int(rng.integers(0, len(ints)))]
            for seq in (s1, s2):
                seq = tuple(int(x) for x in seq)
                lo, hi = idx.lookup_range(seq)
                n_cls_cpqx += hi - lo
                lo, hi = ia.lookup_range(seq)
                n_cls_ia += hi - lo
                lo, hi = pi.lookup_range(seq)
                n_pairs_path += hi - lo
            n_q += 1
        emit(f"table3/{ds}/CPQx_classes", n_cls_cpqx / n_q, "avg per S query")
        emit(f"table3/{ds}/iaCPQx_classes", n_cls_ia / n_q, "avg per S query")
        emit(f"table3/{ds}/iaPath_pairs", n_pairs_path / n_q, "avg per S query")
        # the paper's Table III comparison: ia classes <= ia path pairs
        assert n_cls_ia <= n_pairs_path + 1e-9

    skew_section()


def skew_section() -> None:
    """Conjunct imbalance on the label-skewed generator: max/min pair
    counts across the LOOKUP leaves of each gated optimizer probe."""
    g = DATASETS["skewed-hub"]()
    stats = IndexStats.from_index(cindex.build(g, 2))
    for name, labels in OPT_GATED:
        q = instantiate_template(name, labels)
        seqs = plan_lookup_seqs(plan_query(q, 2))
        pairs = [stats.seq_pairs(s) for s in seqs]
        hi, lo = max(pairs), max(1, min(pairs))
        emit(f"table3/skewed-hub/{name}/conjunct_imbalance", hi / lo,
             f"max_pairs={hi};min_pairs={lo};n_lookups={len(seqs)}")
        # the skew the optimizer exploits must actually be present
        assert hi / lo >= 10, (name, pairs)


if __name__ == "__main__":
    main()
