"""LR schedules: linear-warmup cosine, and WSD (warmup-stable-decay,
the MiniCPM schedule [arXiv:2404.06395]: warmup -> long constant plateau
-> short exponential/linear decay) — pure functions of the step."""

from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr: float, warmup: int, total: int,
           floor_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor_frac * peak_lr + (1 - floor_frac) * peak_lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, stable: int, decay: int,
        floor_frac: float = 0.01):
    """Warmup-Stable-Decay: the decay phase drops exponentially to
    floor_frac * peak (MiniCPM uses ~10% tail for the decay phase)."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    in_decay = step > (warmup + stable)
    t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0, 1)
    decay_lr = peak_lr * jnp.exp(jnp.log(floor_frac) * t)
    lr = jnp.where(step < warmup, warm,
                   jnp.where(in_decay, decay_lr, peak_lr))
    return lr


SCHEDULES = {"cosine": cosine, "wsd": wsd}
