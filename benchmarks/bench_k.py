"""Paper Figs. 14/15: behavior in k — query time, index size, and build
time for k in {1, 2, 3} (iaCPQx, as the paper's scalable variant)."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import capacity, interest
from repro.core.engine import Engine
from repro.core.query import instantiate_template

from .bench_query import interests_for
from .common import DATASETS, emit, timeit


def main() -> None:
    g = DATASETS["robots-like"]()
    rng = np.random.default_rng(1)
    present = np.unique(g.lbl)
    for k in (1, 2, 3):
        ints = [s[:k] if len(s) > k else s for s in interests_for(g)]
        caps = capacity.estimate_build_caps(g, k)
        us_build = timeit(lambda: interest.build_interest(g, k, ints, caps),
                          warmup=0, iters=1)
        ia = interest.build_interest(g, k, ints, caps)
        l2c, c2p = ia.size_entries()
        emit(f"fig15/robots-like/k{k}/build", us_build,
             f"IS={l2c + c2p} classes={ia.n_classes}")
        eng = Engine(ia)
        qs = [instantiate_template("S", rng.choice(present, 4).tolist())
              for _ in range(3)]
        qs += [instantiate_template("C4", rng.choice(present, 4).tolist())
               for _ in range(3)]
        us = timeit(lambda: [eng.execute(q) for q in qs]) / len(qs)
        emit(f"fig14/robots-like/k{k}/query", us, "S+C4 mix")
        jax.clear_caches()


if __name__ == "__main__":
    main()
