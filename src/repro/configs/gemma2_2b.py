"""gemma2-2b [arXiv:2408.00118; hf]: 26L d_model=2304 8H (GQA kv=4)
d_ff=9216 vocab=256000 — local(4096)/global alternating attention, attn
logit softcap 50, final logit softcap 30, GeGLU, sandwich norms,
head_dim 256, embeddings scaled by sqrt(d_model)."""

import dataclasses
import math

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10_000.0,
    activation="gelu",
    gemma_norms=True,
    embed_scale=math.sqrt(2304),
    tie_embeddings=True,
    max_seq_len=524288 + 64,
    remat=True,
    q_chunk=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window=16, max_seq_len=128,
    embed_scale=math.sqrt(64), param_dtype="float32",
)

SPEC = ArchSpec(
    arch_id="gemma2-2b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    # long_500k RUNS: local layers cap their KV window at 4096; the 13
    # global layers keep a 524k KV cache (decode is O(T) per token), which
    # shards over the mesh — see DESIGN.md.
    shapes=lm_shapes(long_ok=True, arch="gemma2-2b"),
    notes="alternating local/global attention + logit softcaps.",
)
