"""Version-compatibility shims for the jax mesh / shard_map API.

The sharded engine targets the modern API (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``, ``jax.sharding.set_mesh``);
older installs (<= 0.4.x) spell these ``jax.experimental.shard_map``
with ``check_rep``, no axis types, and the Mesh context manager.  All
mesh-touching code goes through this module so both generations work.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType  # noqa: F401

    _HAS_AXIS_TYPES = True
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None
    _HAS_AXIS_TYPES = False


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported; on jax
    builds predating ``jax.make_mesh`` (< 0.4.35), assemble the Mesh
    from ``mesh_utils`` directly."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils

    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    New jax has ``jax.sharding.set_mesh``; on older versions the Mesh
    object itself is the context manager."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off (our bodies mix
    replicated and sharded outputs, which the checker rejects)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental import shard_map as _sm

    return _sm.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
