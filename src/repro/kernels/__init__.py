"""Pallas TPU kernels for the engine's compute hot spots, each with an
ops.py jit wrapper (+ jnp fallback) and a ref.py pure-jnp oracle:
sorted_intersect (CONJUNCTION), expand_join (JOIN / I_c2p materialize),
fingerprint (signature sets), segment_softmax (GNN substrate)."""
