"""Hypothesis property tests for lazy maintenance (Prop. 4.2):

* arbitrary interleavings of edge updates and queries on small random
  graphs never change query answers — the lazily-split mirror, a
  from-scratch rebuilt index, and the semantics oracle always agree;
* ``n_splits`` grows monotonically between rebuilds (lazy updates only
  ever split classes, never merge);
* the mirror→device flush agrees with the mirror at every prefix point.
"""

import tempfile

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import lifecycle, oracle
from repro.core.engine import Engine
from repro.core.graph import LabeledGraph
from repro.core.maintenance import MaintainableIndex
from repro.core.service import QueryService

N_VERTICES = 7
N_LABELS = 2

edge_st = st.tuples(
    st.integers(0, N_VERTICES - 1),
    st.integers(0, N_VERTICES - 1),
    st.integers(0, N_LABELS - 1),
)

# an op is (kind, v, u, lbl): kind 0 = insert, 1 = delete, 2 = relabel
op_st = st.tuples(st.integers(0, 2), st.integers(0, N_VERTICES - 1),
                  st.integers(0, N_VERTICES - 1), st.integers(0, N_LABELS - 1))


def _to_update(op, g: LabeledGraph):
    kind, v, u, l = op
    base = [tuple(map(int, e)) for e in g._base_edges()]
    if kind == 0 or not base:
        return ("insert_edge", v, u, l)
    target = base[(v * N_VERTICES + u) % len(base)]
    if kind == 1:
        return ("delete_edge", *target)
    return ("change_label", *target, (target[2] + 1) % N_LABELS)


class TestInterleavingProperty:
    @given(edges=st.lists(edge_st, min_size=2, max_size=10),
           ops=st.lists(op_st, min_size=1, max_size=6),
           qseed=st.integers(0, 2**31 - 1))
    @settings(max_examples=12, deadline=None)
    def test_answers_invariant_under_lazy_maintenance(self, edges, ops, qseed):
        """At every point of an update/query interleaving, the lazy
        mirror answers exactly like a from-scratch rebuild of the current
        graph (Prop. 4.2) — the split partition loses pruning power, not
        correctness."""
        g = LabeledGraph.from_edges(N_VERTICES, N_LABELS, edges)
        mi = MaintainableIndex.build(g, 2)
        rng = np.random.default_rng(qseed)
        splits_seen = 0
        for op in ops:
            mi.apply_updates([_to_update(op, mi.g)])
            assert mi.n_splits >= splits_seen  # only grows between rebuilds
            splits_seen = mi.n_splits
            q = oracle.random_cpq(rng, mi.g, 2)
            rebuilt = oracle.build_index(mi.g, 2)
            truth = oracle.cpq_eval(mi.g, q)
            assert mi.query(q) == truth
            assert oracle.query_with_index(mi.g, rebuilt, q) == truth

    @given(edges=st.lists(edge_st, min_size=2, max_size=8),
           ops=st.lists(op_st, min_size=1, max_size=4),
           qseed=st.integers(0, 2**31 - 1))
    @settings(max_examples=6, deadline=None)
    def test_flush_agrees_with_mirror_at_every_prefix(self, edges, ops, qseed):
        """The device image refreshed by flush() answers exactly like the
        host mirror after every update batch."""
        g = LabeledGraph.from_edges(N_VERTICES, N_LABELS, edges)
        mi = MaintainableIndex.build(g, 2)
        rng = np.random.default_rng(qseed)
        for op in ops:
            mi.apply_updates([_to_update(op, mi.g)])
            eng = Engine(mi.flush())
            for _ in range(2):
                q = oracle.random_cpq(rng, mi.g, 2)
                got = {tuple(r) for r in eng.execute(q).tolist()}
                assert got == oracle.cpq_eval(mi.g, q), q

    @given(edges=st.lists(edge_st, min_size=2, max_size=10),
           ops=st.lists(op_st, min_size=1, max_size=8))
    @settings(max_examples=12, deadline=None)
    def test_partition_stays_cpq_correct(self, edges, ops):
        """The lazily-updated mirror keeps the partition invariant the
        index needs: classes are cycle-pure and signature-pure."""
        g = LabeledGraph.from_edges(N_VERTICES, N_LABELS, edges)
        mi = MaintainableIndex.build(g, 2)
        updates = []
        for op in ops:
            updates.append(_to_update(op, mi.g))
        mi.apply_updates(updates)
        seqs = oracle.enumerate_pairs(mi.g, 2)
        for c, ps in mi.index.c2p.items():
            sig0 = frozenset(seqs.get(ps[0], frozenset()))
            if mi.index.interests is not None:
                sig0 = frozenset(s for s in sig0 if s in mi.index.interests)
            for p in ps[1:]:
                sig = frozenset(seqs.get(p, frozenset()))
                if mi.index.interests is not None:
                    sig = frozenset(s for s in sig if s in mi.index.interests)
                assert sig == sig0, f"class {c} not signature-pure"
                assert (p[0] == p[1]) == mi.index.cyclic[c]


# an event drives one step of the lifecycle interleaving:
# kind 0-2 = graph op (as op_st), 3 = interest op, 4 = checkpoint,
# 5 = restore an earlier checkpoint; (a, b) parameterize the event.
event_st = st.tuples(st.integers(0, 5), st.integers(0, N_VERTICES - 1),
                     st.integers(0, N_VERTICES - 1), st.integers(0, 3))


class TestCheckpointInterleavingProperty:
    @given(edges=st.lists(edge_st, min_size=2, max_size=8),
           events=st.lists(event_st, min_size=2, max_size=8),
           qseed=st.integers(0, 2**31 - 1))
    @settings(max_examples=6, deadline=None)
    def test_restore_plus_replay_equals_from_scratch(self, edges, events,
                                                     qseed):
        """Crash-recovery is equivalent to never having crashed: under a
        random interleaving of graph updates, interest updates, queries,
        checkpoints, and in-place restores, restoring ANY checkpoint
        whose history is a prefix of the final history and replaying the
        suffix of updates reaches exactly the final serving state — same
        graph, same interests, answers equal to the semantics oracle on
        a from-scratch view of the final graph."""
        g = LabeledGraph.from_edges(N_VERTICES, N_LABELS, edges)
        mi = MaintainableIndex.build(g, 2, interests=[(0,), (1,), (2,), (3,)])
        svc = QueryService(Engine(mi.flush()), maintainer=mi)
        rng = np.random.default_rng(qseed)

        with tempfile.TemporaryDirectory() as d:
            log: list = []  # concrete update tuples applied so far
            ckpts: list = []  # (step, snapshot of log at checkpoint time)
            step0 = svc.checkpoint(d)
            ckpts.append((step0, []))

            for kind, a, b, c in events:
                if kind <= 2:  # graph update through the write path
                    upd = _to_update((kind, a, b, c % N_LABELS),
                                     svc.maintainer.g)
                    svc.apply_updates([upd])
                    log.append(upd)
                elif kind == 3:  # interest update (k=2: len-2 sequences)
                    seq = (a % 4, b % 4)
                    op = ("insert_interest" if c % 2 else "delete_interest",
                          seq)
                    svc.apply_updates([op])
                    log.append(op)
                elif kind == 4:
                    step = svc.checkpoint(d)
                    ckpts.append((step, list(log)))
                else:  # in-place restore: history rewinds to the ckpt's
                    step, snap = ckpts[b % len(ckpts)]
                    svc.restore(d, step)
                    log = list(snap)
                if a % 2:  # interleave a served query (drains the queue)
                    q = oracle.random_cpq(rng, svc.maintainer.g, 2)
                    # careful: the query itself drains queued updates
                    got = {tuple(r) for r in svc.query(q).tolist()}
                    assert got == oracle.cpq_eval(svc.maintainer.g, q), q

            svc.flush()
            final_edges = {tuple(map(int, e))
                           for e in svc.maintainer.g._base_edges()}
            final_interests = svc.maintainer.index.interests
            probes = [oracle.random_cpq(rng, svc.maintainer.g, 2)
                      for _ in range(3)]
            truth = {q: oracle.cpq_eval(svc.maintainer.g, q) for q in probes}

            for step, snap in ckpts:
                if log[:len(snap)] != snap:
                    continue  # a restore rewound history past this ckpt
                replica = lifecycle.restore_service(d, step)
                suffix = log[len(snap):]
                if suffix:
                    replica.apply_updates(suffix)
                replica.flush()
                assert {tuple(map(int, e))
                        for e in replica.maintainer.g._base_edges()} \
                    == final_edges
                assert replica.maintainer.index.interests == final_interests
                for q in probes:
                    got = {tuple(r) for r in replica.query(q).tolist()}
                    assert got == truth[q], (step, q)


# a serving event: kind 0 = burst-submit reads, 1 = graph write through
# the service, 2 = manual flush; (v, u, l) parameterize the write.
serve_event_st = st.tuples(st.integers(0, 2), op_st)


class TestServingSerializabilityProperty:
    @given(edges=st.lists(edge_st, min_size=2, max_size=8),
           events=st.lists(serve_event_st, min_size=2, max_size=8),
           qseed=st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_queued_reads_see_the_submit_time_graph(self, edges, events,
                                                    qseed):
        """PR 7's serializability contract at the service level: with
        auto-flush off, a read burst-submitted between writes must
        answer on exactly the prefix of writes accepted BEFORE its
        submission — queued-but-undrained writes included, later writes
        never — under any interleaving of submits, writes and flushes."""
        g = LabeledGraph.from_edges(N_VERTICES, N_LABELS, edges)
        mi = MaintainableIndex.build(g, 2)
        svc = QueryService(Engine(mi.flush()), maintainer=mi,
                           max_batch=4, auto_flush=False)
        rng = np.random.default_rng(qseed)
        # host mirror of the ACCEPTED write prefix (the maintainer's own
        # graph only advances when the service drains)
        shadow = {tuple(map(int, e)) for e in g._base_edges()}
        expected = []  # (request, oracle truth at submit time)

        for kind, (opk, v, u, l) in events:
            if kind == 0:
                sg = LabeledGraph.from_edges(N_VERTICES, N_LABELS,
                                             sorted(shadow))
                for _ in range(2):
                    q = oracle.random_cpq(rng, sg, 2)
                    expected.append((svc.submit(q),
                                     oracle.cpq_eval(sg, q)))
            elif kind == 1:
                base = sorted(shadow)
                if opk != 0 and base:
                    target = base[(v * N_VERTICES + u) % len(base)]
                    shadow.discard(target)
                    if opk == 1:
                        svc.apply_updates([("delete_edge", *target)])
                    else:
                        relabeled = (target[0], target[1],
                                     (target[2] + 1) % N_LABELS)
                        shadow.add(relabeled)
                        svc.apply_updates([("change_label", *target,
                                            relabeled[2])])
                else:
                    shadow.add((v, u, l % N_LABELS))
                    svc.apply_updates([("insert_edge", v, u,
                                        l % N_LABELS)])
            else:
                svc.flush()
        svc.flush()
        for req, truth in expected:
            assert req.done and not req.shed
            got = {tuple(r) for r in req.result.tolist()}
            assert got == truth, req.query
