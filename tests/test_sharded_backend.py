"""ShardedBackend parity — in-process, over every visible device.

The whole-plan sharded executor (replicated class space, canonical
hash-partitioned pair space, psum'd overflow) runs fine on a mesh of one
device — every exchange is a self-send — so the full equivalence matrix
``ShardedBackend == LocalBackend == numpy oracle`` is checked here
without subprocess machinery.  The mesh spans ``jax.device_count()``
devices: 1 in the plain tier-1 run, 8 in the CI distributed step
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the
acceptance matrix at n_shards ∈ {1, 8}.  test_distributed.py
additionally covers the 8-device path from inside the plain suite via
subprocesses."""

import jax
import numpy as np
import pytest

from repro import compat
from repro.core import index as cindex, lifecycle, oracle
from repro.core.backend import LocalBackend
from repro.core.distributed import ShardedBackend
from repro.core.engine import Engine, QueryCaps
from repro.core.graph import LabeledGraph, example_graph
from repro.core.maintenance import MaintainableIndex
from repro.core.query import (
    TEMPLATE_ARITY,
    TEMPLATES,
    instantiate_template,
    parse,
)
from repro.core.service import QueryService

from conftest import random_graph


@pytest.fixture(scope="module")
def mesh1():
    """All visible devices on one 'engine' axis (1 normally; 8 in the
    CI distributed step)."""
    return compat.make_mesh((jax.device_count(),), ("engine",))


def _rows_set(rows):
    return {tuple(r) for r in np.asarray(rows).tolist()}


class TestShardedEngineParity:
    def test_template_suite_bit_identical(self, ex_graph, mesh1):
        """Every Fig. 5 template: the mesh engine returns the *same
        array* (values and order) as the local engine, and the right
        answer."""
        idx = cindex.build(ex_graph, 2)
        local = Engine(idx)
        sharded = Engine(idx, mesh=mesh1)
        assert isinstance(local.backend, LocalBackend)
        assert isinstance(sharded.backend, ShardedBackend)
        rng = np.random.default_rng(7)
        present = np.unique(ex_graph.lbl)
        for name in TEMPLATES:
            q = instantiate_template(
                name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
            a, b = local.execute(q), sharded.execute(q)
            assert a.shape == b.shape and np.array_equal(a, b), name
            assert _rows_set(b) == oracle.cpq_eval(ex_graph, q), name

    def test_identity_and_parse_paths(self, ex_graph, mesh1):
        idx = cindex.build(ex_graph, 2)
        local, sharded = Engine(idx), Engine(idx, mesh=mesh1)
        for text in ("id", "l0 & id", "(l0 . l1) & id", "l0 . id . l1"):
            q = parse(text, None, ex_graph.n_labels)
            a, b = local.execute(q), sharded.execute(q)
            assert np.array_equal(a, b), text
            assert _rows_set(b) == oracle.cpq_eval(ex_graph, q), text

    def test_batch_matches_sequential(self, ex_graph, mesh1):
        idx = cindex.build(ex_graph, 2)
        sharded = Engine(idx, mesh=mesh1)
        rng = np.random.default_rng(3)
        present = np.unique(ex_graph.lbl)
        qs = [instantiate_template("T", rng.choice(present, 3).tolist())
              for _ in range(5)]
        qs += [instantiate_template("C2", rng.choice(present, 2).tolist())
               for _ in range(3)]
        batch = sharded.execute_batch(qs)
        for q, rows in zip(qs, batch):
            assert np.array_equal(rows, sharded.execute(q))

    def test_overflow_ladder_retries_to_exact(self, ex_graph, mesh1):
        """Deliberately tiny caps: the psum'd sticky flag must drive the
        host double-and-retry to the exact answer, same as local."""
        idx = cindex.build(ex_graph, 2)
        sharded = Engine(idx, mesh=mesh1)
        q = parse("l0 . l1", None, ex_graph.n_labels)
        tiny = QueryCaps(class_cap=2, pair_cap=2, join_cap=2)
        rows = sharded.execute(q, caps=tiny)
        assert _rows_set(rows) == oracle.cpq_eval(ex_graph, q)


class TestShardedService:
    def test_service_and_write_path_reshard(self, mesh1):
        """QueryService over a mesh engine: same serving semantics, and
        the maintenance write path (mirror batch -> flush -> rebind)
        reshards the flushed arrays — answers track the updated graph."""
        g = example_graph()
        mi = MaintainableIndex.build(g, 2)
        engine = Engine(mi.flush(), mesh=mesh1)
        svc = QueryService(engine, maintainer=mi)
        q = parse("l0 . l1", None, g.n_labels)
        before = svc.query(q)
        assert _rows_set(before) == oracle.cpq_eval(g, q)
        old_backend = engine.backend
        old_arrays = old_backend.sharded
        old_compiled = dict(old_backend._cache)

        svc.apply_updates([("insert_edge", 0, 3, 0), ("delete_edge", 0, 1, 0)])
        after = svc.query(q)  # drain applies updates, flush reshards
        # rebind resharded *into* the same backend: new arrays, but the
        # compiled plan executables survive the flush
        assert engine.backend is old_backend
        assert engine.backend.sharded is not old_arrays
        for key, fn in old_compiled.items():
            assert engine.backend._cache.get(key) is fn
        assert _rows_set(after) == oracle.cpq_eval(mi.g, q)  # updated graph
        assert svc.stats.update_batches == 1
        # epoch bumped: the pre-update cached answer is unreachable
        assert svc.graph_epoch >= 1

    def test_random_graphs_seeded_sweep(self, mesh1):
        """Deterministic cousin of the hypothesis property (which lives
        in test_sharded_properties.py): a seeded sweep of random graphs
        through a random template each, sharded == local == oracle."""
        for seed in range(4):
            g = random_graph(seed, n_max=14, m_max=36)
            idx = cindex.build(g, 2)
            local, sharded = Engine(idx), Engine(idx, mesh=mesh1)
            rng = np.random.default_rng(seed)
            present = np.unique(g.lbl)
            names = sorted(TEMPLATES)
            name = names[int(rng.integers(len(names)))]
            q = instantiate_template(
                name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
            a, b = local.execute(q), sharded.execute(q)
            assert np.array_equal(a, b), (seed, name)
            assert _rows_set(b) == oracle.cpq_eval(g, q), (seed, name)


class TestShardedCheckpointRoundTrip:
    """Elastic save/restore of the sharded layout (lifecycle satellite):
    a checkpoint taken at n shards restores at m shards bit-identically
    to resharding the live index — the restore path IS gather_index →
    shard_index, and these tests pin that equality both ways (8 → 1 and
    1 → 8) without needing an 8-device mesh."""

    def _fields_equal(self, a, b):
        from repro.core.sharded_index import ShardedIndexArrays

        for f in ShardedIndexArrays._fields:
            x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
            assert x.shape == y.shape and np.array_equal(x, y), f

    def test_same_count_restore_is_verbatim(self, ex_graph, tmp_path):
        from repro.core.sharded_index import shard_index

        idx = cindex.build(ex_graph, 2)
        sharded = shard_index(idx, 4)
        lifecycle.save_sharded(sharded, idx.n_vertices, idx.k, str(tmp_path))
        back, n_vertices, k = lifecycle.load_sharded_arrays(str(tmp_path))
        assert (n_vertices, k) == (idx.n_vertices, idx.k)
        assert back.n_shards == 4
        self._fields_equal(back, sharded)

    def test_restore_at_other_count_equals_live_reshard(self, ex_graph,
                                                        tmp_path):
        """Save at 8, restore at 1 — and re-save the 1-way, restore at
        8 — each bit-identical to gather_index → shard_index."""
        from repro.core.sharded_index import gather_index, shard_index

        idx = cindex.build(ex_graph, 2)
        eight = shard_index(idx, 8)
        d8 = str(tmp_path / "eight")
        lifecycle.save_sharded(eight, idx.n_vertices, idx.k, d8)

        one, _, _ = lifecycle.load_sharded_arrays(d8, n_shards=1)
        assert one.n_shards == 1
        # the elastic path is literally gather -> shard: pin it
        gathered = gather_index(eight)
        wrapper = cindex.CPQxIndex(
            k=idx.k, n_vertices=idx.n_vertices, arrays=gathered,
            seq_ranges=cindex._pull_seq_ranges(gathered, idx.k),
            caps=idx.caps)
        self._fields_equal(one, shard_index(wrapper, 1))

        d1 = str(tmp_path / "one")
        lifecycle.save_sharded(one, idx.n_vertices, idx.k, d1)
        eight_again, _, _ = lifecycle.load_sharded_arrays(d1, n_shards=8)
        assert eight_again.n_shards == 8
        gathered1 = gather_index(one)
        wrapper1 = cindex.CPQxIndex(
            k=idx.k, n_vertices=idx.n_vertices, arrays=gathered1,
            seq_ranges=cindex._pull_seq_ranges(gathered1, idx.k),
            caps=idx.caps)
        self._fields_equal(eight_again, shard_index(wrapper1, 8))

    def test_backend_restore_serves_identically(self, ex_graph, mesh1,
                                                tmp_path):
        """ShardedBackend.save / .restore: the restored backend answers
        bit-identically to the local engine on the same index."""
        idx = cindex.build(ex_graph, 2)
        engine = Engine(idx, mesh=mesh1)
        engine.backend.save(str(tmp_path))
        restored = ShardedBackend.restore(str(tmp_path), mesh1)
        local = Engine(idx)
        mesh_engine = Engine(idx, mesh=mesh1)
        mesh_engine.backend = restored  # serve off the restored leaves
        rng = np.random.default_rng(11)
        present = np.unique(ex_graph.lbl)
        for name in sorted(TEMPLATES)[:6]:
            q = instantiate_template(
                name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
            a, b = local.execute(q), mesh_engine.execute(q)
            assert np.array_equal(a, np.asarray(b)), name
            assert _rows_set(b) == oracle.cpq_eval(ex_graph, q), name

    def test_service_restored_on_mesh_survives_maintenance(self, tmp_path,
                                                           mesh1):
        """The promotion story end-to-end on a mesh: checkpoint a local
        service, promote a replica ONTO the mesh (restore_service(mesh=)),
        then push updates through the replica's write path — the flush
        reshards and answers track the updated graph."""
        g = example_graph()
        mi = MaintainableIndex.build(g, 2)
        svc = QueryService(Engine(mi.flush()), maintainer=mi)
        q = parse("l0 . l1", None, g.n_labels)
        svc.query(q)
        step = svc.checkpoint(str(tmp_path))

        replica = lifecycle.restore_service(str(tmp_path), step, mesh=mesh1)
        assert isinstance(replica.engine.backend, ShardedBackend)
        assert _rows_set(replica.query(q)) == oracle.cpq_eval(g, q)

        replica.apply_updates([("insert_edge", 0, 3, 0),
                               ("delete_edge", 0, 1, 0)])
        after = replica.query(q)  # drain -> mirror batch -> reshard flush
        assert _rows_set(after) == oracle.cpq_eval(replica.maintainer.g, q)
        assert replica.stats.update_batches == 1
