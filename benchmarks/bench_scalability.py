"""Paper Fig. 11: iaCPQx query time as the (gMark citation) graph grows.
CPU-scaled sizes; the claim is near-flat growth for class-space queries
and bounded growth for join-heavy ones."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import capacity, interest
from repro.core.engine import Engine
from repro.core.query import instantiate_template
from repro.data.graphs import gmark_citation

from .common import emit, timeit

# the paper's five citation-schema interests (Sec. VI "Methods"):
# cites-cites, cites-supervises, publishesIn-heldIn, worksIn-heldIn⁻¹,
# livesIn-worksIn⁻¹  (base labels: 0..5, inverse = +6)
GMARK_INTERESTS = [(0, 0), (0, 1), (4, 5), (3, 11), (2, 9)]


def main() -> None:
    rng = np.random.default_rng(2)
    for n in (250, 500, 1000, 2000):
        g = gmark_citation(n, avg_degree=6, seed=5)
        caps = capacity.estimate_build_caps(g, 2)
        ia = interest.build_interest(g, 2, GMARK_INTERESTS, caps)
        eng = Engine(ia)
        present = np.unique(g.lbl)
        qs = [instantiate_template("S", rng.choice(present, 4).tolist())
              for _ in range(3)]
        qs += [instantiate_template("T", rng.choice(present, 3).tolist())
               for _ in range(3)]
        us = timeit(lambda: [eng.execute(q) for q in qs]) / len(qs)
        emit(f"fig11/gmark-n{n}/query", us,
             f"edges={g.n_edges} classes={ia.n_classes}")
        jax.clear_caches()


if __name__ == "__main__":
    main()
