"""Logical-axis sharding rules -> concrete PartitionSpecs per mesh.

One rule table drives every architecture; each dimension is sharded over
its logical axis only when the size divides the mesh axis (else
replicated) — so the same model code lowers on the 16x16 pod, the
2x16x16 multi-pod, and any elastic restart shape.

Baseline layout (the paper-faithful / standard-megatron starting point;
§Perf hillclimbs mutate this):
  * batch        -> ("pod", "data")     [DP across pods and data axis]
  * TP (heads, d_ff, vocab)   -> "model"
  * FSDP (param d_model dims) -> "data"
  * GNN nodes/edges, engine pair tables -> all axes flattened
  * MoE experts  -> replicated (per-group local dispatch), expert d_ff
    over "model"
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.transformer import LMConfig


def _ok(size: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    else:
        n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    return size % n == 0


def _spec(mesh, *dim_axis_pairs):
    """Build a PartitionSpec, dropping axes that don't divide."""
    spec = []
    for size, axis in dim_axis_pairs:
        spec.append(axis if (axis and _ok(size, mesh, axis)) else None)
    return P(*spec)


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------- #
# LM
# ---------------------------------------------------------------------- #


def lm_param_specs(cfg: LMConfig, mesh: Mesh, fsdp: str | tuple | None = "data",
                   embed_fsdp: bool = True) -> dict:
    """``embed_fsdp=False`` keeps the embedding's d_model dim replicated:
    the token gather over a (vocab x d_model)-sharded table triggers
    GSPMD "involuntary full rematerialization" (measured: +tens of GB of
    temp per device on train cells) — §Perf hillclimb lever."""
    d, h, kv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                       cfg.d_ff)
    v, nl = cfg.padded_vocab, cfg.n_layers
    m = "model"
    efsdp = fsdp if embed_fsdp else None
    layer = {
        "attn_norm": P(None, None),
        "wq": _spec(mesh, (nl, None), (d, fsdp), (h * hd, m)),
        "wk": _spec(mesh, (nl, None), (d, fsdp), (kv * hd, m)),
        "wv": _spec(mesh, (nl, None), (d, fsdp), (kv * hd, m)),
        "wo": _spec(mesh, (nl, None), (h * hd, m), (d, fsdp)),
        "mlp_norm": P(None, None),
    }
    if cfg.gemma_norms:
        layer["post_attn_norm"] = P(None, None)
        layer["post_mlp_norm"] = P(None, None)
    if cfg.is_moe:
        e = cfg.n_experts
        layer["router"] = _spec(mesh, (nl, None), (d, fsdp), (e, None))
        layer["w_gate"] = _spec(mesh, (nl, None), (e, None), (d, fsdp), (f, m))
        layer["w_up"] = _spec(mesh, (nl, None), (e, None), (d, fsdp), (f, m))
        layer["w_down"] = _spec(mesh, (nl, None), (e, None), (f, m), (d, fsdp))
    else:
        layer["w_gate"] = _spec(mesh, (nl, None), (d, fsdp), (f, m))
        layer["w_up"] = _spec(mesh, (nl, None), (d, fsdp), (f, m))
        layer["w_down"] = _spec(mesh, (nl, None), (f, m), (d, fsdp))
    specs = {
        "embed": _spec(mesh, (v, m), (d, efsdp)),
        "final_norm": P(None),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = _spec(mesh, (d, efsdp), (v, m))
    return specs


def lm_batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return P(axes, None)


def lm_cache_specs(cfg: LMConfig, mesh: Mesh) -> dict:
    """KV cache (L, B, T, KV, hd): batch over data axes; kv heads over
    model when divisible, else shard the *time* axis over model
    (flash-decoding style; the combine is a psum XLA inserts)."""
    baxes = tuple(a for a in mesh.axis_names if a != "model")
    kv_div = _ok(cfg.n_kv_heads, mesh, "model")
    if kv_div:
        spec = P(None, baxes, None, "model", None)
    else:
        spec = P(None, baxes, "model", None, None)
    return {"k": spec, "v": spec}


def lm_opt_specs(param_specs: dict) -> dict:
    """AdamW moments shard exactly like their params."""
    import jax

    from repro.train.optim import AdamWState

    return AdamWState(
        step=P(), mu=jax.tree.map(lambda s: s, param_specs),
        nu=jax.tree.map(lambda s: s, param_specs),
    )


# ---------------------------------------------------------------------- #
# GNN — nodes/edges flattened over every axis
# ---------------------------------------------------------------------- #


def gnn_batch_specs(mesh: Mesh, n_nodes: int, n_edges: int) -> dict:
    axes = tuple(mesh.axis_names)

    def rowspec(n):
        return P(axes, None) if n % mesh.devices.size == 0 else P()

    def rowspec1(n):
        return P(axes) if n % mesh.devices.size == 0 else P()

    return {
        "node_feat": rowspec(n_nodes),
        "edge_feat": rowspec(n_edges),
        "senders": rowspec1(n_edges),
        "receivers": rowspec1(n_edges),
        "node_mask": rowspec1(n_nodes),
        "edge_mask": rowspec1(n_edges),
        "positions": rowspec(n_nodes),
        "graph_ids": rowspec1(n_nodes),
    }


def gnn_param_specs(params, mesh: Mesh) -> dict:
    """GNN params are small: replicate (the hillclimb may TP d_hidden)."""
    return jax.tree.map(lambda _: P(), params)


# ---------------------------------------------------------------------- #
# recsys
# ---------------------------------------------------------------------- #


def bst_param_specs(cfg, mesh: Mesh) -> dict:
    """Embedding tables row-sharded over "model" (the big memory);
    dense layers replicated."""
    m = "model"
    return {
        "item_emb": _spec(mesh, (cfg.n_items, m), (cfg.embed_dim, None)),
        "cat_emb": _spec(mesh, (cfg.n_cats, m), (cfg.embed_dim, None)),
        "ctx_emb": _spec(mesh, (cfg.n_context, m), (cfg.embed_dim, None)),
        "pos_emb": P(None, None),
        "blocks": {
            k: P(*([None] * nd))
            for k, nd in [("wq", 3), ("wk", 3), ("wv", 3), ("wo", 3),
                          ("ff1", 3), ("ff2", 3), ("ln1", 2), ("ln2", 2)]
        },
        "mlp": {k: P(None, None) if k.startswith("w") else P(None)
                for k in _bst_mlp_keys(cfg)},
    }


def _bst_mlp_keys(cfg):
    n = len(cfg.mlp_dims) + 1
    return [f"w{i}" for i in range(n)] + [f"b{i}" for i in range(n)]


def bst_batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in mesh.axis_names if a != "model")
    return P(axes)
