"""Pallas TPU kernel: fused multi-column two-lane avalanche fingerprint —
the signature-set hashing hot spot of index construction (Algorithm 1's
set grouping; DESIGN.md §2 "order-invariant fingerprint").

The jnp reference chains 6 elementwise ops per column per lane, i.e.
XLA materializes ~12·k intermediates through HBM for a k-column relation.
The kernel runs the whole mix chain for both lanes over a VMEM tile in
registers: one HBM read per input element, two writes per row.

All arithmetic is wrapping uint32 (TPU-native; no 64-bit on the hot
path).  Must stay bit-identical to ``relational.fingerprint_rows`` — the
op is used interchangeably with it and tests assert exact equality.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 2048

_MIX_A = np.uint32(0x7FEB352D)
_MIX_B = np.uint32(0x846CA68B)


def _mix32(h, salt):
    h = h ^ jnp.uint32(salt)
    h = (h ^ (h >> 16)) * _MIX_A
    h = (h ^ (h >> 15)) * _MIX_B
    return h ^ (h >> 16)


def _fp_kernel(*refs, n_cols: int, salt: int):
    col_refs = refs[:n_cols]
    h1_ref, h2_ref = refs[n_cols], refs[n_cols + 1]
    shape = col_refs[0].shape
    h1 = jnp.full(shape, np.uint32(0x9E3779B9), jnp.uint32)
    h2 = jnp.full(shape, np.uint32(0x85EBCA6B), jnp.uint32)
    for j in range(n_cols):
        c = col_refs[j][...].astype(jnp.uint32)
        h1 = _mix32(c ^ (h1 * np.uint32(31)), salt * 2 + 101 + j)
        h2 = _mix32(c ^ (h2 * np.uint32(37)), salt * 2 + 202 + j)
    h1_ref[...] = h1
    h2_ref[...] = h2


@functools.partial(jax.jit, static_argnames=("salt", "block"))
def fingerprint_rows(cols: tuple, salt: int = 0, block: int = DEFAULT_BLOCK):
    """Two uint32 fingerprints per row of an int32 column tuple.
    Bit-identical to ``relational.fingerprint_rows``."""
    n = cols[0].shape[0]
    assert n % block == 0 or n < block, (n, block)
    blk = min(block, n)
    kernel = functools.partial(_fp_kernel, n_cols=len(cols), salt=salt)
    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32)] * 2,
        grid=(max(1, n // blk),),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,), memory_space=pltpu.VMEM)
            for _ in cols
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,), memory_space=pltpu.VMEM)
        ] * 2,
        interpret=jax.default_backend() == "cpu",
    )(*cols)
