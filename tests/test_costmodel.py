"""The device cost model (PR 8): affine fits and their noise clamps, the
versioned JSON / checkpoint codec, cost-aware planning (plans without a
table stay byte-identical; a join-heavy table flips the C4 split),
calibrated capacity-rung selection (answers never change), online
refinement, and engine telemetry semantics across rebind."""

import numpy as np
import pytest

from repro.core import index as cindex, lifecycle, oracle
from repro.core.costmodel import DeviceCostTable, OpCost, fit_affine
from repro.core.engine import Engine
from repro.core.graph import example_graph
from repro.core.maintenance import MaintainableIndex
from repro.core.optimizer import estimate_plan, optimize_query
from repro.core.query import instantiate_template, parse, plan_query
from repro.core.service import QueryService
from repro.core.stats import IndexStats
from repro.data.graphs import skewed_labeled_graph


@pytest.fixture(scope="module")
def skewed_stats():
    g = skewed_labeled_graph(n_vertices=40, wave=12, rare_edges=10, seed=7)
    return IndexStats.from_oracle(oracle.build_index(g, 2), g.n_vertices)


def _toy_table(**overrides) -> DeviceCostTable:
    """A hand-built table with every operator priced — small enough to
    reason about, complete enough to drive the optimizer."""
    fields = dict(
        device_kind="test",
        scale=1.25,
        dispatch_floor_ns=42.0,
        ops={"lookup": OpCost(100.0, 1.0),
             "materialize": OpCost(200.0, 2.0),
             "conjoin": OpCost(150.0, 1.5),
             "join": OpCost(5_000.0, 3.0),
             "identity": OpCost(50.0, 0.5),
             "union_step": OpCost(80.0, 0.0)},
        block_q={256: 64, 4096: 512},
        block_t={1024: 128},
        vmem_words=123_456,
        samples={"join": [[256.0, 5768.0], [1024.0, 8072.0]]},
    )
    fields.update(overrides)
    return DeviceCostTable(**fields)


def _rows_set(rows):
    return {tuple(r) for r in np.asarray(rows).tolist()}


# ---------------------------------------------------------------------- #
# affine fitting
# ---------------------------------------------------------------------- #


class TestAffineFit:
    def test_exact_affine_data_recovered(self):
        rows = np.array([128.0, 512.0, 2048.0, 8192.0])
        cost = fit_affine(rows, 750.0 + 3.25 * rows)
        assert cost.fixed_ns == pytest.approx(750.0)
        assert cost.per_row_ns == pytest.approx(3.25)
        assert cost.ns(1000) == pytest.approx(750.0 + 3250.0)

    def test_negative_slope_collapses_to_constant(self):
        """Decreasing times are noise — never price work below zero."""
        cost = fit_affine([100, 200, 400], [900.0, 600.0, 300.0])
        assert cost.per_row_ns == 0.0
        assert cost.fixed_ns == pytest.approx(600.0)  # the mean

    def test_negative_intercept_refits_through_origin(self):
        rows = np.array([100.0, 1000.0, 10_000.0])
        cost = fit_affine(rows, 5.0 * rows - 40.0)
        assert cost.fixed_ns == 0.0
        assert cost.per_row_ns == pytest.approx(5.0, rel=0.01)

    def test_degenerate_inputs(self):
        assert fit_affine([], []) == OpCost(0.0, 0.0)
        single = fit_affine([256.0], [1234.0])
        assert single == OpCost(1234.0, 0.0)
        same_rows = fit_affine([512.0, 512.0], [100.0, 300.0])
        assert same_rows == OpCost(200.0, 0.0)


# ---------------------------------------------------------------------- #
# the artifact codec (JSON file + checkpoint leaf)
# ---------------------------------------------------------------------- #


class TestTableCodec:
    def test_json_round_trip_is_lossless(self):
        t = _toy_table()
        back = DeviceCostTable.from_json(t.to_json())
        assert back.to_json() == t.to_json()
        assert back.ops["join"] == t.ops["join"]
        assert back.block_q == t.block_q and back.block_t == t.block_t
        assert back.vmem_words == t.vmem_words

    def test_save_load_round_trip(self, tmp_path):
        t = _toy_table()
        path = str(tmp_path / "table.json")
        t.save(path)
        assert DeviceCostTable.load(path).to_json() == t.to_json()

    def test_rejects_foreign_and_future_payloads(self):
        with pytest.raises(ValueError, match="not a cost table"):
            DeviceCostTable.from_json({"format": "something-else"})
        future = _toy_table().to_json()
        future["version"] = future["version"] + 1
        with pytest.raises(ValueError, match="newer than supported"):
            DeviceCostTable.from_json(future)

    def test_checkpoint_leaf_round_trip(self):
        """export_state is one flat uint8 array — the only shape the
        checkpoint pytree codec accepts — and decodes losslessly."""
        t = _toy_table()
        leaf = t.export_state()
        assert leaf.dtype == np.uint8 and leaf.ndim == 1
        assert DeviceCostTable.from_state(leaf).to_json() == t.to_json()

    def test_none_vmem_words_survives(self):
        t = _toy_table(vmem_words=None)
        assert DeviceCostTable.from_json(t.to_json()).vmem_words is None


# ---------------------------------------------------------------------- #
# pricing semantics
# ---------------------------------------------------------------------- #


class TestPricing:
    def test_stage_ns_applies_scale(self):
        t = _toy_table(scale=2.0)
        assert t.stage_ns("lookup", 100) == pytest.approx(2.0 * 200.0)

    def test_unknown_operator_prices_zero(self):
        """Old tables stay usable when a new operator kind appears."""
        assert _toy_table().stage_ns("hyperjoin", 1 << 20) == 0.0

    def test_dispatch_floor_caps_from_below(self):
        t = _toy_table(dispatch_floor_ns=1e9)
        assert t.plan_dispatch_ns(256) == 1e9

    def test_expected_dispatch_prices_retry_risk(self):
        t = _toy_table(dispatch_floor_ns=0.0)
        base = t.plan_dispatch_ns(1024)
        # generous headroom, sound bound: almost no retry mass
        safe = t.expected_dispatch_ns(1024, est_rows=16, risky=False)
        # same rung but join-bearing estimate near capacity: retry priced
        risky = t.expected_dispatch_ns(1024, est_rows=1000, risky=True)
        assert base <= safe < risky
        assert risky <= base + t.plan_dispatch_ns(2048)

    def test_tuned_block_right_neighbor(self):
        t = _toy_table()
        assert t.tuned_block("block_q", 256) == 64
        assert t.tuned_block("block_q", 300) == 512  # next rung up
        assert t.tuned_block("block_q", 1 << 20) == 512  # largest known
        assert t.tuned_block("block_t", 8) == 128
        assert DeviceCostTable().tuned_block("block_q", 256) is None


# ---------------------------------------------------------------------- #
# cost-aware planning
# ---------------------------------------------------------------------- #


class TestCostAwarePlanning:
    # representative golden pairs from test_optimizer.TestGoldenPlans —
    # the byte-identity contract for table-less planning
    GOLDEN = [
        ("T", [0, 0, 1],
         ("conj", ("lookup", [(1,)]), ("lookup", [(0, 0)]))),
        ("C4", [1, 0, 2, 3],
         ("lookup", [(1,), (0, 2), (3,)])),
    ]

    @pytest.mark.parametrize("case", GOLDEN, ids=[c[0] for c in GOLDEN])
    def test_no_table_is_byte_identical(self, skewed_stats, case):
        """cost_table=None must reproduce the golden row-count plans
        exactly — the new cost channel defaults to inert."""
        name, labels, want = case
        q = instantiate_template(name, labels)
        assert optimize_query(q, 2, skewed_stats) == want
        assert optimize_query(q, 2, skewed_stats, cost_table=None) == want
        est = estimate_plan(want, skewed_stats)
        assert est.cost_ns == 0.0  # no table, no nanoseconds

    def test_table_populates_cost_channel(self, skewed_stats):
        q = instantiate_template("C4", [1, 0, 2, 3])
        plan = plan_query(q, 2)
        est = estimate_plan(plan, skewed_stats, cost_table=_toy_table())
        assert est.cost_ns > 0.0

    def test_join_heavy_table_flips_c4_to_two_leaves(self, skewed_stats):
        """When the fixed dispatch cost of a JOIN dwarfs per-row work
        (the calibrated CPU/interpret regime), the rare-leaf 3-segment
        split (2 joins) must lose to the greedy 2-segment split (1
        join) — the exact misprediction ISSUE 8's C4 gate closes."""
        table = _toy_table()
        table.ops["join"] = OpCost(1e9, 3.0)
        q = instantiate_template("C4", [1, 0, 2, 3])
        assert optimize_query(q, 2, skewed_stats, cost_table=table) == \
            ("lookup", [(1, 0), (2, 3)])

    def test_per_row_dominated_table_keeps_rare_leaf_split(
            self, skewed_stats):
        """With free dispatches and pure per-row pricing the cost model
        degenerates to the row-count model, so the golden 3-leaf split
        must survive."""
        table = _toy_table(scale=1.0, dispatch_floor_ns=0.0)
        table.ops = {op: OpCost(0.0, 1.0) for op in table.ops}
        q = instantiate_template("C4", [1, 0, 2, 3])
        assert optimize_query(q, 2, skewed_stats, cost_table=table) == \
            ("lookup", [(1,), (0, 2), (3,)])


# ---------------------------------------------------------------------- #
# calibrated engines: answers never change
# ---------------------------------------------------------------------- #


class TestCalibratedEngine:
    def test_answers_identical_with_and_without_table(self, ex_graph):
        """The table moves capacities and splits, never answers — the
        same contract the ladder gives misestimates."""
        idx = cindex.build(ex_graph, 2)
        plain, priced = Engine(idx), Engine(idx, cost_table=_toy_table())
        for text in ("(l0 . l0) & l0-", "l0 . l1", "l0 & id", "l1 . l0"):
            q = parse(text, None, ex_graph.n_labels)
            assert _rows_set(plain.execute(q)) == _rows_set(priced.execute(q))

    def test_calibrated_caps_stay_pow2_and_bounded(self, ex_graph):
        from repro.core.query import plan_shape

        eng = Engine(cindex.build(ex_graph, 2),
                     cost_table=_toy_table(dispatch_floor_ns=0.0))
        q = parse("l0 . l1", None, ex_graph.n_labels)
        plan = eng.plan(q)
        caps = eng.estimate_caps(eng.lookup_ranges(plan), plan_shape(plan),
                                 plan)
        cap = int(caps.pair_cap)
        assert cap & (cap - 1) == 0  # pow2 rung
        assert cap <= int(eng._default_caps.pair_cap) * 8


# ---------------------------------------------------------------------- #
# online refinement
# ---------------------------------------------------------------------- #


class TestRefinement:
    def test_refit_from_observations(self):
        t = DeviceCostTable()
        for rows in (256, 1024, 4096):
            t.observe("join", rows, 1000.0 + 2.0 * rows)
        cost = t.refit("join")
        assert cost.fixed_ns == pytest.approx(1000.0)
        assert cost.per_row_ns == pytest.approx(2.0)

    def test_refine_scale_geometric_ema_and_clamp(self):
        t = DeviceCostTable(scale=1.0)
        assert t.refine_scale(2000.0, 1000.0, weight=1.0) == pytest.approx(2.0)
        t.refine_scale(0.0, 1000.0)  # non-positive measurement: ignored
        assert t.scale == pytest.approx(2.0)
        for _ in range(40):
            t.refine_scale(1e12, 1.0, weight=1.0)
        assert t.scale == 64.0  # clamped — one corrupt row can't explode it

    def test_refine_from_telemetry_moves_dispatch_floor(self):
        t = DeviceCostTable(dispatch_floor_ns=0.0)

        class Snap:
            dispatches = 10

        t.refine_from_telemetry(Snap(), elapsed_ns=10_000.0, weight=0.5)
        assert t.dispatch_floor_ns == pytest.approx(500.0)
        t.refine_from_telemetry(Snap(), elapsed_ns=0.0)  # no-op
        assert t.dispatch_floor_ns == pytest.approx(500.0)

    def test_refine_from_trajectory_consumes_tagged_rows(self):
        t = DeviceCostTable(scale=1.0)
        payloads = [{"rows": [
            {"name": "q/cal", "us_per_call": 2.0,
             "derived": "predicted_ns=1000.0;scale=1.0"},  # measured 2000ns
            {"name": "q/other", "us_per_call": 5.0, "derived": "plain"},
        ]}]
        assert t.refine_from_trajectory(payloads, weight=1.0) == 1
        assert t.scale == pytest.approx(2.0)


# ---------------------------------------------------------------------- #
# checkpoint lifecycle
# ---------------------------------------------------------------------- #


def _service(cost_table=None):
    g = example_graph()
    mi = MaintainableIndex.build(g, 2)
    return QueryService(Engine(mi.flush(), cost_table=cost_table),
                        maintainer=mi), g


class TestCheckpointRoundTrip:
    def test_cost_table_survives_checkpoint(self, tmp_path):
        table = _toy_table()
        svc, g = _service(cost_table=table)
        step = svc.checkpoint(str(tmp_path))
        state = lifecycle.load_state(str(tmp_path), step)
        assert state.cost_table is not None
        assert state.cost_table.to_json() == table.to_json()

    def test_restored_service_answers_and_keeps_table(self, tmp_path):
        table = _toy_table()
        svc, g = _service(cost_table=table)
        step = svc.checkpoint(str(tmp_path))
        restored = lifecycle.restore_service(str(tmp_path), step)
        assert restored.engine.cost_table.to_json() == table.to_json()
        for text in ("l0 . l1", "(l0 . l0) & l0-"):
            q = parse(text, None, g.n_labels)
            assert _rows_set(restored.query(q)) == oracle.cpq_eval(g, q)

    def test_legacy_checkpoint_without_table_loads(self, tmp_path):
        """Pre-PR-8 checkpoints carry no costtable.blob leaf; they must
        restore with cost_table=None and serve unchanged."""
        svc, g = _service(cost_table=None)
        step = svc.checkpoint(str(tmp_path))
        state = lifecycle.load_state(str(tmp_path), step)
        assert state.cost_table is None
        restored = lifecycle.restore_service(str(tmp_path), step)
        assert restored.engine.cost_table is None
        q = parse("l0 . l1", None, g.n_labels)
        assert _rows_set(restored.query(q)) == oracle.cpq_eval(g, q)


# ---------------------------------------------------------------------- #
# telemetry semantics (the counters the refinement loop reads)
# ---------------------------------------------------------------------- #


class TestTelemetry:
    def test_counters_monotone_and_survive_rebind(self, ex_graph):
        table = _toy_table()
        eng = Engine(cindex.build(ex_graph, 2), cost_table=table)
        q = parse("l0 . l1", None, ex_graph.n_labels)
        eng.execute(q)
        q0, d0 = eng.telemetry.queries, eng.telemetry.dispatches
        assert q0 >= 1 and d0 >= 1
        # rebind describes a NEW index on the SAME device: lifetime
        # counters and the cost table both survive
        eng.rebind(cindex.build(ex_graph, 2))
        assert eng.telemetry.queries == q0
        assert eng.telemetry.dispatches == d0
        assert eng.cost_table is table
        eng.execute(q)
        assert eng.telemetry.queries == q0 + 1
        assert eng.telemetry.dispatches > d0

    def test_reset_zeroes_every_counter(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        eng.execute(parse("(l0 . l0) & l0-", None, ex_graph.n_labels))
        assert eng.telemetry.dispatches > 0
        eng.telemetry.reset()
        t = eng.telemetry
        assert (t.queries, t.dispatches, t.retry_rungs,
                t.default_jumps, t.union_lanes) == (0, 0, 0, 0, 0)
