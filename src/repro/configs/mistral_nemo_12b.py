"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407; hf]: 40L
d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072 — head_dim 128
(separate from d_model/n_heads), 128k context (rope theta 1e6)."""

import dataclasses

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="mistral-nemo-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    attn_pattern=("global",),
    rope_theta=1_000_000.0,
    activation="silu",
    tie_embeddings=False,
    max_seq_len=32768 * 16 + 64,
    remat=True,
    q_chunk=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, max_seq_len=128, param_dtype="float32",
)

SPEC = ArchSpec(
    arch_id="mistral-nemo-12b",
    family="lm",
    config=CONFIG,
    smoke=SMOKE,
    shapes=lm_shapes(long_ok=False, arch="mistral-nemo-12b"),
    notes="128k-context dense model; untied embeddings.",
)
