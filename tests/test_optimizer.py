"""Cost-based optimizer (PR 4): the statistics view agrees across every
index form (device, oracle mirror, sharded layout), the cost model's
exact/bounded estimates hold, golden plan snapshots on the skewed
fixture pin syntactic-vs-optimized behavior, and optimized plans are
always oracle-identical (hypothesis property + device differential)."""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import oracle
from repro.core.optimizer import (
    enumerate_splits,
    estimate_plan,
    join_card,
    optimize_query,
)
from repro.core.query import (
    TEMPLATE_ARITY,
    TEMPLATES,
    instantiate_template,
    parse,
    plan_lookup_seqs,
    plan_query,
)
from repro.core.stats import IndexStats
from repro.data.graphs import skewed_labeled_graph


def eval_plan_host(g, oidx, plan):
    """Evaluate any physical plan against the dict-form oracle index
    (the host twin of the device walker)."""
    pairs, classes = oracle._eval_plan(g, oidx, plan)
    if classes is not None:
        pairs = oracle._materialize(oidx, classes)
    return pairs


@pytest.fixture(scope="module")
def skewed():
    """Small deterministic skewed-hub fixture: graph + oracle index +
    stats (host-only — the optimizer needs no device)."""
    g = skewed_labeled_graph(n_vertices=40, wave=12, rare_edges=10, seed=7)
    oidx = oracle.build_index(g, 2)
    return g, oidx, IndexStats.from_oracle(oidx, g.n_vertices)


# ---------------------------------------------------------------------- #
# the statistics view
# ---------------------------------------------------------------------- #


class TestIndexStats:
    def test_seq_stats_are_exact(self, skewed):
        """seq_pairs / seq_classes / seq_cyclic_pairs recompute exactly
        from the oracle dicts for every indexed sequence."""
        _, oidx, stats = skewed
        assert stats.seq_ranges  # non-degenerate fixture
        for s, classes in oidx.l2c.items():
            assert stats.seq_classes(s) == len(classes)
            assert stats.seq_pairs(s) == sum(
                len(oidx.c2p[c]) for c in classes)
            assert stats.seq_cyclic_pairs(s) == sum(
                len(oidx.c2p[c]) for c in classes if oidx.cyclic[c])

    def test_missing_seq_is_zero(self, skewed):
        _, _, stats = skewed
        assert not stats.has_seq((5, 5))
        assert stats.seq_classes((5, 5)) == 0
        assert stats.seq_pairs((5, 5)) == 0

    def test_oracle_and_device_views_agree(self):
        """IndexStats.from_index (device arrays) == from_oracle (dict
        mirror) on every invariant the optimizer consumes — the PR 5
        endpoint statistics included."""
        from repro.core import index as cindex

        g = random_graph(31, n_max=14, m_max=40)
        dev = IndexStats.from_index(cindex.build(g, 2))
        host = IndexStats.from_oracle(oracle.build_index(g, 2),
                                      g.n_vertices)
        assert set(dev.seq_ranges) == set(host.seq_ranges)
        assert (dev.n_classes, dev.total_pairs) == (host.n_classes,
                                                    host.total_pairs)
        for s in dev.seq_ranges:
            assert dev.seq_classes(s) == host.seq_classes(s), s
            assert dev.seq_pairs(s) == host.seq_pairs(s), s
            assert dev.seq_cyclic_pairs(s) == host.seq_cyclic_pairs(s), s
            assert dev.seq_endpoints(s) == host.seq_endpoints(s), s

    def test_endpoint_stats_are_exact(self, skewed):
        """seq_endpoints recomputes exactly from the oracle dicts:
        distinct sources/targets and max out/in fanout over the union of
        the sequence's class pair lists."""
        _, oidx, stats = skewed
        for s, classes in oidx.l2c.items():
            pairs = [p for c in classes for p in oidx.c2p[c]]
            srcs = [p[0] for p in pairs]
            dsts = [p[1] for p in pairs]
            ep = stats.seq_endpoints(s)
            assert ep.d_src == len(set(srcs)), s
            assert ep.d_dst == len(set(dsts)), s
            assert ep.max_out == max(srcs.count(v) for v in set(srcs)), s
            assert ep.max_in == max(dsts.count(v) for v in set(dsts)), s
        assert stats.seq_endpoints((5, 5)) == (0, 0, 0, 0)  # unindexed

    def test_sharded_stats_match_local(self):
        """replicated_stats rebuilds the local statistics from a sharded
        layout alone — sharded planning can never drift from local
        planning (endpoint statistics need the sharded pair columns, but
        classes live whole on one shard, so the reassembled view is
        statistic-identical)."""
        from repro.core import index as cindex
        from repro.core.sharded_index import replicated_stats, shard_index

        g = random_graph(32, n_max=16, m_max=45)
        idx = cindex.build(g, 2)
        local = IndexStats.from_index(idx)
        rep = replicated_stats(shard_index(idx, 4), idx.n_vertices, idx.k)
        assert rep.seq_ranges == local.seq_ranges
        assert (rep.n_classes, rep.total_pairs) == (local.n_classes,
                                                    local.total_pairs)
        for s in local.seq_ranges:
            assert rep.seq_pairs(s) == local.seq_pairs(s), s
            assert rep.seq_classes(s) == local.seq_classes(s), s
            assert rep.seq_cyclic_pairs(s) == local.seq_cyclic_pairs(s), s
            assert rep.seq_endpoints(s) == local.seq_endpoints(s), s


# ---------------------------------------------------------------------- #
# cost model
# ---------------------------------------------------------------------- #


class TestCostModel:
    def test_join_card(self):
        assert join_card(0, 5, 10) == 0
        assert join_card(5, 0, 10) == 0
        assert join_card(10, 20, 100) == 2  # uniform estimate
        assert join_card(10, 20, 10_000) == 1  # floored at one row
        assert join_card(2, 3, 1) == 6  # never exceeds the cross product

    def test_lookup_estimates_are_exact(self, skewed):
        _, oidx, stats = skewed
        for s in oidx.l2c:
            e = estimate_plan(("lookup", [tuple(s)]), stats)
            assert e.pairs == stats.seq_pairs(s)
            assert e.classes == stats.seq_classes(s)
            assert e.max_pairs == e.pairs  # final materialization only

    def test_class_conjunction_min_bound(self, skewed):
        """A class-space conjunction's materialization is bounded by its
        smallest operand — exactly what lets the engine cap a selective
        conjunction near its answer instead of near its largest lookup."""
        _, _, stats = skewed
        plan = ("conj", ("lookup", [(0, 0)]), ("lookup", [(1,)]))
        e = estimate_plan(plan, stats)
        small = min(stats.seq_pairs((0, 0)), stats.seq_pairs((1,)))
        assert e.pairs == small
        assert e.max_pairs == small  # leaves never materialize
        assert e.max_join == 0

    def test_conj_id_single_lookup_is_exact(self, skewed):
        _, oidx, stats = skewed
        seq = (1, 0)  # the fixture's cyclic-rich sequence
        assert stats.seq_cyclic_pairs(seq) > 0
        e = estimate_plan(("conj_id", ("lookup", [seq])), stats)
        assert e.pairs == stats.seq_cyclic_pairs(seq)

    def test_identity_floor(self, skewed):
        g, _, stats = skewed
        e = estimate_plan(("identity",), stats)
        assert e.pairs == e.max_pairs == g.n_vertices

    def test_join_tracks_intermediates(self, skewed):
        _, _, stats = skewed
        plan = ("join", ("lookup", [(1,)]), ("lookup", [(0, 0)]))
        e = estimate_plan(plan, stats)
        assert e.max_pairs >= stats.seq_pairs((0, 0))  # leaf materializes
        assert e.max_join == e.pairs > 0


# ---------------------------------------------------------------------- #
# split enumeration
# ---------------------------------------------------------------------- #


class TestSplits:
    def test_enumerates_all_compositions(self):
        segs = enumerate_splits((1, 2, 3), 2, None)
        assert sorted(segs) == sorted([
            [(1,), (2,), (3,)], [(1, 2), (3,)], [(1,), (2, 3)]])

    def test_respects_available(self):
        segs = enumerate_splits((1, 2, 3), 2, {(1, 2)})
        assert sorted(segs) == sorted([[(1,), (2,), (3,)], [(1, 2), (3,)]])

    def test_limit_returns_none(self):
        assert enumerate_splits(tuple(range(24)), 3, None, limit=10) is None

    def test_full_run_single_segment_wins(self, skewed):
        """Sec. VI-D: a diameter-k chain on a k-index is ONE lookup even
        when a split would have smaller leaves — the single segment's
        materialization is exactly the answer."""
        _, _, stats = skewed
        q = parse("l0 . l2", None, 6)
        assert optimize_query(q, 2, stats) == ("lookup", [(0, 2)])


# ---------------------------------------------------------------------- #
# golden plan snapshots (skewed fixture, deterministic seed)
# ---------------------------------------------------------------------- #


class TestGoldenPlans:
    """Syntactic vs optimized plans for the representative Fig. 5
    templates on the skewed fixture — pinned literally, so any cost
    model or enumeration change that flips a decision is visible."""

    CASES = [
        # (template, labels, syntactic plan, optimized plan)
        ("T", [0, 0, 1],
         ("conj", ("lookup", [(0, 0)]), ("lookup", [(1,)])),
         ("conj", ("lookup", [(1,)]), ("lookup", [(0, 0)]))),
        ("S", [0, 0, 2, 3],
         ("conj", ("lookup", [(0, 0)]), ("lookup", [(2, 3)])),
         ("conj", ("lookup", [(2, 3)]), ("lookup", [(0, 0)]))),
        ("St", [0, 4, 5],
         ("conj", ("conj", ("lookup", [(0,)]), ("lookup", [(4,)])),
          ("lookup", [(5,)])),
         ("conj", ("conj", ("lookup", [(4,)]), ("lookup", [(5,)])),
          ("lookup", [(0,)]))),
        # ∩ is idempotent: TT's duplicated triangle evaluates once
        ("TT", [0, 0, 0, 0, 1],
         ("conj", ("conj", ("lookup", [(0, 0)]), ("lookup", [(1,)])),
          ("conj", ("lookup", [(0, 0)]), ("lookup", [(1,)]))),
         ("conj", ("lookup", [(1,)]), ("lookup", [(0, 0)]))),
        # chain: greedy (1,0)+(2,3) loses to the rare-leaf split; since
        # the endpoint statistics (PR 5) the witness-aware estimates
        # also flip the DP to the left-deep association, which fuses
        # into one multi-segment LOOKUP
        ("C4", [1, 0, 2, 3],
         ("lookup", [(1, 0), (2, 3)]),
         ("lookup", [(1,), (0, 2), (3,)])),
        ("C2i", [0, 1],
         ("conj_id", ("lookup", [(0, 1)])),
         ("conj_id", ("lookup", [(0, 1)]))),
    ]

    @pytest.mark.parametrize("case", CASES, ids=[c[0] for c in CASES])
    def test_golden(self, skewed, case):
        g, oidx, stats = skewed
        name, labels, want_syn, want_opt = case
        q = instantiate_template(name, labels)
        assert plan_query(q, 2) == want_syn
        assert optimize_query(q, 2, stats) == want_opt
        # snapshots must describe plans that agree with the semantics
        truth = oracle.cpq_eval(g, q)
        assert eval_plan_host(g, oidx, want_syn) == truth
        assert eval_plan_host(g, oidx, want_opt) == truth


# ---------------------------------------------------------------------- #
# optimized plans are always oracle-identical
# ---------------------------------------------------------------------- #


class TestOracleIdentical:
    def test_templates_host(self, skewed):
        g, oidx, stats = skewed
        rng = np.random.default_rng(4)
        present = np.unique(g.lbl)
        for name in sorted(TEMPLATES):
            q = instantiate_template(
                name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
            plan = optimize_query(q, 2, stats)
            assert eval_plan_host(g, oidx, plan) == oracle.cpq_eval(g, q), \
                (name, plan)

    def test_interest_aware_respects_available(self):
        """On an iaCPQx index every optimized LOOKUP segment must exist
        in the available set (or be a singleton), and answers match."""
        g = random_graph(33, n_max=14, m_max=40)
        ints = [(0, 1), (1, 0), (2, 2)]
        oidx = oracle.build_interest_index(g, 2, ints)
        stats = IndexStats.from_oracle(oidx, g.n_vertices)
        available = set(oidx.l2c)
        rng = np.random.default_rng(9)
        for _ in range(15):
            q = oracle.random_cpq(rng, g, 3)
            plan = optimize_query(q, 2, stats, available=available)
            for seg in plan_lookup_seqs(plan):
                assert len(seg) == 1 or tuple(seg) in available, (q, plan)
            assert eval_plan_host(g, oidx, plan) == \
                oracle.query_with_index(g, oidx, q) == oracle.cpq_eval(g, q)

    def test_property_random_graphs(self):
        hypothesis = pytest.importorskip("hypothesis")
        given, settings, st = (hypothesis.given, hypothesis.settings,
                               hypothesis.strategies)

        @settings(max_examples=30, deadline=None)
        @given(seed=st.integers(0, 10_000))
        def run(seed):
            g = random_graph(seed % 97, n_max=12, m_max=30)
            oidx = oracle.build_index(g, 2)
            stats = IndexStats.from_oracle(oidx, g.n_vertices)
            rng = np.random.default_rng(seed)
            for _ in range(3):
                q = oracle.random_cpq(rng, g, 3)
                truth = oracle.cpq_eval(g, q)
                p_opt = optimize_query(q, 2, stats)
                assert eval_plan_host(g, oidx, p_opt) == truth, (q, p_opt)
                assert eval_plan_host(g, oidx, plan_query(q, 2)) == truth

        run()

    def test_device_engine_differential(self, skewed):
        """The full device path: Engine(optimize=True) == Engine(
        optimize=False) == oracle, bit-identical rows, on the fixture's
        gated probes (conjunctions AND the re-split chain)."""
        from repro.core import index as cindex
        from repro.core.engine import Engine

        g, _, _ = skewed
        idx = cindex.build(g, 2)
        opt, syn = Engine(idx), Engine(idx, optimize=False)
        for name, labels in [("T", [0, 0, 1]), ("S", [0, 0, 2, 3]),
                             ("St", [0, 4, 5]), ("TT", [0, 0, 0, 0, 1]),
                             ("C4", [1, 0, 2, 3]), ("C2i", [0, 1])]:
            q = instantiate_template(name, labels)
            a, b = opt.execute(q), syn.execute(q)
            assert a.shape == b.shape and bool(np.all(a == b)), name
            assert {tuple(r) for r in a.tolist()} == oracle.cpq_eval(g, q)
        # batch path groups optimized plans by shape+caps; same rows out
        qs = [instantiate_template("T", [0, 0, 1]),
              instantiate_template("S", [0, 0, 2, 3])] * 2
        for rows, q in zip(opt.execute_batch(qs), qs):
            assert {tuple(r) for r in rows.tolist()} == oracle.cpq_eval(g, q)
