"""Device query processing with CPQx — Algorithms 3 & 4, backend-agnostic.

The host plans and a *backend* executes.  Planning is cost-based by
default: ``core.optimizer.optimize_query`` reorders join chains, splits
and conjunctions using the exact cardinalities of
:class:`~repro.core.stats.IndexStats` (pulled once per ``rebind``);
``core.query.plan_query`` remains the stats-free syntactic fallback
(``Engine(..., optimize=False)``), and is what the numpy oracle uses.
A plan is compiled once per (plan shape, capacity profile) — plans are
nested tuples, hence hashable jit keys; the per-query *data* (the
(start, len) ranges of each LOOKUP) streams in as traced scalars, so ten
queries of the same template hit one executable.

The physical algebra lives in ``core.backend`` (protocol + the
single-device :class:`~repro.core.backend.LocalBackend`) and
``core.distributed`` (:class:`~repro.core.distributed.ShardedBackend`,
the same plan walker inside one ``shard_map`` over a mesh axis).  The
:class:`Engine` here owns everything backend-independent: planning, the
host-side capacity estimator, the overflow retry schedule (the capacity
ladder itself is specified once, in the ``core.backend`` module
docstring), and plan-shape batching.  Constructing the engine with a
``mesh`` serves the identical API off a sharded index.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .backend import (  # noqa: F401  (QueryCaps/run_plan* are public API)
    OP_NOP,
    ExecutionBackend,
    LocalBackend,
    QueryCaps,
    _join_pairs,
    default_caps,
    plan_program,
    program_ranges,
    run_plan,
    run_plan_batch,
)
from .index import CPQxIndex
from .optimizer import estimate_plan, optimize_query
from .query import CPQ, plan_query, plan_lookup_seqs, plan_shape
from .stats import IndexStats


# ---------------------------------------------------------------------- #
# host driver
# ---------------------------------------------------------------------- #


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


def _has_identity(shape) -> bool:
    if shape[0] == "identity":
        return True
    return any(_has_identity(s) for s in shape[1:]
               if isinstance(s, tuple))


@dataclasses.dataclass
class LadderTelemetry:
    """Cumulative capacity-ladder counters of one engine (reset on
    demand, *not* on rebind — they track the engine's lifetime traffic).

    Wall-clock hides retries: a query that ladders three rungs before
    fitting looks like one slow query.  These counters make estimator
    regressions (and estimator wins, e.g. from richer statistics or an
    adapted interest set) directly visible — ``ServiceStats`` and the
    bench JSON surface them.

    ``queries``      — queries evaluated (batch lanes count individually);
    ``dispatches``   — device dispatches, including every retry rung;
    ``retry_rungs``  — ladder rungs climbed past the first attempt,
                       summed per query/lane (0 when the estimate fit);
    ``default_jumps``— escalations that hit the jump-to-default rung
                       (attempt >= 3 — the expensive worst-case dispatch).
    """

    queries: int = 0
    dispatches: int = 0
    retry_rungs: int = 0
    default_jumps: int = 0
    union_lanes: int = 0  # lanes served through the union executable

    def snapshot(self) -> "LadderTelemetry":
        return dataclasses.replace(self)

    def reset(self) -> None:
        self.queries = self.dispatches = 0
        self.retry_rungs = self.default_jumps = self.union_lanes = 0


@dataclasses.dataclass
class _Group:
    """One dispatch unit of a batch: a same-shape bucket, or a union
    group (``opcodes`` set, ``shape`` None) of mixed-shape stragglers."""

    shape: object
    caps: QueryCaps
    members: list
    ranges: np.ndarray
    opcodes: np.ndarray | None = None
    stack_size: int = 0
    handle: object = None


@dataclasses.dataclass
class BatchHandle:
    """In-flight batch: returned by :meth:`Engine.dispatch_batch`, settled
    by :meth:`Engine.harvest_batch`.  Between the two calls the device is
    executing every group while the host is free to plan the next batch —
    the service's pipelined drain lives on exactly this gap."""

    results: list
    groups: list


class Engine:
    """Query engine bound to a built index.

    ``mesh``/``axis``/``cluster`` select the execution backend: ``None``
    (default) binds the single-device :class:`LocalBackend`; a mesh binds
    a :class:`~repro.core.distributed.ShardedBackend` that shards the
    index over the mesh axis and evaluates every plan inside one
    ``shard_map``; ``cluster=n`` (an int, or a pre-built
    :class:`~repro.core.cluster.ClusterRuntime`) binds a
    :class:`~repro.core.cluster.ClusterBackend` serving off ``n``
    persistent worker *processes* driven over an instruction stream.
    Whichever way, the public API — ``execute``, ``execute_batch``,
    ``rebind`` — is identical, and answers are bit-identical.

    ``optimize`` selects the planner: True (default) runs the cost-based
    optimizer over the index statistics; False pins the syntactic
    ``plan_query`` (the stats-free fallback — what the oracle and the
    pre-PR-4 engine used), which benchmarks use as the baseline.

    ``cost_table`` (a :class:`~repro.core.costmodel.DeviceCostTable`)
    upgrades the row-count objective to calibrated device nanoseconds:
    the planner prices per-stage dispatch constants and
    :meth:`estimate_caps` picks the starting capacity rung with the
    minimal expected cost *including retry risk* instead of the pure row
    bound.  None (default) keeps today's behavior bit-for-bit.  The
    table survives :meth:`rebind` — like the telemetry, it describes the
    device, not the index.
    """

    def __init__(self, index: CPQxIndex, mesh=None, axis: str = "engine",
                 optimize: bool = True, cost_table=None, cluster=None):
        if mesh is not None and cluster is not None:
            raise ValueError("mesh and cluster are mutually exclusive "
                             "backend selectors")
        self.mesh = mesh
        self.axis = axis
        self.cluster = cluster
        self.optimize = optimize
        self.cost_table = cost_table
        self.telemetry = LadderTelemetry()
        self.rebind(index)

    def rebind(self, index: CPQxIndex,
               stats: IndexStats | None = None) -> None:
        """Swap in a new index (a maintenance flush or a rebuild) in
        place: re-pulls the host-side statistics view (optimizer +
        capacity estimator) and the default caps, and rebuilds the
        backend — for a mesh engine that reshards the flushed arrays.
        Compiled executables are keyed on (plan shape, caps, n_vertices)
        — not on the index identity — so traffic after a rebind keeps
        hitting the same jit cache as long as the flushed arrays keep
        their capacities.

        ``stats`` optionally supplies a pre-built statistics view for
        this exact index — a checkpoint restore passes one whose
        endpoint cache is pre-warmed from the donor, and the sharded
        path can pass its replicated-leaf view — skipping the default
        ``IndexStats.from_index`` pull."""
        self.index = index
        self._available = index.available_seqs() if index.interests is not None else None
        # the statistics view: per-class pair counts, the l2c class table
        # and per-seq prefix sums (a few KB — pulled once per rebind, so
        # a maintenance flush refreshes what the optimizer plans against)
        self.stats = stats if stats is not None else IndexStats.from_index(index)
        self._class_sizes = self.stats.class_sizes
        self._l2c_host = self.stats.l2c_cls
        self._default_caps = default_caps(index)  # one device sync, here
        if self.cluster is not None:
            from .cluster import ClusterBackend, ClusterRuntime

            prev = getattr(self, "backend", None)
            if isinstance(prev, ClusterBackend):
                prev.reshard(index)  # one FLUSH_REBIND/INTEREST broadcast
            elif isinstance(self.cluster, ClusterRuntime):
                if not self.cluster.started:
                    self.cluster.start(index)
                self.backend = ClusterBackend(self.cluster)
                if self.cluster.index is not index:
                    self.backend.reshard(index)
            else:
                self.backend = ClusterBackend.from_index(
                    index, int(self.cluster))
        elif self.mesh is None:
            self.backend: ExecutionBackend = LocalBackend(
                index.arrays, index.n_vertices)
        else:
            from .distributed import ShardedBackend  # engine <- distributed is one-way

            prev = getattr(self, "backend", None)
            if isinstance(prev, ShardedBackend) and prev.mesh is self.mesh:
                prev.reshard(index)  # keep the compiled plan cache warm
            else:
                self.backend = ShardedBackend.from_index(
                    index, self.mesh, axis=self.axis)

    def plan(self, q: CPQ):
        """Compile ``q`` to a physical plan: cost-optimized against the
        index statistics by default, syntactic (``plan_query``) when the
        engine was constructed with ``optimize=False``."""
        if self.optimize:
            return optimize_query(q, self.index.k, self.stats,
                                  available=self._available,
                                  cost_table=self.cost_table)
        return plan_query(q, self.index.k, available=self._available)

    def predict_cost_ns(self, plan) -> float:
        """Calibrated prediction of one dispatch of ``plan`` in device
        nanoseconds — what the service's SLO-aware shedding prices a
        request at *before* admitting it.  0.0 without a cost table (the
        row-count objective has no time unit), so SLO shedding is
        automatically inert on uncalibrated engines."""
        if self.cost_table is None:
            return 0.0
        est = estimate_plan(plan, self.stats, cost_table=self.cost_table)
        return float(est.cost_ns)

    def estimate_caps(self, ranges: np.ndarray, shape,
                      plan=None) -> QueryCaps:
        """Optimistic per-query capacities from the host index stats.

        With a ``plan``, the cost model walks it and sizes the pair cap
        to 2x the largest *estimated intermediate* (for a class-space
        conjunction that is a sound upper bound — the min operand — so a
        selective conjunction gets caps near its answer instead of near
        its largest lookup), and the join cap to the plan's largest
        pre-dedup witness bound (``PlanEstimate.max_join`` — with the
        endpoint/fanout statistics of PR 5 that bound is sound at leaf
        joins, so skewed hub fanout no longer ladders what the uniform
        estimate used to under-size).  Without one, the stats-free
        fallback keeps the PR-1 behavior: 2x the largest single-lookup
        materialization.  Either way the class cap covers the largest
        LOOKUP's class list exactly, and the sticky-overflow retry
        (doubling along the same power-of-two ladder, so executables are
        shared) keeps undersized estimates exact."""
        max_classes, max_pairs = 1, 1
        for start, length in np.asarray(ranges, np.int64).reshape(-1, 2):
            max_classes = max(max_classes, int(length))
            if plan is None:  # the cost model supersedes the per-leaf sum
                cls = self._l2c_host[start: start + length]
                max_pairs = max(max_pairs, int(self._class_sizes[cls].sum()))
        headroom = 2
        max_join = 0
        risky = False
        if plan is not None:
            est = estimate_plan(plan, self.stats, cost_table=self.cost_table)
            max_pairs = int(max(est.max_pairs, est.pairs))
            # conjunction bounds are exact (min operand) but join outputs
            # are *estimates* — give plans with pair-space joins double
            # the headroom so residual misestimates rarely ladder
            risky = est.max_join > 0
            headroom = 4 if risky else 2
            max_join = int(min(est.max_join, 4 * self._default_caps.join_cap))
        floor = self.index.n_vertices if _has_identity(shape) else 0
        # never *start* above the worst-case default (the retry ladder can
        # still climb past it if a join genuinely needs more)
        ceiling = max(self._default_caps.pair_cap, _pow2(floor))
        pair_cap = min(_pow2(max(64, headroom * max_pairs, floor)), ceiling)
        if self.cost_table is not None and plan is not None:
            # calibrated rung selection: among the tight rung and the
            # headroom rungs above it, start at the one whose *expected*
            # cost — this dispatch plus the overflow-risk-weighted retry
            # at the next rung — is minimal.  A cheap dispatch (small
            # fixed constants) makes optimistic starts worth the retry
            # risk; an expensive one buys headroom up front.
            base = min(_pow2(max(64, max_pairs, floor)), ceiling)
            cands = sorted({min(c, ceiling) for c in
                            (base, 2 * base, 4 * base, pair_cap)})
            pair_cap = min(cands, key=lambda c: self.cost_table.
                           expected_dispatch_ns(c, max_pairs, risky))
        join_cap = max(2 * pair_cap, _pow2(max_join))
        return QueryCaps(class_cap=_pow2(max(16, max_classes)),
                         pair_cap=pair_cap, join_cap=join_cap)

    def lookup_ranges(self, plan) -> np.ndarray:
        """(n_lookups, 2) int32 (start, len) rows, in plan order — the
        per-query data streamed into the compiled plan executable."""
        seqs = plan_lookup_seqs(plan)
        ranges = np.array(
            [self.index.lookup_range(s) for s in seqs], np.int32
        ).reshape(-1, 2)
        ranges[:, 1] = ranges[:, 1] - ranges[:, 0]  # (start, len)
        return ranges

    def execute(self, q: CPQ, caps: QueryCaps | None = None,
                max_retries: int = 10) -> np.ndarray:
        """Evaluate ⟦q⟧_G; returns (n, 2) numpy array of s-t pairs."""
        plan = self.plan(q)
        ranges = self.lookup_ranges(plan)
        shape = plan_shape(plan)
        caps = caps or self.estimate_caps(ranges, shape,
                                          plan if self.optimize else None)
        self.telemetry.queries += 1
        for attempt in range(max_retries):
            self.telemetry.dispatches += 1
            rows, overflow = self.backend.run(shape, caps, ranges)
            if not overflow:
                return rows
            self.telemetry.retry_rungs += 1
            caps = self._escalate(caps, attempt)
            if attempt >= 3:
                self.telemetry.default_jumps += 1
        raise RuntimeError("query overflow not resolved after retries")

    def execute_rpq(self, q, srcs=None, dsts=None,
                    n_labels: int | None = None,
                    info=None) -> np.ndarray:
        """Evaluate a regular path query (:mod:`repro.core.rpq` AST) as
        an automaton fixpoint of per-sequence lookups; returns (n, 2)
        s-t pairs like :meth:`execute`.  Every device dispatch inside
        the fixpoint is an ordinary :meth:`execute_batch` round, so the
        capacity ladder, telemetry, the optimizer's query-time splits
        and (on a mesh engine) the sharded backend all apply unchanged.
        ``srcs``/``dsts`` pin the endpoints (the Cypher ``WHERE``
        lowering); ``info`` (an ``rpq.FixpointInfo``) captures iteration
        telemetry."""
        from .rpq import evaluate  # engine <- rpq is one-way at runtime

        return evaluate(self, q, srcs=srcs, dsts=dsts,
                        n_labels=n_labels, info=info)

    def _escalate(self, caps: QueryCaps, attempt: int) -> QueryCaps:
        """Overflow-retry schedule (the host half of the ladder contract
        in the ``core.backend`` docstring): double, and after a few
        failed attempts from a (possibly far-too-tight) estimate jump to
        at least the worst-case default so the ladder can't exhaust
        below the caps the pre-estimator engine would have started from.
        Early rungs are cheap (small executables), the default rung is
        not — so the jump waits for three doublings, which lets a mildly
        undersized estimate land on a right-sized rung instead of paying
        the worst-case dispatch.  (The default ``max_retries`` is 10 so
        the reachable ceiling past the jump — default x 2^6 — matches
        the pre-optimizer schedule's.)"""
        caps = caps.doubled()
        if attempt >= 3:
            d = self._default_caps
            caps = QueryCaps(max(caps.class_cap, d.class_cap),
                             max(caps.pair_cap, d.pair_cap),
                             max(caps.join_cap, d.join_cap))
        return caps

    def execute_batch(self, queries, caps: QueryCaps | None = None,
                      max_retries: int = 10, plans: list | None = None,
                      min_bucket: int = 4, union: bool = False) -> list:
        """Evaluate many queries; returns one (n, 2) array per query, in
        input order.  Equivalent to ``dispatch_batch`` + ``harvest_batch``
        back to back — callers that want to overlap host work with device
        execution use the two halves directly."""
        handle = self.dispatch_batch(queries, caps=caps, plans=plans,
                                     min_bucket=min_bucket, union=union)
        return self.harvest_batch(handle, max_retries=max_retries)

    def dispatch_batch(self, queries, caps: QueryCaps | None = None,
                       plans: list | None = None, min_bucket: int = 4,
                       union: bool = False) -> BatchHandle:
        """Plan, bucket and asynchronously dispatch a batch; returns a
        :class:`BatchHandle` the caller settles with ``harvest_batch``.

        Queries are grouped by (plan *shape*, estimated caps) — labels
        don't change the executable, and the power-of-two capacity
        estimates quantize size-similar queries into shared buckets, so
        a lane never pays for a much larger neighbor.  Buckets smaller
        than ``min_bucket`` merge upward into the next-larger caps rung
        (one dispatch beats a little lane padding).  Each group's lookup
        ranges stack into a (batch, n_lookups, 2) array evaluated by the
        backend (one vmapped dispatch on the local backend).

        With ``union=True`` (and a backend that supports it), the
        *mixed-shape* straggler buckets still smaller than ``min_bucket``
        after same-shape merging fuse into one union-executable group —
        their per-lane programs stream as data — instead of serializing
        into one dispatch per leftover shape.

        ``plans`` lets a caller with a plan cache (the service layer)
        skip re-planning; must align with ``queries``."""
        if not queries:
            return BatchHandle(results=[], groups=[])
        if plans is None:
            plans = [self.plan(q) for q in queries]
        all_ranges = [self.lookup_ranges(p) for p in plans]

        shape_groups: dict = {}
        for i, p in enumerate(plans):
            shape = plan_shape(p)
            e = caps or self.estimate_caps(all_ranges[i], shape,
                                           p if self.optimize else None)
            shape_groups.setdefault(shape, {}).setdefault(e, []).append(i)

        work: list = []  # (shape, caps, member indices)
        for shape, by_caps in shape_groups.items():
            if caps is not None:
                work.extend((shape, c, m) for c, m in by_caps.items())
                continue
            buckets = sorted(
                by_caps.items(),
                key=lambda kv: (kv[0].pair_cap, kv[0].join_cap,
                                kv[0].class_cap))
            cur_caps, cur_members = None, []
            for cb, mem in buckets:
                if cur_caps is None:
                    cur_caps, cur_members = cb, list(mem)
                else:
                    cur_caps = QueryCaps(
                        max(cur_caps.class_cap, cb.class_cap),
                        max(cur_caps.pair_cap, cb.pair_cap),
                        max(cur_caps.join_cap, cb.join_cap))
                    cur_members += mem
                if len(cur_members) >= min_bucket:
                    work.append((shape, cur_caps, cur_members))
                    cur_caps, cur_members = None, []
            if cur_caps is not None:
                # undersized largest-caps tail: keep it separate rather
                # than inflating an already-flushed smaller bucket
                work.append((shape, cur_caps, cur_members))

        groups = [_Group(shape, c, m, np.stack([all_ranges[i] for i in m]))
                  for shape, c, m in work]
        if union and self.backend.supports_union:
            groups = self._fuse_stragglers(groups, all_ranges, min_bucket)

        self.telemetry.queries += len(queries)
        for g in groups:
            self.telemetry.dispatches += 1
            g.handle = self._dispatch_group(g)
        return BatchHandle(results=[None] * len(queries), groups=groups)

    def _fuse_stragglers(self, groups: list, all_ranges: list,
                         min_bucket: int) -> list:
        """Fuse the sub-``min_bucket`` shape buckets into one union group
        (caps = elementwise max, programs NOP-padded to the longest)."""
        stragglers = [g for g in groups if len(g.members) < min_bucket]
        if len(stragglers) < 2:
            return groups
        kept = [g for g in groups if len(g.members) >= min_bucket]
        programs = {}
        members, progs, ucaps = [], [], None
        for g in stragglers:
            if g.shape not in programs:
                programs[g.shape] = plan_program(g.shape)
            for i in g.members:
                members.append(i)
                progs.append(programs[g.shape])
            ucaps = g.caps if ucaps is None else QueryCaps(
                max(ucaps.class_cap, g.caps.class_cap),
                max(ucaps.pair_cap, g.caps.pair_cap),
                max(ucaps.join_cap, g.caps.join_cap))
        n_steps = max(len(p) for p, _ in progs)
        stack_size = max(2, max(d for _, d in progs))
        opcodes = np.full((len(members), n_steps), OP_NOP, np.int32)
        step_ranges = np.zeros((len(members), n_steps, 2), np.int32)
        for lane, (i, (prog, _)) in enumerate(zip(members, progs)):
            opcodes[lane, : len(prog)] = prog
            step_ranges[lane] = program_ranges(prog, all_ranges[i], n_steps)
        self.telemetry.union_lanes += len(members)
        kept.append(_Group(None, ucaps, members, step_ranges,
                           opcodes=opcodes, stack_size=stack_size))
        return kept

    def _dispatch_group(self, g: _Group):
        if g.opcodes is not None:
            return self.backend.run_union_batch_async(
                g.opcodes, g.caps, g.stack_size, g.ranges)
        return self.backend.run_batch_async(g.shape, g.caps, g.ranges)

    def harvest_batch(self, handle: BatchHandle,
                      max_retries: int = 10) -> list:
        """Block on a dispatched batch and drive the overflow ladder.

        Overflow is tracked per lane: only the queries whose own sticky
        flag tripped are retried (synchronously), at doubled capacities.
        ``retry_rungs`` and ``default_jumps`` both count per lane — a
        4-lane bucket that jumps to default caps records 4 jumps, the
        same as 4 single-query executes would."""
        results = handle.results
        for g in handle.groups:
            if max_retries <= 0:
                raise RuntimeError("query overflow not resolved after retries")
            pending = np.asarray(g.members, np.int64)
            ranges = g.ranges
            opcodes = g.opcodes
            grp_caps = g.caps
            rows, overflow = self.backend.harvest_batch(g.handle)
            attempt = 0
            while True:
                for lane, r in enumerate(rows):
                    if r is not None:
                        results[pending[lane]] = r
                if not overflow.any():
                    break
                # only the lanes whose own flag tripped climb a rung
                self.telemetry.retry_rungs += int(overflow.sum())
                if attempt >= 3:
                    self.telemetry.default_jumps += int(overflow.sum())
                grp_caps = self._escalate(grp_caps, attempt)
                attempt += 1
                if attempt >= max_retries:
                    raise RuntimeError(
                        "query overflow not resolved after retries")
                pending = pending[overflow]
                ranges = ranges[overflow]
                self.telemetry.dispatches += 1
                if opcodes is not None:
                    opcodes = opcodes[overflow]
                    rows, overflow = self.backend.run_union_batch(
                        opcodes, grp_caps, g.stack_size, ranges)
                else:
                    rows, overflow = self.backend.run_batch(
                        g.shape, grp_caps, ranges)
        return results
