"""iaCPQx — interest-aware index construction (paper Sec. V).

Interest-aware path-equivalence (Def. 5.1) groups s-t pairs by
``(cycle flag, L^{<=k}(v,u) ∩ L_q)`` where L_q is the user's interest
set of label sequences, always closed with every length-1 sequence so
arbitrary CPQs stay evaluable (long/uninterested sequences are split at
query time — the planner's ``available`` set does this).

Construction shares the path enumeration and the ``_assemble`` tail with
CPQx; the only difference is (1) the incidence rows are filtered to L_q
(vectorized binary search against the interest table) and (2) class ids
come from the per-pair *set of realized interest sequences* instead of
the bisimulation signature.  Because interest-equivalence is coarser than
k-path-bisimulation, the index is smaller and lookups prune harder —
exactly the paper's scalability story.
"""

from __future__ import annotations

import functools
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from . import relational as R
from .capacity import BuildCaps, estimate_build_caps
from .graph import LabeledGraph
from .index import CPQxIndex, _assemble, _pull_seq_ranges
from .paths import DeviceGraph, device_graph, enumerate_path_levels, seq_rows_of_levels
from .bisim import _fp_cols


def normalize_interests(g: LabeledGraph, k: int,
                        interests: Iterable[tuple]) -> tuple:
    """L_q = interests ∪ all length-1 sequences, as a sorted tuple of
    k-padded tuples (pad -1)."""
    lq = {(l,) for l in range(g.alphabet_size)}
    lq |= {tuple(int(x) for x in s) for s in interests}
    for s in lq:
        if not 1 <= len(s) <= k:
            raise ValueError(f"interest {s} must have length in [1, {k}]")
        if any(not 0 <= x < g.alphabet_size for x in s):
            raise ValueError(f"interest {s} has labels outside the alphabet")
    padded = sorted(tuple(s) + (-1,) * (k - len(s)) for s in lq)
    return tuple(padded)


@functools.partial(jax.jit, static_argnames=("k", "caps_key", "interest_key"))
def build_ia_index_arrays(dg: DeviceGraph, k: int, caps_key: tuple,
                          interest_key: tuple):
    caps = BuildCaps(*caps_key)
    itable = jnp.asarray(np.array(interest_key, np.int32))  # (n_int, k) sorted

    levels = enumerate_path_levels(dg, k, caps.level_rows)
    seq_rows = seq_rows_of_levels(levels, k, caps.seq_rows)  # (s1..sk, v, u)
    overflow = seq_rows.overflow
    for lvl in levels:
        overflow = overflow | lvl.overflow

    # ---- filter rows to L_q (lex membership against interest table) ---- #
    icols = tuple(itable[:, j] for j in range(k))
    cnt = R.lex_count_matches(icols, seq_rows.cols[:k],
                              jnp.asarray(itable.shape[0], R.I32))
    rows = R.rel_compact(seq_rows, cnt > 0)

    # ---- class ids: per-pair set of realized interest sequences -------- #
    # sort rows by (v, u, seq) so pairs group together
    byp = R.rel_sort(
        R.Relation((rows.cols[k], rows.cols[k + 1]) + tuple(rows.cols[:k]),
                   rows.count, rows.overflow)
    )
    seg, n_pairs = R.dense_rank(byp, num_keys=2)
    h1, h2 = R.fingerprint_rows(byp.cols[2:], salt=77)
    f1, f2 = R.segment_fingerprint(h1, h2, seg, byp.capacity, R.valid_mask(byp))
    upairs = R.rel_unique(byp, num_keys=2)
    v, u = upairs.cols[0], upairs.cols[1]
    validm = jnp.arange(byp.capacity, dtype=R.I32) < n_pairs
    cyc = jnp.where(validm, (v == u).astype(R.I32), R.SENTINEL)
    fa, fb, fc, fd = _fp_cols(f1, f2)
    fa = jnp.where(validm, fa, R.SENTINEL)
    fb = jnp.where(validm, fb, R.SENTINEL)
    fc = jnp.where(validm, fc, R.SENTINEL)
    fd = jnp.where(validm, fd, R.SENTINEL)
    keyed = R.rel_sort(
        R.Relation((cyc, fa, fb, fc, fd, v, u), n_pairs, rows.overflow),
        num_keys=5,
    )
    cls, n_classes = R.dense_rank(keyed, num_keys=5)
    cls = jnp.where(R.valid_mask(keyed), cls, R.SENTINEL)
    pairs = R.rel_sort(
        R.Relation((keyed.cols[5], keyed.cols[6], cls), n_pairs, keyed.overflow),
        num_keys=2,
    )
    # re-embed the pair table at pair_cap
    pairs = _recap_rel(pairs, caps.pair_cap)

    return _assemble(pairs, n_classes, rows, k, caps, overflow)


def _recap_rel(rel: R.Relation, cap: int) -> R.Relation:
    idx = jnp.arange(cap, dtype=R.I32)
    m = idx < rel.count
    src = jnp.clip(idx, 0, rel.capacity - 1)
    cols = tuple(jnp.where(m, c[src], R.SENTINEL) for c in rel.cols)
    return R.Relation(cols, jnp.minimum(rel.count, cap).astype(R.I32),
                      rel.overflow | (rel.count > cap))


def build_interest(g: LabeledGraph, k: int, interests: Iterable[tuple],
                   caps: BuildCaps | None = None) -> CPQxIndex:
    """Build iaCPQx over interest set L_q (paper Sec. V-B)."""
    interest_key = normalize_interests(g, k, interests)
    if caps is None:
        caps = estimate_build_caps(g, k)
    dg = device_graph(g)
    arrays = build_ia_index_arrays(dg, k, caps.key(), interest_key)
    if bool(arrays.overflow):
        raise RuntimeError("iaCPQx build overflow — estimator undersized")
    return CPQxIndex(
        k=k, n_vertices=g.n_vertices, arrays=arrays,
        seq_ranges=_pull_seq_ranges(arrays, k), caps=caps,
        interests=frozenset(tuple(x for x in s if x >= 0) for s in interest_key),
    )
