"""Differential update-test harness: the mirror→device flush pipeline.

After any batch of lazy mirror updates (Sec. IV-E / V-C), a flushed
device index must answer bit-identically to the host oracle on the
updated graph — for identity, joins, conjunctions, and inverse labels,
across k ∈ {2, 3}.  Also covers capacity growth across flushes, the
class-partition invariants of the serialized arrays, interest-update
round-trips, and the ``QueryService`` write path end to end.
"""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import index as cindex
from repro.core import oracle
from repro.core.capacity import FlushCaps
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import Conj, Edge, Identity, Join, instantiate_template
from repro.core.service import QueryService


def _rows(arr) -> set:
    return {tuple(r) for r in arr.tolist()}


def _query_pool(g, rng, n_random: int = 8) -> list:
    """Identity, forward/inverse edges, joins, conjunctions, conj-id —
    plus random CPQs for breadth."""
    L = g.n_labels
    pool = [
        Identity(),
        Edge(0),
        Edge(L),  # inverse of label 0
        Join(Edge(0), Edge(1 % L)),
        Join(Edge(0), Edge(L)),  # forward then inverse
        Conj(Join(Edge(0), Edge(1 % L)), Edge(L)),
        Conj(Join(Edge(0), Edge(0)), Identity()),  # cycle check
    ]
    pool += [oracle.random_cpq(rng, g, 3) for _ in range(n_random)]
    return pool


def _random_batch(g, rng, n_ops: int) -> list:
    base = g._base_edges()
    ops = []
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.45 or base.shape[0] == 0:
            ops.append(("insert_edge", int(rng.integers(0, g.n_vertices)),
                        int(rng.integers(0, g.n_vertices)),
                        int(rng.integers(0, g.n_labels))))
        elif roll < 0.8:
            e = base[int(rng.integers(0, base.shape[0]))]
            ops.append(("delete_edge", int(e[0]), int(e[1]), int(e[2])))
        else:
            e = base[int(rng.integers(0, base.shape[0]))]
            ops.append(("change_label", int(e[0]), int(e[1]), int(e[2]),
                        (int(e[2]) + 1) % g.n_labels))
    return ops


def _assert_device_matches_oracle(mi, rng, n_random: int = 8) -> None:
    eng = Engine(mi.flush())
    for q in _query_pool(mi.g, rng, n_random):
        got = _rows(eng.execute(q))
        want = oracle.cpq_eval(mi.g, q)
        assert got == want, f"device != oracle for {q}"


class TestFlushDifferential:
    """The harness proper: randomized update batches, flush, compare."""

    @pytest.mark.parametrize("seed", [0, 3, 11])
    @pytest.mark.parametrize("k", [2, 3])
    def test_randomized_batches(self, seed, k):
        g = random_graph(seed, n_max=12, m_max=26)
        rng = np.random.default_rng(seed + 100)
        mi = MaintainableIndex.build(g, k)
        for _ in range(3):
            mi.apply_updates(_random_batch(mi.g, rng, n_ops=4))
            _assert_device_matches_oracle(mi, rng, n_random=6)

    def test_flush_without_updates_round_trips(self, ex_graph):
        """Flushing a pristine mirror must agree with a device build."""
        mi = MaintainableIndex.build(ex_graph, 2)
        flushed = mi.flush()
        built = cindex.build(ex_graph, 2)
        assert flushed.n_classes == built.n_classes
        assert flushed.n_pairs == built.n_pairs
        assert flushed.seq_ranges.keys() == built.seq_ranges.keys()
        ef, eb = Engine(flushed), Engine(built)
        rng = np.random.default_rng(5)
        for q in _query_pool(ex_graph, rng, 4):
            assert _rows(ef.execute(q)) == _rows(eb.execute(q))

    def test_flush_preserves_lazy_partition(self, ex_graph):
        """Flush must serialize the *split* partition, not re-merge it —
        class count equals the mirror's, not the fresh-build minimum."""
        mi = MaintainableIndex.build(ex_graph, 2)
        v, u, l = map(int, mi.g._base_edges()[0])
        mi.delete_edge(v, u, l)
        mi.insert_edge(v, u, l)  # same graph, lazily-split mirror
        assert mi.n_splits > 0
        flushed = mi.flush()
        assert flushed.n_classes == mi.index.n_classes
        assert flushed.n_classes > cindex.build(mi.g, 2).n_classes

    def test_flushed_array_invariants(self):
        """Serialized arrays obey the engine's structural contracts:
        CSR monotonicity, sorted class lists per seq, seq_ranges
        consistency, valid-entry counts."""
        g = random_graph(4, n_max=12, m_max=28)
        rng = np.random.default_rng(4)
        mi = MaintainableIndex.build(g, 2)
        mi.apply_updates(_random_batch(mi.g, rng, 5))
        idx = mi.flush()
        a = idx.arrays
        starts = np.asarray(a.class_starts)
        assert (np.diff(starts) >= 0).all()
        n_pairs = int(a.pair_count)
        assert starts[int(a.n_classes)] == n_pairs
        l2c = np.asarray(a.l2c_cls)
        for s, (lo, hi) in idx.seq_ranges.items():
            block = l2c[lo:hi]
            assert (np.diff(block) > 0).all()  # strictly ascending class ids
            assert (block < int(a.n_classes)).all()
        assert int(a.l2c_count) == sum(hi - lo for lo, hi in idx.seq_ranges.values())
        # host mirror and device image report identical sizes
        assert idx.size_entries() == mi.size_entries()

    def test_caps_grow_geometrically_and_stay_stable(self):
        g = random_graph(6, n_max=10, m_max=14)
        mi = MaintainableIndex.build(g, 2)
        first = mi.flush()
        assert isinstance(first.caps, FlushCaps)
        # no growth without updates: identical caps object
        assert mi.flush().caps == first.caps
        rng = np.random.default_rng(8)
        for _ in range(4):
            ins = [("insert_edge", int(rng.integers(0, g.n_vertices)),
                    int(rng.integers(0, g.n_vertices)),
                    int(rng.integers(0, g.n_labels))) for _ in range(6)]
            mi.apply_updates(ins)
        grown = mi.flush().caps
        assert grown.pair_cap >= first.caps.pair_cap
        # pow2 ladder: any growth is by doubling
        for before, after in [(first.caps.pair_cap, grown.pair_cap),
                              (first.caps.l2c_cap, grown.l2c_cap),
                              (first.caps.seq_cap, grown.seq_cap)]:
            ratio = after / before
            assert ratio >= 1 and ratio == int(ratio)
            assert int(ratio) & (int(ratio) - 1) == 0

    def test_flush_after_emptying_the_graph(self):
        g = random_graph(13, n_max=8, m_max=10)
        mi = MaintainableIndex.build(g, 2)
        for (v, u, l) in [tuple(map(int, e)) for e in g._base_edges()]:
            mi.delete_edge(v, u, l)
        eng = Engine(mi.flush())
        assert eng.execute(Edge(0)).shape[0] == 0
        assert _rows(eng.execute(Identity())) == {
            (v, v) for v in range(g.n_vertices)}


class TestBatchedUpdates:
    def test_batch_equals_sequential_answers(self):
        """One apply_updates batch and per-op application must yield the
        same query answers (the batch may split less — that's the point)."""
        g = random_graph(17, n_max=12, m_max=24)
        rng = np.random.default_rng(17)
        batch = _random_batch(g, rng, 6)
        mb = MaintainableIndex.build(g, 2)
        mb.apply_updates(batch)
        ms = MaintainableIndex.build(g, 2)
        for op in batch:
            ms.apply_updates([op])
        assert {tuple(map(int, e)) for e in mb.g._base_edges()} == \
               {tuple(map(int, e)) for e in ms.g._base_edges()}
        qrng = np.random.default_rng(3)
        for q in _query_pool(mb.g, qrng, 6):
            assert mb.query(q) == ms.query(q) == oracle.cpq_eval(mb.g, q)
        assert mb.n_splits <= ms.n_splits

    def test_delete_vertex(self):
        g = random_graph(19, n_max=12, m_max=24)
        mi = MaintainableIndex.build(g, 2)
        mi.apply_updates([("delete_vertex", 1)])
        assert all(1 not in (int(s), int(d))
                   for s, d in zip(mi.g.src, mi.g.dst))
        rng = np.random.default_rng(2)
        _assert_device_matches_oracle(mi, rng, 4)

    def test_delete_isolated_vertex_is_noop(self):
        g = random_graph(23, n_max=10, m_max=16)
        iso = g.n_vertices - 1
        mi = MaintainableIndex.build(g.with_edges_removed(
            [tuple(map(int, e)) for e in g._base_edges()
             if iso in (int(e[0]), int(e[1]))]), 2)
        splits0, classes0 = mi.n_splits, dict(mi.index.c2p)
        mi.delete_vertex(iso)
        assert mi.n_splits == splits0
        assert mi.index.c2p == classes0  # untouched, not resplit

    def test_insert_vertex_batch(self):
        g = random_graph(29, n_max=10, m_max=16)
        mi = MaintainableIndex.build(g, 2)
        x = 0  # wire an existing vertex id with fresh edges
        mi.apply_updates([("insert_vertex",
                           [(x, 2, 0), (3, x, 1), (x, 4, 1)])])
        rng = np.random.default_rng(6)
        _assert_device_matches_oracle(mi, rng, 4)


class TestInterestMaintenanceFlush:
    """Sec. V-C on iaCPQx mirrors: interest updates round-trip through
    flush; lookup_range stays consistent with seq_ranges."""

    @pytest.mark.parametrize("seed", [1, 10])
    def test_insert_delete_interest_roundtrip(self, seed):
        g = random_graph(seed, n_max=14, m_max=30)
        mi = MaintainableIndex.build(g, 2, interests=[(0, 1), (1, 1)])
        rng = np.random.default_rng(seed)
        _assert_device_matches_oracle(mi, rng, 5)

        mi.delete_interest((0, 1))
        idx = mi.flush()
        assert (0, 1) not in idx.seq_ranges
        assert idx.lookup_range((0, 1)) == (0, 0)  # split at query time
        _assert_device_matches_oracle(mi, rng, 5)

        mi.insert_interest((2, 0))
        idx = mi.flush()
        # every mirror sequence is flushable and the ranges cover exactly
        # the mirror's class lists
        for s, cs in mi.index.l2c.items():
            lo, hi = idx.lookup_range(s)
            assert (lo, hi) == idx.seq_ranges[s]
            assert hi - lo == len(cs), f"seq {s}"
        _assert_device_matches_oracle(mi, rng, 5)

    def test_mixed_graph_and_interest_updates_flush(self):
        g = random_graph(15, n_max=12, m_max=24)
        mi = MaintainableIndex.build(g, 2, interests=[(0, 0)])
        v, u, l = map(int, mi.g._base_edges()[0])
        mi.apply_updates([("delete_edge", v, u, l)])
        mi.insert_interest((1, 0))
        mi.apply_updates([("insert_edge", v, u, l)])
        rng = np.random.default_rng(1)
        _assert_device_matches_oracle(mi, rng, 5)


class TestServiceWritePath:
    def test_apply_updates_coalesce_and_serve(self, ex_graph):
        mi = MaintainableIndex.build(ex_graph, 2)
        svc = QueryService(Engine(mi.flush()), max_batch=16, maintainer=mi)
        q = instantiate_template("C2", [0, 0])
        before = _rows(svc.query(q))
        assert before == oracle.cpq_eval(ex_graph, q)
        assert svc.submit(q).from_cache  # warmed

        svc.apply_updates([("insert_edge", 2, 3, 0)])
        svc.apply_updates([("delete_edge", 0, 1, 0)])
        assert svc.pending_updates == 2  # queued, not yet applied

        stale = svc.submit(q)
        assert not stale.from_cache  # write bumped the epoch immediately
        got = _rows(svc.query(q))
        assert svc.pending_updates == 0
        assert svc.stats.update_batches == 1  # both calls coalesced
        assert svc.stats.updates_applied == 2
        assert got == oracle.cpq_eval(mi.g, q)
        assert got != before

    def test_reads_before_write_see_old_graph(self, ex_graph):
        mi = MaintainableIndex.build(ex_graph, 2)
        svc = QueryService(Engine(mi.flush()), max_batch=64, maintainer=mi)
        q = instantiate_template("C2", [0, 0])
        req = svc.submit(q)
        gt_old = oracle.cpq_eval(ex_graph, q)
        svc.apply_updates([("insert_edge", 2, 3, 0)])
        assert req.done and _rows(req.result) == gt_old  # drained first
        assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q)

    def test_write_path_requires_maintainer(self, ex_graph):
        svc = QueryService(Engine(cindex.build(ex_graph, 2)))
        with pytest.raises(RuntimeError, match="maintainer"):
            svc.apply_updates([("insert_edge", 0, 1, 0)])

    def test_malformed_op_rejected_at_enqueue(self, ex_graph):
        mi = MaintainableIndex.build(ex_graph, 2)
        svc = QueryService(Engine(mi.flush()), maintainer=mi)
        with pytest.raises(ValueError, match="unknown update op"):
            svc.apply_updates([("frobnicate", 0, 1)])
        assert svc.pending_updates == 0

    def test_failed_drain_requeues_updates(self, ex_graph):
        """A batch that fails mirror validation at drain time must not be
        silently dropped: the pending updates survive for a retry and the
        mirror/graph stay untouched."""
        mi = MaintainableIndex.build(ex_graph, 2)
        svc = QueryService(Engine(mi.flush()), maintainer=mi)
        q = instantiate_template("C2", [0, 0])
        bad_label = ex_graph.n_labels  # out of range -> from_edges raises
        svc.apply_updates([("insert_edge", 2, 3, 0)])
        svc.apply_updates([("insert_edge", 0, 1, bad_label)])
        with pytest.raises(ValueError):
            svc.query(q)
        assert svc.pending_updates == 2  # both ops requeued, none lost
        assert mi.g is ex_graph  # mirror untouched by the failed batch
        # dropping the poison op lets the valid one apply on the retry
        svc._pending_updates = [u for u in svc._pending_updates
                                if u[3] != bad_label]
        assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q)
        assert (2, 3, 0) in {tuple(map(int, e)) for e in mi.g._base_edges()}

    def test_interleaved_updates_and_queries(self):
        g = random_graph(31, n_max=12, m_max=24)
        mi = MaintainableIndex.build(g, 2)
        svc = QueryService(Engine(mi.flush()), max_batch=8, maintainer=mi)
        rng = np.random.default_rng(31)
        for step in range(4):
            svc.apply_updates(_random_batch(mi.g, rng, 3))
            for q in _query_pool(mi.g, rng, 2)[:5]:
                assert _rows(svc.query(q)) == oracle.cpq_eval(mi.g, q), q
        assert svc.stats.update_batches == 4
