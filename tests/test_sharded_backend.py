"""ShardedBackend parity — in-process, over every visible device.

The whole-plan sharded executor (replicated class space, canonical
hash-partitioned pair space, psum'd overflow) runs fine on a mesh of one
device — every exchange is a self-send — so the full equivalence matrix
``ShardedBackend == LocalBackend == numpy oracle`` is checked here
without subprocess machinery.  The mesh spans ``jax.device_count()``
devices: 1 in the plain tier-1 run, 8 in the CI distributed step
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the
acceptance matrix at n_shards ∈ {1, 8}.  test_distributed.py
additionally covers the 8-device path from inside the plain suite via
subprocesses."""

import jax
import numpy as np
import pytest

from repro import compat
from repro.core import index as cindex, oracle
from repro.core.backend import LocalBackend
from repro.core.distributed import ShardedBackend
from repro.core.engine import Engine, QueryCaps
from repro.core.graph import LabeledGraph, example_graph
from repro.core.maintenance import MaintainableIndex
from repro.core.query import (
    TEMPLATE_ARITY,
    TEMPLATES,
    instantiate_template,
    parse,
)
from repro.core.service import QueryService

from conftest import random_graph


@pytest.fixture(scope="module")
def mesh1():
    """All visible devices on one 'engine' axis (1 normally; 8 in the
    CI distributed step)."""
    return compat.make_mesh((jax.device_count(),), ("engine",))


def _rows_set(rows):
    return {tuple(r) for r in np.asarray(rows).tolist()}


class TestShardedEngineParity:
    def test_template_suite_bit_identical(self, ex_graph, mesh1):
        """Every Fig. 5 template: the mesh engine returns the *same
        array* (values and order) as the local engine, and the right
        answer."""
        idx = cindex.build(ex_graph, 2)
        local = Engine(idx)
        sharded = Engine(idx, mesh=mesh1)
        assert isinstance(local.backend, LocalBackend)
        assert isinstance(sharded.backend, ShardedBackend)
        rng = np.random.default_rng(7)
        present = np.unique(ex_graph.lbl)
        for name in TEMPLATES:
            q = instantiate_template(
                name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
            a, b = local.execute(q), sharded.execute(q)
            assert a.shape == b.shape and np.array_equal(a, b), name
            assert _rows_set(b) == oracle.cpq_eval(ex_graph, q), name

    def test_identity_and_parse_paths(self, ex_graph, mesh1):
        idx = cindex.build(ex_graph, 2)
        local, sharded = Engine(idx), Engine(idx, mesh=mesh1)
        for text in ("id", "l0 & id", "(l0 . l1) & id", "l0 . id . l1"):
            q = parse(text, None, ex_graph.n_labels)
            a, b = local.execute(q), sharded.execute(q)
            assert np.array_equal(a, b), text
            assert _rows_set(b) == oracle.cpq_eval(ex_graph, q), text

    def test_batch_matches_sequential(self, ex_graph, mesh1):
        idx = cindex.build(ex_graph, 2)
        sharded = Engine(idx, mesh=mesh1)
        rng = np.random.default_rng(3)
        present = np.unique(ex_graph.lbl)
        qs = [instantiate_template("T", rng.choice(present, 3).tolist())
              for _ in range(5)]
        qs += [instantiate_template("C2", rng.choice(present, 2).tolist())
               for _ in range(3)]
        batch = sharded.execute_batch(qs)
        for q, rows in zip(qs, batch):
            assert np.array_equal(rows, sharded.execute(q))

    def test_overflow_ladder_retries_to_exact(self, ex_graph, mesh1):
        """Deliberately tiny caps: the psum'd sticky flag must drive the
        host double-and-retry to the exact answer, same as local."""
        idx = cindex.build(ex_graph, 2)
        sharded = Engine(idx, mesh=mesh1)
        q = parse("l0 . l1", None, ex_graph.n_labels)
        tiny = QueryCaps(class_cap=2, pair_cap=2, join_cap=2)
        rows = sharded.execute(q, caps=tiny)
        assert _rows_set(rows) == oracle.cpq_eval(ex_graph, q)


class TestShardedService:
    def test_service_and_write_path_reshard(self, mesh1):
        """QueryService over a mesh engine: same serving semantics, and
        the maintenance write path (mirror batch -> flush -> rebind)
        reshards the flushed arrays — answers track the updated graph."""
        g = example_graph()
        mi = MaintainableIndex.build(g, 2)
        engine = Engine(mi.flush(), mesh=mesh1)
        svc = QueryService(engine, maintainer=mi)
        q = parse("l0 . l1", None, g.n_labels)
        before = svc.query(q)
        assert _rows_set(before) == oracle.cpq_eval(g, q)
        old_backend = engine.backend
        old_arrays = old_backend.sharded
        old_compiled = dict(old_backend._cache)

        svc.apply_updates([("insert_edge", 0, 3, 0), ("delete_edge", 0, 1, 0)])
        after = svc.query(q)  # drain applies updates, flush reshards
        # rebind resharded *into* the same backend: new arrays, but the
        # compiled plan executables survive the flush
        assert engine.backend is old_backend
        assert engine.backend.sharded is not old_arrays
        for key, fn in old_compiled.items():
            assert engine.backend._cache.get(key) is fn
        assert _rows_set(after) == oracle.cpq_eval(mi.g, q)  # updated graph
        assert svc.stats.update_batches == 1
        # epoch bumped: the pre-update cached answer is unreachable
        assert svc.graph_epoch >= 1

    def test_random_graphs_seeded_sweep(self, mesh1):
        """Deterministic cousin of the hypothesis property (which lives
        in test_sharded_properties.py): a seeded sweep of random graphs
        through a random template each, sharded == local == oracle."""
        for seed in range(4):
            g = random_graph(seed, n_max=14, m_max=36)
            idx = cindex.build(g, 2)
            local, sharded = Engine(idx), Engine(idx, mesh=mesh1)
            rng = np.random.default_rng(seed)
            present = np.unique(g.lbl)
            names = sorted(TEMPLATES)
            name = names[int(rng.integers(len(names)))]
            q = instantiate_template(
                name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
            a, b = local.execute(q), sharded.execute(q)
            assert np.array_equal(a, b), (seed, name)
            assert _rows_set(b) == oracle.cpq_eval(g, q), (seed, name)
