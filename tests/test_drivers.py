"""End-to-end driver tests (deliverable (b)): the training and serving
CLIs run, learn/produce tokens, checkpoint, and resume — via subprocess
so they exercise the real entry points."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestTrainDriver:
    def test_train_learns_and_resumes(self, tmp_path):
        out = _run(["repro.launch.train", "--arch", "minicpm-2b",
                    "--steps", "14", "--batch", "2", "--seq", "32",
                    "--ckpt-dir", str(tmp_path)])
        assert "[train] done" in out
        # loss decreased
        first = float(out.split("loss ")[-1].split(" ->")[0])
        last = float(out.split("-> ")[-1].split(" over")[0])
        assert last <= first
        # resume from the checkpoint written at step 10... ckpt_every=50
        # default means none; rerun with resume anyway (no-crash contract)
        out2 = _run(["repro.launch.train", "--arch", "minicpm-2b",
                     "--steps", "6", "--batch", "2", "--seq", "32",
                     "--ckpt-dir", str(tmp_path), "--resume"])
        assert "[train] done" in out2

    def test_wsd_schedule_selected_for_minicpm(self):
        out = _run(["repro.launch.train", "--arch", "minicpm-2b",
                    "--steps", "4", "--batch", "2", "--seq", "16"])
        assert "schedule=wsd" in out


class TestServeDriver:
    def test_continuous_batching_completes(self):
        out = _run(["repro.launch.serve", "--arch", "gemma2-2b",
                    "--requests", "4", "--slots", "2", "--max-new", "4",
                    "--max-len", "32"])
        assert "all 4 requests done" in out
        # more requests than slots => slots were reused
        assert "admitted request 3" in out
