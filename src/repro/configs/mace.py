"""mace [arXiv:2206.07697; paper]: 2L d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, E(3)-equivariant ACE message passing
(Cartesian-irrep implementation; see models/gnn.py docstring)."""

import dataclasses

from repro.configs import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="mace", arch="mace", n_layers=2, d_hidden=128,
    d_in=64, d_out=1,  # d_in replaced per shape by the launcher
    l_max=2, correlation=3, n_rbf=8, r_cut=5.0,
)

SMOKE = dataclasses.replace(CONFIG, d_hidden=16, d_in=8, n_rbf=4)

SPEC = ArchSpec(
    arch_id="mace", family="gnn", config=CONFIG, smoke=SMOKE,
    shapes=gnn_shapes(),
    notes=(
        "citation-graph shapes carry no 3D coordinates; input_specs "
        "synthesizes positions (the model is coordinate-consuming by "
        "construction). Correlation-3 products via exact Cartesian "
        "couplings (dot/cross/traceless-outer)."
    ),
)
