"""Mini-optax: AdamW with decoupled weight decay and global-norm clipping,
as plain pytree transforms (no external deps).

Optimizer state lives in f32 regardless of param dtype (bf16 params +
f32 moments is the standard large-scale recipe); the launcher shards the
moment trees with the same FSDP specs as the params."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(
    grads, state: AdamWState, params, lr, *,
    b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1, clip_norm: float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "clip_scale": scale,
    }
