"""CPQ query serving layer — continuous batching for index-backed query
traffic.

``launch/serve.py`` proved the slot/continuous-batching pattern for LM
decoding; this module adapts it to CPQ serving on top of
``Engine.execute_batch``:

* **request queue** — ``submit`` enqueues; nothing touches the device
  until a flush, so concurrent requests of the same plan shape ride one
  vmapped dispatch.
* **plan-shape buckets** — at flush time the queue is grouped by
  :func:`repro.core.query.plan_shape` (the jit key); every bucket is one
  device dispatch regardless of how many queries (or which labels) it
  holds.
* **bounded plan cache keyed by (graph epoch, query)** — AST -> physical
  plan memoization (planning is host work but repeated verbatim for
  recurring traffic); LRU beyond ``plan_cache_size``.  The epoch
  component matters since PR 4: plans come from the cost-based optimizer
  (``core.optimizer``), so they depend on the index *statistics*, not
  just the available sequences — any rebind bumps the epoch and every
  plan optimized against stale statistics becomes unreachable in O(1),
  exactly like stale results.
* **LRU result cache keyed by (graph epoch, query)** — repeat queries
  are answered host-side with zero device work.  The epoch component
  makes invalidation O(1): any graph mutation bumps the epoch and every
  cached answer for older epochs becomes unreachable (aging out of the
  LRU naturally).
* **admission/flush policy** — the queue admits up to ``max_batch``
  requests; submitting past that point flushes synchronously.  ``query``
  is the one-shot convenience wrapper (submit + flush).

A graph update re-enters the service two ways:

* **rebind path** — any fresh :class:`CPQxIndex` (a from-scratch rebuild
  or a maintenance flush) through :meth:`rebind`, which swaps the index
  into the engine, bumps the epoch, and drops the plan cache (plans
  depend on the index's available sequences).
* **write path** — :meth:`apply_updates` on a service constructed with a
  ``maintainer`` (:class:`repro.core.maintenance.MaintainableIndex`).
  Updates are *queued*, not applied: the epoch bumps immediately (stale
  cached answers become unreachable in O(1)) but the host-mirror surgery
  and the mirror→device flush are deferred and **coalesced** — the next
  query drain applies every queued update as ONE
  ``MaintainableIndex.apply_updates`` batch (one affected-pair union BFS)
  followed by ONE flush/rebind.  Reads submitted before a write are
  drained first, so the service serves a strict serializable history:
  every query sees exactly the writes applied before it was submitted.

The service is backend-agnostic: an ``Engine`` constructed with a mesh
(``Engine(index, mesh=...)`` — the sharded backend of
``core.distributed``) serves the identical API and answers through this
layer.  On the write path nothing changes either: ``Engine.rebind``
re-shards the flushed arrays, and the epoch/caching machinery here never
looks at the backend.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from .engine import Engine, QueryCaps
from .index import CPQxIndex
from .query import CPQ, plan_shape


_UPDATE_OPS = frozenset({"insert_edge", "delete_edge", "change_label",
                         "delete_vertex", "insert_vertex"})


@dataclasses.dataclass
class QueryRequest:
    """One in-flight query: filled in place when its flush completes."""

    rid: int
    query: CPQ
    result: np.ndarray | None = None
    done: bool = False
    from_cache: bool = False


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    served: int = 0
    cache_hits: int = 0
    executed: int = 0  # queries that reached the device
    deduped: int = 0  # in-flight duplicates folded into one execution
    flushes: int = 0
    shape_buckets: int = 0  # distinct plan shapes across all flushes (the
    # device may dispatch more often: caps buckets and overflow retries)
    plan_hits: int = 0
    updates_applied: int = 0  # individual update ops through apply_updates
    update_batches: int = 0  # coalesced mirror/device maintenance rounds


class QueryService:
    """Continuous-batching front end over a CPQx/iaCPQx engine."""

    def __init__(self, engine: Engine, *, max_batch: int = 64,
                 result_cache_size: int = 1024, plan_cache_size: int = 256,
                 caps: QueryCaps | None = None, max_retries: int = 10,
                 maintainer=None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.max_batch = max_batch
        self.caps = caps
        self.max_retries = max_retries
        self.graph_epoch = 0
        self.stats = ServiceStats()
        self.maintainer = maintainer  # MaintainableIndex enabling the write path
        self._next_rid = 0
        self._queue: list[QueryRequest] = []
        self._pending_updates: list = []
        self._results: OrderedDict = OrderedDict()  # (epoch, query) -> rows
        self._result_cache_size = result_cache_size
        self._plans: OrderedDict = OrderedDict()  # (epoch, query) -> plan
        self._plan_cache_size = plan_cache_size

    # ------------------------------------------------------------------ #
    # request lifecycle
    # ------------------------------------------------------------------ #

    def submit(self, query: CPQ) -> QueryRequest:
        """Enqueue a query.  Served straight from the result cache when
        possible; otherwise it completes on the next flush (which happens
        automatically once the queue holds ``max_batch`` requests)."""
        req = QueryRequest(self._next_rid, query)
        self._next_rid += 1
        self.stats.submitted += 1
        cached = self._cache_get(query)
        if cached is not None:
            req.result, req.done, req.from_cache = cached, True, True
            self.stats.cache_hits += 1
            self.stats.served += 1
            return req
        self._queue.append(req)
        if len(self._queue) >= self.max_batch:
            self.flush()
        return req

    def flush(self) -> list[QueryRequest]:
        """Execute everything queued and return the completed requests.

        Duplicate queries in the queue collapse onto one execution, and
        the engine groups the distinct ones by plan shape — each shape
        bucket is a single vmapped device dispatch.  Queued graph updates
        (``apply_updates``) are drained first, so every query in this
        flush is answered on the post-update index."""
        self._drain_updates()
        batch, self._queue = self._queue, []
        if not batch:
            return []
        self.stats.flushes += 1
        # re-check the cache (an earlier flush may have answered a dup)
        todo: list[QueryRequest] = []
        for req in batch:
            cached = self._cache_get(req.query)
            if cached is not None:
                req.result, req.done, req.from_cache = cached, True, True
                self.stats.cache_hits += 1
            else:
                todo.append(req)
        by_query: dict = {}
        for req in todo:
            by_query.setdefault(req.query, []).append(req)
        queries = list(by_query)
        if queries:
            plans = [self._plan(q) for q in queries]
            try:
                rows = self.engine.execute_batch(
                    queries, caps=self.caps, max_retries=self.max_retries,
                    plans=plans)
            except Exception:
                # nothing completed: requeue so the requests aren't lost
                self._queue = todo + self._queue
                raise
            self.stats.shape_buckets += len({plan_shape(p) for p in plans})
            self.stats.executed += len(queries)
            self.stats.deduped += len(todo) - len(queries)
            for q, res in zip(queries, rows):
                self._cache_put(q, res)
                for req in by_query[q]:
                    req.result, req.done = res, True
        self.stats.served += len(batch)
        return batch

    def query(self, query: CPQ) -> np.ndarray:
        """One-shot convenience: submit + flush, returns the (n, 2) rows."""
        req = self.submit(query)
        if not req.done:
            self.flush()
        return req.result

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def pending_updates(self) -> int:
        return len(self._pending_updates)

    # ------------------------------------------------------------------ #
    # graph mutation / epoch handling
    # ------------------------------------------------------------------ #

    def apply_updates(self, updates: list) -> None:
        """The write path: queue a batch of graph updates (op tuples in
        ``MaintainableIndex.apply_updates`` form, e.g.
        ``("insert_edge", v, u, lbl)``).

        Reads already queued are drained first (they targeted the
        pre-update graph), then the updates are queued and the epoch
        bumps — O(1) invalidation of every cached answer.  The expensive
        work (mirror surgery + mirror→device flush) is deferred to the
        next query drain, so consecutive ``apply_updates`` calls coalesce
        into one batched maintenance round."""
        if self.maintainer is None:
            raise RuntimeError(
                "no maintainer bound — construct the service with "
                "QueryService(engine, maintainer=MaintainableIndex.build(...))"
            )
        if not updates:
            return
        for op in updates:  # reject malformed ops at enqueue, not drain
            if not op or op[0] not in _UPDATE_OPS:
                raise ValueError(f"unknown update op {op!r}")
        if self._queue:
            self.flush()  # reads before the write see the pre-update graph
        self._pending_updates.extend(updates)
        self.bump_epoch()

    def _drain_updates(self) -> None:
        """Coalesce every queued update into one mirror batch + one
        mirror→device flush, and rebind the engine to the flushed
        arrays."""
        if not self._pending_updates:
            return
        ups, self._pending_updates = self._pending_updates, []
        try:
            self.maintainer.apply_updates(ups)
        except Exception:
            # the mirror validates before mutating, so a failed batch left
            # it untouched: requeue so ops coalesced into this batch
            # aren't silently dropped
            self._pending_updates = ups + self._pending_updates
            raise
        self.engine.rebind(self.maintainer.flush())
        self.stats.updates_applied += len(ups)
        self.stats.update_batches += 1

    def rebind(self, index: CPQxIndex) -> None:
        """Swap in a rebuilt index (after ``core.maintenance`` mirror
        surgery or a from-scratch rebuild).  Bumps the graph epoch so
        every cached result — and every cached plan, which since PR 4 is
        optimized against the old index's statistics — is dead."""
        if self._queue:
            self.flush()  # drain against the index the requests targeted
        self.engine.rebind(index)
        self.bump_epoch()

    def bump_epoch(self) -> None:
        """O(1) invalidation: results *and* plans are keyed by epoch, so
        stale entries become unreachable and age out of their LRUs."""
        self.graph_epoch += 1

    # ------------------------------------------------------------------ #
    # caches
    # ------------------------------------------------------------------ #

    def _cache_get(self, query: CPQ):
        key = (self.graph_epoch, query)
        if key in self._results:
            self._results.move_to_end(key)
            return self._results[key]
        return None

    def _cache_put(self, query: CPQ, rows: np.ndarray) -> None:
        # the same array is handed to every requester and to future cache
        # hits — freeze it so no caller can corrupt the shared answer
        rows.setflags(write=False)
        key = (self.graph_epoch, query)
        self._results[key] = rows
        self._results.move_to_end(key)
        while len(self._results) > self._result_cache_size:
            self._results.popitem(last=False)

    def _plan(self, query: CPQ):
        key = (self.graph_epoch, query)
        if key in self._plans:
            self._plans.move_to_end(key)
            self.stats.plan_hits += 1
            return self._plans[key]
        plan = self.engine.plan(query)
        self._plans[key] = plan
        while len(self._plans) > self._plan_cache_size:
            self._plans.popitem(last=False)
        return plan
