from .checkpoint import (  # noqa: F401
    committed_steps,
    latest_step,
    load_checkpoint,
    load_checkpoint_items,
    restore_sharded,
    save_checkpoint,
    wait_for_writes,
)
