"""Jitted public wrappers for the Pallas kernels, with automatic padding
and a jnp fallback when the problem exceeds the kernels' VMEM-resident
assumptions (or when ``REPRO_DISABLE_PALLAS=1``).

The engine calls these; tests sweep them against ``ref.py``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import expand_join as _ej
from . import fingerprint as _fp
from . import ref
from . import segment_softmax as _ss
from . import sorted_intersect as _si

SENTINEL = np.int32(2**31 - 1)

# VMEM-residency ceiling for the broadcast operands (int32 words); beyond
# this the ops fall back to the XLA path, which tiles through HBM.
_VMEM_WORDS = 1_000_000


def _pallas_enabled() -> bool:
    return os.environ.get("REPRO_DISABLE_PALLAS", "0") != "1"


def _pad_to(x: jax.Array, n: int, fill) -> jax.Array:
    if x.shape[0] == n:
        return x
    pad = jnp.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def sorted_member_mask(hay, hay_count, queries, block_q: int = 1024):
    """0/1 membership of queries in sorted hay[:hay_count]."""
    if not _pallas_enabled() or hay.shape[0] > _VMEM_WORDS:
        return ref.sorted_member_mask(hay, hay_count, queries)
    n_q = queries.shape[0]
    blk = min(block_q, max(8, 1 << (n_q - 1).bit_length()))
    n_pad = ((n_q + blk - 1) // blk) * blk
    q = _pad_to(queries, n_pad, SENTINEL)
    out = _si.sorted_member_mask(hay, hay_count, q, block_q=blk)
    return out[:n_q]


def expand_join_gather(ends, lo, a_payload, b_v, b_u, total, out_capacity,
                       block_t: int = 1024):
    if (not _pallas_enabled()
            or ends.shape[0] + 2 * b_v.shape[0] > _VMEM_WORDS):
        return ref.expand_join_gather(ends, lo, a_payload, b_v, b_u, total,
                                      out_capacity)
    blk = min(block_t, max(8, 1 << (out_capacity - 1).bit_length()))
    cap = ((out_capacity + blk - 1) // blk) * blk
    ov, ou, oa = _ej.expand_join_gather(ends, lo, a_payload, b_v, b_u, total,
                                        cap, block_t=blk)
    return ov[:out_capacity], ou[:out_capacity], oa[:out_capacity]


def fingerprint_rows(cols: tuple, salt: int = 0):
    n = cols[0].shape[0]
    if not _pallas_enabled():
        return ref.fingerprint_rows(cols, salt)
    return _fp.fingerprint_rows(tuple(cols), salt=salt)


def segment_softmax(scores, segment_ids, num_segments, eps: float = 1e-9):
    e = scores.shape[0]
    if (not _pallas_enabled() or num_segments * scores.shape[1] > _VMEM_WORDS
            or e % min(512, e) != 0):
        return ref.segment_softmax(scores, segment_ids, num_segments, eps)
    return _ss.segment_softmax(scores, segment_ids, num_segments, eps=eps)
