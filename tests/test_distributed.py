"""Distributed engine + sharded model tests — run in a subprocess with 8
forced host devices (the main test process must keep 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestDistributedEngine:
    def test_distributed_join_matches_ground_truth(self):
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.core import distributed as D

            mesh = compat.make_mesh((8,), ("engine",))
            rng = np.random.default_rng(0)
            A = np.unique(rng.integers(0, 30, (200, 2)).astype(np.int32), axis=0)
            B = np.unique(rng.integers(0, 30, (180, 2)).astype(np.int32), axis=0)
            gt = sorted({(int(v), int(u)) for v, m in A for m2, u in B if m == m2})
            a_blocks, a_counts = D.shard_relation(A, 8, 128, key_col=0)
            b_blocks, b_counts = D.shard_relation(B, 8, 128, key_col=1)
            a_cols = tuple(jnp.asarray(a_blocks[:, :, j]) for j in range(2))
            b_cols = tuple(jnp.asarray(b_blocks[:, :, j]) for j in range(2))
            join = D.make_distributed_join(mesh, "engine", 8, 2, 2,
                                           bucket_cap=128, out_cap=4096)
            with compat.set_mesh(mesh):
                oc, on, ovf = join(a_cols, jnp.asarray(a_counts),
                                   b_cols, jnp.asarray(b_counts))
            assert not np.asarray(ovf).any()
            ov, ou, cnt = np.asarray(oc[0]), np.asarray(oc[1]), np.asarray(on)
            rows = sorted({(int(ov[s, i]), int(ou[s, i]))
                           for s in range(8) for i in range(cnt[s])})
            assert rows == gt, (len(rows), len(gt))
            print("JOIN_OK", len(rows))
        """)
        assert "JOIN_OK" in out

    def test_distributed_query_step(self):
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.core import distributed as D
            from repro.core import relational as R

            mesh = compat.make_mesh((8,), ("engine",))
            rng = np.random.default_rng(1)
            n_cls = 40
            c2p = np.unique(rng.integers(0, 25, (300, 3)).astype(np.int32), axis=0)
            c2p[:, 0] = rng.integers(0, n_cls, c2p.shape[0])
            c2p = c2p[np.lexsort((c2p[:,2], c2p[:,1], c2p[:,0]))]
            ca = np.unique(rng.choice(n_cls, 10)).astype(np.int32)
            cb = np.unique(rng.choice(n_cls, 12)).astype(np.int32)
            inter = set(ca) & set(cb)
            gt = sorted({(int(r[1]), int(r[2])) for r in c2p if r[0] in inter})
            blocks, counts = D.shard_relation(c2p, 8, 128, key_col=0)
            cols = tuple(jnp.asarray(blocks[:, :, j]) for j in range(3))
            def padded(x, n):
                out = np.full(n, R.SENTINEL, np.int32); out[:len(x)] = x
                return jnp.asarray(out)
            step = D.make_distributed_query_step(mesh, "engine")
            with compat.set_mesh(mesh):
                (pv, pu), pc = step(padded(ca, 16), padded(cb, 16),
                                    cols[0], cols[1], cols[2],
                                    jnp.asarray(counts))
            pv, pu, pc = np.asarray(pv), np.asarray(pu), np.asarray(pc)
            got = sorted({(int(pv[s,i]), int(pu[s,i]))
                          for s in range(8) for i in range(pc[s])})
            assert got == gt
            print("QUERY_OK", len(got))
        """)
        assert "QUERY_OK" in out

    def test_sharded_backend_whole_plans_8dev(self):
        """The tentpole property at n_shards=8: whole plans (lookup,
        materialize, join, conj, identity) through ``Engine(mesh=...)``
        return bit-identical arrays to the local engine and set-identical
        answers to the semantics oracle, including the batch path and the
        reshard-on-rebind maintenance path."""
        out = _run_with_devices("""
            import numpy as np
            from repro import compat
            from repro.core import index as cindex, oracle
            from repro.core.engine import Engine
            from repro.core.maintenance import MaintainableIndex
            from repro.core.query import (TEMPLATES, TEMPLATE_ARITY,
                                          instantiate_template)
            from repro.data.graphs import gmark_citation

            g = gmark_citation(150, avg_degree=5, seed=2)
            idx = cindex.build(g, 2)
            mesh = compat.make_mesh((8,), ("engine",))
            local, sharded = Engine(idx), Engine(idx, mesh=mesh)
            rng = np.random.default_rng(5)
            present = np.unique(g.lbl)
            for name in sorted(TEMPLATES):
                q = instantiate_template(
                    name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
                a, b = local.execute(q), sharded.execute(q)
                assert a.shape == b.shape and np.array_equal(a, b), name
                assert ({tuple(r) for r in b.tolist()}
                        == oracle.cpq_eval(g, q)), name
            qs = [instantiate_template(
                      "S", rng.choice(present, 4).tolist())
                  for _ in range(6)]
            for x, y in zip(local.execute_batch(qs), sharded.execute_batch(qs)):
                assert np.array_equal(x, y)
            # maintenance: flush -> rebind reshards, answers track updates
            mi = MaintainableIndex.build(g, 2)
            se = Engine(mi.flush(), mesh=mesh)
            mi.apply_updates([("insert_edge", 0, 7, 0),
                              ("delete_edge", *map(int, g._base_edges()[0]))])
            se.rebind(mi.flush())
            q = instantiate_template("C2", rng.choice(present, 2).tolist())
            assert ({tuple(r) for r in se.execute(q).tolist()}
                    == oracle.cpq_eval(mi.g, q))
            print("SHARDED_BACKEND_OK")
        """)
        assert "SHARDED_BACKEND_OK" in out

    def test_bucket_overflow_flags_and_retry_recovers(self):
        """Exchange-capacity overflow at the edges: an undersized
        bucket_cap must raise the sticky flag (never silently drop rows),
        and the host-side double-and-retry ladder must converge to the
        exact join.  Also covers shard counts that don't divide the rows
        and shards left empty by the hash."""
        out = _run_with_devices("""
            import jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.core import distributed as D

            mesh = compat.make_mesh((8,), ("engine",))
            rng = np.random.default_rng(4)
            # skewed: every a-row joins through key 0 -> one shard gets all
            A = np.stack([np.arange(37, dtype=np.int32),
                          np.zeros(37, np.int32)], 1)
            B = np.unique(np.stack([np.zeros(29, np.int32),
                          rng.integers(0, 50, 29).astype(np.int32)], 1),
                          axis=0)
            gt = sorted({(int(v), int(y)) for v, m in A for m2, y in B
                         if m == m2})
            # shard by the (constant) join key: every row lands on one
            # shard, so its exchange bucket holds all 37 rows -> overflow
            a_blocks, a_counts = D.shard_relation(A, 8, 64, key_col=1)
            b_blocks, b_counts = D.shard_relation(B, 8, 64, key_col=0)
            assert (a_counts == 0).sum() == 7  # skew leaves 7 shards empty
            a_cols = tuple(jnp.asarray(a_blocks[:, :, j]) for j in range(2))
            b_cols = tuple(jnp.asarray(b_blocks[:, :, j]) for j in range(2))
            bucket_cap, rows = 8, None  # far below the 37-row hot bucket
            for attempt in range(6):
                join = D.make_distributed_join(mesh, "engine", 8, 2, 2,
                                               bucket_cap=bucket_cap,
                                               out_cap=4096)
                with compat.set_mesh(mesh):
                    oc, on, ovf = join(a_cols, jnp.asarray(a_counts),
                                       b_cols, jnp.asarray(b_counts))
                if not np.asarray(ovf).any():
                    ov, ou = np.asarray(oc[0]), np.asarray(oc[1])
                    cnt = np.asarray(on)
                    rows = sorted({(int(ov[s, i]), int(ou[s, i]))
                                   for s in range(8) for i in range(cnt[s])})
                    break
                bucket_cap *= 2
            assert attempt > 0, "undersized bucket must flag overflow"
            assert rows == gt, (len(rows or []), len(gt))
            print("BUCKET_RETRY_OK", attempt, bucket_cap)
        """)
        assert "BUCKET_RETRY_OK" in out

    def test_compressed_allreduce(self):
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro import compat
            from repro.train import compress

            mesh = compat.make_mesh((8,), ("dp",))
            rng = np.random.default_rng(0)
            g_all = rng.normal(0, 1, (8, 1024)).astype(np.float32)
            state = compress.compress_init({"g": jnp.zeros(1024)})

            def body(g, res):
                mean, new_state = compress.compressed_psum_grads(
                    {"g": g}, compress.CompressState({"g": res}), "dp")
                return mean["g"], new_state.residual["g"]

            fn = jax.jit(compat.shard_map(body, mesh=mesh,
                                          in_specs=(P("dp"), P("dp")),
                                          out_specs=(P("dp"), P("dp"))))
            with compat.set_mesh(mesh):
                g_in = jnp.asarray(g_all.reshape(-1))
                res = jnp.zeros_like(g_in)
                mean, res = fn(g_in, res)
            mean = np.asarray(mean).reshape(8, 1024)
            true_mean = g_all.mean(0)
            # every shard holds the same (approximate) mean
            for s in range(8):
                rel = np.linalg.norm(mean[s] - true_mean) / np.linalg.norm(true_mean)
                assert rel < 0.05, rel
            print("COMPRESS_OK")
        """)
        assert "COMPRESS_OK" in out

    def test_sharded_lm_step_runs(self):
        """Tiny LM train step actually EXECUTES on an 8-device mesh with
        the production sharding rules (not just lowers)."""
        out = _run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro import compat
            from repro.configs import get_arch
            from repro.launch import shardings as S
            from repro.models import transformer as T
            from repro.train.optim import adamw_init, adamw_update

            mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
            cfg = get_arch("gemma2-2b").smoke
            params = T.init_params(cfg, jax.random.PRNGKey(0))
            pspecs = S.lm_param_specs(cfg, mesh)
            shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
            params = jax.device_put(params, shard)
            opt = adamw_init(params)
            toks = jnp.zeros((8, 16), jnp.int32)

            def step(p, o, t):
                def lf(p):
                    return T.train_loss(cfg, p, t, t)
                (loss, _), g = jax.value_and_grad(lf, has_aux=True)(p)
                np_, no, _ = adamw_update(g, o, p, 1e-3)
                return np_, no, loss

            with compat.set_mesh(mesh):
                jstep = jax.jit(step)
                p2, o2, loss = jstep(params, opt, toks)
                p3, o3, loss2 = jstep(p2, o2, toks)
            assert np.isfinite(float(loss)) and float(loss2) < float(loss) + 1.0
            print("LM_SHARDED_OK", float(loss))
        """)
        assert "LM_SHARDED_OK" in out
