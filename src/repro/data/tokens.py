"""Deterministic synthetic token stream for LM training.

Zipf-distributed tokens with a simple bigram structure so loss curves are
non-trivial (the model can learn something); fully deterministic in
(seed, step) so distributed resume can skip to any step without state —
the fault-tolerance contract of the train loop."""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        ranks = np.arange(1, vocab_size + 1)
        w = 1.0 / ranks ** zipf_a
        self.p = w / w.sum()

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for a given global step — stateless."""
        rng = np.random.default_rng((self.seed, step))
        toks = rng.choice(self.vocab, size=(self.batch, self.seq + 1),
                          p=self.p).astype(np.int32)
        # bigram structure: every even position strongly predicts +1
        toks[:, 1::2] = (toks[:, 0:-1:2] + 1) % self.vocab
        return toks[:, :-1], toks[:, 1:]

    def shard_at(self, step: int, shard: int, n_shards: int):
        """This host's slice of the global batch (data-parallel input
        pipeline: each host materializes only its rows)."""
        toks, labels = self.batch_at(step)
        b = self.batch // n_shards
        sl = slice(shard * b, (shard + 1) * b)
        return toks[sl], labels[sl]
