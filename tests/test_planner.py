"""Planner edge cases (PR 1 satellites): `_split_seq` under restricted
``available`` sets, `freeze_plan` round-trips, `plan_shape` keys, and the
contract that ``plan_lookup_seqs`` emits exactly the order in which the
device executor consumes ``lookup_ranges`` rows."""

import numpy as np
import pytest

from conftest import random_graph
from repro.core import oracle
from repro.core.query import (
    Edge, Identity, TEMPLATES, TEMPLATE_ARITY, _split_seq, freeze_plan,
    instantiate_template, parse, plan_lookup_seqs, plan_query, plan_shape,
)


class TestSplitSeq:
    def test_unrestricted_greedy_k_chunks(self):
        assert _split_seq((1, 2, 3, 4, 5), 2, None) == [(1, 2), (3, 4), (5,)]
        assert _split_seq((1, 2, 3), 3, None) == [(1, 2, 3)]

    def test_restricted_available_falls_back_to_singletons(self):
        # no 2-sequences available: every segment must be length 1
        avail = {(1,), (2,), (3,)}
        assert _split_seq((1, 2, 3), 2, avail) == [(1,), (2,), (3,)]

    def test_restricted_available_prefers_longest_prefix(self):
        # (1,2) present, (3,4) absent -> greedy takes (1,2) then splits
        avail = {(1, 2), (2, 3)}
        assert _split_seq((1, 2, 3, 4), 2, avail) == [(1, 2), (3,), (4,)]
        # greedy is not optimal lookahead: (1,2) wins over (2,3)
        assert _split_seq((1, 2, 3), 2, avail) == [(1, 2), (3,)]

    def test_k3_restricted(self):
        avail = {(1, 2, 3), (1, 2)}
        assert _split_seq((1, 2, 3, 1, 2), 3, avail) == [(1, 2, 3), (1, 2)]
        avail = {(1, 2)}
        assert _split_seq((1, 2, 3, 1, 2), 3, avail) == [(1, 2), (3,), (1, 2)]

    def test_singletons_always_available(self):
        # length-1 segments need not be listed: L_q ⊇ L
        assert _split_seq((7,), 2, set()) == [(7,)]


class TestFreezePlan:
    def _plans(self):
        g = random_graph(21, n_max=10, m_max=25)
        rng = np.random.default_rng(21)
        qs = [oracle.random_cpq(rng, g, 3) for _ in range(12)]
        qs += [instantiate_template(t, list(range(8))) for t in
               ["C4", "TT", "SC", "ST"]]
        return [plan_query(q, 2) for q in qs]

    def test_round_trip_structure(self):
        """Freezing only converts lists to tuples — node kinds, nesting
        and every label survive; thawing back gives the original plan."""

        def thaw(p):
            if isinstance(p, tuple) and p and p[0] == "lookup":
                return ("lookup", [tuple(s) for s in p[1]])
            if isinstance(p, tuple):
                return tuple(thaw(x) if isinstance(x, tuple) else x for x in p)
            return p

        for plan in self._plans():
            frozen = freeze_plan(plan)
            hash(frozen)  # must be a valid dict / jit key
            assert freeze_plan(frozen) == frozen  # idempotent
            assert thaw(frozen) == plan
            assert plan_lookup_seqs(frozen) == [
                tuple(s) for s in plan_lookup_seqs(plan)]

    def test_equal_plans_freeze_equal(self):
        q = parse("l0 . l1 . l0 & l1", None, 2)
        assert freeze_plan(plan_query(q, 2)) == freeze_plan(plan_query(q, 2))


class TestPlanShape:
    def test_labels_do_not_change_shape(self):
        a = plan_query(instantiate_template("T", [0, 0, 1]), 2)
        b = plan_query(instantiate_template("T", [1, 1, 0]), 2)
        assert plan_shape(a) == plan_shape(b)
        assert hash(plan_shape(a)) == hash(plan_shape(b))

    def test_lookup_counts_match_segment_lists(self):
        for t in sorted(TEMPLATES):
            plan = plan_query(
                instantiate_template(t, list(range(TEMPLATE_ARITY[t]))), 2)

            def check(node, shape):
                assert node[0] == shape[0]
                if node[0] == "lookup":
                    assert shape[1] == len(node[1])
                elif node[0] == "conj_id":
                    check(node[1], shape[1])
                elif node[0] in ("join", "conj"):
                    check(node[1], shape[1])
                    check(node[2], shape[2])

            check(plan, plan_shape(plan))

    def test_shape_differs_when_structure_differs(self):
        shapes = {plan_shape(plan_query(
            instantiate_template(t, list(range(8))), 2))
            for t in ["C2", "C4", "T", "St"]}
        assert len(shapes) == 4


class TestLookupOrderContract:
    """plan_lookup_seqs must enumerate LOOKUP segments in exactly the
    order `run_plan`'s `next_range` consumes them — otherwise a query's
    ranges feed the wrong lookups."""

    @staticmethod
    def _consumption_order(plan):
        """Mirror of the executor's traversal in core.backend.run_plan_ops:
        a lookup node consumes one range per segment in list order;
        conj/join evaluate left then right; conj_id recurses."""
        out = []

        def ev(node):
            kind = node[0]
            if kind == "lookup":
                for seg in node[1]:
                    out.append(tuple(seg))
            elif kind == "conj_id":
                ev(node[1])
            elif kind in ("join", "conj"):
                ev(node[1])
                ev(node[2])

        ev(plan)
        return out

    def test_templates(self):
        for t in sorted(TEMPLATES):
            plan = plan_query(
                instantiate_template(t, list(range(TEMPLATE_ARITY[t]))), 2)
            assert [tuple(s) for s in plan_lookup_seqs(plan)] == \
                self._consumption_order(plan)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_queries_and_restricted_availability(self, seed):
        g = random_graph(seed + 40, n_max=12, m_max=30)
        rng = np.random.default_rng(seed)
        # a restricted availability set forces interesting splits
        avail = {(int(a), int(b)) for a, b in
                 rng.integers(0, 2 * g.n_labels, (3, 2))}
        for _ in range(10):
            q = oracle.random_cpq(rng, g, 3)
            for av in (None, avail):
                plan = plan_query(q, 2, available=av)
                if isinstance(q, Identity):
                    continue
                assert [tuple(s) for s in plan_lookup_seqs(plan)] == \
                    self._consumption_order(plan)

    def test_join_of_sub_and_lookup(self):
        # (a & b) . c . d: ranges must arrive as [a, b, c, d]
        q = parse("(l0 & l1) . l1 . l0", None, 2)
        plan = plan_query(q, 2)
        assert plan_lookup_seqs(plan) == [(0,), (1,), (1, 0)]
        assert self._consumption_order(plan) == [(0,), (1,), (1, 0)]


class TestFreezePlanCollisions:
    def test_distinct_plans_never_share_a_frozen_key(self):
        """`freeze_plan` is the plan-cache / jit key: two *different*
        plans colliding on one frozen key would silently serve one
        query's executable for another.  Sweep a broad set of distinct
        plans (templates, random CPQs, restricted availability) and
        require the frozen-key map to be injective."""
        g = random_graph(33, n_max=10, m_max=25)
        rng = np.random.default_rng(33)
        qs = [oracle.random_cpq(rng, g, 3) for _ in range(25)]
        qs += [instantiate_template(t, list(range(TEMPLATE_ARITY[t])))
               for t in sorted(TEMPLATES)]
        avail = {(0, 1), (1, 0)}
        plans = []
        for q in qs:
            plans.append(plan_query(q, 2))
            plans.append(plan_query(q, 2, available=avail))
            plans.append(plan_query(q, 3))
        by_key = {}
        for plan in plans:
            key = freeze_plan(plan)
            if key in by_key:
                assert by_key[key] == plan, (
                    f"frozen-key collision: {by_key[key]} vs {plan}")
            by_key[key] = plan
        # sanity: the sweep actually produced many distinct plans
        assert len(by_key) > 20

    def test_near_miss_plans_differ(self):
        """Minimal pairs that a sloppy freeze (e.g. flattening segment
        lists) would conflate."""
        pairs = [
            # one 2-segment lookup vs two 1-segment lookups joined
            (("lookup", [(0, 1)]),
             ("join", ("lookup", [(0,)]), ("lookup", [(1,)]))),
            # segmentation boundary moves
            (("lookup", [(0,), (1, 2)]), ("lookup", [(0, 1), (2,)])),
            # conj vs join of the same operands
            (("join", ("lookup", [(0,)]), ("lookup", [(1,)])),
             ("conj", ("lookup", [(0,)]), ("lookup", [(1,)]))),
        ]
        for a, b in pairs:
            assert freeze_plan(a) != freeze_plan(b), (a, b)


class TestParseErrors:
    """`parse` must reject malformed CPQ text with the offending
    position in the message (PR 9 satellite — previously the errors
    named the problem but not where)."""

    LABELS = {"f": 0, "v": 1}

    def test_each_error_site_reports_position(self):
        cases = [
            ("f.@v", "bad token", "position 2"),
            ("(f.v", "expected ')'", "position 4"),
            ("f..v", "expected label", "position 2"),
            ("f.zzz", "unknown label", "position 2"),
            ("l9", "out of range", "position 0"),
            ("f v", "trailing", "position 2"),
            ("f.", "expected label", "position 2"),
            ("", "expected label", "position 0"),
        ]
        for text, frag, pos in cases:
            with pytest.raises(SyntaxError) as e:
                parse(text, self.LABELS, 2)
            assert frag in str(e.value), text
            assert pos in str(e.value), (text, str(e.value))

    def test_good_text_still_parses(self):
        q = parse("(f . v) & id", self.LABELS, 2)
        assert plan_query(q, 2)[0] == "conj_id"
        # inverse suffix forms
        assert parse("f-", self.LABELS, 2) == Edge(2)
        assert parse("f^-1 . v", self.LABELS, 2) is not None
