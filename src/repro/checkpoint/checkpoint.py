"""Sharded, atomic, async checkpointing with elastic restore.

Layout::

    <dir>/step_000123/
        manifest.json        tree structure + leaf metadata + status
        leaf_00000.npy ...   one file per pytree leaf (host-gathered here;
                             on a real multi-host pod each host writes its
                             own shard files — the manifest records which)
    <dir>/LATEST             committed step pointer (atomic rename)

Guarantees:
  * atomic commit: data written to ``step_X.tmp`` then renamed, LATEST
    updated last — a crash mid-write can never corrupt a committed step;
  * durable commit: every leaf file, the manifest, and the directory
    entries are fsync'd before the rename, and the rename itself is made
    durable before LATEST moves — the pointer can never lead a committed
    step to disk;
  * async: writes happen on a daemon thread; ``wait_for_writes`` joins
    (registered via atexit so interpreter exit can't drop a write);
  * crash-tolerant discovery: ``latest_step`` treats LATEST as the
    commit point when it is readable and points at a real manifest, and
    otherwise falls back to scanning ``step_*`` dirs — uncommitted
    ``.tmp`` dirs and torn pointers are skipped, never trusted;
  * elastic restore: leaves are loaded on host and ``jax.device_put`` to
    ANY target sharding — restarting on a different mesh shape (scale up
    or down) just works; no resharding pass needed.
"""

from __future__ import annotations

import atexit
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_PENDING: list = []
_LOCK = threading.Lock()


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


# str(DictKey('x')) renders as "['x']"; strip the decoration so flat-dict
# checkpoints can be read back by plain key without a like-tree.
_DICTKEY_RE = re.compile(r"^\['(.*)'\]$")


def _norm_key(path: str) -> str:
    m = _DICTKEY_RE.match(path)
    return m.group(1) if m else path


def _fsync_file(f) -> None:
    f.flush()
    os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    """Make directory entries (new files, renames) durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


# numpy can't round-trip ml_dtypes (bfloat16, fp8) through npy files —
# store them as raw uint views with the true dtype in the manifest.
_RAW_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _to_native(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _RAW_VIEW:
        return arr.view(_RAW_VIEW[name]), name
    return arr, name


def _from_native(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _RAW_VIEW:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr.astype(dtype_name)


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:09d}")


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    async_write: bool = False,
                    extra: Optional[dict] = None) -> str:
    """Write one checkpoint; returns the committed directory path.

    ``extra`` is an optional JSON-serializable dict stored verbatim in
    the manifest — for small non-array metadata (strings, version tags)
    that has no business being an npy leaf.
    """
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(l) for l in leaves]

    def _write():
        final = _step_dir(ckpt_dir, step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):  # stale debris from a crashed writer
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        if extra is not None:
            manifest["extra"] = extra
        for i, (p, arr) in enumerate(zip(paths, host_leaves)):
            fname = f"leaf_{i:05d}.npy"
            raw, dtype_name = _to_native(arr)
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, raw)
                _fsync_file(f)
            manifest["leaves"].append(
                {"path": p, "file": fname, "shape": list(arr.shape),
                 "dtype": dtype_name})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            _fsync_file(f)
        _fsync_dir(tmp)  # directory entries durable before the rename
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        _fsync_dir(ckpt_dir)  # the rename itself durable before LATEST
        latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
            _fsync_file(f)
        os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
        _fsync_dir(ckpt_dir)
        return final

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        with _LOCK:
            _PENDING.append(t)
        t.start()
        return _step_dir(ckpt_dir, step)
    return _write()


def wait_for_writes():
    with _LOCK:
        pending = list(_PENDING)
        _PENDING.clear()
    for t in pending:
        t.join()


# a daemon writer thread dies with the interpreter mid-write; joining at
# exit turns "usually committed" into "committed".
atexit.register(wait_for_writes)


def committed_steps(ckpt_dir: str) -> list[int]:
    """All fully-renamed steps on disk, ascending.  A step counts only if
    its directory survived the atomic rename (no ``.tmp`` suffix) AND its
    manifest exists — a partially-copied dir is not a checkpoint."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        try:
            s = int(name[len("step_"):])
        except ValueError:
            continue
        if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(s)
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed step, or None.

    LATEST is the commit point when it is intact: readable, an int, and
    pointing at a directory with a manifest.  A torn or dangling pointer
    (crash between data rename and pointer replace, or a partial pointer
    write on a filesystem without atomic replace) falls back to scanning
    the committed ``step_*`` dirs — never crashes, never returns an
    uncommitted ``.tmp``."""
    p = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(p):
        try:
            with open(p) as f:
                s = int(f.read().strip())
        except (OSError, ValueError):
            s = None
        if s is not None and os.path.exists(
                os.path.join(_step_dir(ckpt_dir, s), "manifest.json")):
            return s
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Load into the structure of ``like`` (host numpy leaves)."""
    wait_for_writes()
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for p, leaf in zip(paths, leaves):
        e = by_path[p]
        arr = _from_native(np.load(os.path.join(d, e["file"])), e["dtype"])
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {p}: checkpoint shape {arr.shape} != model {want}")
        out.append(arr.astype(leaf.dtype) if str(arr.dtype) != str(leaf.dtype)
                   else arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def load_checkpoint_items(
        ckpt_dir: str, step: Optional[int] = None,
) -> tuple[dict, Optional[dict], int]:
    """Dynamic loader: ``(items, extra, step)`` with no like-tree.

    ``items`` maps normalized leaf paths (dict-key decoration stripped)
    to host numpy arrays at their *checkpointed* shapes — the reader
    decides what to do with them.  This is what a fresh process uses: it
    has no live tree whose capacities match the checkpoint's."""
    wait_for_writes()
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint under {ckpt_dir!r}")
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    items = {}
    for e in manifest["leaves"]:
        arr = _from_native(np.load(os.path.join(d, e["file"])), e["dtype"])
        items[_norm_key(e["path"])] = arr
    return items, manifest.get("extra"), step


def restore_sharded(ckpt_dir: str, step: int, like: Any,
                    shardings: Any = None) -> Any:
    """Elastic restore: host leaves -> device_put with target shardings
    (any mesh shape — scale-up/down restart)."""
    host = load_checkpoint(ckpt_dir, step, like)
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, host)
    return jax.tree.map(
        lambda arr, s: jax.device_put(arr, s), host, shardings)
