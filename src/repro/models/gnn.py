"""GNN zoo: GatedGCN, EGNN, MACE (reduced-order equivariant), GraphCast —
all message passing on the same edge-list substrate the CPQx engine uses:
``jax.ops.segment_sum`` over (senders, receivers) int32 arrays (JAX has no
CSR SpMM; the segment-scatter substrate IS the system, per the assignment
notes).

Batch format: a single flat ``GraphBatch`` — batched small graphs
(``molecule`` shape) are disjoint unions with ``graph_ids``; sampled
subgraphs (``minibatch_lg``) are padded flat graphs with masks.

MACE adaptation (DESIGN.md §Arch-applicability): the higher-order
equivariant message construction (correlation order 3) is implemented in
*Cartesian irrep* form — l=0 scalars, l=1 vectors, l=2 traceless
symmetric tensors — with exact E(3)-equivariant couplings (dot, cross,
outer-traceless, tensor contraction) instead of spherical-harmonic CG
tables: identical expressive content for l_max=2, TPU-friendly dense
einsums instead of irregular CG index lists.  Node states carry the
invariant channels between layers (equivariant intermediates are rebuilt
per layer); equivariance is property-tested.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class GraphBatch(NamedTuple):
    node_feat: jax.Array  # (N, F)
    edge_feat: Optional[jax.Array]  # (E, Fe) or None
    senders: jax.Array  # (E,) int32
    receivers: jax.Array  # (E,) int32
    node_mask: jax.Array  # (N,) bool
    edge_mask: jax.Array  # (E,) bool
    positions: Optional[jax.Array]  # (N, 3) for EGNN / MACE
    graph_ids: jax.Array  # (N,) int32 — disjoint-union membership
    n_graphs: int  # static


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # gatedgcn | egnn | mace | graphcast
    n_layers: int
    d_hidden: int
    d_in: int
    d_out: int
    d_edge_in: int = 0
    # mace
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    # graphcast
    n_mlp_layers: int = 1
    param_dtype: str = "float32"

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)


# ---------------------------------------------------------------------- #
# shared pieces
# ---------------------------------------------------------------------- #


def _mlp_init(key, dims, dt):
    ks = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": (jax.random.normal(ks[i], (dims[i], dims[i + 1]), jnp.float32)
                  / np.sqrt(dims[i])).astype(dt)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dt) for i in range(len(dims) - 1)
    }


def _mlp(p, x, n, act=jax.nn.silu, final_act=False):
    for i in range(n):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def _ln(x, eps=1e-6):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + eps)


def _agg(messages: jax.Array, receivers: jax.Array, n_nodes: int,
         edge_mask: jax.Array) -> jax.Array:
    """Masked scatter-sum of edge messages to destination nodes — the one
    substrate op every model shares (and the engine's segment machinery)."""
    mask = edge_mask.reshape((-1,) + (1,) * (messages.ndim - 1))
    m = jnp.where(mask, messages, 0)
    return jax.ops.segment_sum(m, receivers, n_nodes)


def edge_softmax(scores: jax.Array, receivers: jax.Array, n_nodes: int):
    """Per-destination softmax over incoming edges (GAT-style) — Pallas
    segment_softmax kernel."""
    return kops.segment_softmax(scores, receivers, n_nodes)


# ---------------------------------------------------------------------- #
# GatedGCN  [arXiv:2003.00982 benchmark config: 16L, d=70]
# ---------------------------------------------------------------------- #


def gatedgcn_init(cfg: GNNConfig, key) -> dict:
    dt = cfg.dtype
    d = cfg.d_hidden
    ks = jax.random.split(key, 4 + cfg.n_layers)
    def lin(k, i, o):
        return (jax.random.normal(k, (i, o), jnp.float32) / np.sqrt(i)).astype(dt)
    layers = []
    for li in range(cfg.n_layers):
        lk = jax.random.split(ks[4 + li], 6)
        layers.append({
            "A": lin(lk[0], d, d), "B": lin(lk[1], d, d), "C": lin(lk[2], d, d),
            "U": lin(lk[3], d, d), "V": lin(lk[4], d, d),
        })
    return {
        "embed_n": lin(ks[0], cfg.d_in, d),
        "embed_e": lin(ks[1], max(cfg.d_edge_in, 1), d),
        "readout": lin(ks[2], d, cfg.d_out),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
    }


def gatedgcn_apply(cfg: GNNConfig, params: dict, g: GraphBatch) -> jax.Array:
    h = g.node_feat.astype(cfg.dtype) @ params["embed_n"]
    if g.edge_feat is not None:
        e = g.edge_feat.astype(cfg.dtype) @ params["embed_e"]
    else:
        e = jnp.zeros((g.senders.shape[0], cfg.d_hidden), cfg.dtype)
    n = h.shape[0]

    def body(carry, lp):
        h, e = carry
        hs = h[g.senders]
        hr = h[g.receivers]
        e_new = hr @ lp["A"] + hs @ lp["B"] + e @ lp["C"]
        e_new = e + jax.nn.silu(_ln(e_new))
        gate = jax.nn.sigmoid(e_new)
        num = _agg(gate * (hs @ lp["V"]), g.receivers, n, g.edge_mask)
        den = _agg(gate, g.receivers, n, g.edge_mask)
        h_new = h @ lp["U"] + num / (den + 1e-6)
        h_new = h + jax.nn.silu(_ln(h_new))
        return (h_new, e_new), None

    (h, _), _ = jax.lax.scan(body, (h, e), params["layers"])
    return h @ params["readout"]


# ---------------------------------------------------------------------- #
# EGNN  [arXiv:2102.09844: 4L, d=64, E(n) equivariant]
# ---------------------------------------------------------------------- #


def egnn_init(cfg: GNNConfig, key) -> dict:
    dt = cfg.dtype
    d = cfg.d_hidden
    ks = jax.random.split(key, 2 + cfg.n_layers)
    def lin(k, i, o):
        return (jax.random.normal(k, (i, o), jnp.float32) / np.sqrt(i)).astype(dt)
    layers = []
    for li in range(cfg.n_layers):
        lk = jax.random.split(ks[2 + li], 3)
        layers.append({
            "phi_e": _mlp_init(lk[0], [2 * d + 1, d, d], dt),
            "phi_x": _mlp_init(lk[1], [d, d, 1], dt),
            "phi_h": _mlp_init(lk[2], [2 * d, d, d], dt),
        })
    return {
        "embed_n": lin(ks[0], cfg.d_in, d),
        "readout": lin(ks[1], d, cfg.d_out),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
    }


def egnn_apply(cfg: GNNConfig, params: dict, g: GraphBatch):
    """Returns (node outputs (N, d_out), updated positions (N, 3))."""
    h = g.node_feat.astype(cfg.dtype) @ params["embed_n"]
    x = g.positions.astype(cfg.dtype)
    n = h.shape[0]

    def body(carry, lp):
        h, x = carry
        diff = x[g.senders] - x[g.receivers]  # (E, 3)
        dist2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = _mlp(lp["phi_e"], jnp.concatenate(
            [h[g.receivers], h[g.senders], dist2], -1), 2, final_act=True)
        w = _mlp(lp["phi_x"], m, 2)  # (E, 1)
        # normalize by degree for stability (paper's C = 1/(n-1))
        x_agg = _agg(diff * w, g.receivers, n, g.edge_mask)
        deg = _agg(jnp.ones_like(w), g.receivers, n, g.edge_mask)
        x = x + x_agg / (deg + 1.0)
        m_agg = _agg(m, g.receivers, n, g.edge_mask)
        h = h + _mlp(lp["phi_h"], jnp.concatenate([h, m_agg], -1), 2)
        return (h, x), None

    (h, x), _ = jax.lax.scan(body, (h, x), params["layers"])
    return h @ params["readout"], x


# ---------------------------------------------------------------------- #
# MACE (reduced, Cartesian irreps)  [arXiv:2206.07697: 2L, d=128,
# l_max=2, correlation 3, n_rbf=8]
# ---------------------------------------------------------------------- #


def _bessel_basis(r: jax.Array, n: int, r_cut: float) -> jax.Array:
    """(E, n) radial Bessel basis with smooth cutoff envelope."""
    r = jnp.clip(r, 1e-4, None)
    k = jnp.arange(1, n + 1, dtype=r.dtype) * np.pi / r_cut
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(k * r[:, None]) / r[:, None]
    x = jnp.clip(r / r_cut, 0, 1)
    env = 1 - 10 * x**3 + 15 * x**4 - 6 * x**5  # C2-smooth polynomial cutoff
    return basis * env[:, None]


def mace_init(cfg: GNNConfig, key) -> dict:
    dt = cfg.dtype
    c = cfg.d_hidden
    ks = jax.random.split(key, 3 + cfg.n_layers)
    def lin(k, i, o):
        return (jax.random.normal(k, (i, o), jnp.float32) / np.sqrt(i)).astype(dt)
    layers = []
    # invariant scalar contributions per correlation order: nu=1 (A0),
    # nu=2 (3 couplings), nu=3 (4 couplings) => 8 scalar channels blocks
    for li in range(cfg.n_layers):
        lk = jax.random.split(ks[3 + li], 8)
        layers.append({
            "radial0": _mlp_init(lk[0], [cfg.n_rbf, c], dt),
            "radial1": _mlp_init(lk[1], [cfg.n_rbf, c], dt),
            "radial2": _mlp_init(lk[2], [cfg.n_rbf, c], dt),
            "wsrc": lin(lk[3], c, c),
            "path_w": (jax.random.normal(lk[4], (8, c), jnp.float32) * 0.3).astype(dt),
            "update": _mlp_init(lk[5], [8 * c, c, c], dt),
        })
    return {
        "embed_n": lin(ks[0], cfg.d_in, c),
        "readout": _mlp_init(ks[1], [c, c, cfg.d_out], dt),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
    }


def mace_apply(cfg: GNNConfig, params: dict, g: GraphBatch) -> jax.Array:
    """Higher-order equivariant message passing; returns (N, d_out)."""
    h = g.node_feat.astype(cfg.dtype) @ params["embed_n"]  # (N, C)
    x = g.positions.astype(cfg.dtype)
    n, c = h.shape
    eye3 = jnp.eye(3, dtype=h.dtype)

    diff = x[g.senders] - x[g.receivers]  # (E, 3)
    r = jnp.sqrt(jnp.sum(diff * diff, -1) + 1e-12)
    rhat = diff / r[:, None]
    rbf = _bessel_basis(r, cfg.n_rbf, cfg.r_cut)  # (E, n_rbf)
    # Cartesian "spherical harmonics": Y1 = rhat, Y2 = rhat rhat^T - I/3
    y1 = rhat  # (E, 3)
    y2 = rhat[:, :, None] * rhat[:, None, :] - eye3 / 3.0  # (E, 3, 3)

    def body(h, lp):
        hs = (h @ lp["wsrc"])[g.senders]  # (E, C)
        r0 = _mlp(lp["radial0"], rbf, 1)  # (E, C)
        r1 = _mlp(lp["radial1"], rbf, 1)
        r2 = _mlp(lp["radial2"], rbf, 1)
        # atomic basis A (density trick): sum over neighbors
        a0 = _agg(r0 * hs, g.receivers, n, g.edge_mask)  # (N, C)
        a1 = _agg((r1 * hs)[:, :, None] * y1[:, None, :], g.receivers, n,
                  g.edge_mask)  # (N, C, 3)
        a2 = _agg((r2 * hs)[:, :, None, None] * y2[:, None, :, :], g.receivers,
                  n, g.edge_mask)  # (N, C, 3, 3)

        # ---- higher-order invariants via exact Cartesian couplings ----- #
        # nu=1
        b1 = a0
        # nu=2: A0*A0, A1.A1, A2:A2
        b2a = a0 * a0
        b2b = jnp.einsum("nci,nci->nc", a1, a1)
        b2c = jnp.einsum("ncij,ncij->nc", a2, a2)
        # nu=3: A0*A1.A1, A1.(A2@A1), A0*A2:A2, det-like tr(A2@A2@A2)
        a2a1 = jnp.einsum("ncij,ncj->nci", a2, a1)
        b3a = a0 * b2b
        b3b = jnp.einsum("nci,nci->nc", a1, a2a1)
        b3c = a0 * b2c
        b3d = jnp.einsum("ncij,ncjk,ncki->nc", a2, a2, a2)
        feats = jnp.stack([b1, b2a, b2b, b2c, b3a, b3b, b3c, b3d], 1)  # (N,8,C)
        feats = feats * lp["path_w"][None]  # learnable path weights
        m = _mlp(lp["update"], feats.reshape(n, 8 * c), 2)
        return h + m, None

    h, _ = jax.lax.scan(body, h, params["layers"])
    out = _mlp(params["readout"], h, 2)
    return out


# ---------------------------------------------------------------------- #
# GraphCast-style encode-process-decode  [arXiv:2212.12794: 16L, d=512]
# ---------------------------------------------------------------------- #


def graphcast_init(cfg: GNNConfig, key) -> dict:
    dt = cfg.dtype
    d = cfg.d_hidden
    ks = jax.random.split(key, 4 + cfg.n_layers)
    layers = []
    for li in range(cfg.n_layers):
        lk = jax.random.split(ks[4 + li], 2)
        layers.append({
            "edge_mlp": _mlp_init(lk[0], [3 * d, d, d], dt),
            "node_mlp": _mlp_init(lk[1], [2 * d, d, d], dt),
        })
    return {
        "enc_n": _mlp_init(ks[0], [cfg.d_in, d, d], dt),
        "enc_e": _mlp_init(ks[1], [max(cfg.d_edge_in, 1), d, d], dt),
        "dec": _mlp_init(ks[2], [d, d, cfg.d_out], dt),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
    }


def graphcast_apply(cfg: GNNConfig, params: dict, g: GraphBatch) -> jax.Array:
    h = _mlp(params["enc_n"], g.node_feat.astype(cfg.dtype), 2)
    if g.edge_feat is not None:
        e = _mlp(params["enc_e"], g.edge_feat.astype(cfg.dtype), 2)
    else:
        e = jnp.zeros((g.senders.shape[0], cfg.d_hidden), cfg.dtype)
    n = h.shape[0]

    def body(carry, lp):
        h, e = carry
        e_in = jnp.concatenate([e, h[g.senders], h[g.receivers]], -1)
        e = e + _mlp(lp["edge_mlp"], e_in, 2)
        agg = _agg(e, g.receivers, n, g.edge_mask)
        h = h + _mlp(lp["node_mlp"], jnp.concatenate([h, agg], -1), 2)
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    return _mlp(params["dec"], h, 2)


# ---------------------------------------------------------------------- #
# dispatch
# ---------------------------------------------------------------------- #

INIT = {"gatedgcn": gatedgcn_init, "egnn": egnn_init, "mace": mace_init,
        "graphcast": graphcast_init}


def init_params(cfg: GNNConfig, key) -> dict:
    return INIT[cfg.arch](cfg, key)


def apply(cfg: GNNConfig, params: dict, g: GraphBatch) -> jax.Array:
    if cfg.arch == "gatedgcn":
        return gatedgcn_apply(cfg, params, g)
    if cfg.arch == "egnn":
        return egnn_apply(cfg, params, g)[0]
    if cfg.arch == "mace":
        return mace_apply(cfg, params, g)
    if cfg.arch == "graphcast":
        return graphcast_apply(cfg, params, g)
    raise ValueError(cfg.arch)


def train_loss(cfg: GNNConfig, params: dict, g: GraphBatch,
               targets: jax.Array):
    """Masked regression loss (graph tasks are regression/classif-agnostic
    for the substrate; benchmarks use squared error)."""
    out = apply(cfg, params, g)
    err = jnp.where(g.node_mask[:, None], out - targets, 0.0)
    loss = jnp.sum(err * err) / jnp.maximum(jnp.sum(g.node_mask), 1)
    return loss.astype(jnp.float32), {"mse": loss}
