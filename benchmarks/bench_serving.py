"""Multi-tenant serving under drifting traffic — the PR 7 serving gate.

One bounded-queue :class:`repro.core.service.QueryService` (union
dispatch on, ``auto_flush`` off) replays an interleaved two-tenant
stream from :func:`repro.data.graphs.drifting_workload`: tenant
``alpha`` (3x the traffic) drifts phase A -> B while tenant ``beta``
drifts B -> A, so their hot sets differ at every instant AND move —
the per-tenant sketches and the round-robin interest arbitration both
have to work for either tenant to win adaptation capacity.

Traffic arrives in bursts larger than ``max_queue``, so admission
control *must* shed — the gate checks it did (``stats.shed > 0``) and,
the flip side of the same contract, that every request it *accepted*
completed with a result (zero lost accepted requests).  A few graph
updates land between bursts to exercise the write path mid-replay.

Correctness is gated the only way serving can be: sampled probes.  At
submit time, whenever no writes are pending (the graph the request
will see is exactly the current one), the numpy oracle's answer is
recorded; after the replay every probed request's rows must equal its
recorded truth — answers == oracle *at the submit-time graph*, which
is the strict-serializability claim made executable.

Emits per-tenant p50/p99 latency and qps plus shed/served/cache-hit
counts.  Any gate failure exits non-zero.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--json out.json]
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.service import QueryService
from repro.core.workload import AdaptationConfig, AdaptationController
from repro.data.graphs import drifting_workload

from .common import ADAPTIVE_PHASES, DATASETS, emit

TENANTS = {
    # name -> (phase schedule, traffic weight): alpha drifts A->B at 3x
    # the volume of beta, which drifts B->A.
    "alpha": ([ADAPTIVE_PHASES[0], ADAPTIVE_PHASES[1]], 3.0),
    "beta": ([ADAPTIVE_PHASES[1], ADAPTIVE_PHASES[0]], 1.0),
}

# graph updates interleaved between bursts (insert then retract, so the
# final graph matches the initial one and phases stay comparable).
UPDATE_SLICES = [
    [("insert_edge", 0, 51, 1)],
    [("delete_edge", 0, 51, 1)],
]


def _rows(arr) -> set:
    return {tuple(r) for r in arr.tolist()}


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def bench_serving(ds: str, n_per_phase: int, burst: int,
                  adapt_interval: int) -> bool:
    g = DATASETS[ds]()
    k = 2

    mi = MaintainableIndex.build(g, k, interests=[])
    adapter = AdaptationController(
        k, config=AdaptationConfig(budget=2, min_count=3.0, dwell=1,
                                   swap_margin=2.0, decay=0.5))
    svc = QueryService(Engine(mi.flush()), maintainer=mi, adapter=adapter,
                       adapt_interval=adapt_interval, max_batch=16,
                       max_queue=32, auto_flush=False, union=True)

    stream = drifting_workload(g, None, n_per_phase, seed=11,
                               tenants=TENANTS)
    probes = []  # (request, truth rows) sampled at submit time
    accepted = []  # every request admission control let through
    upd_i = 0
    for pi, slot in enumerate(stream):
        for off in range(0, len(slot), burst):
            for ti, (tenant, q) in enumerate(slot[off:off + burst]):
                req = svc.submit(q, tenant=tenant)
                if req.shed:
                    continue
                accepted.append(req)
                # probe: only when the graph the request sees is the
                # current one (no writes pending) and cheaply subsampled
                if svc.pending_updates == 0 and ti % 7 == 0:
                    probes.append((req, oracle.cpq_eval(mi.g, q)))
            svc.flush()
            if upd_i < len(UPDATE_SLICES):
                svc.apply_updates(UPDATE_SLICES[upd_i])
                upd_i += 1
        svc.flush()
        emit(f"serving/{ds}/phase{pi}/progress", 0.0,
             f"served={svc.stats.served};shed={svc.stats.shed};"
             f"adapt_rounds={svc.stats.adapt_rounds};"
             f"union_lanes={svc.engine.telemetry.union_lanes}")

    failed = False

    # gate 1: answers == oracle at the submit-time graph
    bad = sum(1 for req, truth in probes
              if not req.done or req.result is None
              or _rows(req.result) != truth)
    ok1 = bad == 0 and len(probes) > 0
    emit(f"serving/{ds}/answers", 0.0,
         f"probes={len(probes)};mismatches={bad};"
         f"{'PASS' if ok1 else 'FAIL'}")
    failed |= not ok1

    # gate 2: admission control shed under pressure, yet no accepted
    # request was lost (every non-shed submit completed with rows)
    st = svc.stats
    lost = sum(1 for req in accepted
               if not req.done or req.result is None)
    ok2 = st.shed > 0 and lost == 0 and len(st.tenants) >= 2
    emit(f"serving/{ds}/admission", 0.0,
         f"shed={st.shed};lost_accepted={lost};"
         f"tenants={len(st.tenants)};{'PASS' if ok2 else 'FAIL'}")
    failed |= not ok2

    # gate 3: adaptation fired from multi-tenant traffic
    ok3 = st.adapt_rounds >= 1
    emit(f"serving/{ds}/adaptation", 0.0,
         f"adapt_rounds={st.adapt_rounds};"
         f"mined={sorted(s for s in mi.index.interests if len(s) >= 2)};"
         f"{'PASS' if ok3 else 'FAIL'}")
    failed |= not ok3

    # per-tenant latency / throughput over the full accepted log
    lat = {t: [] for t in st.tenants}
    t_first, t_last = None, None
    for req in accepted:
        if not req.done:
            continue
        lat.setdefault(req.tenant, []).append(req.latency * 1e6)
        t_first = req.t_submit if t_first is None else min(t_first,
                                                          req.t_submit)
        t_last = req.t_done if t_last is None else max(t_last, req.t_done)
    wall = max(1e-9, (t_last or 0.0) - (t_first or 0.0))
    for t, ts in sorted(st.tenants.items()):
        xs = lat.get(t, [])
        emit(f"serving/{ds}/tenant/{t}/p50", _pct(xs, 50),
             f"submitted={ts.submitted};served={ts.served};"
             f"shed={ts.shed};cache_hits={ts.cache_hits}")
        emit(f"serving/{ds}/tenant/{t}/p99", _pct(xs, 99),
             f"qps={ts.served / wall:.1f}")

    emit(f"serving/{ds}/acceptance", 0.0,
         f"answers,admission,adaptation;{'FAIL' if failed else 'PASS'}")
    return failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI config (one dataset, short stream)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON")
    args, _ = ap.parse_known_args()

    if args.smoke:
        jobs = [("skewed-hub-small", 96, 48, 24)]
    else:
        jobs = [("skewed-hub-small", 240, 48, 24),
                ("skewed-hub", 240, 48, 24)]

    failed = False
    for ds, n_per_phase, burst, interval in jobs:
        failed |= bench_serving(ds, n_per_phase, burst, interval)

    if args.json:
        from .common import write_json

        write_json(args.json, bench="bench_serving", smoke=args.smoke)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
