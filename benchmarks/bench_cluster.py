"""Persistent-worker cluster serving — the PR 10 cluster gate.

For each worker count in {1, 2, 4} a fresh fleet (one coordinator, N
persistent worker processes, each owning one canonical shard slice of
the CPQx index) serves the same lifecycle a production deployment
would, and every answer along the way is checked two ways: bit-identical
(``np.array_equal``, not set-equal — the canonical merge order is part
of the contract) against a single-process :class:`Engine` bound to the
same index, and set-equal against the numpy oracle on the graph the
query actually saw.

The lifecycle per worker count, in order:

1. **queries** — the full Fig. 5 template suite (random labels, one per
   template) plus one RPQ fixpoint shape, timed through
   :class:`QueryService` for the qps/p50/p99 rows.
2. **maintenance flush** — graph updates through the service write
   path; the drain broadcasts exactly one FLUSH_REBIND to the fleet,
   then the suite re-runs against the updated graph's oracle.
3. **interest round** — ``insert_interest`` lands as one INTEREST_BATCH
   instruction; the suite re-runs on the extended index.
4. **kill-one-worker recovery** — a worker is hard-killed
   (``proc.kill()``); the heartbeat detects it, the coordinator
   respawns from the promotion base + instruction replay, and the suite
   re-runs bit-identical with ``runtime.recoveries`` incremented.

Any mismatch or missing instruction/recovery fails the gate and the
bench exits non-zero.

    PYTHONPATH=src python -m benchmarks.bench_cluster [--smoke] [--json out.json]
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import cluster as cl
from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.rpq import RAlt, RConcat, RStar, RSym
from repro.core.service import QueryService
from repro.data.graphs import random_queries_for_graph

from .common import DATASETS, TEMPLATE_NAMES, emit

WORKER_COUNTS = (1, 2, 4)

# graph updates for the maintenance phase (write path -> FLUSH_REBIND)
UPDATES = [("insert_edge", 0, 1, 0), ("insert_edge", 1, 2, 1)]


def _rows(arr) -> set:
    return {tuple(r) for r in np.asarray(arr).tolist()}


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


def _rpq():
    # (l0 | l1)* . l2 — alternation under a fixpoint, then a concat:
    # exercises the masked-frontier iteration end to end per worker.
    return RConcat(RStar(RAlt(RSym(0), RSym(1))), RSym(2))


def _check_suite(tag, queries, svc, ref, maint, mismatches):
    """Serve every query through the cluster service; gate bit-identity
    vs the local reference engine and set-equality vs the oracle."""
    for name, q in queries:
        got = svc.query(q)
        if not np.array_equal(got, ref.execute(q)):
            mismatches.append((tag, name, "bit"))
        if _rows(got) != oracle.cpq_eval(maint.g, q):
            mismatches.append((tag, name, "oracle"))
    got = svc.engine.execute_rpq(_rpq())
    if not np.array_equal(got, ref.execute_rpq(_rpq())):
        mismatches.append((tag, "rpq", "bit"))
    if _rows(got) != oracle.rpq_eval(maint.g, _rpq()):
        mismatches.append((tag, "rpq", "oracle"))


def bench_cluster(ds: str, n_per: int) -> bool:
    g = DATASETS[ds]()
    k = 2
    # singleton interests for every label keep the whole template suite
    # plannable while leaving headroom for the interest-round phase.
    interests = [(lbl,) for lbl in range(g.alphabet_size)]
    failed = False

    for n in WORKER_COUNTS:
        maint = MaintainableIndex.build(g, k, interests=interests)
        ref = Engine(maint.flush())
        eng = Engine(maint.flush(), cluster=n)
        runtime = eng.backend.runtime
        svc = QueryService(eng, maintainer=maint, max_batch=8)
        queries = random_queries_for_graph(maint.g, TEMPLATE_NAMES, n_per,
                                           seed=7)
        mismatches: list = []
        try:
            # phase 1: queries, timed --------------------------------- #
            lat = []
            t0 = time.perf_counter()
            for _, q in queries:
                t = time.perf_counter()
                svc.query(q)
                lat.append((time.perf_counter() - t) * 1e6)
            wall = time.perf_counter() - t0
            _check_suite("queries", queries, svc, ref, maint, mismatches)
            emit(f"cluster/{ds}/workers{n}/qps", 0.0,
                 f"qps={len(lat) / wall:.1f}")
            emit(f"cluster/{ds}/workers{n}/p50", _pct(lat, 50),
                 f"n={len(lat)}")
            emit(f"cluster/{ds}/workers{n}/p99", _pct(lat, 99),
                 f"n={len(lat)}")

            # phase 2: maintenance flush ------------------------------ #
            before_fr = runtime.instructions[cl.FLUSH_REBIND]
            svc.apply_updates(list(UPDATES))
            svc.query(queries[0][1])  # drains the coalesced write batch
            ref.rebind(maint.flush())
            _check_suite("maintenance", queries, svc, ref, maint,
                         mismatches)
            rebinds = runtime.instructions[cl.FLUSH_REBIND] - before_fr
            ok = rebinds == 1
            emit(f"cluster/{ds}/workers{n}/maintenance", 0.0,
                 f"flush_rebinds={rebinds};{'PASS' if ok else 'FAIL'}")
            failed |= not ok

            # phase 3: interest round --------------------------------- #
            before_ib = runtime.instructions[cl.INTEREST_BATCH]
            svc.insert_interest((0, 1))
            svc.query(queries[0][1])  # drains the interest batch
            ref.rebind(maint.flush())
            _check_suite("interest", queries, svc, ref, maint, mismatches)
            rounds = runtime.instructions[cl.INTEREST_BATCH] - before_ib
            ok = rounds >= 1
            emit(f"cluster/{ds}/workers{n}/interest", 0.0,
                 f"interest_batches={rounds};{'PASS' if ok else 'FAIL'}")
            failed |= not ok

            # phase 4: kill-one-worker recovery ----------------------- #
            # fresh labels: the service result cache must not be able to
            # answer these — the fleet itself has to come back.
            q_rec = random_queries_for_graph(maint.g, TEMPLATE_NAMES,
                                             n_per, seed=23)
            before_rec = runtime.recoveries
            runtime._workers[n - 1].proc.kill()
            time.sleep(0.3)
            _check_suite("recovery", q_rec, svc, ref, maint, mismatches)
            ok = runtime.recoveries > before_rec
            emit(f"cluster/{ds}/workers{n}/recovery", 0.0,
                 f"recoveries={runtime.recoveries - before_rec};"
                 f"{'PASS' if ok else 'FAIL'}")
            failed |= not ok

            ok = not mismatches
            emit(f"cluster/{ds}/workers{n}/answers", 0.0,
                 f"checks={4 * (len(queries) + 1)};"
                 f"mismatches={len(mismatches)};"
                 f"{'PASS' if ok else 'FAIL'}")
            if mismatches:
                for tag, name, kind in mismatches[:8]:
                    emit(f"cluster/{ds}/workers{n}/mismatch", 0.0,
                         f"{tag}/{name}/{kind}")
            failed |= not ok
        finally:
            eng.backend.shutdown()
    return failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI config (one dataset, 1 query/template)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON")
    args, _ = ap.parse_known_args()

    jobs = [("skewed-hub-small", 1)] if args.smoke else \
        [("skewed-hub-small", 2), ("skewed-hub", 1)]

    failed = False
    for ds, n_per in jobs:
        failed |= bench_cluster(ds, n_per)

    if args.json:
        from .common import write_json

        write_json(args.json, bench="bench_cluster", smoke=args.smoke)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
