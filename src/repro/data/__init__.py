"""Data substrate: synthetic token streams, labeled-graph generators,
fanout neighbor sampling, and behavior-sequence streams."""
