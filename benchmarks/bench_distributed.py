"""Distributed engine benchmark (PR 3 tentpole): the sharded CPQx
backend vs the local engine on the Fig. 5 template workload.

Every speedup number this bench emits is *gated on bit-identical
answers*: for each query the sharded engine's (n, 2) array must equal
the local engine's exactly (values and order), and in ``--smoke`` mode
both must match the numpy semantics oracle.  A distributed engine that
is fast but wrong prints FAIL and exits non-zero.

On CPU the mesh is ``--xla_force_host_platform_device_count`` fake
devices, so the point is the *contract* (same executables, psum'd
overflow ladder, exchange-based joins), not wall-clock wins — all_to_all
between fake devices is memcpy.  The emitted per-path timings document
the collective overhead honestly; on a real TPU pod slice the same code
shards the index memory n_shards-way, which is the scaling story
(ROADMAP: graphs whose index exceeds one device's HBM).

    PYTHONPATH=src python -m benchmarks.bench_distributed [--smoke]
(sets XLA_FLAGS itself when unset; run standalone, not under pytest)
"""

from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, oracle-checked, n_shards in {1, 8} (CI)")
    ap.add_argument("--shards", type=int, default=8,
                    help="mesh size for the non-smoke run")
    ap.add_argument("--iters", type=int, default=3)
    args, _ = ap.parse_known_args()

    n_dev = max(args.shards, 8)
    if "XLA_FLAGS" not in os.environ:  # must precede the first jax import
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev}")

    import numpy as np

    from repro import compat
    from repro.core import index as cindex, oracle
    from repro.core.engine import Engine
    from repro.core.query import TEMPLATE_ARITY, instantiate_template

    from benchmarks.common import DATASETS, TEMPLATE_NAMES, emit, timeit

    ds = "example" if args.smoke else "gmark-small"
    shard_counts = [1, 8] if args.smoke else [args.shards]
    iters = 1 if args.smoke else args.iters

    g = DATASETS[ds]()
    idx = cindex.build(g, 2)
    local = Engine(idx)
    rng = np.random.default_rng(17)
    present = np.unique(g.lbl)
    queries = []
    for name in TEMPLATE_NAMES:
        for _ in range(1 if args.smoke else 4):
            queries.append(instantiate_template(
                name, rng.choice(present, TEMPLATE_ARITY[name]).tolist()))

    local_res = [local.execute(q) for q in queries]
    if args.smoke:
        for q, rows in zip(queries, local_res):
            assert ({tuple(r) for r in rows.tolist()}
                    == oracle.cpq_eval(g, q)), f"local != oracle: {q}"
    local_us = timeit(lambda: [local.execute(q) for q in queries],
                      iters=iters) / len(queries)
    emit(f"distributed/{ds}/local/sequential", local_us,
         f"n_queries={len(queries)}")

    failed = False
    for n_shards in shard_counts:
        mesh = compat.make_mesh((n_shards,), ("engine",))
        sharded = Engine(idx, mesh=mesh)
        got = [sharded.execute(q) for q in queries]
        exact = all(a.shape == b.shape and bool(np.all(a == b))
                    for a, b in zip(local_res, got))
        if args.smoke:
            exact = exact and all(
                {tuple(r) for r in b.tolist()} == oracle.cpq_eval(g, q)
                for q, b in zip(queries, got))
        us = timeit(lambda: [sharded.execute(q) for q in queries],
                    iters=iters) / len(queries)
        bat = sharded.execute_batch(queries)
        exact = exact and all(bool(np.all(a == b))
                              for a, b in zip(local_res, bat))
        speedup = local_us / us
        verdict = "PASS" if exact else "FAIL"
        emit(f"distributed/{ds}/shards{n_shards}/sequential", us,
             f"speedup={speedup:.2f}x;bit_identical={exact};{verdict}")
        failed |= not exact
        del sharded

    emit(f"distributed/{ds}/acceptance", 0.0,
         "answers==local==oracle;" + ("FAIL" if failed else "PASS"))
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
