"""Execution backends — the physical algebra behind the CPQx engine.

The planner (``core.query``) compiles a CPQ to a physical plan; *how*
that plan's operators execute is a backend concern.  This module defines
the backend protocol and writes the plan walker (:func:`run_plan_ops`)
ONCE against it:

  * :class:`PlanOps` — the operator protocol (lookup / materialize /
    conjoin / join / identity over capacity-padded relations) with the
    single-device math as shared default implementations;
  * :class:`LocalOps` — the protocol bound to one device's
    ``DeviceIndexArrays`` (the classic engine path);
  * :class:`ExecutionBackend` — the host-facing contract the
    :class:`repro.core.engine.Engine` drives (``run`` / ``run_batch``
    with numpy in, numpy-or-overflow out);
  * :class:`LocalBackend` — ``ExecutionBackend`` over :class:`LocalOps`
    (one jit per (plan shape, caps), vmapped for batches).

``repro.core.distributed.ShardedBackend`` implements the same two
protocols over a mesh: it subclasses :class:`PlanOps` with
repartitioning materialize/join and runs the *same* walker inside one
``shard_map``, so the local and distributed engines cannot drift — they
are one algorithm over two array layouts.

Evaluation is two-stage exactly as in the paper:
  * class space: LOOKUP returns sorted class-id lists; CONJUNCTION is a
    sorted intersection of class ids (Prop. 4.1); IDENTITY is a gather of
    the cycle-purity flag (classes are cycle-pure by construction).
  * pair space: after any JOIN the evaluator materializes s-t pairs
    (expansion join through I_c2p) and proceeds with sorted set algebra.

The overflow-ladder contract (canonical statement — ``core.engine``,
``core.distributed`` and the capacity estimators all defer here):
every relation is capacity-padded, and any operator that would drop
rows sets a *sticky* overflow flag that propagates to the plan's final
result instead of raising.  The host driver is the only party that
reacts: it re-runs the whole plan with every capacity doubled, and
after three doublings from a (possibly far-too-tight) estimate it
jumps to at least the worst-case ``default_caps`` so the ladder cannot
exhaust below where a stats-free engine would have started.  All
capacities live on the power-of-two ladder, so retried plans land on
already-compiled executables.  Variations are mechanical, not
semantic: the batched path keeps one sticky flag per lane and retries
only the lanes that tripped; the sharded backend psum-reduces per-shard
flags so every shard and the host agree on the same retry decision.
This is the honest dynamic->static bridge — estimates and optimizer
cost models can be arbitrarily wrong about *sizes* without ever being
wrong about *answers*.

Two serving-oriented extensions (PR 7):

* **async dispatch** — ``run_batch_async`` returns immediately after the
  device dispatch (JAX dispatch is asynchronous); ``harvest_batch``
  blocks and converts.  The service's pipelined drain plans bucket N+1
  on the host while bucket N executes on device.
* **the union executable** — heterogeneous plan *shapes* normally
  serialize into one dispatch per shape.  :func:`plan_program` compiles
  any plan shape into a linear postorder program over a small value
  stack (opcodes below), and :func:`run_union_batch` interprets a whole
  *mixed-shape* batch in ONE vmapped executable: each lane streams its
  own opcode/range rows as data, shorter programs pad with ``OP_NOP``.
  Every step evaluates all candidate operators and selects by opcode
  (the price of shape-generic compilation under vmap), so the union
  path trades per-step redundancy for dispatch amortization — the
  engine reserves it for straggler buckets below ``min_bucket``.
  Union programs run entirely in pair space (lookups materialize
  eagerly); by cycle-purity of classes this is answer-identical to the
  two-stage walker, and the sticky overflow contract is unchanged.
"""

from __future__ import annotations

import abc
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import relational as R
from .index import DeviceIndexArrays
from .paths import _recap
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class QueryCaps:
    """Static capacities of the compiled plan (jit key)."""

    class_cap: int  # class-id sets
    pair_cap: int  # materialized pair sets
    join_cap: int  # expansion-join outputs (pre-dedup)

    def doubled(self) -> "QueryCaps":
        return QueryCaps(self.class_cap * 2, self.pair_cap * 2, self.join_cap * 2)


def default_caps(index) -> QueryCaps:
    n_pairs = max(16, int(index.arrays.pair_count))
    n_cls = max(16, int(index.arrays.n_classes))
    p2 = 1 << (n_pairs - 1).bit_length()
    c2 = 1 << (n_cls - 1).bit_length()
    return QueryCaps(class_cap=c2, pair_cap=p2, join_cap=2 * p2)


# ---------------------------------------------------------------------- #
# free-function device operators (shared math; backends compose these)
# ---------------------------------------------------------------------- #


def _join_pairs(a: R.Relation, b: R.Relation, join_cap: int, pair_cap: int) -> R.Relation:
    """(v,u) ⋈ (x,y) on u == x -> distinct (v, y).  b sorted by (x, y)."""
    out = R.expansion_join(a, b, a_on=[1], out_cols=[("a", 0), ("b", 1)],
                           out_capacity=join_cap)
    out = R.rel_unique(R.rel_sort(out, num_keys=2), 2)
    return _recap(out, pair_cap)


# ---------------------------------------------------------------------- #
# the operator protocol
# ---------------------------------------------------------------------- #


class PlanOps:
    """Device-side operator set a plan executes against.

    Subclasses bind the index arrays (one device's, or one shard's local
    view) as attributes before the walker runs:

    ``l2c_cls``       (l2c_cap,) class ids, ascending within a seq block
    ``class_starts``  (class_cap + 1,) CSR offsets into the c2p arrays
    ``c2p_v, c2p_u``  the I_c2p pair columns the offsets index
    ``class_cyclic``  (class_cap,) 0/1 cycle-purity flags
    ``n_vertices``    static vertex count (IDENTITY)

    The default method bodies are the exact single-device operators; a
    distributed backend overrides the pair-space producers (materialize,
    join, identity, finish) to add exchanges, and inherits the class-space
    ops verbatim — class relations are replicated by the paper's central
    size observation, so their math is layout-independent.
    """

    l2c_cls: jax.Array
    class_starts: jax.Array
    c2p_v: jax.Array
    c2p_u: jax.Array
    class_cyclic: jax.Array
    n_vertices: int

    # ---- class space ---- #

    def lookup_classes(self, start, length, cap: int) -> R.Relation:
        idx = jnp.arange(cap, dtype=R.I32)
        valid = idx < length
        src = jnp.clip(start + idx, 0, self.l2c_cls.shape[0] - 1)
        ids = jnp.where(valid, self.l2c_cls[src], R.SENTINEL)
        ovf = length > cap
        return R.Relation((ids,), jnp.minimum(length, cap).astype(R.I32), ovf)

    def conj_classes(self, a: R.Relation, b: R.Relation) -> R.Relation:
        """Prop. 4.1 on device: sorted-intersect Pallas kernel."""
        mask = kops.sorted_member_mask(b.cols[0], b.count, a.cols[0])
        out = R.rel_compact(a, mask > 0)
        # an undersized RIGHT list means missing matches: sticky
        return R.Relation(out.cols, out.count, out.overflow | b.overflow)

    def conj_id_classes(self, classes: R.Relation) -> R.Relation:
        cyc = self.class_cyclic[
            jnp.clip(classes.cols[0], 0, self.class_cyclic.shape[0] - 1)]
        keep = (cyc == 1) & R.valid_mask(classes)
        return R.rel_compact(classes, keep)

    # ---- pair space ---- #

    def materialize(self, classes: R.Relation, pair_cap: int) -> R.Relation:
        """classes -> sorted distinct (v, u).  Classes are disjoint, so the
        expansion introduces no duplicate pairs.  The gather pass is the
        ``expand_join`` Pallas kernel (fused binary search + payload
        gather)."""
        cid = jnp.clip(classes.cols[0], 0, self.class_starts.shape[0] - 2)
        lo = self.class_starts[cid]
        cnt = self.class_starts[cid + 1] - lo
        cnt = jnp.where(R.valid_mask(classes), cnt, 0).astype(R.I32)
        ends = jnp.cumsum(cnt, dtype=R.I32)
        total = ends[-1]
        v, u, _ = kops.expand_join_gather(
            ends, lo, classes.cols[0], self.c2p_v, self.c2p_u, total, pair_cap
        )
        rel = R.Relation((v, u), jnp.minimum(total, pair_cap).astype(R.I32),
                         classes.overflow | (total > pair_cap))
        return R.rel_sort(rel, num_keys=2)

    def join_pairs(self, a: R.Relation, b: R.Relation, join_cap: int,
                   pair_cap: int) -> R.Relation:
        return _join_pairs(a, b, join_cap, pair_cap)

    def conj_pairs(self, a: R.Relation, b: R.Relation) -> R.Relation:
        return R.rel_intersect(a, b, 2)

    def conj_id_pairs(self, pairs: R.Relation) -> R.Relation:
        return R.rel_compact(pairs, pairs.cols[0] == pairs.cols[1])

    def identity_pairs(self, pair_cap: int) -> R.Relation:
        v = jnp.arange(pair_cap, dtype=R.I32)
        m = v < self.n_vertices
        col = jnp.where(m, v, R.SENTINEL)
        return R.Relation(
            (col, col),
            jnp.asarray(min(self.n_vertices, pair_cap), R.I32),
            jnp.asarray(self.n_vertices > pair_cap))

    # ---- epilogue ---- #

    def finish(self, pairs: R.Relation):
        """Final (relation, overflow) of a plan — a distributed backend
        reduces the per-shard sticky flags here."""
        return pairs, pairs.overflow


class LocalOps(PlanOps):
    """The operator protocol bound to one device's index arrays."""

    def __init__(self, a: DeviceIndexArrays, n_vertices: int):
        self.l2c_cls = a.l2c_cls
        self.class_starts = a.class_starts
        self.c2p_v = a.c2p_v
        self.c2p_u = a.c2p_u
        self.class_cyclic = a.class_cyclic
        self.n_vertices = n_vertices


# ---------------------------------------------------------------------- #
# plan walker — written once against the protocol
# ---------------------------------------------------------------------- #


def run_plan_ops(ops: PlanOps, plan, caps: QueryCaps, lookup_ranges: jax.Array):
    """Execute a physical plan against a :class:`PlanOps` operator set.

    ``lookup_ranges``: (n_lookups, 2) int32 of (start, len) per LOOKUP
    segment, in plan order.  Returns whatever ``ops.finish`` yields — for
    every shipped backend a pair Relation (sorted distinct (v, u)) and
    the sticky overflow flag.

    ``plan`` may be a frozen plan or its :func:`repro.core.query.plan_shape`
    — the device computation only depends on the shape (LOOKUP nodes carry
    their segment count; the label values stream in via ``lookup_ranges``).
    """
    counter = [0]

    def next_range():
        i = counter[0]
        counter[0] += 1
        return lookup_ranges[i, 0], lookup_ranges[i, 1]

    def as_pairs(res):
        kind, rel = res
        if kind == "classes":
            return ops.materialize(rel, caps.pair_cap)
        return rel

    def ev(node):
        kind = node[0]
        if kind == "lookup":
            nseg = node[1] if isinstance(node[1], int) else len(node[1])
            start, length = next_range()
            cur = ("classes", ops.lookup_classes(start, length, caps.class_cap))
            for _ in range(nseg - 1):
                start, length = next_range()
                nxt = ops.lookup_classes(start, length, caps.class_cap)
                cur = ("pairs", ops.join_pairs(as_pairs(cur),
                                               ops.materialize(nxt, caps.pair_cap),
                                               caps.join_cap, caps.pair_cap))
            return cur
        if kind == "identity":
            return ("pairs", ops.identity_pairs(caps.pair_cap))
        if kind == "conj_id":
            res = ev(node[1])
            if res[0] == "classes":
                return ("classes", ops.conj_id_classes(res[1]))
            return ("pairs", ops.conj_id_pairs(res[1]))
        left = ev(node[1])
        right = ev(node[2])
        if kind == "conj":
            if left[0] == "classes" and right[0] == "classes":
                return ("classes", ops.conj_classes(left[1], right[1]))
            return ("pairs", ops.conj_pairs(as_pairs(left), as_pairs(right)))
        if kind == "join":
            return ("pairs", ops.join_pairs(as_pairs(left), as_pairs(right),
                                            caps.join_cap, caps.pair_cap))
        raise ValueError(kind)

    return ops.finish(as_pairs(ev(plan)))


# ---------------------------------------------------------------------- #
# jitted local entry points
# ---------------------------------------------------------------------- #


def _run_plan(a: DeviceIndexArrays, plan, caps: QueryCaps, n_vertices: int,
              lookup_ranges: jax.Array):
    return run_plan_ops(LocalOps(a, n_vertices), plan, caps, lookup_ranges)


run_plan = functools.partial(
    jax.jit, static_argnames=("plan", "caps", "n_vertices"))(_run_plan)


@functools.partial(jax.jit, static_argnames=("plan", "caps", "n_vertices"))
def run_plan_batch(a: DeviceIndexArrays, plan, caps: QueryCaps,
                   n_vertices: int, lookup_ranges: jax.Array):
    """Batched :func:`run_plan`: ``lookup_ranges`` is (batch, n_lookups, 2)
    and the whole batch evaluates through one vmapped dispatch of the same
    executable a single query would use.  Returns a batched Relation
    (cols (batch, cap)) and a per-query (batch,) overflow vector — each
    lane's overflow is its own sticky flag, so the host retries only the
    lanes that overflowed."""
    return jax.vmap(lambda r: _run_plan(a, plan, caps, n_vertices, r))(
        lookup_ranges)


# ---------------------------------------------------------------------- #
# the union executable — one dispatch for a mixed-shape batch
# ---------------------------------------------------------------------- #

OP_NOP = 0  # padding past the end of a lane's program
OP_LOOKUP = 1  # push materialize(lookup(start, len))
OP_JOIN = 2  # pop b, pop a, push a ⋈ b
OP_CONJ = 3  # pop b, pop a, push a ∩ b
OP_CONJ_ID = 4  # replace top with its v == u filter
OP_IDENTITY = 5  # push the identity relation

# per-opcode stack-pointer delta and write offset (relative to sp)
_OP_DELTA = (0, 1, -1, -1, 0, 1)
_OP_WRITE = (0, 0, -2, -2, -1, 0)


def plan_program(plan):
    """Compile a plan (or its shape) to the union executable's postorder
    program.  Returns ``(opcodes, stack_depth)`` — opcodes is a list of
    ints, LOOKUP steps consume ``lookup_ranges`` rows in exactly the
    order :func:`run_plan_ops` does (DFS, segments left to right)."""
    prog: list = []
    depth = 0
    max_depth = 0

    def push():
        nonlocal depth, max_depth
        depth += 1
        max_depth = max(max_depth, depth)

    def emit(node):
        nonlocal depth
        kind = node[0]
        if kind == "lookup":
            nseg = node[1] if isinstance(node[1], int) else len(node[1])
            prog.append(OP_LOOKUP)
            push()
            for _ in range(nseg - 1):
                prog.append(OP_LOOKUP)
                push()
                prog.append(OP_JOIN)
                depth -= 1
        elif kind == "identity":
            prog.append(OP_IDENTITY)
            push()
        elif kind == "conj_id":
            emit(node[1])
            prog.append(OP_CONJ_ID)
        elif kind in ("conj", "join"):
            emit(node[1])
            emit(node[2])
            prog.append(OP_CONJ if kind == "conj" else OP_JOIN)
            depth -= 1
        else:
            raise ValueError(kind)

    emit(plan)
    return prog, max_depth


def program_ranges(prog, ranges: np.ndarray, n_steps: int) -> np.ndarray:
    """Step-align one lane's (n_lookups, 2) ranges to its program: LOOKUP
    steps carry their (start, len) row, everything else (0, 0), padded to
    ``n_steps``."""
    out = np.zeros((n_steps, 2), dtype=np.int32)
    j = 0
    for i, op in enumerate(prog):
        if op == OP_LOOKUP:
            out[i] = ranges[j]
            j += 1
    return out


def _run_program_lane(ops: PlanOps, caps: QueryCaps, stack_size: int,
                      opcodes: jax.Array, step_ranges: jax.Array):
    """Interpret one lane of the union executable.

    The value stack holds ``stack_size`` capacity-padded pair relations;
    every step computes ALL candidate operator results and the opcode
    selects one (vmap executes every branch anyway, so a lax.switch
    would buy nothing).  Overflow is one sticky flag for the lane,
    exactly as in the shaped path.
    """
    cap = caps.pair_cap
    sentinel_col = jnp.full((cap,), R.SENTINEL, R.I32)

    def step(carry, inp):
        v, u, cnt, sp, ovf = carry
        op, rng = inp

        def slot(i):
            i = jnp.clip(i, 0, stack_size - 1)
            return R.Relation((v[i], u[i]), cnt[i], jnp.asarray(False))

        top = slot(sp - 1)
        sec = slot(sp - 2)
        lk = ops.materialize(
            ops.lookup_classes(rng[0], rng[1], caps.class_cap), cap)
        cands = [
            R.Relation((sentinel_col, sentinel_col), jnp.asarray(0, R.I32),
                       jnp.asarray(False)),  # NOP
            lk,  # LOOKUP
            ops.join_pairs(sec, top, caps.join_cap, cap),  # JOIN
            ops.conj_pairs(sec, top),  # CONJ
            ops.conj_id_pairs(top),  # CONJ_ID
            ops.identity_pairs(cap),  # IDENTITY
        ]
        sel_v = jnp.stack([r.cols[0] for r in cands])[op]
        sel_u = jnp.stack([r.cols[1] for r in cands])[op]
        sel_c = jnp.stack([jnp.asarray(r.count, R.I32) for r in cands])[op]
        sel_o = jnp.stack([jnp.asarray(r.overflow) for r in cands])[op]
        widx = jnp.where(op == OP_NOP, -1,
                         sp + jnp.asarray(_OP_WRITE, R.I32)[op])
        mask = jnp.arange(stack_size, dtype=R.I32) == widx
        v = jnp.where(mask[:, None], sel_v[None, :], v)
        u = jnp.where(mask[:, None], sel_u[None, :], u)
        cnt = jnp.where(mask, sel_c, cnt)
        ovf = ovf | (sel_o & (op != OP_NOP))
        sp = sp + jnp.asarray(_OP_DELTA, R.I32)[op]
        return (v, u, cnt, sp, ovf), None

    zeros = jnp.full((stack_size, cap), R.SENTINEL, R.I32)
    carry = (zeros, zeros, jnp.zeros((stack_size,), R.I32),
             jnp.asarray(0, R.I32), jnp.asarray(False))
    (v, u, cnt, _, ovf), _ = jax.lax.scan(step, carry, (opcodes, step_ranges))
    return ops.finish(R.Relation((v[0], u[0]), cnt[0], ovf))


@functools.partial(jax.jit, static_argnames=("caps", "stack_size",
                                             "n_vertices"))
def run_union_batch(a: DeviceIndexArrays, caps: QueryCaps, stack_size: int,
                    n_vertices: int, opcodes: jax.Array,
                    step_ranges: jax.Array):
    """Mixed-shape batch through ONE executable: ``opcodes`` (batch, T)
    and ``step_ranges`` (batch, T, 2) stream per-lane programs as traced
    data, so the jit key is only (T, stack_size, caps, n_vertices).
    Returns a batched Relation + per-lane sticky overflow, the same
    contract as :func:`run_plan_batch`."""
    ops = LocalOps(a, n_vertices)
    return jax.vmap(
        lambda oc, rg: _run_program_lane(ops, caps, stack_size, oc, rg)
    )(opcodes, step_ranges)


# ---------------------------------------------------------------------- #
# host-facing backend contract
# ---------------------------------------------------------------------- #


class ExecutionBackend(abc.ABC):
    """What the :class:`repro.core.engine.Engine` drives.

    A backend owns the physical index arrays (however they are laid out)
    and turns (plan shape, caps, lookup ranges) into numpy answers.  Both
    entry points report overflow instead of raising: the engine owns the
    double-and-retry capacity ladder, identically for every backend.
    """

    n_vertices: int

    #: whether :meth:`run_union_batch` is implemented (the engine falls
    #: back to per-shape dispatches when it is not).
    supports_union = False

    @abc.abstractmethod
    def run(self, shape, caps: QueryCaps, ranges: np.ndarray):
        """One query.  ``ranges`` (n_lookups, 2) -> (rows | None, overflow):
        sorted distinct (n, 2) int32 s-t pairs, or None when the sticky
        overflow flag tripped (the caller retries with doubled caps)."""

    @abc.abstractmethod
    def run_batch(self, shape, caps: QueryCaps, ranges: np.ndarray):
        """Batch of same-shape queries.  ``ranges`` (batch, n_lookups, 2)
        -> (list of rows-or-None per lane, (batch,) bool overflow)."""

    def run_union_batch(self, opcodes: np.ndarray, caps: QueryCaps,
                        stack_size: int, step_ranges: np.ndarray):
        """Mixed-shape batch via the union executable.  ``opcodes``
        (batch, T), ``step_ranges`` (batch, T, 2); same result contract
        as :meth:`run_batch`.  Optional — guarded by ``supports_union``."""
        raise NotImplementedError

    # -- async dispatch (pipelined drain) -- #
    #
    # ``*_async`` returns an opaque handle immediately after the device
    # dispatch; ``harvest_batch`` blocks on it and converts to the
    # ``run_batch`` result contract.  The defaults degrade to synchronous
    # execution so every backend supports the pipelined drain.

    def run_batch_async(self, shape, caps: QueryCaps, ranges: np.ndarray):
        return ("sync", self.run_batch(shape, caps, ranges))

    def run_union_batch_async(self, opcodes: np.ndarray, caps: QueryCaps,
                              stack_size: int, step_ranges: np.ndarray):
        return ("sync", self.run_union_batch(opcodes, caps, stack_size,
                                             step_ranges))

    def harvest_batch(self, handle):
        tag, payload = handle[0], handle[1:]
        if tag == "sync":
            return payload[0]
        raise NotImplementedError(tag)


class LocalBackend(ExecutionBackend):
    """Single-device execution over :class:`DeviceIndexArrays`."""

    supports_union = True

    def __init__(self, arrays: DeviceIndexArrays, n_vertices: int):
        self.arrays = arrays
        self.n_vertices = n_vertices

    def run(self, shape, caps: QueryCaps, ranges: np.ndarray):
        pairs, overflow = run_plan(self.arrays, shape, caps, self.n_vertices,
                                   jnp.asarray(ranges))
        if bool(overflow):
            return None, True
        return R.to_numpy(pairs), False

    def run_batch(self, shape, caps: QueryCaps, ranges: np.ndarray):
        return self.harvest_batch(self.run_batch_async(shape, caps, ranges))

    def run_union_batch(self, opcodes: np.ndarray, caps: QueryCaps,
                        stack_size: int, step_ranges: np.ndarray):
        return self.harvest_batch(self.run_union_batch_async(
            opcodes, caps, stack_size, step_ranges))

    def run_batch_async(self, shape, caps: QueryCaps, ranges: np.ndarray):
        rel, overflow = run_plan_batch(self.arrays, shape, caps,
                                       self.n_vertices, jnp.asarray(ranges))
        # JAX dispatch is asynchronous: the device is now computing while
        # the caller plans the next bucket; harvest_batch blocks.
        return ("lanes", rel, overflow)

    def run_union_batch_async(self, opcodes: np.ndarray, caps: QueryCaps,
                              stack_size: int, step_ranges: np.ndarray):
        rel, overflow = run_union_batch(
            self.arrays, caps, stack_size, self.n_vertices,
            jnp.asarray(opcodes, dtype=jnp.int32),
            jnp.asarray(step_ranges, dtype=jnp.int32))
        return ("lanes", rel, overflow)

    def harvest_batch(self, handle):
        if handle[0] != "lanes":
            return super().harvest_batch(handle)
        _, rel, overflow = handle
        overflow = np.asarray(overflow)
        results: list = [None] * overflow.shape[0]
        ok = np.nonzero(~overflow)[0]
        if ok.size:
            for lane, rows in zip(ok, R.batch_to_numpy(rel, lanes=ok)):
                results[lane] = rows
        return results, overflow
