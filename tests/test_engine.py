"""Device query engines (CPQx, iaCPQx, Path, iaPath) vs the ground-truth
CPQ semantics — templates, random queries, and overflow-retry behavior."""

import jax
import numpy as np
import pytest

from conftest import random_graph
from repro.core import baselines, interest, oracle
from repro.core import index as cindex
from repro.core.baselines import PathEngine
from repro.core.engine import Engine, QueryCaps
from repro.core.graph import example_graph
from repro.core.query import TEMPLATES, instantiate_template, parse


@pytest.fixture(scope="module")
def built(ex_graph):
    g = ex_graph
    return {
        "g": g,
        "cpqx": Engine(cindex.build(g, 2)),
        "ia": Engine(interest.build_interest(g, 2, [(0, 0), (1, 1)])),
        "path": PathEngine(baselines.build_path(g, 2)),
        "iapath": PathEngine(baselines.build_path(g, 2, interests=[(0, 0), (1, 1)])),
    }


class TestPaperExampleOnDevice:
    def test_triad(self, built):
        q = parse("(f . f) & f-", {"f": 0, "v": 1}, 2)
        for name in ("cpqx", "ia", "path", "iapath"):
            ans = {tuple(r) for r in built[name].execute(q).tolist()}
            assert ans == {(0, 2), (1, 0), (2, 1)}, name


class TestTemplates:
    @pytest.mark.parametrize("template", sorted(TEMPLATES))
    def test_all_engines_match_ground_truth(self, template, built):
        g = built["g"]
        rng = np.random.default_rng(hash(template) % 2**31)
        for _ in range(3):
            labels = rng.integers(0, g.alphabet_size, 8).tolist()
            q = instantiate_template(template, labels)
            gt = oracle.cpq_eval(g, q)
            for name in ("cpqx", "ia", "path", "iapath"):
                got = {tuple(r) for r in built[name].execute(q).tolist()}
                assert got == gt, f"{template} on {name}"


class TestRandomQueries:
    @pytest.mark.parametrize("seed", [0, 3])
    def test_random_graph_random_queries(self, seed):
        g = random_graph(seed, n_max=18, m_max=45)
        engines = [
            Engine(cindex.build(g, 2)),
            Engine(interest.build_interest(g, 2, [(0, 1)])),
            PathEngine(baselines.build_path(g, 2)),
        ]
        rng = np.random.default_rng(seed)
        for i in range(8):
            q = oracle.random_cpq(rng, g, 3)
            gt = oracle.cpq_eval(g, q)
            for e in engines:
                assert {tuple(r) for r in e.execute(q).tolist()} == gt
        jax.clear_caches()

    def test_k3_engine(self):
        g = random_graph(11, n_max=14, m_max=35)
        eng = Engine(cindex.build(g, 3))
        rng = np.random.default_rng(11)
        for _ in range(6):
            q = oracle.random_cpq(rng, g, 3)
            assert {tuple(r) for r in eng.execute(q).tolist()} == oracle.cpq_eval(g, q)
        jax.clear_caches()


class TestOverflowRetry:
    def test_undersized_caps_recover(self, built):
        q = parse("f . f", {"f": 0, "v": 1}, 2)
        tiny = QueryCaps(class_cap=2, pair_cap=2, join_cap=2)
        got = {tuple(r) for r in built["cpqx"].execute(q, caps=tiny).tolist()}
        assert got == oracle.cpq_eval(built["g"], q)

    def test_missing_sequence_yields_empty(self, built):
        # a 2-seq absent from the graph: lookup range (0, 0) -> empty result
        g = built["g"]
        q = parse("v . v", {"f": 0, "v": 1}, 2)
        got = {tuple(r) for r in built["cpqx"].execute(q).tolist()}
        assert got == oracle.cpq_eval(g, q) == set()


class TestClassSpacePruning:
    def test_conjunction_stays_in_class_space(self, built):
        """The paper's headline: CONJUNCTION of lookups compares class ids,
        never materializing pairs until the end (Prop. 4.1)."""
        eng = built["cpqx"]
        q = parse("(f . f) & f-", {"f": 0, "v": 1}, 2)
        plan = eng.plan(q)
        assert plan[0] == "conj"
        assert plan[1][0] == "lookup" and plan[2][0] == "lookup"
        # Ex. 4.3: both lookups return short class lists whose intersection
        # is exactly one class — the triad class (our Fig.-1 reconstruction
        # has 2 and 3 classes resp.; the paper's graph has 3 and 3).
        idx = eng.index
        import numpy as np

        lo, hi = idx.lookup_range((0, 0))
        ff = set(np.asarray(idx.arrays.l2c_cls)[lo:hi].tolist())
        assert 1 <= len(ff) <= 3
        lo, hi = idx.lookup_range((2,))
        finv = set(np.asarray(idx.arrays.l2c_cls)[lo:hi].tolist())
        assert 1 <= len(finv) <= 3
        assert len(ff & finv) == 1  # a single class answers the conjunction
