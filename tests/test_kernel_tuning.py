"""The kernel wrappers' tuning surface (PR 8): the derived VMEM ceiling
with its Pallas -> XLA fallback boundary, and the autotuned per-rung
block-shape registry.  Separate from test_kernels.py so these run
without hypothesis."""

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


class TestVmemBoundary:
    """The Pallas -> XLA fallback at the VMEM ceiling must be invisible:
    bit-identical answers on either side of the boundary, whichever path
    runs.  The ceiling itself is derived (env > table override > backend
    default), no longer a hard-coded constant."""

    def test_vmem_words_resolution_order(self, monkeypatch):
        monkeypatch.delenv("REPRO_VMEM_WORDS", raising=False)
        ops.set_vmem_words_override(None)
        base = ops.vmem_words()
        assert base >= 1_000_000  # never below the historical ceiling
        ops.set_vmem_words_override(4096)
        assert ops.vmem_words() == 4096
        monkeypatch.setenv("REPRO_VMEM_WORDS", "512")  # env always wins
        assert ops.vmem_words() == 512
        monkeypatch.delenv("REPRO_VMEM_WORDS")
        ops.set_vmem_words_override(None)
        assert ops.vmem_words() == base

    def test_member_mask_bit_identical_at_exact_ceiling(self, monkeypatch):
        n_hay = 256
        rng = np.random.default_rng(7)
        hay = np.sort(rng.choice(5 * n_hay, n_hay,
                                 replace=False)).astype(np.int32)
        q = rng.integers(0, 5 * n_hay, 300).astype(np.int32)
        args = (jnp.array(hay), n_hay, jnp.array(q))
        # hay.shape[0] == ceiling: kernel path (the guard is strict >)
        monkeypatch.setenv("REPRO_VMEM_WORDS", str(n_hay))
        at = np.asarray(ops.sorted_member_mask(*args))
        # one word less: fallback path
        monkeypatch.setenv("REPRO_VMEM_WORDS", str(n_hay - 1))
        below = np.asarray(ops.sorted_member_mask(*args))
        np.testing.assert_array_equal(at, below)
        np.testing.assert_array_equal(
            at, np.isin(q, hay[:n_hay]).astype(np.int32))

    def test_expand_join_bit_identical_at_exact_ceiling(self, monkeypatch):
        rng = np.random.default_rng(11)
        n_a, n_b = 32, 48
        a = rng.integers(0, 6, (n_a, 2)).astype(np.int32)
        b = rng.integers(0, 6, (n_b, 2)).astype(np.int32)
        b = b[np.lexsort((b[:, 1], b[:, 0]))]
        lo = np.searchsorted(b[:, 0], a[:, 1], "left").astype(np.int32)
        hi = np.searchsorted(b[:, 0], a[:, 1], "right").astype(np.int32)
        ends = np.cumsum(hi - lo).astype(np.int32)
        total = int(ends[-1])
        cap = max(8, 1 << max(0, (total - 1)).bit_length())
        args = (jnp.array(ends), jnp.array(lo), jnp.array(a[:, 0]),
                jnp.array(b[:, 0]), jnp.array(b[:, 1]), total, cap)
        words = n_a + 2 * n_b  # the wrapper's residency formula
        monkeypatch.setenv("REPRO_VMEM_WORDS", str(words))
        at = [np.asarray(x) for x in ops.expand_join_gather(*args)]
        monkeypatch.setenv("REPRO_VMEM_WORDS", str(words - 1))
        below = [np.asarray(x) for x in ops.expand_join_gather(*args)]
        for g, e in zip(at, below):
            np.testing.assert_array_equal(g, e)


class TestTunedBlocks:
    def test_tuned_block_q_changes_nothing_but_speed(self):
        """Installing autotuned winners must keep answers bit-identical
        (the sweep's own invariant, re-checked through the wrapper)."""
        rng = np.random.default_rng(3)
        n = 1024
        hay = np.sort(rng.choice(8 * n, n, replace=False)).astype(np.int32)
        q = rng.integers(0, 8 * n, n).astype(np.int32)
        args = (jnp.array(hay), n, jnp.array(q))
        base = np.asarray(ops.sorted_member_mask(*args))
        try:
            ops.set_tuned_blocks({1024: 256}, {1024: 512})
            tuned = np.asarray(ops.sorted_member_mask(*args))
        finally:
            ops.set_tuned_blocks(None, None)
        np.testing.assert_array_equal(base, tuned)

    def test_tuned_rung_lookup_picks_right_neighbor(self):
        ops.set_tuned_blocks({256: 64, 4096: 1024}, None)
        try:
            assert ops._tuned(ops._tuned_block_q, 256) == 64
            assert ops._tuned(ops._tuned_block_q, 512) == 1024  # next up
            assert ops._tuned(ops._tuned_block_q, 1 << 20) == 1024  # largest
        finally:
            ops.set_tuned_blocks(None, None)

    def test_autotune_winners_fit_their_rung(self):
        from repro.kernels.autotune import autotune

        block_q, block_t, raw = autotune([256], repeats=1)
        assert set(block_q) == set(block_t) == {256}
        assert block_q[256] <= 256 and block_t[256] <= 256
        assert raw  # timings emitted for the bench trajectory
