"""Assigned-architecture model zoo: LM transformers (dense + MoE), GNNs,
and recsys — pure JAX pytrees with logical-axis sharding metadata."""
