"""Unit + property tests for the capacity-padded relational algebra —
the substrate every engine op builds on."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import relational as R


def _np_rel(rows, cap):
    return R.from_numpy(np.asarray(rows, np.int32).reshape(-1, 2), cap)


class TestSortUniqueRank:
    def test_sort_and_sentinel_padding(self):
        rel = _np_rel([[3, 1], [1, 2], [2, 0]], 8)
        s = R.rel_sort(rel)
        out = R.to_numpy(s)
        assert out.tolist() == [[1, 2], [2, 0], [3, 1]]
        # padding sorts to the end
        assert int(np.asarray(s.cols[0])[-1]) == R.SENTINEL

    def test_unique(self):
        rel = R.rel_sort(_np_rel([[1, 1], [1, 1], [2, 2], [2, 3], [2, 3]], 8))
        u = R.rel_unique(rel)
        assert R.to_numpy(u).tolist() == [[1, 1], [2, 2], [2, 3]]

    def test_dense_rank(self):
        rel = R.rel_sort(_np_rel([[1, 1], [1, 1], [2, 2], [3, 3]], 8))
        ranks, n = R.dense_rank(rel)
        assert int(n) == 3
        assert np.asarray(ranks)[:4].tolist() == [0, 0, 1, 2]

    def test_compact_stable(self):
        rel = _np_rel([[5, 0], [1, 0], [7, 0], [2, 0]], 8)
        keep = jnp.array([True, False, True, False] + [False] * 4)
        c = R.rel_compact(rel, keep)
        assert R.to_numpy(c)[:, 0].tolist() == [5, 7]


class TestBinarySearch:
    @given(
        hay=st.lists(st.integers(0, 50), min_size=1, max_size=80),
        needles=st.lists(st.integers(-5, 60), min_size=1, max_size=40),
        side=st.sampled_from(["left", "right"]),
    )
    @settings(max_examples=60, deadline=None)
    def test_matches_numpy_1col(self, hay, needles, side):
        h = np.sort(np.asarray(hay, np.int32))
        n = np.asarray(needles, np.int32)
        got = np.asarray(R.lex_searchsorted((jnp.array(h),), (jnp.array(n),), side))
        exp = np.searchsorted(h, n, side)
        assert (got == exp).all()

    @given(
        seed=st.integers(0, 2**31 - 1),
        side=st.sampled_from(["left", "right"]),
    )
    @settings(max_examples=40, deadline=None)
    def test_matches_numpy_2col(self, seed, side):
        rng = np.random.default_rng(seed)
        hay = rng.integers(0, 8, (rng.integers(1, 60), 2)).astype(np.int32)
        hay = hay[np.lexsort((hay[:, 1], hay[:, 0]))]
        nee = rng.integers(-1, 10, (20, 2)).astype(np.int32)
        enc_h = hay[:, 0] * 100 + hay[:, 1]
        enc_n = nee[:, 0] * 100 + nee[:, 1]
        got = np.asarray(
            R.lex_searchsorted(
                (jnp.array(hay[:, 0]), jnp.array(hay[:, 1])),
                (jnp.array(nee[:, 0]), jnp.array(nee[:, 1])),
                side,
            )
        )
        assert (got == np.searchsorted(enc_h, enc_n, side)).all()


class TestSetOps:
    def test_intersect(self):
        a = R.rel_sort(_np_rel([[1, 1], [2, 2], [3, 3], [5, 5]], 8))
        b = R.rel_sort(_np_rel([[2, 2], [3, 3], [9, 9]], 8))
        assert R.to_numpy(R.rel_intersect(a, b)).tolist() == [[2, 2], [3, 3]]

    def test_difference(self):
        a = R.rel_sort(_np_rel([[1, 1], [2, 2], [3, 3]], 8))
        b = R.rel_sort(_np_rel([[2, 2]], 4))
        assert R.to_numpy(R.rel_difference(a, b)).tolist() == [[1, 1], [3, 3]]

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_intersect_matches_python_sets(self, seed):
        rng = np.random.default_rng(seed)
        a = np.unique(rng.integers(0, 20, (30, 2)).astype(np.int32), axis=0)
        b = np.unique(rng.integers(0, 20, (30, 2)).astype(np.int32), axis=0)
        ra = R.rel_sort(R.from_numpy(a, 64))
        rb = R.rel_sort(R.from_numpy(b, 64))
        got = {tuple(r) for r in R.to_numpy(R.rel_intersect(ra, rb)).tolist()}
        exp = {tuple(r) for r in a.tolist()} & {tuple(r) for r in b.tolist()}
        assert got == exp

    def test_concat_overflow_flag(self):
        a = _np_rel([[1, 1], [2, 2]], 4)
        b = _np_rel([[3, 3], [4, 4], [5, 5]], 4)
        c = R.rel_concat(a, b, 4)
        assert bool(c.overflow)
        c2 = R.rel_concat(a, b, 8)
        assert not bool(c2.overflow) and int(c2.count) == 5


class TestExpansionJoin:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_matches_python_join(self, seed):
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 8, (rng.integers(1, 25), 2)).astype(np.int32)
        b = rng.integers(0, 8, (rng.integers(1, 25), 2)).astype(np.int32)
        b = b[np.lexsort((b[:, 1], b[:, 0]))]
        ra = R.from_numpy(a, 32)
        rb = R.from_numpy(b, 32)
        out = R.expansion_join(ra, rb, a_on=[1], out_cols=[("a", 0), ("b", 1)],
                               out_capacity=1024)
        got = sorted(map(tuple, R.to_numpy(out).tolist()))
        exp = sorted(
            (int(x), int(w)) for x, y in a for v, w in b if y == v
        )
        assert got == exp

    def test_overflow_flag(self):
        a = _np_rel([[0, 1]], 4)
        b = R.rel_sort(_np_rel([[1, 5], [1, 6], [1, 7]], 4))
        out = R.expansion_join(a, b, [1], [("a", 0), ("b", 1)], 2)
        assert bool(out.overflow) and int(out.count) == 2


class TestFingerprints:
    def test_order_invariance(self):
        c1 = (jnp.array([5, 3, 9], jnp.int32), jnp.array([1, 2, 0], jnp.int32))
        c2 = (jnp.array([9, 5, 3], jnp.int32), jnp.array([0, 1, 2], jnp.int32))
        seg = jnp.zeros(3, jnp.int32)
        ok = jnp.array([True] * 3)
        f1 = R.segment_fingerprint(*R.fingerprint_rows(c1), seg, 1, ok)
        f2 = R.segment_fingerprint(*R.fingerprint_rows(c2), seg, 1, ok)
        assert int(f1[0][0]) == int(f2[0][0]) and int(f1[1][0]) == int(f2[1][0])

    def test_different_sets_differ(self):
        c1 = (jnp.array([5, 3], jnp.int32),)
        c2 = (jnp.array([5, 4], jnp.int32),)
        seg = jnp.zeros(2, jnp.int32)
        ok = jnp.array([True] * 2)
        f1 = R.segment_fingerprint(*R.fingerprint_rows(c1), seg, 1, ok)
        f2 = R.segment_fingerprint(*R.fingerprint_rows(c2), seg, 1, ok)
        assert (int(f1[0][0]), int(f1[1][0])) != (int(f2[0][0]), int(f2[1][0]))
