"""Fanout neighbor sampler (GraphSAGE-style) for the ``minibatch_lg``
GNN shape — a real sampler over CSR adjacency, not a stub.

Produces fixed-capacity padded subgraphs (XLA-friendly): seed nodes,
layer-1 fanout f1, layer-2 fanout f2 — node capacity
B + B*f1 + B*f1*f2, edge capacity B*f1 + B*f1*f2, with masks."""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class CSRGraph(NamedTuple):
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,)
    n_nodes: int


def random_csr(n_nodes: int, avg_degree: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    deg = rng.poisson(avg_degree, n_nodes).astype(np.int64)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n_nodes, int(indptr[-1])).astype(np.int32)
    return CSRGraph(indptr, indices, n_nodes)


class SampledSubgraph(NamedTuple):
    """Padded, flat subgraph in GraphBatch-compatible layout."""

    node_ids: np.ndarray  # (cap_nodes,) global ids (-1 pad)
    senders: np.ndarray  # (cap_edges,) local indices
    receivers: np.ndarray  # (cap_edges,)
    node_mask: np.ndarray
    edge_mask: np.ndarray
    seed_count: int


def sample_fanout(g: CSRGraph, seeds: np.ndarray, fanout: tuple,
                  seed: int = 0) -> SampledSubgraph:
    """Layered fanout sampling with replacement-free neighbor choice
    (falls back to with-replacement when degree < fanout)."""
    rng = np.random.default_rng(seed)
    b = len(seeds)
    cap_nodes = b
    cap_edges = 0
    layer_width = b
    for f in fanout:
        cap_edges += layer_width * f
        layer_width *= f
        cap_nodes += layer_width

    node_ids = np.full(cap_nodes, -1, np.int64)
    senders = np.zeros(cap_edges, np.int32)
    receivers = np.zeros(cap_edges, np.int32)
    edge_mask = np.zeros(cap_edges, bool)

    node_ids[:b] = seeds
    n_nodes = b
    n_edges = 0
    frontier = np.arange(b)  # local indices of the current layer
    for f in fanout:
        new_locals = []
        for local in frontier:
            gid = node_ids[local]
            lo, hi = g.indptr[gid], g.indptr[gid + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg) if deg >= f else f
            replace = deg < f
            picks = rng.choice(g.indices[lo:hi], size=f, replace=True) \
                if replace else rng.choice(g.indices[lo:hi], size=f,
                                           replace=False)
            for p in picks:
                li = n_nodes
                node_ids[li] = p
                new_locals.append(li)
                senders[n_edges] = li
                receivers[n_edges] = local  # messages flow to the seed side
                edge_mask[n_edges] = True
                n_nodes += 1
                n_edges += 1
        frontier = np.asarray(new_locals, np.int64)

    node_mask = node_ids >= 0
    return SampledSubgraph(node_ids, senders, receivers, node_mask,
                           edge_mask, b)
