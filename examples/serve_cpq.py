"""Serving CPQ traffic: the PR-1 query serving layer end to end.

Builds CPQx over a gMark citation graph, then drives a synthetic query
workload (repeating Fig. 5 templates with a skewed label distribution —
the recurring-traffic shape a production endpoint sees) through the
three execution paths and prints the throughput and cache behavior:

  1. sequential ``Engine.execute``          (one dispatch per query)
  2. ``Engine.execute_batch``               (plan-shape bucketed vmap)
  3. ``QueryService``                       (queue + dedup + LRU cache)

Ends with live graph updates through the service write path
(``service.apply_updates`` -> coalesced mirror surgery -> mirror→device
flush -> rebind) showing epoch-keyed cache invalidation and
update→queryable latency without a rebuild — and with the PR-5
adaptation loop: an interest-aware service that starts with NO mined
interests, watches the same traffic, and indexes its hot label
sequences by itself.

    PYTHONPATH=src python examples/serve_cpq.py
"""

import time

import numpy as np

from repro.core import index as cindex
from repro.core import oracle
from repro.core.engine import Engine
from repro.core.maintenance import MaintainableIndex
from repro.core.query import TEMPLATE_ARITY, instantiate_template
from repro.core.service import QueryService
from repro.data.graphs import gmark_citation


def make_workload(g, n_queries: int, seed: int = 0):
    """Skewed recurring traffic: few templates, zipf-ish label reuse."""
    rng = np.random.default_rng(seed)
    present = np.unique(g.lbl)
    names = ["T", "C2", "S", "C2i"]
    out = []
    for _ in range(n_queries):
        name = names[int(rng.integers(0, len(names)))]
        # draw from a small label pool so queries repeat (cacheable)
        pool = present[: max(2, len(present) // 2)]
        labels = pool[rng.integers(0, len(pool), TEMPLATE_ARITY[name])]
        out.append(instantiate_template(name, labels.tolist()))
    return out


def main() -> None:
    g = gmark_citation(400, avg_degree=6, seed=0)
    idx = cindex.build(g, 2)
    engine = Engine(idx)
    print(f"graph {g}; CPQx: {idx.n_classes} classes, {idx.n_pairs} pairs")

    workload = make_workload(g, 64)

    # warm each path's executables once (compile time is not serving
    # time; note the vmapped jit keys include the batch size, so every
    # path compiles its own variants)
    for q in workload:
        engine.execute(q)
    engine.execute_batch(workload)
    warmup_svc = QueryService(engine, max_batch=32)
    for q in workload:
        warmup_svc.submit(q)
    warmup_svc.flush()

    t0 = time.perf_counter()
    seq = [engine.execute(q) for q in workload]
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    bat = engine.execute_batch(workload)
    t_bat = time.perf_counter() - t0
    assert all(a.shape == b.shape and np.all(a == b)
               for a, b in zip(seq, bat))

    svc = QueryService(engine, max_batch=32)
    t0 = time.perf_counter()
    for q in workload:
        svc.submit(q)
    svc.flush()
    t_svc = time.perf_counter() - t0

    n = len(workload)
    print(f"sequential : {n / t_seq:8.0f} q/s")
    print(f"batched    : {n / t_bat:8.0f} q/s ({t_seq / t_bat:.2f}x)")
    print(f"service    : {n / t_svc:8.0f} q/s cold "
          f"(dedup folded {svc.stats.deduped} of {n})")

    t0 = time.perf_counter()
    for q in workload:
        svc.submit(q)
    svc.flush()
    t_warm = time.perf_counter() - t0
    print(f"service    : {n / t_warm:8.0f} q/s warm "
          f"({svc.stats.cache_hits} cache hits)")

    # live updates through the write path: apply_updates queues writes
    # and bumps the epoch (O(1) invalidation of every cached answer);
    # the next query drain coalesces them into one mirror batch + one
    # mirror→device flush — no rebuild on the serving path
    m = MaintainableIndex.build(g, 2)
    svc = QueryService(engine, max_batch=32, maintainer=m)
    q = workload[0]
    svc.query(q)  # warm the cache at the current epoch
    v, u, l = map(int, m.g._base_edges()[0])
    t0 = time.perf_counter()
    svc.apply_updates([("insert_edge", u, v, l)])  # reciprocal edge
    svc.apply_updates([("delete_edge", v, u, l)])
    req = svc.submit(q)
    print(f"after 2 writes: epoch={svc.graph_epoch}, served from cache: "
          f"{req.from_cache}, queued updates: {svc.pending_updates}")
    if not req.done:
        svc.flush()  # drains the coalesced writes, then answers
    t_upd = time.perf_counter() - t0
    assert {tuple(r) for r in req.result.tolist()} == oracle.cpq_eval(m.g, q)
    print(f"post-update answer verified against the semantics oracle "
          f"(update->queryable {t_upd * 1e3:.1f} ms, "
          f"{svc.stats.update_batches} coalesced maintenance round)")

    # adaptive iaCPQx: start from an interest-aware index with nothing
    # mined, let the workload sketch + benefit model + controller close
    # the loop (proposals drain through the same write path as above)
    from repro.core.workload import AdaptationConfig, AdaptationController

    mi = MaintainableIndex.build(g, 2, interests=[])
    adaptive = QueryService(
        Engine(mi.flush()), maintainer=mi,
        adapter=AdaptationController(2, config=AdaptationConfig(budget=4)),
        adapt_interval=32, max_batch=32)
    for _ in range(3):  # recurring traffic: the frequency signal
        for q in workload:
            adaptive.submit(q)
        adaptive.flush()
    mined = sorted(s for s in mi.index.interests if len(s) >= 2)
    q = workload[0]
    assert {tuple(r) for r in adaptive.query(q).tolist()} == \
        oracle.cpq_eval(mi.g, q)
    print(f"adaptive   : mined interests {mined} "
          f"({adaptive.stats.adapt_rounds} rounds, "
          f"{adaptive.stats.sequences_observed} sequence votes, "
          f"answers oracle-verified)")

    # kill and promote: checkpoint the adaptive service (drains writes,
    # snapshots arrays + mirror + mined interests + sketch at one
    # epoch), "crash", and promote a cold replica from the last
    # committed step — load + rebind, no rebuild, caches invalidated by
    # the epoch bump, the mined interest set already hot
    import tempfile

    from repro.core import lifecycle

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # steady state: one more serving pass at the post-mining index
        # generation, so its executables are compiled (the replica hits
        # the same jit cache — promotion measures recovery, not XLA)
        adaptive.bump_epoch()
        adaptive.query(q)
        adaptive.checkpoint(ckpt_dir)
        del adaptive  # the crash: in-process serving state is gone

        t0 = time.perf_counter()
        replica = lifecycle.restore_service(ckpt_dir)
        first = replica.query(q)  # first served answer after the crash
        t_promote = time.perf_counter() - t0
        assert {tuple(r) for r in first.tolist()} == \
            oracle.cpq_eval(replica.maintainer.g, q)
        assert sorted(s for s in replica.maintainer.index.interests
                      if len(s) >= 2) == mined
        print(f"promotion  : replica serving in {t_promote * 1e3:.1f} ms "
              f"(epoch={replica.graph_epoch}, interests intact, first "
              f"answer oracle-verified)")


if __name__ == "__main__":
    main()
