"""The paper's engine as a distributed workload: build a gMark citation
graph, shard its CPQx pair table over an 8-device mesh, and run the
distributed conjunction query step (replicated class intersect + sharded
materialization) — the same code path the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/engine_at_scale.py
(sets XLA_FLAGS itself; run as a standalone script, not under pytest)
"""

import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.core import index as cindex  # noqa: E402
from repro.core import oracle, relational as R  # noqa: E402
from repro.core.query import instantiate_template  # noqa: E402
from repro.data.graphs import gmark_citation  # noqa: E402


def main() -> None:
    n_shards = 8
    mesh = compat.make_mesh((n_shards,), ("engine",))
    g = gmark_citation(400, avg_degree=6, seed=0)
    idx = cindex.build(g, 2)
    print(f"graph {g}; CPQx: {idx.n_classes} classes, {idx.n_pairs} pairs")

    # shard I_c2p rows (cls, v, u) by class hash across the mesh
    n = idx.n_pairs
    rows = np.stack([
        np.asarray(idx.arrays.c2p_cls)[:n], np.asarray(idx.arrays.c2p_v)[:n],
        np.asarray(idx.arrays.c2p_u)[:n]], axis=1)
    cap = 1 << int(np.ceil(np.log2(max(64, n))))
    blocks, counts = D.shard_relation(rows, n_shards, cap, key_col=0)
    cols = tuple(jnp.asarray(blocks[:, :, j]) for j in range(3))
    print(f"pair table sharded: {counts.tolist()} rows per shard")

    # a conjunction query: S template (2-path ∩ 2-path)
    labels = [0, 0, 1, 0]
    q = instantiate_template("S", labels)
    la, lb = (0, 0), (1, 0)

    def class_list(seq):
        lo, hi = idx.lookup_range(seq)
        out = np.full(256, R.SENTINEL, np.int32)
        out[: hi - lo] = np.asarray(idx.arrays.l2c_cls)[lo:hi]
        return jnp.asarray(out)

    step = D.make_distributed_query_step(mesh, "engine")
    with compat.set_mesh(mesh):
        (pv, pu), pc = step(class_list(la), class_list(lb),
                            cols[0], cols[1], cols[2], jnp.asarray(counts))
    pv, pu, pc = np.asarray(pv), np.asarray(pu), np.asarray(pc)
    got = sorted({(int(pv[s, i]), int(pu[s, i]))
                  for s in range(n_shards) for i in range(pc[s])})
    gt = sorted(oracle.cpq_eval(g, q))
    print(f"distributed conjunction: {len(got)} pairs "
          f"(per-shard {pc.tolist()}); matches semantics oracle: {got == gt}")
    assert got == gt


if __name__ == "__main__":
    main()
