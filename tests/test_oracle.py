"""Oracle self-consistency: the numpy reference implements the paper's
semantics, partitions, indexes and query algorithms coherently."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from conftest import random_graph
from repro.core import oracle
from repro.core.graph import example_graph
from repro.core.query import (
    Conj, Edge, Identity, Join, diameter, instantiate_template, parse,
    plan_query, TEMPLATES,
)


class TestPaperExample:
    """The running example of Sec. I / Example 4.3."""

    def test_triad_query(self, ex_graph):
        q = parse("(f . f) & f-", {"f": 0, "v": 1}, 2)
        ans = oracle.cpq_eval(ex_graph, q)
        # (sue, zoe), (joe, sue), (zoe, joe)
        assert ans == {(0, 2), (1, 0), (2, 1)}

    def test_index_agrees(self, ex_graph):
        idx = oracle.build_index(ex_graph, 2)
        q = parse("(f . f) & f-", {"f": 0, "v": 1}, 2)
        assert oracle.query_with_index(ex_graph, idx, q) == oracle.cpq_eval(
            ex_graph, q
        )

    def test_example_41_lookup_pruning(self, ex_graph):
        """Example 4.1: |C(ff) ∩ C(f⁻)| = 1 — a single class answers."""
        idx = oracle.build_index(ex_graph, 2)
        c_ff = set(idx.l2c[(0, 0)])
        c_finv = set(idx.l2c[(2,)])
        both = c_ff & c_finv
        assert len(both) == 1
        (c,) = both
        assert set(idx.c2p[c]) == {(0, 2), (1, 0), (2, 1)}


class TestSemantics:
    def test_identity(self, ex_graph):
        assert oracle.cpq_eval(ex_graph, Identity()) == {
            (v, v) for v in range(ex_graph.n_vertices)
        }

    def test_diameter(self):
        q = Conj(Join(Edge(0), Join(Edge(1), Edge(0))), Join(Edge(1), Edge(1)))
        assert diameter(q) == 3
        assert diameter(Conj(q, Identity())) == 3
        assert diameter(Identity()) == 0

    def test_parser_roundtrip(self):
        ids = {"f": 0, "v": 1}
        q = parse("((f . v-) & id) . f^-1", ids, 2)
        assert isinstance(q, Join)
        assert diameter(q) == 3

    def test_parser_rejects_garbage(self):
        with pytest.raises(SyntaxError):
            parse("f . . v", {"f": 0, "v": 1}, 2)
        with pytest.raises(SyntaxError):
            parse("unknown", {"f": 0}, 1)


class TestPartition:
    """The CPQ-correctness invariant (Thm. 4.1 / Cor. 4.1)."""

    @given(seed=st.integers(0, 10_000), k=st.sampled_from([1, 2, 3]))
    @settings(max_examples=15, deadline=None)
    def test_partition_is_cpq_correct(self, seed, k):
        g = random_graph(seed)
        part = oracle.path_partition(g, k)
        assert oracle.verify_partition(g, k, part)

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_interest_partition_is_cpq_correct_for_interests(self, seed):
        g = random_graph(seed)
        part = oracle.interest_partition(g, 2, [(0, 1)])
        # every class must be pure w.r.t. membership in any interest seq
        seqs = oracle.enumerate_pairs(g, 2)
        lq = {(l,) for l in range(g.alphabet_size)} | {(0, 1)}
        for c, ps in part.classes.items():
            sig0 = frozenset(s for s in seqs.get(ps[0], ()) if s in lq)
            for p in ps[1:]:
                assert frozenset(s for s in seqs.get(p, ()) if s in lq) == sig0

    def test_refinement(self):
        """k-path-bisim refines interest-equivalence (Sec. V-A)."""
        g = example_graph()
        bis = oracle.path_partition(g, 2)
        ia = oracle.interest_partition(g, 2, [(0, 0)])
        ia_class_of = ia.class_of
        mapping = {}
        for p, c in bis.class_of.items():
            if p not in ia_class_of:
                continue
            if c in mapping:
                assert mapping[c] == ia_class_of[p]
            mapping[c] = ia_class_of[p]

    def test_index_never_larger_than_path_index(self):
        """Thm. 4.2: |CPQx| = O(gamma|C| + |P|) <= O(gamma|P|) = |Path|."""
        for seed in (1, 2, 3):
            g = random_graph(seed)
            idx = oracle.build_index(g, 2)
            pidx = oracle.build_path_index(g, 2)
            l2c, c2p = idx.size_entries()
            assert l2c + c2p <= 2 * pidx.size_entries() + len(idx.c2p)
            # the l2c side alone is never larger than the path index
            assert l2c <= pidx.size_entries()


class TestQueryEquivalence:
    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_all_evaluators_agree(self, seed):
        g = random_graph(seed)
        idx = oracle.build_index(g, 2)
        pidx = oracle.build_path_index(g, 2)
        ia = oracle.build_interest_index(g, 2, [(0, 1)])
        rng = np.random.default_rng(seed)
        for _ in range(15):
            q = oracle.random_cpq(rng, g, 3)
            gt = oracle.cpq_eval(g, q)
            assert oracle.query_with_index(g, idx, q) == gt
            assert oracle.query_with_path_index(g, pidx, q) == gt
            assert oracle.query_with_index(g, ia, q) == gt

    def test_templates_cover_language(self, ex_graph):
        idx = oracle.build_index(ex_graph, 2)
        rng = np.random.default_rng(0)
        for name in TEMPLATES:
            labels = rng.integers(0, ex_graph.alphabet_size, 8).tolist()
            q = instantiate_template(name, labels)
            gt = oracle.cpq_eval(ex_graph, q)
            assert oracle.query_with_index(ex_graph, idx, q) == gt

    def test_plan_splits_long_chains(self):
        q = Join(Edge(0), Join(Edge(1), Join(Edge(0), Edge(1))))
        plan = plan_query(q, 2)
        assert plan[0] == "lookup"
        assert [len(s) for s in plan[1]] == [2, 2]

    def test_plan_available_restriction(self):
        q = Join(Edge(0), Edge(1))
        plan = plan_query(q, 2, available={(0,), (1,)})
        assert [len(s) for s in plan[1]] == [1, 1]
