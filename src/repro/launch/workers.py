"""Worker-process entrypoint for the cluster runtime (``core.cluster``).

``worker_main`` is the ``spawn`` target of every persistent worker: it
starts the heartbeat *before* the heavy imports (so the coordinator sees
liveness while jax initializes), builds one :class:`WorkerState` — the
singleton that owns the device slice and whose module-level jit kernels
make compilation once-per-(op, caps)-per-process — and then loops on the
instruction queue forever: receive ``(seq, kind, payload)``, execute,
reply ``(rank, seq, status, payload)`` on the shared result queue.

Status protocol: ``ok`` (instruction done, payload is the result),
``aborted`` (the coordinator's abort event interrupted an exchange —
the round is void and will be re-issued), ``error`` (the instruction
raised; payload is the traceback).  Every instruction gets exactly one
reply — the coordinator's quiesce protocol counts on it.

Run as a module for a self-contained demo of the fleet:

    PYTHONPATH=src python -m repro.launch.workers --workers 2
"""

from __future__ import annotations


def worker_main(rank, iq, rq, inboxes, outboxes, hb, abort) -> None:
    """Body of one persistent worker process.

    Parameters are the coordinator's plumbing: ``iq`` the FIFO
    instruction queue (the total order this worker observes), ``rq`` the
    shared reply queue, ``inboxes``/``outboxes`` this rank's row of the
    peer exchange matrix, ``hb`` the shared heartbeat double, ``abort``
    the fleet-wide round-abort event."""
    import os
    import threading
    import time
    import traceback

    def _beat() -> None:
        while True:
            hb.value = time.time()
            time.sleep(0.2)

    threading.Thread(target=_beat, daemon=True, name="heartbeat").start()

    # heavy imports only after the heartbeat is live
    from repro.core import cluster as C

    state = C.WorkerState(rank, inboxes, outboxes, abort)
    while True:
        seq, kind, payload = iq.get()
        if kind == C.SHUTDOWN:
            rq.put((rank, seq, "ok", None))
            return
        if kind == C.CRASH:  # test-only fault injection: die, hard
            os._exit(int(payload.get("code", 3)))
        try:
            out = state.handle(seq, kind, payload)
        except C.RoundAborted:
            rq.put((rank, seq, "aborted", None))
        except Exception:  # noqa: BLE001 — ship the traceback upstream
            rq.put((rank, seq, "error", traceback.format_exc()))
        else:
            rq.put((rank, seq, "ok", out))


def main(argv=None) -> None:
    """Demo: serve the example graph from a persistent-worker fleet."""
    import argparse

    parser = argparse.ArgumentParser(
        description="CPQx cluster demo: QueryService over worker processes")
    parser.add_argument("--workers", type=int, default=2,
                        help="number of persistent worker processes")
    parser.add_argument("--k", type=int, default=2,
                        help="CPQx index diameter")
    parser.add_argument("--queries", type=int, default=12,
                        help="number of demo queries to serve")
    args = parser.parse_args(argv)

    import numpy as np

    from repro.core import index as cindex
    from repro.core.engine import Engine
    from repro.core.graph import example_graph
    from repro.core.query import (TEMPLATE_ARITY, TEMPLATES,
                                  instantiate_template)
    from repro.core.service import QueryService

    g = example_graph()
    engine = Engine(cindex.build(g, args.k), cluster=args.workers)
    service = QueryService(engine)
    rng = np.random.default_rng(0)
    names = sorted(TEMPLATES)
    try:
        for i in range(args.queries):
            name = names[i % len(names)]
            labels = rng.integers(0, g.alphabet_size,
                                  TEMPLATE_ARITY[name]).tolist()
            rows = service.query(instantiate_template(name, labels))
            print(f"  {name:>3}: {rows.shape[0]} answer pairs")
        runtime = engine.backend.runtime
        print(f"served {args.queries} queries over {runtime.n_shards} "
              f"workers; instruction counts: "
              f"{dict(runtime.instructions)}")
    finally:
        engine.backend.shutdown()


if __name__ == "__main__":
    main()
