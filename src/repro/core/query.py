"""CPQ abstract syntax, parser, diameter, and the query planner.

Host-side only (no jax import) — shared by the numpy oracle, the device
engine, and the benchmarks.

Grammar (paper Sec. III-B)::

    CPQ := id | l | CPQ ∘ CPQ | CPQ ∩ CPQ | (CPQ)

Concrete syntax accepted by :func:`parse`::

    id              identity
    name            edge label (as named in the graph, or ``l3``)
    name-           inverse label (also ``name^-1``)
    a . b           join        (also ``a ∘ b`` / ``a / b``)
    a & b           conjunction (also ``a ∩ b``)
    ( ... )         grouping;  join binds tighter than conjunction

The planner (:func:`plan_query`) compiles an AST to the physical plan of
Sec. IV-D / Fig. 4: maximal label-only join chains collapse into LOOKUP
nodes (label sequences split into <=k segments), ``q ∘ id`` is elided, and
``q ∩ id`` becomes the IDENTITY operator (cycle-flag check on classes).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Sequence

# ---------------------------------------------------------------------- #
# AST
# ---------------------------------------------------------------------- #


class CPQ:
    """Base class of CPQ AST nodes."""

    def __mul__(self, other: "CPQ") -> "CPQ":  # q1 * q2 == join
        return Join(self, other)

    def __and__(self, other: "CPQ") -> "CPQ":  # q1 & q2 == conjunction
        return Conj(self, other)


@dataclasses.dataclass(frozen=True)
class Identity(CPQ):
    def __repr__(self):
        return "id"


@dataclasses.dataclass(frozen=True)
class Edge(CPQ):
    label: int  # closure label id, in [0, 2·n_labels)

    def __repr__(self):
        return f"l{self.label}"


@dataclasses.dataclass(frozen=True)
class Join(CPQ):
    lhs: CPQ
    rhs: CPQ

    def __repr__(self):
        return f"({self.lhs!r} . {self.rhs!r})"


@dataclasses.dataclass(frozen=True)
class Conj(CPQ):
    lhs: CPQ
    rhs: CPQ

    def __repr__(self):
        return f"({self.lhs!r} & {self.rhs!r})"


def diameter(q: CPQ) -> int:
    """dia(q) per Sec. III-B."""
    if isinstance(q, Identity):
        return 0
    if isinstance(q, Edge):
        return 1
    if isinstance(q, Join):
        return diameter(q.lhs) + diameter(q.rhs)
    if isinstance(q, Conj):
        return max(diameter(q.lhs), diameter(q.rhs))
    raise TypeError(q)


# ---------------------------------------------------------------------- #
# Parser
# ---------------------------------------------------------------------- #

_TOKEN = re.compile(
    r"\s*(?:(?P<lpar>\()|(?P<rpar>\))|(?P<join>[.∘/])|(?P<conj>[&∩])"
    r"|(?P<name>[A-Za-z_][A-Za-z_0-9]*)(?P<inv>\^-1|-|⁻¹)?)"
)


def parse(text: str, label_ids: dict[str, int] | None, n_labels: int) -> CPQ:
    """Parse concrete CPQ syntax.  ``label_ids`` maps base-label names to
    base ids; ``None`` enables only the ``l<k>`` positional form.

    Every ``SyntaxError`` reports the character position of the
    offending token so a malformed query in a long workload file is
    locatable without bisection."""
    tokens = []
    pos = 0
    while pos < len(text):
        m = _TOKEN.match(text, pos)
        if not m or m.end() == pos:
            if text[pos:].strip():
                raise SyntaxError(
                    f"bad token at position {pos}: {text[pos:]!r}")
            break
        pos = m.end()
        tokens.append(m)

    idx = 0

    def where() -> str:
        """Location suffix for the current token (or end of input)."""
        if idx < len(tokens):
            t = tokens[idx]
            for g in ("lpar", "rpar", "join", "conj", "name"):
                if t.group(g) is not None:
                    return f"at position {t.start(g)}"
        return f"at end of input (position {len(text)})"

    def peek(kind):
        return idx < len(tokens) and tokens[idx].group(kind)

    def expr():  # conjunction level (loosest)
        nonlocal idx
        node = term()
        while peek("conj"):
            idx += 1
            node = Conj(node, term())
        return node

    def term():  # join level
        nonlocal idx
        node = atom()
        while peek("join"):
            idx += 1
            node = Join(node, atom())
        return node

    def atom():
        nonlocal idx
        if peek("lpar"):
            idx += 1
            node = expr()
            if not peek("rpar"):
                raise SyntaxError(f"expected ')' {where()}")
            idx += 1
            return node
        name = peek("name")
        if not name:
            raise SyntaxError(f"expected label, 'id' or '(' {where()}")
        inv = tokens[idx].group("inv")
        if name == "id" and not inv:
            idx += 1
            return Identity()
        if label_ids and name in label_ids:
            base = label_ids[name]
        elif re.fullmatch(r"l\d+", name):
            base = int(name[1:])
        else:
            raise SyntaxError(f"unknown label {name!r} {where()}")
        if base >= n_labels:
            raise SyntaxError(f"label id {base} out of range {where()}")
        idx += 1
        return Edge(base + n_labels if inv else base)

    node = expr()
    if idx != len(tokens):
        raise SyntaxError(f"trailing tokens {where()}")
    return node


# ---------------------------------------------------------------------- #
# Planner — AST -> physical plan (Sec. IV-D)
#
# Plan nodes are plain tuples (easily traversed host-side and compiled to
# jitted stages by core.engine):
#   ("lookup", [seq, seq, ...])   maximal label chain, segments of len <= k
#   ("identity",)                 bare `id`
#   ("join", left, right)
#   ("conj", left, right)
#   ("conj_id", inner)            inner ∩ id  (IDENTITY operator)
# ---------------------------------------------------------------------- #


def plan_query(q: CPQ, k: int, available: set | None = None):
    """Compile AST to a physical plan.  ``available`` restricts LOOKUP
    segments to sequences actually present in the index (iaCPQx query-time
    splitting, Sec. V-B); None means any segment of length <= k is fine."""
    q = _strip_identity_joins(q)
    if isinstance(q, Identity):
        return ("identity",)
    return _plan(q, k, available)


def _strip_identity_joins(q: CPQ) -> CPQ:
    """q ∘ id == q (both sides)."""
    if isinstance(q, Join):
        l = _strip_identity_joins(q.lhs)
        r = _strip_identity_joins(q.rhs)
        if isinstance(l, Identity):
            return r
        if isinstance(r, Identity):
            return l
        return Join(l, r)
    if isinstance(q, Conj):
        return Conj(_strip_identity_joins(q.lhs), _strip_identity_joins(q.rhs))
    return q


def _plan(q: CPQ, k: int, available):
    if isinstance(q, Edge):
        return ("lookup", [(q.label,)])
    if isinstance(q, Identity):
        return ("identity",)
    if isinstance(q, Conj):
        if isinstance(q.rhs, Identity):
            return ("conj_id", _plan(q.lhs, k, available))
        if isinstance(q.lhs, Identity):
            return ("conj_id", _plan(q.rhs, k, available))
        return ("conj", _plan(q.lhs, k, available), _plan(q.rhs, k, available))
    if isinstance(q, Join):
        leaves = _flatten_join(q)
        # group maximal runs of Edge leaves into label sequences
        groups: list = []  # each: ("seq", [labels]) or ("sub", ast)
        for leaf in leaves:
            if isinstance(leaf, Edge):
                if groups and groups[-1][0] == "seq":
                    groups[-1][1].append(leaf.label)
                else:
                    groups.append(("seq", [leaf.label]))
            else:
                groups.append(("sub", leaf))
        planned = []
        for kind, val in groups:
            if kind == "seq":
                segs = _split_seq(tuple(val), k, available)
                planned.append(("lookup", segs))
            else:
                planned.append(_plan(val, k, available))
        node = planned[0]
        for nxt in planned[1:]:
            # merge adjacent lookups into one chain node
            if node[0] == "lookup" and nxt[0] == "lookup":
                node = ("lookup", node[1] + nxt[1])
            else:
                node = ("join", node, nxt)
        return node
    raise TypeError(q)


def _flatten_join(q: CPQ) -> list:
    if isinstance(q, Join):
        return _flatten_join(q.lhs) + _flatten_join(q.rhs)
    return [q]


def _split_seq(seq: tuple, k: int, available) -> list:
    """Greedy longest-prefix split into segments of length <= k present in
    ``available`` (length-1 segments are always present: L_q ⊇ L)."""
    out, i = [], 0
    n = len(seq)
    while i < n:
        step = min(k, n - i)
        while step > 1:
            if available is None or seq[i: i + step] in available:
                break
            step -= 1
        out.append(tuple(seq[i: i + step]))
        i += step
    return out


def freeze_plan(plan):
    """Plans contain lists (mutable) — freeze to nested tuples so a plan
    can key dicts/caches and serve as a jit static argument."""
    if isinstance(plan, tuple) and plan and plan[0] == "lookup":
        return ("lookup", tuple(tuple(s) for s in plan[1]))
    if isinstance(plan, tuple):
        return tuple(freeze_plan(p) if isinstance(p, tuple) else p for p in plan)
    return plan


def plan_shape(plan):
    """The jit-relevant *shape* of a plan: operator structure plus the
    segment count of each LOOKUP node (the label values themselves only
    select which (start, len) ranges stream in as data, so queries that
    differ only in labels share one compiled executable)."""
    kind = plan[0]
    if kind == "lookup":
        return ("lookup", len(plan[1]))
    if kind == "identity":
        return ("identity",)
    if kind == "conj_id":
        return ("conj_id", plan_shape(plan[1]))
    if kind in ("join", "conj"):
        return (kind, plan_shape(plan[1]), plan_shape(plan[2]))
    raise ValueError(kind)


def plan_lookup_seqs(plan) -> list:
    """All label sequences a plan will LOOKUP (for engine buffer sizing)."""
    out = []
    kind = plan[0]
    if kind == "lookup":
        out.extend(plan[1])
    elif kind in ("join", "conj"):
        out.extend(plan_lookup_seqs(plan[1]))
        out.extend(plan_lookup_seqs(plan[2]))
    elif kind == "conj_id":
        out.extend(plan_lookup_seqs(plan[1]))
    return out


# ---------------------------------------------------------------------- #
# The 12 query templates of Fig. 5 (shapes per Sec. VI: chains C, triangles
# T, squares S, stars St, their identity-closed variants *i, and the
# "flower" combinations TC / SC / ST).  Label arguments are closure ids.
# ---------------------------------------------------------------------- #


def _e(l):
    return Edge(l)


TEMPLATES: dict[str, Callable[..., CPQ]] = {
    # chains
    "C2": lambda l1, l2: _e(l1) * _e(l2),
    "C4": lambda l1, l2, l3, l4: _e(l1) * _e(l2) * _e(l3) * _e(l4),
    # chains closed into cycles with identity
    "C2i": lambda l1, l2: (_e(l1) * _e(l2)) & Identity(),
    "Ti": lambda l1, l2, l3: (_e(l1) * _e(l2) * _e(l3)) & Identity(),
    "Si": lambda l1, l2, l3, l4: (_e(l1) * _e(l2) * _e(l3) * _e(l4)) & Identity(),
    # triangle / square: 2-path (3-path) conjoined with a direct edge / 2-path
    "T": lambda l1, l2, l3: (_e(l1) * _e(l2)) & _e(l3),
    "S": lambda l1, l2, l3, l4: (_e(l1) * _e(l2)) & (_e(l3) * _e(l4)),
    # two triangles glued on the direct edge
    "TT": lambda l1, l2, l3, l4, l5: ((_e(l1) * _e(l2)) & _e(l5))
    & ((_e(l3) * _e(l4)) & _e(l5)),
    # star: parallel edges s->t
    "St": lambda l1, l2, l3: (_e(l1) & _e(l2)) & _e(l3),
    # flowers: triangle/square followed by a chain; star into a triangle
    "TC": lambda l1, l2, l3, l4, l5: ((_e(l1) * _e(l2)) & _e(l3)) * _e(l4) * _e(l5),
    "SC": lambda l1, l2, l3, l4, l5, l6: ((_e(l1) * _e(l2)) & (_e(l3) * _e(l4)))
    * _e(l5) * _e(l6),
    "ST": lambda l1, l2, l3, l4, l5: (_e(l1) & _e(l2)) * ((_e(l3) * _e(l4)) & _e(l5)),
}

TEMPLATE_ARITY = {name: fn.__code__.co_argcount for name, fn in TEMPLATES.items()}


def instantiate_template(name: str, labels: Sequence[int]) -> CPQ:
    fn = TEMPLATES[name]
    need = TEMPLATE_ARITY[name]
    if len(labels) < need:
        raise ValueError(f"template {name} needs {need} labels")
    return fn(*labels[:need])
