"""Host-side statistics view over a built CPQx/iaCPQx index.

The index already *is* a statistics store: the ``I_l2c`` row range of a
label sequence gives its exact class count, and the ``I_c2p`` CSR
offsets give the exact pair count of every class.  This module pulls
those few-KB arrays to the host ONCE per bind/rebind and turns them into
O(1) per-sequence cardinality queries via two prefix sums over the l2c
rows — the raw material of the cost-based optimizer
(:mod:`repro.core.optimizer`) and of the engine's capacity estimator.

Three constructors cover every index form in the repo:

* :meth:`IndexStats.from_index` — a device :class:`~repro.core.index.CPQxIndex`
  (one device sync; called by ``Engine.rebind``, so maintenance flushes
  refresh the statistics automatically);
* :meth:`IndexStats.from_host_arrays` — raw numpy arrays; used by
  :func:`repro.core.sharded_index.replicated_stats` to derive the same
  view from a sharded layout's replicated leaves (sharded planning must
  match local planning bit-for-bit);
* :meth:`IndexStats.from_oracle` — the dict-form ``oracle.Index`` mirror,
  keeping the optimizer testable without jax.

This module is host-only: numpy, no jax import.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class IndexStats:
    """Exact per-sequence cardinalities of one index snapshot.

    ``seq_ranges`` maps a label-sequence tuple to its (lo, hi) row range
    in the l2c class column; the three cumulative arrays turn any range
    into class / pair / cyclic-pair counts in O(1).
    """

    n_vertices: int
    n_classes: int
    total_pairs: int
    seq_ranges: dict
    class_sizes: np.ndarray  # (>= n_classes,) pairs per class id
    l2c_cls: np.ndarray  # (l2c_count,) valid l2c class-column rows
    _pairs_cum: np.ndarray  # (l2c_count + 1,) prefix sum of row class sizes
    _cyc_cum: np.ndarray  # (l2c_count + 1,) same, cyclic classes only

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #

    @classmethod
    def from_host_arrays(
        cls,
        *,
        n_vertices: int,
        n_classes: int,
        total_pairs: int,
        seq_ranges: dict,
        class_starts: np.ndarray,
        l2c_cls: np.ndarray,
        l2c_count: int,
        class_cyclic: np.ndarray,
    ) -> "IndexStats":
        starts = np.asarray(class_starts, np.int64)
        sizes = starts[1:] - starts[:-1]
        cyc = np.asarray(class_cyclic, np.int64)
        rows = np.asarray(l2c_cls, np.int64)[: int(l2c_count)]
        safe = np.clip(rows, 0, sizes.shape[0] - 1)
        row_sizes = np.where(rows < sizes.shape[0], sizes[safe], 0)
        row_cyc = row_sizes * np.where(rows < cyc.shape[0], cyc[safe], 0)
        zero = np.zeros(1, np.int64)
        return cls(
            n_vertices=int(n_vertices),
            n_classes=int(n_classes),
            total_pairs=int(total_pairs),
            seq_ranges=dict(seq_ranges),
            class_sizes=sizes,
            l2c_cls=rows,
            _pairs_cum=np.concatenate([zero, np.cumsum(row_sizes)]),
            _cyc_cum=np.concatenate([zero, np.cumsum(row_cyc)]),
        )

    @classmethod
    def from_index(cls, index) -> "IndexStats":
        """Pull the statistics mirrors off a :class:`~repro.core.index.
        CPQxIndex` (a few KB; the one device sync of a rebind)."""
        a = index.arrays
        return cls.from_host_arrays(
            n_vertices=index.n_vertices,
            n_classes=int(a.n_classes),
            total_pairs=int(a.pair_count),
            seq_ranges=index.seq_ranges,
            class_starts=np.asarray(a.class_starts),
            l2c_cls=np.asarray(a.l2c_cls),
            l2c_count=int(a.l2c_count),
            class_cyclic=np.asarray(a.class_cyclic),
        )

    @classmethod
    def from_oracle(cls, oindex, n_vertices: int) -> "IndexStats":
        """Build the same view from the dict-form ``oracle.Index`` (or a
        :class:`~repro.core.maintenance.MaintainableIndex` mirror).  Class
        ids are densified in ascending order, exactly like
        ``index.from_host_mirror``, so the derived statistics match a
        flush of the same mirror."""
        ids = sorted(c for c, ps in oindex.c2p.items() if ps)
        remap = {c: i for i, c in enumerate(ids)}
        sizes = np.array([len(oindex.c2p[c]) for c in ids] or [0], np.int64)
        cyclic = np.array(
            [1 if oindex.cyclic[c] else 0 for c in ids] or [0], np.int64)
        seq_ranges: dict = {}
        flat: list[int] = []
        for s in sorted(tuple(t) for t in oindex.l2c):
            lo = len(flat)
            flat.extend(sorted(remap[c] for c in oindex.l2c[s] if c in remap))
            seq_ranges[s] = (lo, len(flat))
        return cls.from_host_arrays(
            n_vertices=n_vertices,
            n_classes=len(ids),
            total_pairs=int(sizes.sum()) if ids else 0,
            seq_ranges=seq_ranges,
            class_starts=np.concatenate([np.zeros(1, np.int64),
                                         np.cumsum(sizes)]),
            l2c_cls=np.asarray(flat, np.int64),
            l2c_count=len(flat),
            class_cyclic=cyclic,
        )

    # ------------------------------------------------------------------ #
    # O(1) per-sequence cardinalities (all exact)
    # ------------------------------------------------------------------ #

    def has_seq(self, seq) -> bool:
        return tuple(seq) in self.seq_ranges

    def seq_classes(self, seq) -> int:
        """Number of classes in the sequence's l2c list (LOOKUP output)."""
        lo, hi = self.seq_ranges.get(tuple(seq), (0, 0))
        return hi - lo

    def seq_pairs(self, seq) -> int:
        """Total s-t pairs across the sequence's classes — the exact size
        of materializing this LOOKUP."""
        lo, hi = self.seq_ranges.get(tuple(seq), (0, 0))
        return int(self._pairs_cum[hi] - self._pairs_cum[lo])

    def seq_cyclic_pairs(self, seq) -> int:
        """Pairs in cycle-pure classes only — the exact size of
        ``lookup(seq) ∩ id`` (classes are cycle-pure by construction)."""
        lo, hi = self.seq_ranges.get(tuple(seq), (0, 0))
        return int(self._cyc_cum[hi] - self._cyc_cum[lo])
