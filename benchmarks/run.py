"""Benchmark driver — one bench per paper table/figure (deliverable (d)).

Prints ``name,us_per_call,derived`` CSV rows.  CPU-scaled datasets from
the same generator families as the paper's suite; correctness gates
(all methods agree with the semantics oracle) run inside each bench.

    PYTHONPATH=src python -m benchmarks.run [--only fig6 table4 ...]
"""

from __future__ import annotations

import argparse
import sys
import time

BENCHES = {
    "fig6": "benchmarks.bench_query",  # query time per template x method
    "table3": "benchmarks.bench_pruning",  # pruning power
    "table4": "benchmarks.bench_index",  # index size + build time
    "table5": "benchmarks.bench_update",  # maintenance (+ tables 6/7)
    "fig14": "benchmarks.bench_k",  # behavior in k (+ fig 15)
    "fig11": "benchmarks.bench_scalability",  # graph-size scaling
    "kernels": "benchmarks.bench_kernels",  # Pallas vs jnp reference
    "throughput": "benchmarks.bench_throughput",  # serving qps (PR 1)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {sorted(BENCHES)}")
    args = ap.parse_args()
    todo = args.only or list(BENCHES)
    print("name,us_per_call,derived")
    t0 = time.time()
    failed = []
    for key in todo:
        mod_name = BENCHES[key]
        t1 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {key} done in {time.time()-t1:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failed.append(key)
            print(f"# {key} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        raise SystemExit(f"benches failed: {failed}")


if __name__ == "__main__":
    main()
