"""Crash-consistency harness for the zero-downtime index lifecycle.

The contract under test (``repro.core.lifecycle`` + ``repro.checkpoint``):
a crash at ANY point — mid-leaf-write, before the data-dir rename, after
it but before the LATEST pointer moves, or in the maintenance window
between a graph-batch apply and its flush — leaves the last *committed*
step restorable with answers exactly equal to the numpy oracle on the
checkpointed graph.  No injected failure may ever surface a half-state.

Faults are injected by monkeypatching the exact primitive that would
fail (``os.rename`` / ``os.replace`` / ``MaintainableIndex.flush``) and
by corrupting the on-disk layout directly (torn pointer, partial
``.tmp`` dir) — the same failure modes a real power cut produces.
"""

import os

import numpy as np
import pytest

from repro.checkpoint import committed_steps, latest_step
from repro.core import index as cindex, lifecycle, oracle
from repro.core.engine import Engine
from repro.core.graph import example_graph
from repro.core.maintenance import MaintainableIndex
from repro.core.query import parse
from repro.core.service import QueryService
from repro.core.workload import AdaptationController


def _rows_set(rows):
    return {tuple(r) for r in np.asarray(rows).tolist()}


def _parse_probes(g):
    return [parse(t, None, g.n_labels)
            for t in ("l0 . l1", "(l0 . l0) & l0-", "l0 & id", "l1 . l0")]


def _assert_serves_oracle(svc, g=None):
    svc.flush()  # drain queued updates BEFORE reading the mirror graph
    if g is None:
        g = svc.maintainer.g
    for q in _parse_probes(g):
        assert _rows_set(svc.query(q)) == oracle.cpq_eval(g, q), q


def _fresh_service(adapter: bool = False):
    g = example_graph()
    interests = [(0, 1), (0, 0)] if adapter else None
    mi = MaintainableIndex.build(g, 2, interests=interests)
    engine = Engine(mi.flush())
    adp = AdaptationController(2) if adapter else None
    return QueryService(engine, maintainer=mi, adapter=adp)


# ---------------------------------------------------------------------- #
# happy-path round trips (the baseline the fault tests lean on)
# ---------------------------------------------------------------------- #


class TestRoundTrip:
    def test_index_save_restore_bit_identical(self, ex_graph, tmp_path):
        idx = cindex.build(ex_graph, 2)
        idx.save(str(tmp_path))
        back = cindex.CPQxIndex.restore(str(tmp_path))
        for f in cindex.DeviceIndexArrays._fields:
            a = np.asarray(getattr(idx.arrays, f))
            b = np.asarray(getattr(back.arrays, f))
            assert a.shape == b.shape and np.array_equal(a, b), f
        assert back.seq_ranges == idx.seq_ranges
        assert back.caps == idx.caps and back.k == idx.k
        assert back.interests == idx.interests
        eng = Engine(back)
        for q in _parse_probes(ex_graph):
            assert _rows_set(eng.execute(q)) == oracle.cpq_eval(ex_graph, q)

    def test_service_checkpoint_promotes_cold_replica(self, tmp_path):
        svc = _fresh_service(adapter=True)
        g0 = svc.maintainer.g
        for q in _parse_probes(g0):
            svc.query(q)
        svc.apply_updates([("insert_edge", 0, 5, 0),
                           ("delete_edge", 0, 1, 0)])
        svc.query(_parse_probes(g0)[0])  # drain the write batch
        step = svc.checkpoint(str(tmp_path))
        donor_mirror = svc.maintainer.export_state()
        donor_sketch = svc.adapter.export_state()

        replica = lifecycle.restore_service(str(tmp_path), step)
        # promoted mid-traffic: fresh epoch strictly past the donor's
        assert replica.graph_epoch > svc.graph_epoch
        # the mirror came over exactly (graph, lazy partition, caps)
        for key, arr in replica.maintainer.export_state().items():
            assert np.array_equal(arr, donor_mirror[key]), key
        # so did the adaptation loop (sketch counters, dwell, rounds) —
        # compared before serving, since served queries feed the sketch
        for key, arr in replica.adapter.export_state().items():
            assert np.array_equal(arr, donor_sketch[key]), key
        _assert_serves_oracle(replica, svc.maintainer.g)
        # and it keeps serving under further maintenance
        replica.apply_updates([("insert_edge", 2, 9, 1)])
        _assert_serves_oracle(replica)

    def test_checkpoint_drains_pending_writes_first(self, tmp_path):
        """The snapshot must be taken at a quiescent epoch: updates
        queued (not yet drained) at checkpoint time are IN the
        checkpoint, via the same one-batch ``_drain_updates`` round."""
        svc = _fresh_service()
        svc.apply_updates([("insert_edge", 3, 7, 1)])
        assert svc.pending_updates == 1  # queued, not applied
        step = svc.checkpoint(str(tmp_path))
        assert svc.pending_updates == 0
        replica = lifecycle.restore_service(str(tmp_path), step)
        g = replica.maintainer.g
        assert (3, 7, 1) in {tuple(map(int, e)) for e in g._base_edges()}
        _assert_serves_oracle(replica, g)

    def test_restore_into_live_service_bumps_epoch(self, tmp_path):
        svc = _fresh_service()
        step = svc.checkpoint(str(tmp_path))
        g_at_ckpt = svc.maintainer.g
        svc.apply_updates([("insert_edge", 1, 8, 0)])
        svc.query(_parse_probes(g_at_ckpt)[0])
        epoch_before = svc.graph_epoch
        assert svc.restore(str(tmp_path), step) == step
        assert svc.graph_epoch > epoch_before  # O(1) cache invalidation
        _assert_serves_oracle(svc, g_at_ckpt)


# ---------------------------------------------------------------------- #
# fault injection — the archetype deliverable
# ---------------------------------------------------------------------- #


class TestFaultInjection:
    def test_torn_latest_pointer_falls_back_to_scan(self, tmp_path):
        svc = _fresh_service()
        svc.checkpoint(str(tmp_path))
        svc.apply_updates([("insert_edge", 4, 6, 1)])
        last = svc.checkpoint(str(tmp_path))
        g_last = svc.maintainer.g
        # a torn pointer: partial garbage write, no trailing step id
        with open(os.path.join(str(tmp_path), "LATEST"), "w") as f:
            f.write("\x00\x00garbage")
        assert latest_step(str(tmp_path)) == last  # scan fallback
        replica = lifecycle.restore_service(str(tmp_path))
        _assert_serves_oracle(replica, g_last)

    def test_dangling_latest_pointer_falls_back(self, tmp_path):
        svc = _fresh_service()
        last = svc.checkpoint(str(tmp_path))
        with open(os.path.join(str(tmp_path), "LATEST"), "w") as f:
            f.write(str(last + 7))  # points at a step that never existed
        assert latest_step(str(tmp_path)) == last
        _assert_serves_oracle(lifecycle.restore_service(str(tmp_path)),
                              svc.maintainer.g)

    def test_partial_tmp_dir_never_considered_committed(self, tmp_path):
        svc = _fresh_service()
        last = svc.checkpoint(str(tmp_path))
        g_last = svc.maintainer.g
        # a writer died mid-step: leaves on disk, no manifest, no rename
        tmp = os.path.join(str(tmp_path), f"step_{last + 1:09d}.tmp")
        os.makedirs(tmp)
        np.save(os.path.join(tmp, "leaf_00000.npy"), np.arange(3))
        assert latest_step(str(tmp_path)) == last
        assert committed_steps(str(tmp_path)) == [last]
        _assert_serves_oracle(lifecycle.restore_service(str(tmp_path)),
                              g_last)
        # a retried save over the stale debris commits cleanly
        svc.apply_updates([("insert_edge", 2, 11, 0)])
        nxt = svc.checkpoint(str(tmp_path))
        assert nxt == last + 1 and latest_step(str(tmp_path)) == nxt
        _assert_serves_oracle(lifecycle.restore_service(str(tmp_path)),
                              svc.maintainer.g)

    def test_fully_renamed_dir_without_manifest_skipped(self, tmp_path):
        svc = _fresh_service()
        last = svc.checkpoint(str(tmp_path))
        bogus = os.path.join(str(tmp_path), f"step_{last + 3:09d}")
        os.makedirs(bogus)  # renamed-looking dir, but no manifest inside
        with open(os.path.join(str(tmp_path), "LATEST"), "w") as f:
            f.write("not-a-step")
        assert latest_step(str(tmp_path)) == last

    def test_crash_during_data_rename(self, tmp_path, monkeypatch):
        """Kill the writer at the atomic-commit rename itself: the old
        step stays the committed one; a retry then succeeds."""
        svc = _fresh_service()
        first = svc.checkpoint(str(tmp_path))
        g_first = svc.maintainer.g
        svc.apply_updates([("insert_edge", 5, 10, 1)])

        real_rename = os.rename

        def dying_rename(src, dst):
            if str(src).endswith(".tmp"):
                raise OSError("injected crash at commit rename")
            return real_rename(src, dst)

        monkeypatch.setattr(os, "rename", dying_rename)
        with pytest.raises(OSError, match="injected crash"):
            svc.checkpoint(str(tmp_path))
        monkeypatch.undo()

        assert latest_step(str(tmp_path)) == first
        _assert_serves_oracle(lifecycle.restore_service(str(tmp_path)),
                              g_first)
        nxt = svc.checkpoint(str(tmp_path))  # retry over the debris
        assert latest_step(str(tmp_path)) == nxt
        _assert_serves_oracle(lifecycle.restore_service(str(tmp_path)),
                              svc.maintainer.g)

    def test_crash_between_rename_and_latest(self, tmp_path, monkeypatch):
        """Kill the writer after the data dir renamed but before LATEST
        moved: the pointer is the commit point, so restore returns the
        PREVIOUS step — consistent, never the half-published one."""
        svc = _fresh_service()
        first = svc.checkpoint(str(tmp_path))
        g_first = svc.maintainer.g
        svc.apply_updates([("insert_edge", 6, 12, 0)])

        def dying_replace(src, dst):
            raise OSError("injected crash before LATEST")

        monkeypatch.setattr(os, "replace", dying_replace)
        with pytest.raises(OSError, match="injected crash"):
            svc.checkpoint(str(tmp_path))
        monkeypatch.undo()

        # the new dir IS on disk, but LATEST still names the old step
        assert len(committed_steps(str(tmp_path))) == 2
        assert latest_step(str(tmp_path)) == first
        _assert_serves_oracle(lifecycle.restore_service(str(tmp_path)),
                              g_first)

    def test_crash_between_apply_and_flush(self, tmp_path, monkeypatch):
        """The maintenance half of the contract: a crash in the window
        after the graph batch hit the host mirror but before the
        mirror→device flush published it.  The dying process's state is
        torn by construction — the restart must come up on the last
        committed checkpoint, answering for the checkpointed graph."""
        svc = _fresh_service()
        svc.apply_updates([("insert_edge", 0, 5, 0)])
        step = svc.checkpoint(str(tmp_path))
        g_ckpt = svc.maintainer.g
        ans_ckpt = {q: oracle.cpq_eval(g_ckpt, q)
                    for q in _parse_probes(g_ckpt)}

        svc.apply_updates([("delete_edge", 0, 5, 0),
                           ("insert_edge", 1, 9, 1)])

        def dying_flush(self, caps=None):
            raise RuntimeError("injected crash between apply and flush")

        monkeypatch.setattr(MaintainableIndex, "flush", dying_flush)
        with pytest.raises(RuntimeError, match="injected crash"):
            svc.query(_parse_probes(g_ckpt)[0])  # drain applies, flush dies
        monkeypatch.undo()
        # the dying service really is torn: mirror has the updates, the
        # device arrays don't — exactly the state a restart must escape
        assert svc.maintainer.g is not g_ckpt

        replica = lifecycle.restore_service(str(tmp_path), step)
        for q, truth in ans_ckpt.items():
            assert _rows_set(replica.query(q)) == truth, q
        # and the replayed updates land cleanly on the restored state
        replica.apply_updates([("delete_edge", 0, 5, 0),
                               ("insert_edge", 1, 9, 1)])
        _assert_serves_oracle(replica)

    def test_no_committed_step_raises(self, tmp_path):
        assert latest_step(str(tmp_path)) is None
        with pytest.raises(FileNotFoundError):
            lifecycle.restore_service(str(tmp_path))
