"""The paper's engine as a distributed workload: build a gMark citation
graph, shard its CPQx index over an 8-device mesh with one line —
``Engine(index, mesh=...)`` — and serve the full Fig. 5 template suite
through the sharded backend, bit-identical to the local engine.

What ``mesh=`` changes under the hood (core/sharded_index.py +
core/distributed.py): I_c2p is hash-partitioned by class so each shard
materializes only its own classes; pair-space relations live hash-
partitioned by source vertex and joins exchange rows with all_to_all
inside one shard_map; the tiny l2c/seq/cycle metadata is replicated so
class-space work (the paper's pruning) needs no communication at all.
The serving layer (QueryService) and the maintenance write path are
backend-agnostic: a flush reshards on rebind.

    PYTHONPATH=src python examples/engine_at_scale.py
(sets XLA_FLAGS itself; run as a standalone script, not under pytest)
"""

import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import index as cindex  # noqa: E402
from repro.core import oracle  # noqa: E402
from repro.core.distributed import ShardedBackend  # noqa: E402
from repro.core.engine import Engine  # noqa: E402
from repro.core.maintenance import MaintainableIndex  # noqa: E402
from repro.core.query import (  # noqa: E402
    TEMPLATE_ARITY,
    TEMPLATES,
    instantiate_template,
)
from repro.core.service import QueryService  # noqa: E402
from repro.data.graphs import gmark_citation  # noqa: E402


def main() -> None:
    n_shards = 8
    mesh = compat.make_mesh((n_shards,), ("engine",))
    g = gmark_citation(400, avg_degree=6, seed=0)
    idx = cindex.build(g, 2)
    print(f"graph {g}; CPQx: {idx.n_classes} classes, {idx.n_pairs} pairs")

    # the one-line scale-out: same Engine API, sharded execution
    local = Engine(idx)
    sharded = Engine(idx, mesh=mesh)
    assert isinstance(sharded.backend, ShardedBackend)
    counts = np.asarray(sharded.backend.sharded.c2p_counts)
    print(f"I_c2p class-sharded over {n_shards} devices: "
          f"{counts.tolist()} rows per shard")

    # full template suite: sharded == local (bit-identical) == oracle
    rng = np.random.default_rng(0)
    present = np.unique(g.lbl)
    for name in sorted(TEMPLATES):
        q = instantiate_template(
            name, rng.choice(present, TEMPLATE_ARITY[name]).tolist())
        a, b = local.execute(q), sharded.execute(q)
        assert a.shape == b.shape and bool(np.all(a == b)), name
        print(f"  {name:>3}: {a.shape[0]:5d} pairs — sharded == local")

    # a conjunction checked against the semantics ground truth
    q = instantiate_template("S", [0, 0, 1, 0])
    got = sorted(tuple(r) for r in sharded.execute(q).tolist())
    gt = sorted(oracle.cpq_eval(g, q))
    print(f"distributed conjunction: {len(got)} pairs; "
          f"matches semantics oracle: {got == gt}")
    assert got == gt

    # the serving + maintenance stack is backend-agnostic: queue queries,
    # apply live updates; the flush reshards the index on rebind
    mi = MaintainableIndex.build(g, 2)
    svc = QueryService(Engine(mi.flush(), mesh=mesh), maintainer=mi)
    before = svc.query(q)
    svc.apply_updates([("insert_edge", 1, 2, 0), ("insert_edge", 2, 3, 1)])
    after = svc.query(q)  # drains the write, flushes, reshards
    assert {tuple(r) for r in after.tolist()} == oracle.cpq_eval(mi.g, q)
    print(f"live updates through the sharded service: {before.shape[0]} -> "
          f"{after.shape[0]} pairs, {svc.stats.update_batches} flush "
          f"(resharded on rebind)")


if __name__ == "__main__":
    main()
