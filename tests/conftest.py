"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the single real CPU device; only
``launch/dryrun.py`` (its own process) requests 512 placeholder devices."""

import jax
import numpy as np
import pytest

from repro.core.graph import LabeledGraph, example_graph


@pytest.fixture(autouse=True, scope="module")
def _clear_jit_cache_between_modules():
    """Distinct query plans each compile an executable; keep the CPU JIT
    arena bounded across the suite."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def ex_graph():
    return example_graph()


def random_graph(seed: int, n_max: int = 24, n_labels: int = 3,
                 m_max: int = 60) -> LabeledGraph:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, n_max))
    m = int(rng.integers(8, m_max))
    edges = [
        (int(rng.integers(0, n)), int(rng.integers(0, n)),
         int(rng.integers(0, n_labels)))
        for _ in range(m)
    ]
    return LabeledGraph.from_edges(n, n_labels, edges)
