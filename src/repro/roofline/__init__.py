"""Roofline analysis: compiled-HLO cost extraction (FLOPs, bytes,
collective bytes) and the three-term roofline model for TPU v5e."""
