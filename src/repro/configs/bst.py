"""bst [arXiv:1905.06874; paper]: embed_dim=32 seq_len=20 n_blocks=1
n_heads=8 mlp=1024-512-256 — Behavior Sequence Transformer (Alibaba)."""

import dataclasses

from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import BSTConfig

CONFIG = BSTConfig(
    name="bst",
    n_items=4_000_000,
    n_cats=100_000,
    n_context=1_000_000,
    embed_dim=32,
    seq_len=20,
    n_heads=8,
    n_blocks=1,
    d_ff=128,
    mlp_dims=(1024, 512, 256),
    n_context_fields=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_items=1000, n_cats=100, n_context=500, embed_dim=8,
    mlp_dims=(32, 16), n_heads=2,
)

SPEC = ArchSpec(
    arch_id="bst", family="recsys", config=CONFIG, smoke=SMOKE,
    shapes=recsys_shapes(),
    notes="EmbeddingBag = take + segment_sum (JAX-native); retrieval cell "
          "scores 1M candidates with one batched dot + top-k.",
)
