"""Regular path queries over the CPQx index — automaton fixpoints of
per-sequence lookups.

CPQ is the paper's language, but the index answers more: a per-sequence
lookup is the relation ⟦l₁…l_j⟧_G for any j <= k, and those relations
compose into automaton products.  A Kleene-star RPQ therefore runs as a
*semi-naive fixpoint* whose per-iteration frontier expansion is a batch
of ordinary CPQx lookups (PathFinder, arxiv 2306.02194, and
"Representing Paths in Graph Database Pattern Matching", arxiv
2207.13541, are the playbook):

1. the RPQ AST (concat / alternation / star / plus / optional /
   inverse over closure labels) is normalized (inverses pushed to the
   leaves — ``(ab)⁻ == b⁻a⁻``) and compiled to a **Glushkov position
   automaton** (ε-free: states are symbol occurrences plus a start
   state with no in-edges);
2. the automaton is expanded into **macro-edges** ``p --seq--> q`` for
   every automaton walk of length 1..k (*k-truncated label runs* — k is
   the index's path bound, so each macro-edge's relation is served by
   ONE per-sequence CPQx lookup, or by the planner's query-time split
   when an interest-aware index lacks the sequence);
3. the fixpoint iterates over triples ``(src, state, cur)`` ⊆
   V × Q × V: each round joins the *delta* triples against the
   macro-edge relations.  Relations are fetched lazily — the first
   round a macro-edge becomes active, its sequence joins that round's
   ``Engine.execute_batch`` (one vmapped dispatch for every new
   sequence, the engine's capacity ladder drives overflow, and with a
   :class:`~repro.core.costmodel.DeviceCostTable` bound the per-lookup
   starting rung is the calibrated expected-cost pick) — and cached for
   the rest of the fixpoint, so iteration cost converges to pure
   host-side numpy joins.

Termination is structural: the triple space is finite (|Q| · |V|²) and
every iteration either adds a new triple or the delta is empty, so the
fixpoint runs at most |Q| · |V|² iterations — asserted per iteration,
and by the tests (the |V|² pair-space argument).

Everything here is host-side (numpy only, no jax import): the device
work happens inside the engine the evaluator is handed.  The numpy
oracle's :func:`repro.core.oracle.rpq_eval` — an independent Thompson
NFA-product evaluator — is the differential gate, exactly like
``cpq_eval`` gates the CPQ path.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from functools import reduce

import numpy as np

from .query import CPQ, Edge, Join

# ---------------------------------------------------------------------- #
# AST
# ---------------------------------------------------------------------- #


class RPQ:
    """Base class of RPQ AST nodes (frozen dataclasses — hashable, so an
    RPQ can key the service's (epoch, query) caches like a CPQ)."""

    def __mul__(self, other: "RPQ") -> "RPQ":  # a * b == concatenation
        return RConcat(self, _as_rpq(other))

    def __or__(self, other: "RPQ") -> "RPQ":  # a | b == alternation
        return RAlt(self, _as_rpq(other))


def _as_rpq(x) -> "RPQ":
    if isinstance(x, RPQ):
        return x
    if isinstance(x, Edge):  # CPQ edges lift to RPQ symbols
        return RSym(x.label)
    raise TypeError(f"not an RPQ node: {x!r}")


@dataclasses.dataclass(frozen=True)
class RSym(RPQ):
    label: int  # closure label id, in [0, 2·n_labels)

    def __repr__(self):
        return f"l{self.label}"


@dataclasses.dataclass(frozen=True)
class RConcat(RPQ):
    lhs: RPQ
    rhs: RPQ

    def __repr__(self):
        return f"({self.lhs!r} . {self.rhs!r})"


@dataclasses.dataclass(frozen=True)
class RAlt(RPQ):
    lhs: RPQ
    rhs: RPQ

    def __repr__(self):
        return f"({self.lhs!r} | {self.rhs!r})"


@dataclasses.dataclass(frozen=True)
class RStar(RPQ):
    inner: RPQ

    def __repr__(self):
        return f"({self.inner!r})*"


@dataclasses.dataclass(frozen=True)
class RPlus(RPQ):
    inner: RPQ

    def __repr__(self):
        return f"({self.inner!r})+"


@dataclasses.dataclass(frozen=True)
class ROpt(RPQ):
    inner: RPQ

    def __repr__(self):
        return f"({self.inner!r})?"


@dataclasses.dataclass(frozen=True)
class RInv(RPQ):
    """Inverse (reversal) of a sub-expression: ``(ab)⁻ == b⁻a⁻``.
    Normalized away before automaton construction."""

    inner: RPQ

    def __repr__(self):
        return f"({self.inner!r})^-"


def normalize(q: RPQ, n_labels: int | None = None) -> RPQ:
    """Push :class:`RInv` down to the leaves and eliminate it — the
    algebra ``(ab)⁻ = b⁻a⁻``, ``(a|b)⁻ = a⁻|b⁻``, ``(a*)⁻ = (a⁻)*``,
    ``(l)⁻ = inverse_label(l)``.  ``n_labels`` is required only when the
    expression actually contains an inverse (the closure-label involution
    needs the alphabet split)."""
    if isinstance(q, RSym):
        return q
    if isinstance(q, (RConcat, RAlt)):
        return type(q)(normalize(q.lhs, n_labels), normalize(q.rhs, n_labels))
    if isinstance(q, (RStar, RPlus, ROpt)):
        return type(q)(normalize(q.inner, n_labels))
    if isinstance(q, RInv):
        return _invert(normalize(q.inner, n_labels), n_labels)
    raise TypeError(f"not an RPQ node: {q!r}")


def _invert(q: RPQ, n_labels: int | None) -> RPQ:
    if isinstance(q, RSym):
        if n_labels is None:
            raise ValueError(
                "normalizing an RPQ inverse needs n_labels (the "
                "closure-label involution l <-> l + n_labels)")
        from .graph import inverse_label

        return RSym(int(inverse_label(q.label, n_labels)))
    if isinstance(q, RConcat):  # (ab)⁻ = b⁻a⁻
        return RConcat(_invert(q.rhs, n_labels), _invert(q.lhs, n_labels))
    if isinstance(q, RAlt):
        return RAlt(_invert(q.lhs, n_labels), _invert(q.rhs, n_labels))
    if isinstance(q, (RStar, RPlus, ROpt)):
        return type(q)(_invert(q.inner, n_labels))
    raise TypeError(f"not a normalized RPQ node: {q!r}")


def rpq_labels(q: RPQ) -> set[int]:
    """Every closure label a (normalized) RPQ mentions."""
    if isinstance(q, RSym):
        return {q.label}
    if isinstance(q, (RConcat, RAlt)):
        return rpq_labels(q.lhs) | rpq_labels(q.rhs)
    if isinstance(q, (RStar, RPlus, ROpt, RInv)):
        return rpq_labels(q.inner)
    raise TypeError(q)


def rpq_label_runs(q: RPQ) -> list[list[int]]:
    """Maximal concatenation label runs of an RPQ — the workload
    harvester's view (a hot star *body* is a hot sequence: the fixpoint
    serves it with per-sequence lookups, so mining it into the interest
    set speeds the RPQ up exactly like it speeds a CPQ chain)."""
    runs: list[list[int]] = []

    def walk(node: RPQ) -> None:
        if isinstance(node, RConcat):
            run: list[int] = []
            for leaf in _flatten_concat(node):
                if isinstance(leaf, RSym):
                    run.append(leaf.label)
                else:
                    if run:
                        runs.append(run)
                        run = []
                    walk(leaf)
            if run:
                runs.append(run)
            return
        if isinstance(node, RSym):
            runs.append([node.label])
            return
        if isinstance(node, (RStar, RPlus, ROpt, RInv)):
            walk(node.inner)
            return
        if isinstance(node, RAlt):
            walk(node.lhs)
            walk(node.rhs)
            return
        raise TypeError(node)

    walk(q)
    return runs


def _flatten_concat(q: RPQ) -> list:
    if isinstance(q, RConcat):
        return _flatten_concat(q.lhs) + _flatten_concat(q.rhs)
    return [q]


# ---------------------------------------------------------------------- #
# Glushkov position automaton (ε-free)
# ---------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Automaton:
    """ε-free NFA: state 0 is the start (no in-edges, the Glushkov
    invariant), states 1..n are symbol positions.  ``transitions`` holds
    (state, closure label, state) triples; ``finals`` the accepting set
    (contains 0 iff ε is accepted)."""

    n_states: int
    transitions: tuple
    finals: frozenset

    @property
    def nullable(self) -> bool:
        return 0 in self.finals

    def adjacency(self) -> dict[int, list[tuple[int, int]]]:
        adj: dict[int, list[tuple[int, int]]] = defaultdict(list)
        for p, lbl, q in self.transitions:
            adj[p].append((lbl, q))
        return dict(adj)


def glushkov(q: RPQ) -> Automaton:
    """Compile a *normalized* RPQ (no :class:`RInv`) to its Glushkov
    automaton via the standard (nullable, first, last, follow) sets."""
    label_of: dict[int, int] = {}
    follow: dict[int, set[int]] = defaultdict(set)
    counter = [0]

    def build(node: RPQ) -> tuple[bool, frozenset, frozenset]:
        if isinstance(node, RSym):
            counter[0] += 1
            pos = counter[0]
            label_of[pos] = node.label
            return False, frozenset({pos}), frozenset({pos})
        if isinstance(node, RConcat):
            n1, f1, l1 = build(node.lhs)
            n2, f2, l2 = build(node.rhs)
            for x in l1:
                follow[x] |= f2
            return (n1 and n2,
                    f1 | f2 if n1 else f1,
                    l2 | l1 if n2 else l2)
        if isinstance(node, RAlt):
            n1, f1, l1 = build(node.lhs)
            n2, f2, l2 = build(node.rhs)
            return n1 or n2, f1 | f2, l1 | l2
        if isinstance(node, (RStar, RPlus)):
            n1, f1, l1 = build(node.inner)
            for x in l1:
                follow[x] |= f1
            return isinstance(node, RStar) or n1, f1, l1
        if isinstance(node, ROpt):
            n1, f1, l1 = build(node.inner)
            return True, f1, l1
        if isinstance(node, RInv):
            raise ValueError("normalize() the RPQ before glushkov()")
        raise TypeError(f"not an RPQ node: {node!r}")

    nullable, first, last = build(q)
    transitions = [(0, label_of[p], p) for p in sorted(first)]
    for p in sorted(follow):
        for s in sorted(follow[p]):
            transitions.append((p, label_of[s], s))
    finals = set(last) | ({0} if nullable else set())
    return Automaton(n_states=counter[0] + 1,
                     transitions=tuple(transitions),
                     finals=frozenset(finals))


def macro_edges(auto: Automaton, k: int) -> dict[int, tuple]:
    """Expand the automaton into k-truncated label runs: for every state
    ``p``, every walk of length 1..k gives a macro-edge ``(seq, q)`` —
    the unit the fixpoint joins against, each served by one CPQx
    per-sequence lookup.  Deduplicated; length-1 walks are always
    included, so truncation never loses paths (a longer walk is the
    composition of its <= k chunks, which the fixpoint replays across
    iterations)."""
    if k < 1:
        raise ValueError("k must be >= 1")
    adj = auto.adjacency()
    out: dict[int, set] = {p: set() for p in range(auto.n_states)}
    for p in range(auto.n_states):
        frontier = [((), p)]
        for _ in range(k):
            nxt = []
            for seq, s in frontier:
                for lbl, t in adj.get(s, ()):
                    walk = seq + (lbl,)
                    out[p].add((walk, t))
                    nxt.append((walk, t))
            frontier = nxt
    return {p: tuple(sorted(es)) for p, es in out.items() if es}


# ---------------------------------------------------------------------- #
# semi-naive fixpoint over Engine.execute_batch
# ---------------------------------------------------------------------- #


def seq_to_cpq(seq: tuple) -> CPQ:
    """A label sequence as the CPQ join chain the engine's planner turns
    into per-sequence LOOKUPs (splitting per the index's available set)."""
    return reduce(Join, [Edge(int(l)) for l in seq])


def _prep_relation(rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Sort a (n, 2) pair relation by source for the searchsorted join."""
    rows = np.asarray(rows, np.int64).reshape(-1, 2)
    order = np.lexsort((rows[:, 1], rows[:, 0]))
    rows = rows[order]
    return np.ascontiguousarray(rows[:, 0]), np.ascontiguousarray(rows[:, 1])


def _join_codes(codes: np.ndarray, rel: tuple[np.ndarray, np.ndarray],
                n_vertices: int) -> np.ndarray:
    """Join frontier triples (encoded ``src * |V| + cur``) with a pair
    relation on ``cur == rel.src``; returns new unique codes
    ``src * |V| + next``."""
    rel_src, rel_dst = rel
    if not codes.size or not rel_src.size:
        return np.empty(0, np.int64)
    src = codes // n_vertices
    mid = codes % n_vertices
    lo = np.searchsorted(rel_src, mid, side="left")
    hi = np.searchsorted(rel_src, mid, side="right")
    cnt = hi - lo
    keep = cnt > 0
    if not keep.any():
        return np.empty(0, np.int64)
    src, lo, cnt = src[keep], lo[keep], cnt[keep]
    total = int(cnt.sum())
    starts = np.cumsum(cnt) - cnt
    idx = np.repeat(lo - starts, cnt) + np.arange(total, dtype=np.int64)
    return np.unique(np.repeat(src, cnt) * n_vertices + rel_dst[idx])


@dataclasses.dataclass
class FixpointInfo:
    """Telemetry of one fixpoint run (``evaluate(..., info=...)``)."""

    iterations: int = 0
    lookups: int = 0  # distinct sequences fetched through the engine
    lookup_batches: int = 0  # execute_batch dispatch rounds
    macro_edges: int = 0
    triples: int = 0  # |V|·|Q|·|V| triples materialized (the bound's LHS)
    states: int = 0


def evaluate(engine, q: RPQ, *, srcs=None, dsts=None,
             n_labels: int | None = None,
             info: FixpointInfo | None = None) -> np.ndarray:
    """Evaluate ⟦q⟧_G through ``engine`` (local or sharded — anything
    with ``index`` and ``execute_batch``); returns sorted (n, 2) int32
    s-t pairs, exactly like ``Engine.execute``.

    ``srcs`` / ``dsts`` restrict the answer to pinned endpoints (the
    Cypher ``WHERE`` lowering): a source pin seeds the fixpoint with
    just those vertices — the frontier never grows triples that cannot
    contribute — while a destination pin filters the assembled answer.

    ``n_labels`` is needed only if ``q`` contains :class:`RInv`.
    """
    q = normalize(q, n_labels)
    auto = glushkov(q)
    k = int(engine.index.k)
    edges = macro_edges(auto, k)
    n_v = int(engine.index.n_vertices)
    if info is not None:
        info.states = auto.n_states
        info.macro_edges = sum(len(es) for es in edges.values())

    if srcs is None:
        seeds = np.arange(n_v, dtype=np.int64)
    else:
        seeds = np.unique(np.asarray(list(srcs), np.int64))
        if seeds.size and (seeds.min() < 0 or seeds.max() >= n_v):
            raise ValueError("source pin out of range")
    init = seeds * n_v + seeds  # (v, start, v) triples

    reached: dict[int, np.ndarray] = {0: init}
    delta: dict[int, np.ndarray] = {0: init}
    seq_rel: dict[tuple, tuple] = {}  # seq -> (src-sorted) relation
    # Termination bound: the triple space (src, state, cur) is finite —
    # |Q| · |V|² — and every iteration with a non-empty delta added at
    # least one new triple the round before, so the loop runs at most
    # bound + 1 times.  Asserted hard: a violation means monotonicity
    # broke, and silently spinning would mask it.
    bound = auto.n_states * n_v * n_v
    iters = 0
    while any(d.size for d in delta.values()):
        iters += 1
        assert iters <= bound + 1, "fixpoint exceeded the |Q|·|V|² bound"
        # fetch the relations of newly-active macro-edges in ONE batch:
        # the engine plans each sequence as a per-sequence lookup chain
        # (query-time split if the interest set lacks it), groups the
        # batch by plan shape into vmapped dispatches, sizes capacities
        # through estimate_caps (DeviceCostTable rung selection when the
        # engine is calibrated) and drives the overflow ladder.
        needed = sorted({seq for p, d in delta.items() if d.size
                         for seq, _ in edges.get(p, ())
                         if seq not in seq_rel})
        if needed:
            rows = engine.execute_batch([seq_to_cpq(s) for s in needed])
            for s, r in zip(needed, rows):
                seq_rel[s] = _prep_relation(r)
            if info is not None:
                info.lookups += len(needed)
                info.lookup_batches += 1
        fresh: dict[int, list] = defaultdict(list)
        for p, d in delta.items():
            if not d.size:
                continue
            for seq, t in edges.get(p, ()):
                joined = _join_codes(d, seq_rel[seq], n_v)
                if joined.size:
                    fresh[t].append(joined)
        delta = {}
        for t, parts in fresh.items():
            cand = parts[0] if len(parts) == 1 else np.unique(
                np.concatenate(parts))
            old = reached.get(t)
            new = cand if old is None else np.setdiff1d(
                cand, old, assume_unique=True)
            if new.size:
                reached[t] = new if old is None else np.union1d(old, new)
                delta[t] = new
    if info is not None:
        info.iterations = iters
        info.triples = sum(int(r.size) for r in reached.values())

    answers = [reached[f] for f in auto.finals if f in reached]
    # state 0 is in `reached` exactly when it is final-and-seeded (ε):
    # Glushkov start states have no in-edges, so reached[0] == init
    codes = (np.unique(np.concatenate(answers)) if answers
             else np.empty(0, np.int64))
    pairs = np.stack([codes // n_v, codes % n_v], axis=1).astype(np.int32)
    if dsts is not None:
        pins = np.unique(np.asarray(list(dsts), np.int64))
        pairs = pairs[np.isin(pairs[:, 1], pins)]
    return pairs
