"""openCypher-subset surface — parser goldens, lowering, round-trip.

Corpus-style shapes (the path-query core of SNIPPETS.md Snippet 1's
openCypher corpus) must parse and lower correctly; everything outside
the subset — the clauses the corpus actually uses: ``WITH``, ``ORDER
BY``, ``LIMIT``, node labels, property maps, aggregates — must raise
:class:`UnsupportedCypher` *naming the construct*.  Pure-CPQ shapes must
produce byte-identical plans to the existing ``parse()``/``plan_query``
path (the language-aware lowering contract), and
``parse_cypher(render_cypher(q)) == q`` is the round-trip property."""

import numpy as np
import pytest

from repro.core import index as cindex, oracle
from repro.core.cypher import (
    CypherQuery,
    Rel,
    UnsupportedCypher,
    lower_cypher,
    parse_cypher,
    render_cypher,
)
from repro.core.engine import Engine
from repro.core.graph import inverse_label
from repro.core.query import Conj, Edge, Identity, Join, parse, plan_query
from repro.core.rpq import (
    RAlt,
    RConcat,
    ROpt,
    RPlus,
    RPQ,
    RStar,
    RSym,
)

from conftest import random_graph

LABELS = {"f": 0, "v": 1}  # example_graph's follows / visits


def _pairs(rows) -> set:
    return {tuple(r) for r in np.asarray(rows).reshape(-1, 2).tolist()}


# ---------------------------------------------------------------------- #
# parser goldens
# ---------------------------------------------------------------------- #


class TestParserGoldens:
    def test_fixed_chain(self):
        q = parse_cypher("MATCH (a)-[:f]->(b)-[:v]->(c) RETURN a, c")
        assert q == CypherQuery(
            nodes=("a", "b", "c"),
            rels=(Rel(("f",)), Rel(("v",))),
            returns=("a", "c"))

    def test_variable_length_forms(self):
        cases = {
            "*": (1, None),
            "*2": (2, 2),
            "*1..3": (1, 3),
            "*2..": (2, None),
            "*..3": (1, 3),
            "*0..": (0, None),
        }
        for star, (lo, hi) in cases.items():
            q = parse_cypher(f"MATCH (a)-[:f{star}]->(b) RETURN a, b")
            assert (q.rels[0].lo, q.rels[0].hi) == (lo, hi), star

    def test_inverse_direction(self):
        q = parse_cypher("MATCH (a)<-[:f]-(b) RETURN a, b")
        assert q.rels[0].back

    def test_multi_type_and_legacy_pipe(self):
        for text in ("MATCH (a)-[:f|v]->(b) RETURN a, b",
                     "MATCH (a)-[:f|:v]->(b) RETURN a, b"):
            assert parse_cypher(text).rels[0].types == ("f", "v")

    def test_where_pins_and_id_synonym(self):
        q = parse_cypher(
            "MATCH (a)-[:f]->(b) WHERE a = 3 AND id(b) = 7 RETURN a, b")
        assert q.pins == (("a", 3), ("b", 7))

    def test_return_star_and_anonymous_nodes(self):
        q = parse_cypher("MATCH (a)-[:f]->()-[:v]->(c) RETURN *")
        assert q.nodes == ("a", "", "c") and q.returns == ()

    def test_relationship_variable_ignored(self):
        q = parse_cypher("MATCH (a)-[r:f]->(b) RETURN a, b")
        assert q.rels == (Rel(("f",)),)

    def test_trailing_semicolon(self):
        parse_cypher("MATCH (a)-[:f]->(b) RETURN a, b;")

    def test_syntax_errors_carry_position(self):
        for text in ("FETCH (a)-[:f]->(b) RETURN a, b",
                     "MATCH (a)-[:f]->(b RETURN a, b",
                     "MATCH (a)-[:f*3..1]->(b) RETURN a, b",
                     "MATCH (a)<-[:f]->(b) RETURN a, b"):
            with pytest.raises(SyntaxError, match="position"):
                parse_cypher(text)


class TestUnsupportedNamesTheConstruct:
    """Real corpus clauses must be rejected with the clause named —
    a caller porting a workload learns exactly what to rewrite."""

    CASES = [
        ("MATCH (a)-[:f]->(b) RETURN a, b LIMIT 10", "LIMIT"),
        ("MATCH (a)-[:f]->(b) RETURN a, b ORDER BY a", "ORDER BY"),
        ("MATCH (a)-[:f]->(b) WITH a MATCH (a)-[:v]->(c) RETURN a, c",
         "WITH"),
        ("OPTIONAL MATCH (a)-[:f]->(b) RETURN a, b", "OPTIONAL MATCH"),
        ("MATCH (a)-[:f]->(b) RETURN count(a)", "count"),
        ("MATCH (c:Concept)-[:f]->(b) RETURN c, b", "node label"),
        ("MATCH (a {name: 'x'})-[:f]->(b) RETURN a, b", "property map"),
        ("MATCH (a)-[]->(b) RETURN a, b", "untyped relationship"),
        ("MATCH (a)-[:f]-(b) RETURN a, b", "undirected relationship"),
        ("MATCH (a) RETURN a", "single-node MATCH"),
        ("MATCH (a)-[:f]->(b) WHERE a.name = 3 RETURN a, b",
         "property predicate"),
        ("MATCH (a)-[:f]->(b)-[:v]->(c) WHERE b = 2 RETURN a, c",
         "interior node"),
        ("MATCH (a)-[:f]->(b) RETURN a.name, b", "property projection"),
        ("MATCH (a)-[:f]->(b) RETURN a AS x, b", "AS alias"),
        ("MATCH (a)-[:f]->(b)-[:v]->(c) RETURN a, b", "RETURN"),
        ("MATCH (a)-[:f]->(b) RETURN DISTINCT a, b", "DISTINCT"),
        ("MATCH (a)-[:f]->(b) DELETE a", "DELETE"),
    ]

    def test_each_construct_is_named(self):
        for text, construct in self.CASES:
            with pytest.raises(UnsupportedCypher) as e:
                parse_cypher(text)
            assert construct.lower() in str(e.value).lower(), text


# ---------------------------------------------------------------------- #
# lowering
# ---------------------------------------------------------------------- #


class TestLowering:
    def test_pure_cpq_is_byte_identical_to_parse(self, ex_graph):
        """The language-aware contract: a star-free single-type chain
        lowers to the *same AST* as ``parse()``, hence the same frozen
        plan — the optimizer/plan-cache path is untouched."""
        n = ex_graph.n_labels
        cases = [
            ("MATCH (a)-[:f]->(b)-[:v]->(c) RETURN a, c", "f.v"),
            ("MATCH (a)<-[:f]-(b)-[:f]->(c) RETURN a, c", "f-.f"),
            ("MATCH (a)-[:f]->(b) RETURN a, b", "f"),
        ]
        for text, cpq_text in cases:
            low = lower_cypher(parse_cypher(text), LABELS, n)
            want = parse(cpq_text, LABELS, n)
            assert low.is_cpq and low.ast == want, text
            assert plan_query(low.ast, 2) == plan_query(want, 2), text

    def test_closed_chain_lowers_to_identity_conj(self, ex_graph):
        low = lower_cypher(
            parse_cypher("MATCH (a)-[:f]->(b)-[:v]->(a) RETURN a"),
            LABELS, ex_graph.n_labels)
        assert low.ast == Conj(Join(Edge(0), Edge(1)), Identity())

    def test_star_lowers_to_rpq(self, ex_graph):
        low = lower_cypher(
            parse_cypher("MATCH (a)-[:f*]->(b) RETURN a, b"),
            LABELS, ex_graph.n_labels)
        assert isinstance(low.ast, RPQ)
        assert low.ast == RPlus(RSym(0))
        low = lower_cypher(
            parse_cypher("MATCH (a)-[:f*0..]->(b) RETURN a, b"),
            LABELS, ex_graph.n_labels)
        assert low.ast == RStar(RSym(0))

    def test_bounded_repeat_expansion(self, ex_graph):
        low = lower_cypher(
            parse_cypher("MATCH (a)-[:f*1..3]->(b) RETURN a, b"),
            LABELS, ex_graph.n_labels)
        e = RSym(0)
        assert low.ast == RConcat(RConcat(e, ROpt(e)), ROpt(e))

    def test_inverse_direction_uses_closure_label(self, ex_graph):
        n = ex_graph.n_labels
        low = lower_cypher(
            parse_cypher("MATCH (a)<-[:f*]-(b) RETURN a, b"),
            LABELS, n)
        assert low.ast == RPlus(RSym(int(inverse_label(0, n))))

    def test_multi_type_lowers_to_alternation(self, ex_graph):
        low = lower_cypher(
            parse_cypher("MATCH (a)-[:f|v*]->(b) RETURN a, b"),
            LABELS, ex_graph.n_labels)
        assert low.ast == RPlus(RAlt(RSym(0), RSym(1)))

    def test_pins_surface_on_lowered_query(self, ex_graph):
        low = lower_cypher(
            parse_cypher(
                "MATCH (a)-[:f*]->(b) WHERE a = 2 AND b = 5 RETURN a, b"),
            LABELS, ex_graph.n_labels)
        assert (low.src, low.dst) == (2, 5)

    def test_lowering_rejections(self, ex_graph):
        n = ex_graph.n_labels
        for text, construct in [
            ("MATCH (a)-[:f*]->(b)-[:v]->(a) RETURN a",
             "cyclic variable-length"),
            ("MATCH (a)-[:f]->(b)-[:v]->(b)-[:f]->(c) RETURN a, c",
             "repeated interior"),
            ("MATCH (a)-[:f*0..0]->(b) RETURN a, b", "zero-length"),
            ("MATCH (a)-[:nope]->(b) RETURN a, b", "unknown relationship"),
        ]:
            with pytest.raises(UnsupportedCypher) as e:
                lower_cypher(parse_cypher(text), LABELS, n)
            assert construct in str(e.value), text

    def test_positional_label_names(self, ex_graph):
        low = lower_cypher(
            parse_cypher("MATCH (a)-[:l0]->(b)-[:l1]->(c) RETURN a, c"),
            None, ex_graph.n_labels)
        assert low.ast == Join(Edge(0), Edge(1))


# ---------------------------------------------------------------------- #
# end-to-end: cypher -> lowering -> engine == oracle
# ---------------------------------------------------------------------- #


class TestEndToEnd:
    QUERIES = [
        "MATCH (a)-[:f]->(b)-[:v]->(c) RETURN a, c",
        "MATCH (a)-[:f*]->(b) RETURN a, b",
        "MATCH (a)-[:f*0..]->(b) RETURN a, b",
        "MATCH (a)<-[:f*1..2]-(b) RETURN a, b",
        "MATCH (a)-[:f|v*]->(b) RETURN a, b",
        "MATCH (a)-[:f*2..3]->(b)-[:v]->(c) RETURN a, c",
        "MATCH (a)-[:f]->(b)-[:v]->(a) RETURN a",
    ]

    def test_every_shape_matches_oracle(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        n = ex_graph.n_labels
        for text in self.QUERIES:
            low = lower_cypher(parse_cypher(text), LABELS, n)
            if low.is_cpq:
                got = _pairs(eng.execute(low.ast))
                want = oracle.cpq_eval(ex_graph, low.ast)
            else:
                got = _pairs(eng.execute_rpq(low.ast))
                want = oracle.rpq_eval(ex_graph, low.ast)
            assert got == want, text

    def test_pins_filter_endpoints(self, ex_graph):
        eng = Engine(cindex.build(ex_graph, 2))
        low = lower_cypher(
            parse_cypher(
                "MATCH (a)-[:f*]->(b) WHERE a = 3 RETURN a, b"),
            LABELS, ex_graph.n_labels)
        got = _pairs(eng.execute_rpq(low.ast, srcs=[low.src]))
        want = {(v, u) for v, u in oracle.rpq_eval(ex_graph, low.ast)
                if v == 3}
        assert got == want


# ---------------------------------------------------------------------- #
# round-trip property
# ---------------------------------------------------------------------- #


def _random_cypher(rng: np.random.Generator) -> CypherQuery:
    n_hops = int(rng.integers(1, 4))
    nodes = ["a"] + [f"n{i}" for i in range(1, n_hops)] + ["z"]
    rels = []
    for _ in range(n_hops):
        n_types = int(rng.integers(1, 3))
        types = tuple(rng.choice(["f", "v", "KNOWS"], n_types,
                                 replace=False).tolist())
        lo = int(rng.integers(0, 3))
        hi = None if rng.random() < 0.4 else lo + int(rng.integers(0, 3))
        if (lo, hi) == (0, 0):
            lo, hi = 1, 1
        if lo == 0 and hi is not None and hi == 0:
            hi = 1
        rels.append(Rel(types=types, back=bool(rng.random() < 0.3),
                        lo=lo, hi=hi))
    pins = []
    if rng.random() < 0.5:
        pins.append(("a", int(rng.integers(0, 9))))
    if rng.random() < 0.3:
        pins.append(("z", int(rng.integers(0, 9))))
    returns = () if rng.random() < 0.3 else ("a", "z")
    return CypherQuery(nodes=tuple(nodes), rels=tuple(rels),
                       pins=tuple(pins), returns=returns)


class TestRoundTrip:
    def test_goldens(self):
        for text in TestEndToEnd.QUERIES:
            q = parse_cypher(text)
            assert parse_cypher(render_cypher(q)) == q, text

    def test_random_deterministic(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            q = _random_cypher(rng)
            assert parse_cypher(render_cypher(q)) == q, render_cypher(q)

    def test_hypothesis_round_trip(self):
        hypothesis = pytest.importorskip("hypothesis")
        given, settings, st = (hypothesis.given, hypothesis.settings,
                               hypothesis.strategies)

        @settings(max_examples=50, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1))
        def prop(seed):
            q = _random_cypher(np.random.default_rng(seed))
            assert parse_cypher(render_cypher(q)) == q

        prop()
